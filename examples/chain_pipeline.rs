//! Chain functions (§2's motivating scenario): a five-stage data pipeline —
//! Ingestion → Cleaning → Transformation → Analysis → Output — where each
//! stage needs a different CPU allocation. Vertical scaling lets each stage
//! get its own allocation; in-place scaling applies it without restarts and
//! releases it between items.
//!
//! ```sh
//! cargo run --release --example chain_pipeline
//! ```

use kinetic::coordinator::platform::{Eng, Platform, Simulation};
use kinetic::coordinator::service::Service;
use kinetic::coordinator::Event;
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::quantity::MilliCpu;
use kinetic::util::table::{fmt_ms, Table};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

/// (stage, serving CPU, runtime at 1 CPU ms, cpu-bound fraction)
const STAGES: [(&str, u64, f64, f64); 5] = [
    ("ingestion", 250, 180.0, 0.45),
    ("cleaning", 500, 420.0, 0.80),
    ("transformation", 1000, 900.0, 0.95),
    ("analysis", 2000, 1600.0, 0.98),
    ("output", 250, 120.0, 0.40),
];

fn stage_profile(name: &str, runtime_ms: f64, cpu_frac: f64) -> WorkloadProfile {
    let mut p = WorkloadProfile::paper(WorkloadKind::Cpu);
    p.name = name.to_string();
    p.runtime_1cpu_ms = runtime_ms;
    p.cpu_frac = cpu_frac;
    p.image = format!("kinetic/{name}:v1");
    p
}

/// Submits one item through the chain: stage i's completion submits stage i+1.
fn submit_chain(w: &mut Platform, eng: &mut Eng, stage: usize) {
    if stage >= STAGES.len() {
        return;
    }
    let name = STAGES[stage].0;
    w.submit_with_hook(eng, name, move |w, eng| {
        submit_chain(w, eng, stage + 1);
    });
}

fn run(policy: Policy, items: u32, gap: SimTime) -> (f64, f64) {
    let mut sim = Simulation::paper(21);
    for (name, serving_m, runtime, frac) in STAGES {
        let mut cfg = policy.revision_config();
        // Per-stage vertical sizing — the point of §2's motivation.
        cfg.serving_cpu = MilliCpu(serving_m);
        let svc = Service::with_config(name, stage_profile(name, runtime, frac), policy, cfg);
        sim.deploy_service(svc);
    }
    sim.run(); // pods up (and parked, for in-place)

    let start = sim.now();
    for i in 0..items {
        let at = start + SimTime::from_nanos(gap.as_nanos() * i as u64);
        sim.engine.schedule_at(
            at,
            Event::call(move |w: &mut Platform, eng| {
                submit_chain(w, eng, 0);
            }),
        );
    }
    sim.run();

    let now = sim.now();
    let mut total_mean = 0.0;
    for (name, ..) in STAGES {
        total_mean += sim.world.metrics.service(name).latency_ms.mean();
    }
    let committed = sim.world.metrics.committed_cpu.average_mcpu(now);
    (total_mean, committed)
}

fn main() {
    println!("five-stage chain pipeline, per-stage vertical sizing\n");
    let items = 12;
    let gap = SimTime::from_secs(10); // > stable window: worst case for cold
    let mut t = Table::new(vec![
        "Policy",
        "Chain latency (ms)",
        "Avg committed (mCPU)",
    ])
    .title(format!("{items} items, one every {gap}"));
    for policy in [Policy::Cold, Policy::InPlace, Policy::Warm] {
        let (lat, committed) = run(policy, items, gap);
        t.row(vec![
            policy.name().to_string(),
            fmt_ms(lat),
            format!("{committed:.0}"),
        ]);
    }
    println!("{}", t.to_ascii());
    println!("warm must reserve sum(stage allocations) = 4000 mCPU continuously;");
    println!("in-place parks all five stages at 1 m and pays only the resize on each item.");
}
