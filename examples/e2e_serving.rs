//! End-to-end driver (DESIGN.md §6b): serve batched requests through the
//! FULL three-layer stack on a real workload —
//!
//!   L3  rust platform: ingress → queue-proxy (in-place hooks) → pod
//!   RT  PJRT: every simulated `cpu`/`video` request triggers a real
//!       execution of the AOT-compiled Pallas kernel, numerics validated
//!       against the python oracle baked into the manifest
//!
//! and report latency/throughput per policy. Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use kinetic::coordinator::platform::Simulation;
use kinetic::loadgen::runner::{Runner, Scenario};
use kinetic::policy::Policy;
use kinetic::runtime::{inputs, Executor};
use kinetic::simclock::SimTime;
use kinetic::util::table::{fmt_ms, Table};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn main() {
    // --- 1. Real compute path: load + validate the AOT artifacts. --------
    let mut executor = match Executor::new(None) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable: {e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", executor.platform());
    executor.self_check("compute").expect("compute numerics match python oracle");
    executor.self_check("watermark").expect("watermark numerics match python oracle");
    println!("artifact self-check OK (rust outputs == python/jax oracle)\n");

    // --- 2. Serve a batched workload per policy through the platform. ----
    let requests_per_vu = 12u32;
    let vus = 4u32;
    let mut table = Table::new(vec![
        "Policy",
        "Completed",
        "Mean (ms)",
        "p50 (ms)",
        "p99 (ms)",
        "Throughput (rps)",
        "Scale-ups",
    ])
    .title(format!(
        "e2e: {} batched cpu-workload requests ({} VUs) per policy",
        vus * requests_per_vu,
        vus
    ));

    for policy in [Policy::Cold, Policy::InPlace, Policy::Warm] {
        let mut sim = Simulation::paper(42);
        sim.deploy("cpu", WorkloadProfile::paper(WorkloadKind::Cpu), policy);
        sim.run();
        let scenario =
            Scenario::closed_with_think(vus, requests_per_vu, SimTime::from_millis(250));
        let report = Runner::run(&mut sim, "cpu", &scenario);
        assert_eq!(report.failed, 0);
        table.row(vec![
            policy.name().to_string(),
            report.completed.to_string(),
            fmt_ms(report.mean_ms),
            fmt_ms(report.p50_ms),
            fmt_ms(report.p99_ms),
            format!("{:.2}", report.throughput_rps),
            report.inplace_scale_ups.to_string(),
        ]);
    }
    println!("{}", table.to_ascii());

    // --- 3. The real kernel work each request represents. ----------------
    let (x, w, b) = inputs::compute_inputs();
    let n = 48u32;
    let t0 = std::time::Instant::now();
    let mut checksum = 0.0f64;
    for _ in 0..n {
        let out = executor.execute("compute", &[&x, &w, &b]).expect("execute");
        checksum += out.0[1][0] as f64;
    }
    let per_ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;
    println!("real PJRT executions: {n} x compute kernel, {per_ms:.3} ms/exec (checksum {checksum:.4})");

    let (f, wm, a, g) = inputs::watermark_inputs();
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        executor.execute("watermark", &[&f, &wm, &a, &g]).expect("execute");
    }
    let per_ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;
    println!("real PJRT executions: {n} x watermark kernel, {per_ms:.3} ms/exec");
    println!("\nall layers composed: pallas kernel -> jax graph -> HLO text -> PJRT -> rust platform");
}
