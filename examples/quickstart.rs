//! Quickstart: deploy one function under each of the paper's three policies
//! and compare a single request's end-to-end latency.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use kinetic::coordinator::platform::Simulation;
use kinetic::policy::Policy;
use kinetic::util::table::{fmt_ms, fmt_ratio, Table};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn measure(policy: Policy) -> f64 {
    // A fresh paper testbed: one 8-core node, knative-style serving,
    // InPlacePodVerticalScaling enabled.
    let mut sim = Simulation::paper(42);
    sim.deploy(
        "hello",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        policy,
    );
    sim.run(); // let min-scale pods start and park

    sim.submit("hello");
    sim.run();
    sim.world.metrics.service("hello").latency_ms.mean()
}

fn main() {
    println!("kinetic quickstart: one helloworld request per policy\n");
    let default_ms = 5.31; // Table 2 baseline
    let mut t = Table::new(vec!["Policy", "Latency (ms)", "vs Default", "Paper"]).title(
        "helloworld, single request (paper Table 3: Cold 286.99, In-place 15.81, Warm 3.87)",
    );
    let mut by_policy = Vec::new();
    for policy in [Policy::Cold, Policy::InPlace, Policy::Warm] {
        let ms = measure(policy);
        by_policy.push((policy, ms));
        let paper = match policy {
            Policy::Cold => "286.99",
            Policy::InPlace => "15.81",
            Policy::Warm => "3.87",
        };
        t.row(vec![
            policy.name().to_string(),
            fmt_ms(ms),
            fmt_ratio(ms / default_ms),
            paper.to_string(),
        ]);
    }
    println!("{}", t.to_ascii());

    let cold = by_policy[0].1;
    let inplace = by_policy[1].1;
    println!(
        "in-place beats cold by {}x on this request (paper headline: up to 18.15x)",
        fmt_ratio(cold / inplace)
    );
}
