//! Trace replay: a synthetic Azure-Functions-style trace (Zipf popularity,
//! diurnal rate, bursts — per Shahrad et al., which the paper cites) played
//! against all three policies. Shows the paper's §3 trade-off: warm buys
//! latency with standing reservations; in-place gets close to warm latency
//! at a fraction of the committed CPU.
//!
//! ```sh
//! cargo run --release --example trace_replay
//! ```

use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::trace::generator::{TraceConfig, TraceGenerator};
use kinetic::trace::replay::replay;
use kinetic::util::table::{fmt_ms, Table};

fn main() {
    let cfg = TraceConfig {
        functions: 10,
        peak_rate: 5.0,
        trough_ratio: 0.1,
        period: SimTime::from_secs(600),
        horizon: SimTime::from_secs(1800),
        burst_p: 0.3,
        seed: 7,
        ..TraceConfig::default()
    };
    let gen = TraceGenerator::new(cfg);
    let trace = gen.generate();
    println!(
        "generated {} invocations over 30 virtual minutes across 10 functions\n",
        trace.len()
    );

    let mut t = Table::new(vec![
        "Policy",
        "Mean (ms)",
        "p50 (ms)",
        "p99 (ms)",
        "Failed",
        "Cold starts",
        "Avg committed (mCPU)",
        "Pods created",
    ])
    .title("Policy comparison on the trace (single 8-core node)");
    for policy in Policy::ALL {
        let r = replay(&trace, 10, policy, 7);
        t.row(vec![
            policy.name().to_string(),
            fmt_ms(r.mean_ms),
            fmt_ms(r.p50_ms),
            fmt_ms(r.p99_ms),
            r.failed.to_string(),
            r.cold_starts.to_string(),
            format!("{:.0}", r.avg_committed_mcpu),
            r.pods_created.to_string(),
        ]);
    }
    println!("{}", t.to_ascii());
    println!("expected shape: warm owns the whole node in standing reservations (8 functions x");
    println!("1 CPU = the node) and cannot scale out; in-place parks at ~1 m per function, so");
    println!("horizontal scale-out still fits — near-warm latency at a fraction of the");
    println!("committed CPU. Cold pays the pipeline on every burst edge.");
}
