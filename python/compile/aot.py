"""AOT export: lower each Layer-2 graph to HLO *text* + a JSON manifest.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that the
rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, per artifact: the file, input shapes, output arity and
*expected outputs* for the deterministic example inputs, so the rust runtime
can self-check numerics end-to-end without Python in the loop.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(spec):
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def export_compute(out_dir: str) -> dict:
    specs = model.compute_example_specs()
    lowered = jax.jit(model.compute_fn).lower(*specs)
    path = os.path.join(out_dir, "compute.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))

    # Expected outputs on the deterministic example inputs, via the oracle.
    x, w, b = model.example_compute_inputs()
    y = np.asarray(ref.compute_ref(x, w, b, iters=16))
    mean = np.asarray(y.mean(axis=1))
    return {
        "file": "compute.hlo.txt",
        "inputs": [_spec_json(s) for s in specs],
        "outputs": 2,
        "check": {
            "out0_sum": float(y.sum()),
            "out0_first8": [float(v) for v in y.ravel()[:8]],
            "out1_first4": [float(v) for v in mean[:4]],
            "tolerance": 2e-4,
        },
    }


def export_watermark(out_dir: str) -> dict:
    specs = model.watermark_example_specs()
    lowered = jax.jit(model.watermark_fn).lower(*specs)
    path = os.path.join(out_dir, "watermark.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))

    frames, wm, alpha, gain = model.example_watermark_inputs()
    out = np.asarray(ref.watermark_ref(frames, wm, alpha, gain))
    lum = out.mean(axis=(1, 2))
    return {
        "file": "watermark.hlo.txt",
        "inputs": [_spec_json(s) for s in specs],
        "outputs": 2,
        "check": {
            "out0_sum": float(out.sum()),
            "out0_first8": [float(v) for v in out.ravel()[:8]],
            "out1_first4": [float(v) for v in lum[:4]],
            "tolerance": 2e-3,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    # Kept for Makefile compatibility: --out <file> writes the compute HLO
    # at that exact path in addition to the manifest-driven layout.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "models": {
            "compute": export_compute(out_dir),
            "watermark": export_watermark(out_dir),
        },
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)

    if args.out:
        # Legacy single-file target (Makefile stamp).
        with open(args.out, "w") as f:
            with open(os.path.join(out_dir, "compute.hlo.txt")) as src:
                f.write(src.read())

    sizes = {
        name: os.path.getsize(os.path.join(out_dir, m["file"]))
        for name, m in manifest["models"].items()
    }
    print(f"wrote {mpath}: {sizes}")


if __name__ == "__main__":
    main()
