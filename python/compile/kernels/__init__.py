"""Layer-1 Pallas kernels.

Two kernels back the paper's compute-bearing workloads:

* ``compute`` -- the ``cpu`` workload's "complicate math problem" as an
  MXU-shaped iterated matmul + nonlinearity chain.
* ``watermark`` -- the SeBS video workloads' frame-watermark blend, tiled
  for VMEM via ``BlockSpec``.

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path; TPU
performance is estimated structurally (DESIGN.md section 7).
"""

from .compute import compute_kernel_call, COMPUTE_ITERS
from .watermark import watermark_call, TILE_H, TILE_W
from . import ref

__all__ = [
    "compute_kernel_call",
    "COMPUTE_ITERS",
    "watermark_call",
    "TILE_H",
    "TILE_W",
    "ref",
]
