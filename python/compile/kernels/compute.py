"""The ``cpu`` workload's "complicate math problem" as a Pallas kernel.

The paper's cpu function burns ~2.47 s of pure CPU at 1000 m. We express its
inner loop as the TPU-idiomatic equivalent: an iterated affine map with a
transcendental nonlinearity,

    x_{k+1} = tanh(x_k @ W + b) + 0.1 * x_k      (k = 0..ITERS-1)

over MXU-native (128, 128) tiles. On a real TPU the matmul hits the 128x128
systolic array each iteration; ``interpret=True`` executes the same HLO on
CPU for correctness (DESIGN.md section Hardware-Adaptation).

The whole iteration runs inside one kernel invocation with the operands
pinned in VMEM: one (B,D) activation + one (D,D) weight + bias, i.e.
3 * 128*128*4 B < 200 KiB -- far under the ~16 MiB VMEM budget, leaving
room for double-buffering when batch-tiled by the grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Iterations of the map inside one kernel call. Chosen so one call is a few
# MXU-milliseconds on TPU; the rust workload model calibrates wall time.
COMPUTE_ITERS = 16

# MXU-native tile sizes.
BATCH = 128
DIM = 128


def _compute_kernel(x_ref, w_ref, b_ref, o_ref, *, iters: int):
    """Iterated affine + tanh map, fully in VMEM."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]

    def body(_, x):
        # MXU matmul in f32 (bf16 on real TPU via preferred_element_type).
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        return jnp.tanh(y + b) + 0.1 * x

    x = jax.lax.fori_loop(0, iters, body, x)
    o_ref[...] = x


def compute_kernel_call(x, w, b, iters: int = COMPUTE_ITERS):
    """Runs the compute kernel: x:(B,D), w:(D,D), b:(D,) -> (B,D).

    The grid tiles the batch dimension in BATCH-row blocks; weights and bias
    are broadcast to every grid step (constant index_map), so each step is
    one VMEM-resident (BATCH,D)x(D,D) matmul chain.
    """
    batch, dim = x.shape
    assert dim == DIM, f"dim must be {DIM}, got {dim}"
    assert batch % BATCH == 0, f"batch must be a multiple of {BATCH}"
    assert w.shape == (dim, dim) and b.shape == (dim,)

    grid = (batch // BATCH,)
    return pl.pallas_call(
        functools.partial(_compute_kernel, iters=iters),
        out_shape=jax.ShapeDtypeStruct((batch, dim), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BATCH, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim, dim), lambda i: (0, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BATCH, dim), lambda i: (i, 0)),
        interpret=True,
    )(x, w, b)
