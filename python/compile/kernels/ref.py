"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""

import jax
import jax.numpy as jnp


def compute_ref(x, w, b, iters):
    """Reference for kernels.compute: iterated tanh-affine map."""

    def body(_, x):
        return jnp.tanh(jnp.dot(x, w) + b) + 0.1 * x

    return jax.lax.fori_loop(0, iters, body, x)


def watermark_ref(frames, wm, alpha, gain):
    """Reference for kernels.watermark: alpha blend + clip + gain."""
    a = alpha[0]
    g = gain[0]
    blended = (1.0 - a) * frames + a * wm[None, :, :]
    return jnp.clip(blended, 0.0, 1.0) * g
