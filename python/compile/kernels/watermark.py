"""The video workloads' watermark blend as a Pallas kernel.

SeBS's video workload runs ``ffmpeg -i in.mp4 -i wm.png -filter_complex
overlay`` -- per pixel, an alpha blend of a watermark onto each frame. The
TPU mapping (DESIGN.md section Hardware-Adaptation): instead of a CUDA-style
one-thread-per-pixel overlay, tile each frame into VPU-aligned
(TILE_H, TILE_W) VMEM blocks via ``BlockSpec`` and blend vector-wise, with a
per-frame brightness correction (the kind of light post-pass ffmpeg filter
graphs chain) fused into the same kernel:

    out = clip((1 - a) * frame + a * wm, 0, 1) * gain

The grid walks (frame, h-tile, w-tile); the watermark block is re-used for
every frame (constant leading index), so HBM traffic is one frame read +
one frame write per frame plus a single watermark fetch -- the schedule the
paper's GPU analog would express with threadblocks + shared memory.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# float32 VPU-aligned tiles: 8 sublanes x 128 lanes.
TILE_H = 8
TILE_W = 128


def _watermark_kernel(frame_ref, wm_ref, alpha_ref, gain_ref, o_ref):
    f = frame_ref[...]
    wm = wm_ref[...]
    a = alpha_ref[0]
    g = gain_ref[0]
    blended = (1.0 - a) * f + a * wm
    o_ref[...] = jnp.clip(blended, 0.0, 1.0) * g


def watermark_call(frames, wm, alpha, gain):
    """Blends ``wm`` onto every frame.

    frames: (N, H, W) float32 in [0,1]; wm: (H, W); alpha, gain: scalars
    packed as shape-(1,) arrays (scalars prefetch poorly through BlockSpec
    on some jax versions; a 1-element block is portable).
    """
    n, h, w = frames.shape
    assert wm.shape == (h, w)
    assert h % TILE_H == 0 and w % TILE_W == 0, (h, w)

    grid = (n, h // TILE_H, w // TILE_W)
    return pl.pallas_call(
        _watermark_kernel,
        out_shape=jax.ShapeDtypeStruct((n, h, w), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_H, TILE_W), lambda f, i, j: (f, i, j)),
            pl.BlockSpec((TILE_H, TILE_W), lambda f, i, j: (i, j)),
            pl.BlockSpec((1,), lambda f, i, j: (0,)),
            pl.BlockSpec((1,), lambda f, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, TILE_H, TILE_W), lambda f, i, j: (f, i, j)),
        interpret=True,
    )(frames, wm, alpha, gain)
