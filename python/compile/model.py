"""Layer-2 JAX compute graphs, one per compute-bearing workload.

Each graph is jitted, calls the Layer-1 Pallas kernel, and is what
``aot.py`` lowers to HLO text for the rust runtime. Shapes are fixed at
export (one compiled executable per model variant, per the AOT design).
"""

import jax
import jax.numpy as jnp

from .kernels.compute import compute_kernel_call, COMPUTE_ITERS, BATCH, DIM
from .kernels.watermark import watermark_call

# Export shapes. One "video segment" = 4 frames of 64x256 grayscale; the
# rust workload model invokes the executable per segment as the inner loop
# of the video functions.
FRAMES = 4
FRAME_H = 64
FRAME_W = 256


def compute_fn(x, w, b):
    """The ``cpu`` workload step: kernel + a cheap output reduction the
    function returns to its caller (keeps XLA from DCE'ing anything)."""
    y = compute_kernel_call(x, w, b, iters=COMPUTE_ITERS)
    return (y, jnp.mean(y, axis=1))


def watermark_fn(frames, wm, alpha, gain):
    """The ``video`` workload step: blend + per-frame mean luminance (the
    sort of stats ffmpeg filter chains report)."""
    out = watermark_call(frames, wm, alpha, gain)
    return (out, jnp.mean(out, axis=(1, 2)))


def compute_example_specs():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((BATCH, DIM), f32),
        jax.ShapeDtypeStruct((DIM, DIM), f32),
        jax.ShapeDtypeStruct((DIM,), f32),
    )


def watermark_example_specs():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((FRAMES, FRAME_H, FRAME_W), f32),
        jax.ShapeDtypeStruct((FRAME_H, FRAME_W), f32),
        jax.ShapeDtypeStruct((1,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )


# --- deterministic example inputs --------------------------------------------
# Reproduced bit-exactly by the rust runtime (see rust/src/runtime/inputs.rs):
# simple modular ramps, exact in float32.


def example_compute_inputs():
    import numpy as np

    x = ((np.arange(BATCH * DIM) % 17).astype(np.float32) * 0.0625 - 0.5).reshape(
        BATCH, DIM
    )
    w = ((np.arange(DIM * DIM) % 13).astype(np.float32) * 0.03125 - 0.1875).reshape(
        DIM, DIM
    )
    b = (np.arange(DIM) % 7).astype(np.float32) * 0.125 - 0.375
    return x, w, b


def example_watermark_inputs():
    import numpy as np

    n = FRAMES * FRAME_H * FRAME_W
    frames = ((np.arange(n) % 251).astype(np.float32) / 250.0).reshape(
        FRAMES, FRAME_H, FRAME_W
    )
    wm = ((np.arange(FRAME_H * FRAME_W) % 101).astype(np.float32) / 100.0).reshape(
        FRAME_H, FRAME_W
    )
    alpha = np.array([0.25], dtype=np.float32)
    gain = np.array([1.0625], dtype=np.float32)
    return frames, wm, alpha, gain
