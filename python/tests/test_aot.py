"""AOT export tests: the HLO text parses, the manifest is complete, and the
expected-output check values match a jit evaluation of the lowered graphs."""

import json
import os

import numpy as np
import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = {
        "version": 1,
        "models": {
            "compute": aot.export_compute(str(out)),
            "watermark": aot.export_watermark(str(out)),
        },
    }
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return out, manifest


def test_hlo_text_is_parseable_hlo(exported):
    out, manifest = exported
    for name, m in manifest["models"].items():
        path = os.path.join(out, m["file"])
        text = open(path).read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # return_tuple=True: the root is a tuple.
        assert "tuple(" in text or "(f32[" in text


def test_manifest_shapes_match_specs(exported):
    _, manifest = exported
    c = manifest["models"]["compute"]
    assert c["inputs"][0]["shape"] == [model.BATCH, model.DIM]
    assert c["outputs"] == 2
    w = manifest["models"]["watermark"]
    assert w["inputs"][0]["shape"] == [model.FRAMES, model.FRAME_H, model.FRAME_W]
    assert all(i["dtype"] == "float32" for i in c["inputs"] + w["inputs"])


def test_check_values_match_jit_execution(exported):
    _, manifest = exported
    # compute
    x, w, b = model.example_compute_inputs()
    y, m = jax.jit(model.compute_fn)(x, w, b)
    chk = manifest["models"]["compute"]["check"]
    assert np.isclose(float(np.asarray(y).sum()), chk["out0_sum"], rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y).ravel()[:8], chk["out0_first8"], rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(m)[:4], chk["out1_first4"], rtol=1e-4)

    # watermark
    args = model.example_watermark_inputs()
    out, lum = jax.jit(model.watermark_fn)(*args)
    chk = manifest["models"]["watermark"]["check"]
    assert np.isclose(float(np.asarray(out).sum()), chk["out0_sum"], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(lum)[:4], chk["out1_first4"], rtol=1e-4)


def test_hlo_has_no_custom_calls(exported):
    # interpret=True must lower to plain HLO — a Mosaic custom-call would be
    # unloadable by the CPU PJRT client.
    out, manifest = exported
    for m in manifest["models"].values():
        text = open(os.path.join(out, m["file"])).read()
        assert "custom-call" not in text, "found custom-call in exported HLO"
