"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/values; fixed cases pin the export configuration.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from compile.kernels.compute import compute_kernel_call, BATCH, DIM
from compile.kernels.watermark import watermark_call, TILE_H, TILE_W
from compile.kernels import ref


# ----------------------------------------------------------------- compute


def _rand(shape, seed, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


class TestComputeKernel:
    def test_matches_ref_at_export_shape(self):
        x = _rand((BATCH, DIM), 0)
        w = _rand((DIM, DIM), 1, -0.2, 0.2)
        b = _rand((DIM,), 2)
        got = compute_kernel_call(x, w, b, iters=16)
        want = ref.compute_ref(x, w, b, iters=16)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_iteration(self):
        x = _rand((BATCH, DIM), 3)
        w = _rand((DIM, DIM), 4, -0.2, 0.2)
        b = _rand((DIM,), 5)
        got = compute_kernel_call(x, w, b, iters=1)
        want = np.tanh(x @ w + b) + 0.1 * x
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_iterations_is_identity(self):
        x = _rand((BATCH, DIM), 6)
        w = _rand((DIM, DIM), 7)
        b = _rand((DIM,), 8)
        got = compute_kernel_call(x, w, b, iters=0)
        np.testing.assert_allclose(got, x, rtol=0, atol=0)

    @settings(max_examples=12, deadline=None)
    @given(
        batch_tiles=st.integers(min_value=1, max_value=3),
        iters=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_batch_tiles_and_iters(self, batch_tiles, iters, seed):
        x = _rand((BATCH * batch_tiles, DIM), seed)
        w = _rand((DIM, DIM), seed + 1, -0.3, 0.3)
        b = _rand((DIM,), seed + 2)
        got = compute_kernel_call(x, w, b, iters=iters)
        want = ref.compute_ref(x, w, b, iters=iters)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            compute_kernel_call(
                _rand((BATCH + 1, DIM), 0), _rand((DIM, DIM), 1), _rand((DIM,), 2)
            )
        with pytest.raises(AssertionError):
            compute_kernel_call(
                _rand((BATCH, 64), 0), _rand((64, 64), 1), _rand((64,), 2)
            )

    def test_output_bounded(self):
        # tanh(+0.1x chain) keeps values bounded: |y| <= 1 + 0.1*|x|...
        # iterated: sup bound ~ 1/(1-0.1) + |x0|. Sanity-check no blowup.
        x = _rand((BATCH, DIM), 11, -5, 5)
        w = _rand((DIM, DIM), 12, -1, 1)
        b = _rand((DIM,), 13, -1, 1)
        y = np.asarray(compute_kernel_call(x, w, b, iters=32))
        assert np.all(np.isfinite(y))
        assert np.abs(y).max() < 5.0


# --------------------------------------------------------------- watermark


class TestWatermarkKernel:
    def test_matches_ref_at_export_shape(self):
        frames = _rand((4, 64, 256), 20, 0.0, 1.0)
        wm = _rand((64, 256), 21, 0.0, 1.0)
        alpha = np.array([0.25], dtype=np.float32)
        gain = np.array([1.0625], dtype=np.float32)
        got = watermark_call(frames, wm, alpha, gain)
        want = ref.watermark_ref(frames, wm, alpha, gain)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_alpha_zero_passthrough(self):
        frames = _rand((2, TILE_H, TILE_W), 22, 0.0, 1.0)
        wm = _rand((TILE_H, TILE_W), 23, 0.0, 1.0)
        got = watermark_call(
            frames,
            wm,
            np.array([0.0], dtype=np.float32),
            np.array([1.0], dtype=np.float32),
        )
        np.testing.assert_allclose(got, frames, rtol=1e-6, atol=1e-6)

    def test_alpha_one_is_watermark(self):
        frames = _rand((2, TILE_H, TILE_W), 24, 0.0, 1.0)
        wm = _rand((TILE_H, TILE_W), 25, 0.0, 1.0)
        got = watermark_call(
            frames,
            wm,
            np.array([1.0], dtype=np.float32),
            np.array([1.0], dtype=np.float32),
        )
        want = np.broadcast_to(wm, frames.shape)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_clip_engages(self):
        frames = np.full((1, TILE_H, TILE_W), 0.9, dtype=np.float32)
        wm = np.full((TILE_H, TILE_W), 2.0, dtype=np.float32)  # overbright
        got = np.asarray(
            watermark_call(
                frames,
                wm,
                np.array([0.5], dtype=np.float32),
                np.array([1.0], dtype=np.float32),
            )
        )
        assert got.max() <= 1.0 + 1e-6

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=4),
        h_tiles=st.integers(min_value=1, max_value=4),
        w_tiles=st.integers(min_value=1, max_value=2),
        alpha=st.floats(min_value=0.0, max_value=1.0, width=32),
        gain=st.floats(min_value=0.5, max_value=1.5, width=32),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes_and_params(self, n, h_tiles, w_tiles, alpha, gain, seed):
        h, w = TILE_H * h_tiles, TILE_W * w_tiles
        frames = _rand((n, h, w), seed, 0.0, 1.0)
        wm = _rand((h, w), seed + 1, 0.0, 1.0)
        a = np.array([alpha], dtype=np.float32)
        g = np.array([gain], dtype=np.float32)
        got = watermark_call(frames, wm, a, g)
        want = ref.watermark_ref(frames, wm, a, g)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_misaligned_shapes(self):
        with pytest.raises(AssertionError):
            watermark_call(
                _rand((1, TILE_H + 1, TILE_W), 0, 0, 1),
                _rand((TILE_H + 1, TILE_W), 1, 0, 1),
                np.array([0.5], dtype=np.float32),
                np.array([1.0], dtype=np.float32),
            )


# ------------------------------------------------------------ determinism


def test_kernels_deterministic():
    x = _rand((BATCH, DIM), 30)
    w = _rand((DIM, DIM), 31, -0.2, 0.2)
    b = _rand((DIM,), 32)
    a = np.asarray(compute_kernel_call(x, w, b, iters=4))
    c = np.asarray(compute_kernel_call(x, w, b, iters=4))
    np.testing.assert_array_equal(a, c)
