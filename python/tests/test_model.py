"""L2 graph tests: shapes, reductions, and the deterministic example inputs
the rust runtime replays."""

import numpy as np
import jax

from compile import model
from compile.kernels import ref


class TestComputeModel:
    def test_shapes(self):
        x, w, b = model.example_compute_inputs()
        y, m = jax.jit(model.compute_fn)(x, w, b)
        assert y.shape == (model.BATCH, model.DIM)
        assert m.shape == (model.BATCH,)

    def test_reduction_consistent(self):
        x, w, b = model.example_compute_inputs()
        y, m = jax.jit(model.compute_fn)(x, w, b)
        np.testing.assert_allclose(np.asarray(y).mean(axis=1), m, rtol=1e-6, atol=1e-6)

    def test_matches_oracle_on_example_inputs(self):
        x, w, b = model.example_compute_inputs()
        y, _ = jax.jit(model.compute_fn)(x, w, b)
        want = ref.compute_ref(x, w, b, iters=16)
        np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)

    def test_example_inputs_are_exact_f32(self):
        # The rust side regenerates these bit-exactly; the grids must be
        # exactly representable.
        x, w, b = model.example_compute_inputs()
        for arr in (x, w, b):
            assert arr.dtype == np.float32
            # Values are k/32 - c: multiples of 2^-5, exact in f32.
            assert np.all(arr * 32 == np.round(arr * 32))


class TestWatermarkModel:
    def test_shapes(self):
        args = model.example_watermark_inputs()
        out, lum = jax.jit(model.watermark_fn)(*args)
        assert out.shape == (model.FRAMES, model.FRAME_H, model.FRAME_W)
        assert lum.shape == (model.FRAMES,)

    def test_matches_oracle_on_example_inputs(self):
        args = model.example_watermark_inputs()
        out, lum = jax.jit(model.watermark_fn)(*args)
        want = ref.watermark_ref(*args)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            lum, np.asarray(want).mean(axis=(1, 2)), rtol=1e-5, atol=1e-6
        )

    def test_export_shapes_tile_aligned(self):
        from compile.kernels.watermark import TILE_H, TILE_W

        assert model.FRAME_H % TILE_H == 0
        assert model.FRAME_W % TILE_W == 0
