//! Bench: fleet-scale behaviour beyond the paper — per-policy latency on a
//! 10-node topology, simulator throughput as the fleet grows 10 → 100
//! nodes, the routing-policy sweep over a calibrated heterogeneous fleet,
//! and the incremental-accounting speedup (O(1) counter read vs the
//! O(total pods) rescan the hot path used to pay per event).
//!
//! `cargo bench --bench fleet_scale [-- table|scale|hetero|routing|accounting]`
//!
//! Set `KINETIC_SMOKE=1` to run every section at minimal size (1 bench
//! iteration, small fleets, short horizons) — the CI smoke gate that keeps
//! this bench compiling and running without burning minutes.

use kinetic::cluster::NodeId;
use kinetic::cluster::topology::Topology;
use kinetic::experiments::fleet::{self, FleetConfig};
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::bench::{black_box, Runner};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn smoke() -> bool {
    std::env::var("KINETIC_SMOKE").is_ok()
}

fn cfg(topology: Topology, seed: u64) -> FleetConfig {
    FleetConfig {
        horizon: SimTime::from_secs(if smoke() { 10 } else { 120 }),
        ..FleetConfig::base(topology, seed)
    }
}

fn main() {
    let runner = Runner::from_args();

    runner.section("table", || {
        // The acceptance artifact: per-policy latency table on ≥10 nodes.
        let rows = fleet::run_all(&cfg(Topology::uniform_paper(10), 42));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
    });

    runner.section("scale", || {
        // Simulator throughput as the fleet grows: virtual load scales with
        // node count; report host-time per simulated request.
        let sizes: &[usize] = if smoke() { &[10] } else { &[10, 25, 50, 100] };
        for &nodes in sizes {
            let c = cfg(Topology::uniform_paper(nodes), 7);
            let t0 = std::time::Instant::now();
            let row = fleet::run_policy(&c, Policy::InPlace);
            let wall = t0.elapsed();
            let per_req = if row.completed > 0 {
                wall.as_nanos() as f64 / row.completed as f64 / 1000.0
            } else {
                0.0
            };
            println!(
                "scale/{nodes:>3} nodes  {} tenants  {:>6} requests in {wall:>10.2?}  \
                 ({per_req:.1} us/request host)",
                c.services, row.completed
            );
        }
    });

    runner.section("hetero", || {
        let rows = fleet::run_all(&cfg(Topology::hetero_preset(12), 21));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
        for r in &rows {
            assert_eq!(r.failed, 0, "{:?} failed requests on hetero fleet", r.policy);
        }
    });

    runner.section("routing", || {
        // Placement-aware routing over the calibrated heterogeneous fleet
        // (fast large nodes, slow small nodes — the regime where locality
        // has signal to exploit).
        let n = if smoke() { 6 } else { 12 };
        let rows = fleet::routing_sweep(&cfg(Topology::hetero_preset(n), 21));
        println!("{}", fleet::routing_table(&rows).to_ascii());
        for r in &rows {
            assert_eq!(
                r.failed, 0,
                "{:?}/{:?} failed requests",
                r.routing, r.policy
            );
        }
    });

    runner.section("accounting", || {
        // The incremental-accounting speedup: freeze a loaded fleet
        // mid-flight, then compare the from-scratch rescan (what
        // `node_load`/`committed_changed` paid per event before this
        // subsystem) against the O(1) incremental counter reads.
        let nodes = if smoke() { 10 } else { 100 };
        let c = cfg(Topology::uniform_paper(nodes), 13);
        let mut sim = kinetic::coordinator::platform::Simulation::fleet(c.topology.clone(), 13);
        for i in 0..c.services {
            sim.deploy(
                &format!("fn-{i}"),
                WorkloadProfile::paper(WorkloadKind::Cpu),
                Policy::Warm,
            );
        }
        sim.run();
        // Put every tenant's pod mid-request, then stop between events.
        let start = sim.now();
        for i in 0..c.services {
            sim.submit_at(start + SimTime::from_millis(i as u64), &format!("fn-{i}"));
        }
        sim.run_until(start + SimTime::from_secs(1));
        let tracked = sim.world.fleet.tracked_pods();

        let iters: u32 = if smoke() { 10 } else { 2000 };
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            black_box(sim.world.rescan_accounting());
        }
        let rescan_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let t1 = std::time::Instant::now();
        for _ in 0..iters {
            black_box(sim.world.fleet.committed_total());
            black_box(sim.world.fleet.node(NodeId(0)).busy_mcpu);
        }
        let incr_ns = t1.elapsed().as_nanos() as f64 / iters as f64;
        println!(
            "accounting/{nodes} nodes, {tracked} pods: full rescan {:.0} ns vs \
             incremental read {:.0} ns  ({:.0}× speedup per event)",
            rescan_ns,
            incr_ns,
            rescan_ns / incr_ns.max(1.0)
        );
        // The counters must agree with the rescan on the frozen state.
        assert!(
            sim.world.fleet.diff(&sim.world.rescan_accounting()).is_none(),
            "incremental counters drifted from rescan"
        );
    });
}
