//! Bench: fleet-scale behaviour beyond the paper — per-policy latency on a
//! 10-node topology, simulator throughput as the fleet grows 10 → 100
//! nodes, the routing-policy sweep over a calibrated heterogeneous fleet,
//! the incremental-accounting speedup (O(1) counter read vs the
//! O(total pods) rescan the hot path used to pay per event), and the
//! state-layer speedup (generational-slab pod lookup vs the map probe the
//! dispatch/complete path paid before the arena overhaul).
//!
//! `cargo bench --bench fleet_scale [-- table|scale|hetero|routing|accounting|arena]`
//!
//! Set `KINETIC_SMOKE=1` to run every section at minimal size (1 bench
//! iteration, small fleets, short horizons) — the CI smoke gate that keeps
//! this bench compiling and running without burning minutes.

use kinetic::cluster::NodeId;
use kinetic::cluster::topology::Topology;
use kinetic::experiments::fleet::{self, FleetConfig};
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::bench::{black_box, Runner};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn smoke() -> bool {
    std::env::var("KINETIC_SMOKE").is_ok()
}

fn cfg(topology: Topology, seed: u64) -> FleetConfig {
    FleetConfig {
        horizon: SimTime::from_secs(if smoke() { 10 } else { 120 }),
        ..FleetConfig::base(topology, seed)
    }
}

fn main() {
    let runner = Runner::from_args();

    runner.section("table", || {
        // The acceptance artifact: per-policy latency table on ≥10 nodes.
        let rows = fleet::run_all(&cfg(Topology::uniform_paper(10), 42));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
    });

    runner.section("scale", || {
        // Simulator throughput as the fleet grows: virtual load scales with
        // node count; report host-time per simulated request.
        let sizes: &[usize] = if smoke() { &[10] } else { &[10, 25, 50, 100] };
        for &nodes in sizes {
            let c = cfg(Topology::uniform_paper(nodes), 7);
            let t0 = std::time::Instant::now();
            let row = fleet::run_policy(&c, Policy::InPlace);
            let wall = t0.elapsed();
            let per_req = if row.completed > 0 {
                wall.as_nanos() as f64 / row.completed as f64 / 1000.0
            } else {
                0.0
            };
            println!(
                "scale/{nodes:>3} nodes  {} tenants  {:>6} requests in {wall:>10.2?}  \
                 ({per_req:.1} us/request host)",
                c.services, row.completed
            );
        }
    });

    runner.section("hetero", || {
        let rows = fleet::run_all(&cfg(Topology::hetero_preset(12), 21));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
        for r in &rows {
            assert_eq!(r.failed, 0, "{:?} failed requests on hetero fleet", r.policy);
        }
    });

    runner.section("routing", || {
        // Placement-aware routing over the calibrated heterogeneous fleet
        // (fast large nodes, slow small nodes — the regime where locality
        // has signal to exploit).
        let n = if smoke() { 6 } else { 12 };
        let rows = fleet::routing_sweep(&cfg(Topology::hetero_preset(n), 21));
        println!("{}", fleet::routing_table(&rows).to_ascii());
        for r in &rows {
            assert_eq!(
                r.failed, 0,
                "{:?}/{:?} failed requests",
                r.routing, r.policy
            );
        }
    });

    runner.section("accounting", || {
        // The incremental-accounting speedup: freeze a loaded fleet
        // mid-flight, then compare the from-scratch rescan (what
        // `node_load`/`committed_changed` paid per event before this
        // subsystem) against the O(1) incremental counter reads.
        let nodes = if smoke() { 10 } else { 100 };
        let c = cfg(Topology::uniform_paper(nodes), 13);
        let mut sim = kinetic::coordinator::platform::Simulation::fleet(c.topology.clone(), 13);
        for i in 0..c.services {
            sim.deploy(
                &format!("fn-{i}"),
                WorkloadProfile::paper(WorkloadKind::Cpu),
                Policy::Warm,
            );
        }
        sim.run();
        // Put every tenant's pod mid-request, then stop between events.
        let start = sim.now();
        for i in 0..c.services {
            sim.submit_at(start + SimTime::from_millis(i as u64), &format!("fn-{i}"));
        }
        sim.run_until(start + SimTime::from_secs(1));
        let tracked = sim.world.fleet.tracked_pods();

        let iters: u32 = if smoke() { 10 } else { 2000 };
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            black_box(sim.world.rescan_accounting());
        }
        let rescan_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let t1 = std::time::Instant::now();
        for _ in 0..iters {
            black_box(sim.world.fleet.committed_total());
            black_box(sim.world.fleet.node(NodeId(0)).busy_mcpu);
        }
        let incr_ns = t1.elapsed().as_nanos() as f64 / iters as f64;
        println!(
            "accounting/{nodes} nodes, {tracked} pods: full rescan {:.0} ns vs \
             incremental read {:.0} ns  ({:.0}× speedup per event)",
            rescan_ns,
            incr_ns,
            rescan_ns / incr_ns.max(1.0)
        );
        // The counters must agree with the rescan on the frozen state.
        assert!(
            sim.world.fleet.diff(&sim.world.rescan_accounting()).is_none(),
            "incremental counters drifted from rescan"
        );
    });

    runner.section("arena", || {
        // The state-layer win: a generational-slab pod lookup (one bounds
        // check + one generation compare) vs the `HashMap<PodId, _>` probe
        // every dispatch/complete/resize event paid before the arena
        // overhaul. A third of the fleet is retired and replaced first so
        // the slab carries real generation churn, like a crash-heavy run.
        use std::collections::HashMap;

        use kinetic::cluster::arena::PodSlab;
        use kinetic::cluster::pod::{PodId, PodSpec};
        use kinetic::util::quantity::{Memory, MilliCpu, Resources};
        use kinetic::util::rng::Rng;

        let pods: usize = if smoke() { 256 } else { 8192 };
        let spec = PodSpec::single(
            "fn",
            "img",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        );
        let mut slab = PodSlab::new();
        let mut live: Vec<PodId> = (0..pods).map(|_| slab.alloc(spec.clone())).collect();
        let mut rng = Rng::new(13);
        for _ in 0..pods / 3 {
            let i = rng.below(live.len() as u64) as usize;
            slab.remove(live.swap_remove(i));
            live.push(slab.alloc(spec.clone()));
        }
        let map: HashMap<PodId, u64> = live.iter().map(|&id| (id, id.0)).collect();
        let mut probes = live.clone();
        rng.shuffle(&mut probes);

        let iters: u64 = if smoke() { 20 } else { 2000 };
        let lookups = iters * probes.len() as u64;
        let t0 = std::time::Instant::now();
        let mut slab_hits = 0u64;
        for _ in 0..iters {
            for &id in &probes {
                if black_box(slab.get(id)).is_some() {
                    slab_hits += 1;
                }
            }
        }
        let slab_ns = t0.elapsed().as_nanos() as f64 / lookups as f64;
        let t1 = std::time::Instant::now();
        let mut map_hits = 0u64;
        for _ in 0..iters {
            for &id in &probes {
                if black_box(map.get(&id)).is_some() {
                    map_hits += 1;
                }
            }
        }
        let map_ns = t1.elapsed().as_nanos() as f64 / lookups as f64;
        assert_eq!(slab_hits, map_hits, "slab and map oracle disagree on the live set");
        assert_eq!(slab_hits, lookups, "every live id must resolve");
        println!(
            "arena/{pods} pods ({} retired+replaced): slab get {slab_ns:.1} ns vs \
             map get {map_ns:.1} ns per lookup  ({:.1}× per event)",
            pods / 3,
            map_ns / slab_ns.max(0.1)
        );
    });
}
