//! Bench: fleet-scale behaviour beyond the paper — per-policy latency on a
//! 10-node topology and simulator throughput (host wall-clock per simulated
//! request) as the fleet grows 10 → 100 nodes.
//!
//! `cargo bench --bench fleet_scale [-- table|scale|hetero]`

use kinetic::cluster::topology::Topology;
use kinetic::experiments::fleet::{self, FleetConfig};
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::bench::Runner;

fn cfg(topology: Topology, seed: u64) -> FleetConfig {
    let services = 2 * topology.len();
    FleetConfig {
        topology,
        services,
        rate_per_service: 0.05,
        horizon: SimTime::from_secs(120),
        seed,
    }
}

fn main() {
    let runner = Runner::from_args();

    runner.section("table", || {
        // The acceptance artifact: per-policy latency table on ≥10 nodes.
        let rows = fleet::run_all(&cfg(Topology::uniform_paper(10), 42));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
    });

    runner.section("scale", || {
        // Simulator throughput as the fleet grows: virtual load scales with
        // node count; report host-time per simulated request.
        for nodes in [10usize, 25, 50, 100] {
            let c = cfg(Topology::uniform_paper(nodes), 7);
            let t0 = std::time::Instant::now();
            let row = fleet::run_policy(&c, Policy::InPlace);
            let wall = t0.elapsed();
            let per_req = if row.completed > 0 {
                wall.as_nanos() as f64 / row.completed as f64 / 1000.0
            } else {
                0.0
            };
            println!(
                "scale/{nodes:>3} nodes  {} tenants  {:>6} requests in {wall:>10.2?}  \
                 ({per_req:.1} us/request host)",
                c.services, row.completed
            );
        }
    });

    runner.section("hetero", || {
        let rows = fleet::run_all(&cfg(Topology::hetero_preset(12), 21));
        println!("{}", fleet::fleet_table(&rows).to_ascii());
        for r in &rows {
            assert_eq!(r.failed, 0, "{:?} failed requests on hetero fleet", r.policy);
        }
    });
}
