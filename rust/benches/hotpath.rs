//! Bench: L3 coordinator hot paths — event engine throughput, the
//! router/queue-proxy dispatch path, and end-to-end simulated request cost
//! per policy. These are the perf-pass targets in DESIGN.md §7.
//!
//! `cargo bench --bench hotpath`

use kinetic::coordinator::platform::Simulation;
use kinetic::loadgen::runner::{Runner as LoadRunner, Scenario};
use kinetic::policy::Policy;
use kinetic::simclock::oracle::OracleEngine;
use kinetic::simclock::{Engine, SimTime, World};
use kinetic::util::bench::{bench_fn, black_box, BenchConfig, Runner};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

/// Minimal typed world for raw engine throughput: every event is one
/// counter increment, no allocation anywhere.
struct Counter(u64);

enum Tick {
    Incr,
}

impl World for Counter {
    type Event = Tick;

    fn handle(&mut self, ev: Tick, _eng: &mut Engine<Counter>) {
        match ev {
            Tick::Incr => self.0 += 1,
        }
    }
}

fn main() {
    let runner = Runner::from_args();
    let cfg = BenchConfig::default();

    runner.section("engine", || {
        // Raw DES engine throughput: schedule+run N trivial events through
        // the typed-event calendar queue.
        let r = bench_fn("engine/schedule+run 10k events", &cfg, || {
            let mut eng: Engine<Counter> = Engine::new();
            let mut world = Counter(0);
            for i in 0..10_000u64 {
                eng.schedule_at(SimTime::from_nanos(i), Tick::Incr);
            }
            black_box(eng.run(&mut world));
            black_box(world.0);
        });
        println!("{}", r.line());
        let per_event = r.mean_ns / 10_000.0;
        println!(
            "  -> {per_event:.0} ns/event  ({:.2} M events/s; target >= 1 M/s)",
            1e3 / per_event
        );

        // Same workload through the retained boxed-closure BinaryHeap
        // oracle — the baseline the calendar queue replaced.
        let o = bench_fn("engine/oracle (boxed + BinaryHeap) 10k", &cfg, || {
            let mut eng: OracleEngine<u64> = OracleEngine::new();
            let mut world = 0u64;
            for i in 0..10_000u64 {
                eng.schedule_at(SimTime::from_nanos(i), |w: &mut u64, _| *w += 1);
            }
            black_box(eng.run(&mut world));
            black_box(world);
        });
        println!("{}", o.line());
        println!(
            "  -> speedup vs oracle: {:.2}x",
            o.mean_ns / r.mean_ns.max(1.0)
        );
    });

    runner.section("request", || {
        // End-to-end simulated request cost (wall time per simulated
        // request, warm path, helloworld).
        for policy in [Policy::Warm, Policy::InPlace] {
            let mut sim = Simulation::paper(7);
            sim.deploy(
                "fn",
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                policy,
            );
            sim.run();
            let t0 = std::time::Instant::now();
            let report = LoadRunner::run(&mut sim, "fn", &Scenario::closed(8, 250));
            let wall = t0.elapsed();
            let per = wall.as_nanos() as f64 / report.completed as f64;
            println!(
                "request/{:<8} {} simulated requests in {:?} -> {:.1} us/request (host)",
                policy.name(),
                report.completed,
                wall,
                per / 1000.0
            );
        }
    });

    runner.section("trace", || {
        use kinetic::trace::generator::{TraceConfig, TraceGenerator};
        use kinetic::trace::replay::replay;
        let trace = TraceGenerator::new(TraceConfig {
            functions: 8,
            peak_rate: 20.0,
            horizon: SimTime::from_secs(300),
            ..TraceConfig::default()
        })
        .generate();
        let t0 = std::time::Instant::now();
        let r = replay(&trace, 8, Policy::InPlace, 3);
        let wall = t0.elapsed();
        println!(
            "trace/in-place: {} invocations replayed in {:?} ({:.0} sim-req/s host)",
            r.completed,
            wall,
            r.completed as f64 / wall.as_secs_f64()
        );
    });
}
