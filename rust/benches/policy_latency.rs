//! Bench: regenerates §4.2 — Table 2 (workload runtimes), Table 3 + Fig 5
//! (policy latency comparison) and Fig 6 (runtime vs in-place effect).
//!
//! `cargo bench --bench policy_latency [-- table2|table3|fig5|fig6]`

use kinetic::experiments::policies::PolicyExperiment;
use kinetic::experiments::report::{fig5_table, fig6_table, table3_table};
use kinetic::util::bench::Runner;
use kinetic::util::table::{fmt_ms, fmt_ratio, Table};
use kinetic::workload::registry::WorkloadProfile;

fn main() {
    let runner = Runner::from_args();
    // iterations 8 / 8 s think / seed 42 / least-loaded routing — the
    // documented paper-reproduction configuration.
    let exp = PolicyExperiment::default();

    runner.section("table2", || {
        let mut t = Table::new(vec!["Workload", "Runtime (ms)", "sigma (ms)", "Paper (ms)"])
            .title("Table 2: runtime measurements with 1 CPU");
        for (kind, s) in exp.table2(64) {
            t.row(vec![
                kind.name().to_string(),
                fmt_ms(s.mean()),
                fmt_ms(s.std_dev()),
                fmt_ms(WorkloadProfile::paper(kind).runtime_1cpu_ms),
            ]);
        }
        println!("{}", t.to_ascii());
    });

    // table3 / fig5 / fig6 share one sweep.
    if runner.enabled("table3") || runner.enabled("fig5") || runner.enabled("fig6") {
        let rows = exp.table3();
        runner.section("table3", || {
            println!("{}", table3_table(&rows).to_ascii());
            println!("paper row (helloworld): Cold 286.99, In-place 15.81, Warm 3.87");
        });
        runner.section("fig5", || {
            println!("{}", fig5_table(&rows).to_ascii());
        });
        runner.section("fig6", || {
            println!("{}", fig6_table(&PolicyExperiment::fig6(&rows)).to_ascii());
            // Shape assertions the paper highlights.
            let hello = rows.iter().find(|r| r.function == "helloworld").unwrap();
            let v10m = rows.iter().find(|r| r.function == "videos-10m").unwrap();
            println!(
                "inverse relationship: in-place effect {} (helloworld) -> {} (videos-10m)",
                fmt_ratio(hello.inplace),
                fmt_ratio(v10m.inplace)
            );
            println!(
                "headline improvement band: {}x (paper: 1.16x - 18.15x)",
                fmt_ratio(hello.improvement())
            );
        });
    }
}
