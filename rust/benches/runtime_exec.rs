//! Bench: the PJRT hot path — artifact load/compile time and per-execution
//! latency of both AOT kernels (compute + watermark) from rust.
//!
//! `cargo bench --bench runtime_exec`

use kinetic::runtime::{inputs, Executor};
use kinetic::util::bench::{bench_fn, black_box, BenchConfig, Runner};

fn main() {
    let runner = Runner::from_args();
    let Ok(mut ex) = Executor::new(None) else {
        eprintln!("artifacts missing — run `make artifacts` first; skipping runtime_exec");
        return;
    };
    println!("PJRT platform: {}", ex.platform());

    runner.section("compile", || {
        let t0 = std::time::Instant::now();
        let mut fresh = Executor::new(None).unwrap();
        fresh.load("compute").unwrap();
        let c1 = t0.elapsed();
        let t1 = std::time::Instant::now();
        fresh.load("watermark").unwrap();
        let c2 = t1.elapsed();
        println!("compile compute:   {c1:?}");
        println!("compile watermark: {c2:?}");
        println!("(compilation happens once per model variant; the request path only executes)");
    });

    runner.section("execute", || {
        ex.self_check("compute").expect("numeric check");
        ex.self_check("watermark").expect("numeric check");
        let cfg = BenchConfig::default();

        let (x, w, b) = inputs::compute_inputs();
        let r = bench_fn("execute/compute(128x128,16 iters)", &cfg, || {
            black_box(ex.execute("compute", &[&x, &w, &b]).unwrap());
        });
        println!("{}", r.line());
        let lits = ex.prepare_inputs("compute", &[&x, &w, &b]).unwrap();
        let r = bench_fn("execute_prepared/compute (reused literals)", &cfg, || {
            black_box(ex.execute_prepared("compute", &lits).unwrap());
        });
        println!("{}", r.line());

        let (f, wm, a, g) = inputs::watermark_inputs();
        let r = bench_fn("execute/watermark(4x64x256)", &cfg, || {
            black_box(ex.execute("watermark", &[&f, &wm, &a, &g]).unwrap());
        });
        println!("{}", r.line());
        let lits = ex.prepare_inputs("watermark", &[&f, &wm, &a, &g]).unwrap();
        let r = bench_fn("execute_prepared/watermark (reused literals)", &cfg, || {
            black_box(ex.execute_prepared("watermark", &lits).unwrap());
        });
        println!("{}", r.line());
    });
}
