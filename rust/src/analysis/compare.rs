//! Report-to-report regression diffing — the future CI perf gate.
//!
//! Two [`ScenarioReport`](crate::scenario::ScenarioReport)s are aggregated
//! (cross-rep) and matched cell-by-cell on the full
//! (variant, workload, routing, policy) key. A cell regresses when its
//! mean or p99 latency grows by more than the threshold percentage, or
//! when it fails requests the baseline completed. Cells present on only
//! one side are reported separately — a vanished variant must be visible,
//! not silently skipped.

use crate::analysis::stats::{Group, GroupKey};

/// One matched cell's deltas. Percentages are `(new - base) / base × 100`
/// (positive ⇒ slower). When the base latency is zero but the new one is
/// not, the delta is reported as `None` ("n/a") and still flagged.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    pub key: GroupKey,
    pub base_mean: f64,
    pub new_mean: f64,
    pub mean_pct: Option<f64>,
    pub base_p99: f64,
    pub new_p99: f64,
    pub p99_pct: Option<f64>,
    pub base_failed: u64,
    pub new_failed: u64,
    /// Did this cell regress beyond the threshold?
    pub regression: bool,
}

/// The full diff.
#[derive(Debug, Clone, PartialEq)]
pub struct Comparison {
    pub threshold_pct: f64,
    /// Matched cells, in the new report's order.
    pub deltas: Vec<Delta>,
    /// Cells only the base report has (removed coverage).
    pub only_in_base: Vec<GroupKey>,
    /// Cells only the new report has (added coverage).
    pub only_in_new: Vec<GroupKey>,
}

impl Comparison {
    pub fn regressions(&self) -> impl Iterator<Item = &Delta> {
        self.deltas.iter().filter(|d| d.regression)
    }

    pub fn regression_count(&self) -> usize {
        self.regressions().count()
    }

    pub fn has_regressions(&self) -> bool {
        self.regression_count() > 0
    }

    /// Do the two reports cover different cells?
    pub fn keys_mismatch(&self) -> bool {
        !self.only_in_base.is_empty() || !self.only_in_new.is_empty()
    }
}

fn pct(base: f64, new: f64) -> Option<f64> {
    if base > 0.0 && base.is_finite() && new.is_finite() {
        Some((new - base) / base * 100.0)
    } else if base == 0.0 && new == 0.0 {
        Some(0.0)
    } else {
        None
    }
}

/// Diffs two aggregated reports at `threshold_pct`.
pub fn compare(base: &[Group], new: &[Group], threshold_pct: f64) -> Comparison {
    let mut deltas = Vec::new();
    let mut only_in_new = Vec::new();
    for n in new {
        let Some(b) = base.iter().find(|b| b.key == n.key) else {
            only_in_new.push(n.key.clone());
            continue;
        };
        let mean_pct = pct(b.mean_ms.mean, n.mean_ms.mean);
        let p99_pct = pct(b.p99_ms.mean, n.p99_ms.mean);
        let latency_regressed = |p: Option<f64>, base_v: f64, new_v: f64| match p {
            Some(p) => p > threshold_pct,
            // No percentage: regressed exactly when latency appeared from
            // nothing (base 0 ⇒ the base cell completed no work there).
            None => base_v == 0.0 && new_v > 0.0,
        };
        // A cell that used to complete work and now completes none would
        // read as a -100% "improvement" on latency alone — a total stall
        // must trip the gate, not sail through it.
        let stalled = b.has_latency() && !n.has_latency();
        // Failures are summed across reps, so compare *rates*: cross-
        // multiplying by the other side's rep count avoids floats and a
        // spurious flag (or miss) when the two reports used different
        // rep counts for the same cell.
        let more_failures =
            n.failed * u64::from(b.reps.max(1)) > b.failed * u64::from(n.reps.max(1));
        let regression = stalled
            || latency_regressed(mean_pct, b.mean_ms.mean, n.mean_ms.mean)
            || latency_regressed(p99_pct, b.p99_ms.mean, n.p99_ms.mean)
            || more_failures;
        deltas.push(Delta {
            key: n.key.clone(),
            base_mean: b.mean_ms.mean,
            new_mean: n.mean_ms.mean,
            mean_pct,
            base_p99: b.p99_ms.mean,
            new_p99: n.p99_ms.mean,
            p99_pct,
            base_failed: b.failed,
            new_failed: n.failed,
            regression,
        });
    }
    let only_in_base = base
        .iter()
        .filter(|b| !new.iter().any(|n| n.key == b.key))
        .map(|b| b.key.clone())
        .collect();
    Comparison {
        threshold_pct,
        deltas,
        only_in_base,
        only_in_new,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::stats::{aggregate, test_row as row};
    use crate::policy::Policy;

    fn groups(mean_cold: f64, mean_inplace: f64) -> Vec<Group> {
        aggregate(&[
            row("", "mix", Policy::Cold, 0, mean_cold, 10),
            row("", "mix", Policy::InPlace, 0, mean_inplace, 10),
        ])
    }

    #[test]
    fn identical_reports_have_no_regressions() {
        let base = groups(100.0, 10.0);
        let cmp = compare(&base, &base, 5.0);
        assert_eq!(cmp.deltas.len(), 2);
        assert!(!cmp.has_regressions());
        assert!(!cmp.keys_mismatch());
        assert_eq!(cmp.deltas[0].mean_pct, Some(0.0));
    }

    #[test]
    fn slowdown_beyond_threshold_is_flagged() {
        let base = groups(100.0, 10.0);
        let new = groups(100.0, 12.0); // in-place +20% mean (and p99)
        let cmp = compare(&base, &new, 10.0);
        assert_eq!(cmp.regression_count(), 1);
        let d = cmp.regressions().next().unwrap();
        assert_eq!(d.key.policy, Policy::InPlace);
        assert!((d.mean_pct.unwrap() - 20.0).abs() < 1e-9);
        // Under a looser threshold it passes.
        assert!(!compare(&base, &new, 25.0).has_regressions());
        // An improvement is never a regression.
        assert!(!compare(&base, &groups(100.0, 5.0), 10.0).has_regressions());
    }

    #[test]
    fn new_failures_are_regressions() {
        let base = groups(100.0, 10.0);
        let mut bad = row("", "mix", Policy::InPlace, 0, 10.0, 10);
        bad.failed = 2;
        let new = aggregate(&[row("", "mix", Policy::Cold, 0, 100.0, 10), bad]);
        let cmp = compare(&base, &new, 50.0);
        assert_eq!(cmp.regression_count(), 1);
        assert_eq!(cmp.regressions().next().unwrap().new_failed, 2);
    }

    /// Failure counts are summed across reps, so the gate must compare
    /// per-rep rates: 3 reps × 1 failure is not worse than 1 rep × 2.
    #[test]
    fn failure_comparison_normalizes_by_rep_count() {
        let mut b0 = row("", "mix", Policy::Cold, 0, 100.0, 10);
        let mut b1 = row("", "mix", Policy::Cold, 1, 100.0, 10);
        let mut b2 = row("", "mix", Policy::Cold, 2, 100.0, 10);
        (b0.failed, b1.failed, b2.failed) = (1, 1, 1); // 3 failures over 3 reps
        let base = aggregate(&[b0, b1, b2]);
        let mut worse = row("", "mix", Policy::Cold, 0, 100.0, 10);
        worse.failed = 2; // 2 failures over 1 rep: rate doubled
        let cmp = compare(&base, &aggregate(&[worse]), 50.0);
        assert_eq!(cmp.regression_count(), 1);
        let mut same_rate = row("", "mix", Policy::Cold, 0, 100.0, 10);
        same_rate.failed = 1; // 1 failure over 1 rep: identical rate
        let cmp = compare(&base, &aggregate(&[same_rate]), 50.0);
        assert!(!cmp.has_regressions());
    }

    #[test]
    fn mismatched_variant_sets_are_surfaced_not_dropped() {
        let base = aggregate(&[
            row("a=1", "mix", Policy::Cold, 0, 100.0, 10),
            row("a=2", "mix", Policy::Cold, 0, 100.0, 10),
        ]);
        let new = aggregate(&[
            row("a=1", "mix", Policy::Cold, 0, 100.0, 10),
            row("a=3", "mix", Policy::Cold, 0, 100.0, 10),
        ]);
        let cmp = compare(&base, &new, 5.0);
        assert_eq!(cmp.deltas.len(), 1);
        assert_eq!(cmp.only_in_base.len(), 1);
        assert_eq!(cmp.only_in_base[0].variant, "a=2");
        assert_eq!(cmp.only_in_new.len(), 1);
        assert_eq!(cmp.only_in_new[0].variant, "a=3");
        assert!(cmp.keys_mismatch());
        assert!(!cmp.has_regressions());
    }

    /// A cell that completed work in the base run but nothing in the new
    /// one must regress — latency alone would call the collapse "-100%".
    #[test]
    fn total_stall_is_a_regression_not_an_improvement() {
        let base = aggregate(&[row("", "mix", Policy::InPlace, 0, 10.0, 10)]);
        let new = aggregate(&[row("", "mix", Policy::InPlace, 0, 0.0, 0)]);
        let cmp = compare(&base, &new, 5.0);
        assert_eq!(cmp.regression_count(), 1);
        let d = &cmp.deltas[0];
        assert!(d.regression);
        assert_eq!(d.mean_pct, Some(-100.0));
    }

    #[test]
    fn latency_appearing_from_an_empty_base_cell_is_flagged_without_nan() {
        let base = aggregate(&[row("", "mix", Policy::Cold, 0, 0.0, 0)]);
        let new = aggregate(&[row("", "mix", Policy::Cold, 0, 50.0, 10)]);
        let cmp = compare(&base, &new, 5.0);
        assert_eq!(cmp.deltas[0].mean_pct, None);
        assert!(cmp.deltas[0].regression);
        // Both empty: 0% delta, no regression.
        let cmp = compare(&base, &base, 5.0);
        assert_eq!(cmp.deltas[0].mean_pct, Some(0.0));
        assert!(!cmp.has_regressions());
    }
}
