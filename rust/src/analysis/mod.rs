//! The measurement pipeline: turns raw [`ScenarioReport`] rows into the
//! paper-shaped derived numbers.
//!
//! * [`stats`] — cross-rep aggregation into [`Group`]s (mean/p50/p99 with
//!   min/max spread, counters summed).
//! * [`speedup`] — ratios against a baseline policy, reproducing the
//!   shape of the paper's Table-3 improvement column (1.16×–18.15×).
//! * [`compare`] — report-to-report regression diffing with a threshold
//!   (`kinetic compare`, the future CI perf gate).
//! * [`render`] — every view as ASCII / markdown / CSV through
//!   [`util::table`](crate::util::table).
//!
//! [`AnalysisReport`] is the persistable result: a schema-versioned JSON
//! document (`analysis_<name>.json`) mirroring what `kinetic analyze`
//! prints, so downstream tooling never has to re-derive ratios from raw
//! rows.

pub mod compare;
pub mod render;
pub mod speedup;
pub mod stats;

pub use compare::{compare, Comparison, Delta};
pub use render::{render, Format};
pub use speedup::{against_baseline, ratio_range, Speedup};
pub use stats::{aggregate, Group, GroupKey, MetricAgg};

use std::path::{Path, PathBuf};

use crate::policy::Policy;
use crate::scenario::ScenarioReport;
use crate::util::json::Json;
use crate::util::table::Table;

/// Bumped when a field changes meaning; `validate` pins it.
/// v2: rows carry the predictive-policy speculation counters
/// (`speculative_resizes`, `mispredictions`).
pub const ANALYSIS_SCHEMA_VERSION: u64 = 2;

/// The analysis of one scenario report: aggregated groups annotated with
/// speedups against `baseline`.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisReport {
    /// The analyzed report's scenario name.
    pub name: String,
    /// The policy every ratio is computed against.
    pub baseline: Policy,
    pub rows: Vec<Speedup>,
}

impl AnalysisReport {
    /// Aggregates and annotates a scenario report.
    pub fn from_scenario(report: &ScenarioReport, baseline: Policy) -> AnalysisReport {
        let groups = aggregate(&report.rows);
        AnalysisReport {
            name: report.name.clone(),
            baseline,
            rows: against_baseline(&groups, baseline),
        }
    }

    /// The min–max mean-latency improvement the given policy achieves over
    /// the baseline across every cell — the paper's "1.16×–18.15×" shape.
    pub fn headline(&self, policy: Policy) -> Option<(f64, f64)> {
        ratio_range(&self.rows, policy)
    }

    pub fn aggregate_table(&self) -> Table {
        let groups: Vec<Group> = self.rows.iter().map(|s| s.group.clone()).collect();
        render::aggregate_table(&self.name, &groups)
    }

    pub fn speedup_table(&self) -> Table {
        render::speedup_table(&self.name, self.baseline, &self.rows)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", ANALYSIS_SCHEMA_VERSION.into()),
            ("name", self.name.as_str().into()),
            ("baseline_policy", self.baseline.name().into()),
            ("rows", Json::arr(self.rows.iter().map(speedup_to_json))),
        ])
    }

    /// Validates a JSON document against the analysis schema; returns the
    /// first problem found, with its path.
    pub fn validate(j: &Json) -> Result<(), String> {
        AnalysisReport::from_json(j).map(|_| ())
    }

    /// Parses and validates in one pass (strict top level).
    pub fn from_json(j: &Json) -> Result<AnalysisReport, String> {
        let m = j.as_obj().ok_or("analysis report must be a JSON object")?;
        const KEYS: [&str; 4] = ["schema_version", "name", "baseline_policy", "rows"];
        for key in KEYS {
            if !m.contains_key(key) {
                return Err(format!("missing top-level field '{key}'"));
            }
        }
        for key in m.keys() {
            if !KEYS.contains(&key.as_str()) {
                return Err(format!("unknown top-level field '{key}'"));
            }
        }
        let version = j.req_u64("schema_version").map_err(|e| e.to_string())?;
        if version != ANALYSIS_SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} unsupported (expected {ANALYSIS_SCHEMA_VERSION})"
            ));
        }
        let baseline = j
            .req_str("baseline_policy")
            .map_err(|e| e.to_string())?
            .parse::<Policy>()
            .map_err(|e| format!("baseline_policy: {e}"))?;
        let rows = j
            .req_arr("rows")
            .map_err(|e| e.to_string())?
            .iter()
            .enumerate()
            .map(|(i, r)| speedup_from_json(r, &format!("rows[{i}]")))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(AnalysisReport {
            name: j.req_str("name").map_err(|e| e.to_string())?.to_string(),
            baseline,
            rows,
        })
    }

    /// Writes `<dir>/analysis_<name>.json` (pretty) and returns the path.
    pub fn save(&self, dir: &Path) -> std::io::Result<PathBuf> {
        crate::util::json::save_named(dir, "analysis", &self.name, &self.to_json())
    }
}

fn agg_to_json(m: &MetricAgg) -> Json {
    Json::obj(vec![
        ("mean", m.mean.into()),
        ("min", m.min.into()),
        ("max", m.max.into()),
    ])
}

fn speedup_to_json(s: &Speedup) -> Json {
    let g = &s.group;
    let mut pairs = vec![
        ("variant", Json::from(g.key.variant.as_str())),
        ("workload", g.key.workload.as_str().into()),
        ("routing", g.key.routing.name().into()),
        ("policy", g.key.policy.name().into()),
        ("reps", u64::from(g.reps).into()),
        ("nodes", (g.nodes as u64).into()),
        ("services", (g.services as u64).into()),
        ("completed", g.completed.into()),
        ("failed", g.failed.into()),
        ("cold_starts", g.cold_starts.into()),
        ("inplace_scale_ups", g.inplace_scale_ups.into()),
        ("speculative_resizes", g.speculative_resizes.into()),
        ("mispredictions", g.mispredictions.into()),
        ("pods_created", g.pods_created.into()),
        ("mean_ms", agg_to_json(&g.mean_ms)),
        ("p50_ms", agg_to_json(&g.p50_ms)),
        ("p99_ms", agg_to_json(&g.p99_ms)),
        ("avg_committed_mcpu", agg_to_json(&g.avg_committed_mcpu)),
    ];
    // Fault-recovery counters only appear when the cell saw fault
    // activity — fault-free analyses stay byte-identical to pre-fault
    // emissions (the analysis schema version is unchanged; parsers
    // default absent counters to zero).
    if g.has_fault_counters() {
        pairs.extend([
            ("pods_unschedulable", Json::from(g.pods_unschedulable)),
            ("pods_evicted", g.pods_evicted.into()),
            ("pods_rescheduled", g.pods_rescheduled.into()),
            ("resize_failures", g.resize_failures.into()),
        ]);
    }
    // Undefined ratios are omitted, never NaN.
    if let Some(r) = s.mean_ratio {
        pairs.push(("speedup_mean", r.into()));
    }
    if let Some(r) = s.p99_ratio {
        pairs.push(("speedup_p99", r.into()));
    }
    Json::obj(pairs)
}

fn agg_from_json(j: &Json, path: &str) -> Result<MetricAgg, String> {
    Ok(MetricAgg {
        mean: j.req_f64("mean").map_err(|e| format!("{path}.mean: {e}"))?,
        min: j.req_f64("min").map_err(|e| format!("{path}.min: {e}"))?,
        max: j.req_f64("max").map_err(|e| format!("{path}.max: {e}"))?,
    })
}

fn speedup_from_json(j: &Json, path: &str) -> Result<Speedup, String> {
    let req_u64 = |k: &str| j.req_u64(k).map_err(|e| format!("{path}.{k}: {e}"));
    // Fault counters are optional (absent on fault-free cells).
    let opt_u64 = |k: &str| match j.get(k) {
        None => Ok(0u64),
        Some(_) => req_u64(k),
    };
    let req_str = |k: &str| {
        j.req_str(k)
            .map(str::to_string)
            .map_err(|e| format!("{path}.{k}: {e}"))
    };
    let agg = |k: &str| {
        agg_from_json(
            j.get(k).ok_or_else(|| format!("{path}.{k}: missing"))?,
            &format!("{path}.{k}"),
        )
    };
    let opt_ratio = |k: &str| -> Result<Option<f64>, String> {
        match j.get(k) {
            None => Ok(None),
            Some(v) => v
                .as_f64()
                .map(Some)
                .ok_or_else(|| format!("{path}.{k}: expected a number")),
        }
    };
    Ok(Speedup {
        group: Group {
            key: GroupKey {
                variant: req_str("variant")?,
                workload: req_str("workload")?,
                routing: req_str("routing")?
                    .parse()
                    .map_err(|e| format!("{path}.routing: {e}"))?,
                policy: req_str("policy")?
                    .parse()
                    .map_err(|e| format!("{path}.policy: {e}"))?,
            },
            reps: req_u64("reps")? as u32,
            nodes: req_u64("nodes")? as usize,
            services: req_u64("services")? as usize,
            completed: req_u64("completed")?,
            failed: req_u64("failed")?,
            cold_starts: req_u64("cold_starts")?,
            inplace_scale_ups: req_u64("inplace_scale_ups")?,
            speculative_resizes: req_u64("speculative_resizes")?,
            mispredictions: req_u64("mispredictions")?,
            pods_created: req_u64("pods_created")?,
            pods_unschedulable: opt_u64("pods_unschedulable")?,
            pods_evicted: opt_u64("pods_evicted")?,
            pods_rescheduled: opt_u64("pods_rescheduled")?,
            resize_failures: opt_u64("resize_failures")?,
            mean_ms: agg("mean_ms")?,
            p50_ms: agg("p50_ms")?,
            p99_ms: agg("p99_ms")?,
            avg_committed_mcpu: agg("avg_committed_mcpu")?,
        },
        mean_ratio: opt_ratio("speedup_mean")?,
        p99_ratio: opt_ratio("speedup_p99")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::stats::test_row as row;
    use crate::scenario::ScenarioRow;

    fn scenario_report(rows: Vec<ScenarioRow>) -> ScenarioReport {
        ScenarioReport {
            name: "t".into(),
            spec: Json::obj(vec![("name", "t".into())]),
            rows,
        }
    }

    fn analysis() -> AnalysisReport {
        AnalysisReport::from_scenario(
            &scenario_report(vec![
                row("", "mix", Policy::Cold, 0, 100.0, 10),
                row("", "mix", Policy::Warm, 0, 0.0, 0),
                row("", "mix", Policy::InPlace, 0, 10.0, 10),
            ]),
            Policy::Cold,
        )
    }

    #[test]
    fn from_scenario_computes_ratios_and_headline() {
        let a = analysis();
        assert_eq!(a.rows.len(), 3);
        assert_eq!(a.rows[0].mean_ratio, Some(1.0));
        assert_eq!(a.rows[1].mean_ratio, None); // zero completions → no NaN
        assert_eq!(a.rows[2].mean_ratio, Some(10.0));
        assert_eq!(a.headline(Policy::InPlace), Some((10.0, 10.0)));
        assert_eq!(a.headline(Policy::Warm), None);
    }

    #[test]
    fn json_round_trip_is_identity() {
        let a = analysis();
        let text = a.to_json().to_string_pretty();
        let back = AnalysisReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
        // The undefined warm ratio is omitted from the document.
        assert!(!text.contains("\"speedup_mean\": null"));
    }

    /// Fault counters round-trip when present and are omitted entirely on
    /// fault-free cells — old documents and old readers both keep working.
    #[test]
    fn fault_counters_round_trip_and_stay_optional() {
        let clean = analysis();
        let text = clean.to_json().to_string_pretty();
        assert!(!text.contains("pods_evicted"), "{text}");

        let mut r = row("", "mix", Policy::Cold, 0, 100.0, 10);
        r.pods_evicted = 4;
        r.pods_rescheduled = 3;
        r.pods_unschedulable = 1;
        r.resize_failures = 2;
        let a = AnalysisReport::from_scenario(&scenario_report(vec![r]), Policy::Cold);
        let text = a.to_json().to_string_pretty();
        assert!(text.contains("\"pods_evicted\": 4"), "{text}");
        let back = AnalysisReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
        assert_eq!(back.rows[0].group.pods_rescheduled, 3);
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        let good = analysis().to_json();
        assert!(AnalysisReport::validate(&good).is_ok());

        let mut m = good.as_obj().unwrap().clone();
        m.remove("baseline_policy");
        let e = AnalysisReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("baseline_policy"), "{e}");

        let mut m = good.as_obj().unwrap().clone();
        m.insert("extra".into(), Json::Null);
        let e = AnalysisReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("extra"), "{e}");

        let mut m = good.as_obj().unwrap().clone();
        m.insert("schema_version".into(), 9u64.into());
        let e = AnalysisReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("schema_version 9"), "{e}");

        let text = good.to_string_compact().replace("\"p99_ms\":", "\"p99_xx\":");
        let e = AnalysisReport::validate(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(e.contains("p99_ms"), "{e}");
    }

    #[test]
    fn save_writes_the_slugged_path() {
        let dir = std::env::temp_dir().join(format!("kinetic-ana-{}", std::process::id()));
        let path = analysis().save(&dir).unwrap();
        assert!(path.ends_with("analysis_t.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        AnalysisReport::validate(&Json::parse(&text).unwrap()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tables_render_from_the_report() {
        let a = analysis();
        let md = a.speedup_table().to_markdown();
        assert!(md.contains("× vs cold (mean)"), "{md}");
        assert!(md.contains("10.00×"), "{md}");
        assert!(md.contains("n/a"), "{md}");
        let agg = a.aggregate_table().to_ascii();
        assert!(agg.contains("least-loaded"), "{agg}");
    }
}
