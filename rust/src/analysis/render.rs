//! Table rendering for the analysis subsystem — every view goes through
//! [`util::table::Table`](crate::util::table::Table) so one builder feeds
//! the terminal (ASCII), the docs (markdown) and downstream plotting
//! (CSV).

use crate::analysis::compare::Comparison;
use crate::analysis::speedup::Speedup;
use crate::analysis::stats::{Group, MetricAgg};
use crate::policy::Policy;
use crate::util::table::{fmt_ms, fmt_ratio, Table};

/// Output format for `kinetic analyze` / `kinetic compare`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Ascii,
    Markdown,
    Csv,
}

impl std::str::FromStr for Format {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ascii" => Ok(Format::Ascii),
            "markdown" | "md" => Ok(Format::Markdown),
            "csv" => Ok(Format::Csv),
            other => Err(format!(
                "unknown format: {other} (expected markdown|ascii|csv)"
            )),
        }
    }
}

/// Renders one table in the chosen format.
pub fn render(t: &Table, format: Format) -> String {
    match format {
        Format::Ascii => t.to_ascii(),
        Format::Markdown => t.to_markdown(),
        Format::Csv => t.to_csv(),
    }
}

/// A latency cell: the cross-rep mean, with the min–max spread appended
/// when reps disagree (`12.34 [11.90, 12.80]`).
fn fmt_agg(m: &MetricAgg) -> String {
    if m.has_spread() {
        format!(
            "{} [{}, {}]",
            fmt_ms(m.mean),
            fmt_ms(m.min),
            fmt_ms(m.max)
        )
    } else {
        fmt_ms(m.mean)
    }
}

/// A ratio cell: paper-style two decimals with the `×` mark, `n/a` when
/// the ratio is undefined (zero completions on either side).
fn fmt_speedup(r: Option<f64>) -> String {
    match r {
        Some(r) => format!("{}×", fmt_ratio(r)),
        None => "n/a".to_string(),
    }
}

/// A delta-percent cell: explicit sign, one decimal, `n/a` when undefined.
fn fmt_pct(p: Option<f64>) -> String {
    match p {
        Some(p) => format!("{p:+.1}%"),
        None => "n/a".to_string(),
    }
}

fn has_variants(groups: &[Group]) -> bool {
    groups.iter().any(|g| !g.key.variant.is_empty())
}

/// The cross-rep aggregate view: one row per (variant, workload, routing,
/// policy) with counters summed and latency spreads. The speculation
/// columns (pre-resizes issued / windows missed — the predictive-inplace
/// hit-rate signal) appear exactly when a predictive policy is in the
/// comparison — keyed on the policy, not on observed counts, so a spec
/// always renders the same columns and §3-only reports render exactly as
/// before.
pub fn aggregate_table(name: &str, groups: &[Group]) -> Table {
    let swept = has_variants(groups);
    let multi_rep = groups.iter().any(|g| g.reps > 1);
    let speculative = groups.iter().any(|g| g.key.policy.predictive());
    // Groups carry no spec, so the fault columns key on observed fault
    // activity: any eviction/reschedule/unschedulable/resize-failure in
    // the comparison shows the recovery accounting for every cell.
    let faulty = groups.iter().any(Group::has_fault_counters);
    let mut headers = Vec::new();
    if swept {
        headers.push("Variant");
    }
    headers.extend(["Workload", "Routing", "Policy"]);
    if multi_rep {
        headers.push("Reps");
    }
    headers.extend([
        "Completed",
        "Failed",
        "Mean (ms)",
        "p50 (ms)",
        "p99 (ms)",
        "Cold",
    ]);
    if speculative {
        headers.extend(["Spec", "Miss"]);
    }
    if faulty {
        headers.extend(["Unsched", "Evict", "Resched", "RszFail"]);
    }
    headers.extend(["Committed (mCPU)", "Pods"]);
    let mut t = Table::new(headers).title(format!("Aggregate: {name}"));
    for g in groups {
        let mut cells = Vec::new();
        if swept {
            cells.push(g.key.variant.clone());
        }
        cells.extend([
            g.key.workload.clone(),
            g.key.routing.name().to_string(),
            g.key.policy.name().to_string(),
        ]);
        if multi_rep {
            cells.push(g.reps.to_string());
        }
        cells.extend([
            g.completed.to_string(),
            g.failed.to_string(),
            fmt_agg(&g.mean_ms),
            fmt_agg(&g.p50_ms),
            fmt_agg(&g.p99_ms),
            g.cold_starts.to_string(),
        ]);
        if speculative {
            cells.push(g.speculative_resizes.to_string());
            cells.push(g.mispredictions.to_string());
        }
        if faulty {
            cells.push(g.pods_unschedulable.to_string());
            cells.push(g.pods_evicted.to_string());
            cells.push(g.pods_rescheduled.to_string());
            cells.push(g.resize_failures.to_string());
        }
        cells.extend([
            format!("{:.0}", g.avg_committed_mcpu.mean),
            g.pods_created.to_string(),
        ]);
        t.row(cells);
    }
    t
}

/// The paper-style speedup view: mean/p99 latency per cell plus the ratio
/// columns against the baseline policy (Table 3's improvement column).
pub fn speedup_table(name: &str, baseline: Policy, speedups: &[Speedup]) -> Table {
    let groups: Vec<Group> = speedups.iter().map(|s| s.group.clone()).collect();
    let swept = has_variants(&groups);
    let mut headers = Vec::new();
    if swept {
        headers.push("Variant".to_string());
    }
    headers.extend([
        "Workload".to_string(),
        "Routing".to_string(),
        "Policy".to_string(),
        "Mean (ms)".to_string(),
        "p99 (ms)".to_string(),
        format!("× vs {} (mean)", baseline.name()),
        format!("× vs {} (p99)", baseline.name()),
    ]);
    let mut t = Table::new(headers).title(format!(
        "Speedup vs {} baseline: {name}",
        baseline.name()
    ));
    for s in speedups {
        let g = &s.group;
        let mut cells = Vec::new();
        if swept {
            cells.push(g.key.variant.clone());
        }
        cells.extend([
            g.key.workload.clone(),
            g.key.routing.name().to_string(),
            g.key.policy.name().to_string(),
            fmt_agg(&g.mean_ms),
            fmt_agg(&g.p99_ms),
            fmt_speedup(s.mean_ratio),
            fmt_speedup(s.p99_ratio),
        ]);
        t.row(cells);
    }
    t
}

/// The regression-diff view: matched cells with signed deltas and a
/// status column; `REGRESSED` rows are what the CI gate trips on.
pub fn compare_table(cmp: &Comparison) -> Table {
    let mut t = Table::new(vec![
        "Variant",
        "Workload",
        "Routing",
        "Policy",
        "Base mean",
        "New mean",
        "Δ mean",
        "Base p99",
        "New p99",
        "Δ p99",
        "Failed (base→new)",
        "Status",
    ])
    .title(format!(
        "Compare (regression threshold {:.1}%)",
        cmp.threshold_pct
    ));
    for d in &cmp.deltas {
        t.row(vec![
            d.key.variant.clone(),
            d.key.workload.clone(),
            d.key.routing.name().to_string(),
            d.key.policy.name().to_string(),
            fmt_ms(d.base_mean),
            fmt_ms(d.new_mean),
            fmt_pct(d.mean_pct),
            fmt_ms(d.base_p99),
            fmt_ms(d.new_p99),
            fmt_pct(d.p99_pct),
            format!("{}→{}", d.base_failed, d.new_failed),
            if d.regression { "REGRESSED" } else { "ok" }.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::compare::compare;
    use crate::analysis::speedup::against_baseline;
    use crate::analysis::stats::{aggregate, test_row as row};

    fn sample_groups() -> Vec<Group> {
        aggregate(&[
            row("", "mix", Policy::Cold, 0, 100.0, 10),
            row("", "mix", Policy::Cold, 1, 120.0, 10),
            row("", "mix", Policy::InPlace, 0, 10.0, 10),
            row("", "mix", Policy::InPlace, 1, 10.0, 10),
        ])
    }

    #[test]
    fn format_parses() {
        assert_eq!("markdown".parse::<Format>().unwrap(), Format::Markdown);
        assert_eq!("md".parse::<Format>().unwrap(), Format::Markdown);
        assert_eq!("ASCII".parse::<Format>().unwrap(), Format::Ascii);
        assert_eq!("csv".parse::<Format>().unwrap(), Format::Csv);
        assert!("html".parse::<Format>().is_err());
    }

    #[test]
    fn aggregate_table_shows_spread_only_when_reps_disagree() {
        let groups = sample_groups();
        let ascii = aggregate_table("t", &groups).to_ascii();
        // Cold's two reps disagree → spread cell; in-place's agree → plain.
        assert!(ascii.contains("110.00 [100.00, 120.00]"), "{ascii}");
        assert!(ascii.contains("Reps"), "{ascii}");
    }

    #[test]
    fn aggregate_table_grows_fault_columns_on_fault_activity() {
        let mut a = row("", "mix", Policy::Cold, 0, 100.0, 10);
        a.pods_evicted = 2;
        a.pods_rescheduled = 2;
        let b = row("", "mix", Policy::InPlace, 0, 10.0, 10);
        let groups = aggregate(&[a, b]);
        let ascii = aggregate_table("t", &groups).to_ascii();
        assert!(ascii.contains("Evict") && ascii.contains("Resched"), "{ascii}");
        // Fault-free comparisons render exactly the old columns.
        let quiet = aggregate_table("t", &sample_groups()).to_ascii();
        assert!(!quiet.contains("Evict"), "{quiet}");
    }

    #[test]
    fn speedup_table_carries_the_ratio_column() {
        let groups = sample_groups();
        let s = against_baseline(&groups, Policy::Cold);
        let md = render(&speedup_table("t", Policy::Cold, &s), Format::Markdown);
        assert!(md.contains("× vs cold (mean)"), "{md}");
        assert!(md.contains("1.00×"), "{md}");
        assert!(md.contains("11.00×"), "{md}"); // 110 / 10
        // CSV renders the same cells.
        let csv = render(&speedup_table("t", Policy::Cold, &s), Format::Csv);
        assert!(csv.contains("11.00×"), "{csv}");
    }

    #[test]
    fn compare_table_marks_regressions() {
        let base = sample_groups();
        let new = aggregate(&[
            row("", "mix", Policy::Cold, 0, 100.0, 10),
            row("", "mix", Policy::Cold, 1, 120.0, 10),
            row("", "mix", Policy::InPlace, 0, 20.0, 10),
            row("", "mix", Policy::InPlace, 1, 20.0, 10),
        ]);
        let cmp = compare(&base, &new, 10.0);
        let ascii = compare_table(&cmp).to_ascii();
        assert!(ascii.contains("REGRESSED"), "{ascii}");
        assert!(ascii.contains("+100.0%"), "{ascii}");
        assert!(ascii.contains("ok"), "{ascii}");
    }
}
