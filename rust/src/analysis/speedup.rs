//! Speedup ratios against a baseline policy — the derivation behind the
//! paper's headline "in-place improves cold-start latency by 1.16×–18.15×"
//! (Table 3's improvement column), generalized to any report.
//!
//! Within each (variant, workload, routing) cluster the baseline policy's
//! aggregated latency is the denominator reference: a row's ratio is
//! `baseline_mean / row_mean`, so >1 means faster than the baseline.
//! Ratios are `None` (rendered `n/a`, never NaN/∞) when either side has
//! zero completions or a zero latency.

use crate::analysis::stats::Group;
use crate::policy::Policy;

/// One aggregated cell plus its ratios against the baseline policy.
#[derive(Debug, Clone, PartialEq)]
pub struct Speedup {
    pub group: Group,
    /// `baseline.mean_ms / group.mean_ms` (>1 ⇒ faster than baseline).
    pub mean_ratio: Option<f64>,
    /// Same ratio on the aggregated p99.
    pub p99_ratio: Option<f64>,
}

/// Divides only when the result is meaningful: both sides saw completed
/// requests and the denominator is a real latency.
fn ratio(base: &Group, g: &Group, pick: impl Fn(&Group) -> f64) -> Option<f64> {
    if !base.has_latency() || !g.has_latency() {
        return None;
    }
    let (b, x) = (pick(base), pick(g));
    if b <= 0.0 || x <= 0.0 || !b.is_finite() || !x.is_finite() {
        return None;
    }
    Some(b / x)
}

/// Annotates every group with its ratio against the baseline policy of the
/// same (variant, workload, routing) cluster. Groups whose cluster has no
/// baseline entry (mismatched policy sets) get `None` ratios; order is
/// preserved.
pub fn against_baseline(groups: &[Group], baseline: Policy) -> Vec<Speedup> {
    groups
        .iter()
        .map(|g| {
            let base = groups.iter().find(|b| {
                b.key.policy == baseline
                    && b.key.variant == g.key.variant
                    && b.key.workload == g.key.workload
                    && b.key.routing == g.key.routing
            });
            match base {
                Some(base) => Speedup {
                    group: g.clone(),
                    mean_ratio: ratio(base, g, |x| x.mean_ms.mean),
                    p99_ratio: ratio(base, g, |x| x.p99_ms.mean),
                },
                None => Speedup {
                    group: g.clone(),
                    mean_ratio: None,
                    p99_ratio: None,
                },
            }
        })
        .collect()
}

/// The min/max mean-latency ratio a policy achieves across every cluster —
/// the "1.16×–18.15×" headline shape. `None` when the policy has no valid
/// ratio anywhere.
pub fn ratio_range(speedups: &[Speedup], policy: Policy) -> Option<(f64, f64)> {
    let mut range: Option<(f64, f64)> = None;
    for s in speedups {
        if s.group.key.policy != policy {
            continue;
        }
        if let Some(r) = s.mean_ratio {
            range = Some(match range {
                None => (r, r),
                Some((lo, hi)) => (lo.min(r), hi.max(r)),
            });
        }
    }
    range
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::stats::{aggregate, test_row as row};

    #[test]
    fn ratios_follow_the_paper_convention() {
        // cold 100 ms vs in-place 10 ms ⇒ in-place shows 10×, cold 1×.
        let groups = aggregate(&[
            row("", "mix", Policy::Cold, 0, 100.0, 10),
            row("", "mix", Policy::InPlace, 0, 10.0, 10),
        ]);
        let s = against_baseline(&groups, Policy::Cold);
        assert_eq!(s[0].group.key.policy, Policy::Cold);
        assert_eq!(s[0].mean_ratio, Some(1.0));
        assert_eq!(s[1].group.key.policy, Policy::InPlace);
        assert_eq!(s[1].mean_ratio, Some(10.0));
        assert_eq!(s[1].p99_ratio, Some(10.0)); // p99 = 2×mean in the fixture
    }

    #[test]
    fn zero_completion_rows_produce_no_ratio_not_nan() {
        let groups = aggregate(&[
            row("", "mix", Policy::Cold, 0, 0.0, 0),
            row("", "mix", Policy::InPlace, 0, 10.0, 10),
        ]);
        let s = against_baseline(&groups, Policy::Cold);
        assert_eq!(s[0].mean_ratio, None);
        assert_eq!(s[1].mean_ratio, None);
        // And the mirror case: the measured policy completed nothing.
        let groups = aggregate(&[
            row("", "mix", Policy::Cold, 0, 100.0, 10),
            row("", "mix", Policy::InPlace, 0, 0.0, 0),
        ]);
        let s = against_baseline(&groups, Policy::Cold);
        assert_eq!(s[0].mean_ratio, Some(1.0));
        assert_eq!(s[1].mean_ratio, None);
    }

    #[test]
    fn missing_baseline_cluster_yields_none() {
        // The in-place rows have no cold twin in their cluster.
        let groups = aggregate(&[row("", "mix", Policy::InPlace, 0, 10.0, 10)]);
        let s = against_baseline(&groups, Policy::Cold);
        assert_eq!(s[0].mean_ratio, None);
    }

    #[test]
    fn clusters_do_not_cross_variants_or_workloads() {
        let groups = aggregate(&[
            row("a=1", "mix", Policy::Cold, 0, 100.0, 10),
            row("a=1", "mix", Policy::InPlace, 0, 50.0, 10),
            row("a=2", "mix", Policy::Cold, 0, 40.0, 10),
            row("a=2", "mix", Policy::InPlace, 0, 10.0, 10),
        ]);
        let s = against_baseline(&groups, Policy::Cold);
        assert_eq!(s[1].mean_ratio, Some(2.0));
        assert_eq!(s[3].mean_ratio, Some(4.0));
        let (lo, hi) = ratio_range(&s, Policy::InPlace).unwrap();
        assert_eq!((lo, hi), (2.0, 4.0));
        assert_eq!(ratio_range(&s, Policy::Warm), None);
    }
}
