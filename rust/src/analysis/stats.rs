//! Cross-rep aggregation: collapses the per-run [`ScenarioRow`]s of a
//! report into one [`Group`] per (variant, workload, routing, policy),
//! carrying mean / min / max spread across reps for every latency metric.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::coordinator::accounting::RoutingPolicy;
use crate::policy::Policy;
use crate::scenario::report::ScenarioRow;
use crate::util::stats::Summary;

/// Everything that identifies an aggregated cell — a report row minus the
/// rep index.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub variant: String,
    pub workload: String,
    pub routing: RoutingPolicy,
    pub policy: Policy,
}

impl std::fmt::Display for GroupKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.variant.is_empty() {
            write!(f, "[{}] ", self.variant)?;
        }
        write!(
            f,
            "{}/{}/{}",
            self.workload,
            self.routing.name(),
            self.policy.name()
        )
    }
}

/// One metric aggregated across reps: the mean of the per-rep values plus
/// the min/max spread. With a single rep all three coincide.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricAgg {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
}

impl MetricAgg {
    fn from_summary(s: &Summary) -> MetricAgg {
        MetricAgg {
            mean: s.mean(),
            min: s.min(),
            max: s.max(),
        }
    }

    /// Does the spread carry information beyond the mean?
    pub fn has_spread(&self) -> bool {
        self.min != self.max
    }
}

/// One aggregated cell: counters summed, latency metrics averaged with
/// spread, `reps` recording how many rows folded in.
#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    pub key: GroupKey,
    pub reps: u32,
    pub nodes: usize,
    pub services: usize,
    pub completed: u64,
    pub failed: u64,
    pub cold_starts: u64,
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes (predictive-inplace).
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival (re-parked).
    pub mispredictions: u64,
    pub pods_created: u64,
    /// Scheduling attempts that found no feasible node (fault runs; zero
    /// on fault-free reports).
    pub pods_unschedulable: u64,
    /// Pods killed by injected node crashes.
    pub pods_evicted: u64,
    /// Replacement pods started by crash recovery.
    pub pods_rescheduled: u64,
    /// Resize patches rejected by injected API failures.
    pub resize_failures: u64,
    pub mean_ms: MetricAgg,
    pub p50_ms: MetricAgg,
    pub p99_ms: MetricAgg,
    pub avg_committed_mcpu: MetricAgg,
}

impl Group {
    /// A group with zero completions has no meaningful latency numbers —
    /// speedups against or from it must be suppressed, not NaN.
    pub fn has_latency(&self) -> bool {
        self.completed > 0
    }

    /// Any fault-recovery activity in this cell? Drives the conditional
    /// fault columns and the optional JSON fields.
    pub fn has_fault_counters(&self) -> bool {
        self.pods_unschedulable + self.pods_evicted + self.pods_rescheduled + self.resize_failures
            > 0
    }
}

/// Per-key accumulator while folding rows.
struct Acc {
    reps: u32,
    nodes: usize,
    services: usize,
    completed: u64,
    failed: u64,
    cold_starts: u64,
    inplace_scale_ups: u64,
    speculative_resizes: u64,
    mispredictions: u64,
    pods_created: u64,
    pods_unschedulable: u64,
    pods_evicted: u64,
    pods_rescheduled: u64,
    resize_failures: u64,
    mean_ms: Summary,
    p50_ms: Summary,
    p99_ms: Summary,
    avg_committed_mcpu: Summary,
}

impl Acc {
    fn new(r: &ScenarioRow) -> Acc {
        Acc {
            reps: 0,
            nodes: r.nodes,
            services: r.services,
            completed: 0,
            failed: 0,
            cold_starts: 0,
            inplace_scale_ups: 0,
            speculative_resizes: 0,
            mispredictions: 0,
            pods_created: 0,
            pods_unschedulable: 0,
            pods_evicted: 0,
            pods_rescheduled: 0,
            resize_failures: 0,
            mean_ms: Summary::new(),
            p50_ms: Summary::new(),
            p99_ms: Summary::new(),
            avg_committed_mcpu: Summary::new(),
        }
    }

    fn fold(&mut self, r: &ScenarioRow) {
        self.reps += 1;
        self.completed += r.completed;
        self.failed += r.failed;
        self.cold_starts += r.cold_starts;
        self.inplace_scale_ups += r.inplace_scale_ups;
        self.speculative_resizes += r.speculative_resizes;
        self.mispredictions += r.mispredictions;
        self.pods_created += r.pods_created;
        self.pods_unschedulable += r.pods_unschedulable;
        self.pods_evicted += r.pods_evicted;
        self.pods_rescheduled += r.pods_rescheduled;
        self.resize_failures += r.resize_failures;
        // Rows with zero completions report 0.0 latencies; folding those
        // zeros into the spread would fake a "min latency of 0 ms", so
        // latency metrics only aggregate over reps that completed work.
        if r.completed > 0 {
            self.mean_ms.record(r.mean_ms);
            self.p50_ms.record(r.p50_ms);
            self.p99_ms.record(r.p99_ms);
        }
        self.avg_committed_mcpu.record(r.avg_committed_mcpu);
    }

    fn finish(self, key: GroupKey) -> Group {
        Group {
            key,
            reps: self.reps,
            nodes: self.nodes,
            services: self.services,
            completed: self.completed,
            failed: self.failed,
            cold_starts: self.cold_starts,
            inplace_scale_ups: self.inplace_scale_ups,
            speculative_resizes: self.speculative_resizes,
            mispredictions: self.mispredictions,
            pods_created: self.pods_created,
            pods_unschedulable: self.pods_unschedulable,
            pods_evicted: self.pods_evicted,
            pods_rescheduled: self.pods_rescheduled,
            resize_failures: self.resize_failures,
            mean_ms: MetricAgg::from_summary(&self.mean_ms),
            p50_ms: MetricAgg::from_summary(&self.p50_ms),
            p99_ms: MetricAgg::from_summary(&self.p99_ms),
            avg_committed_mcpu: MetricAgg::from_summary(&self.avg_committed_mcpu),
        }
    }
}

/// Aggregates report rows across reps, preserving first-appearance order
/// of the keys (deterministic: report rows are already in grid order).
pub fn aggregate(rows: &[ScenarioRow]) -> Vec<Group> {
    let mut order: Vec<GroupKey> = Vec::new();
    let mut accs: HashMap<GroupKey, Acc> = HashMap::new();
    for r in rows {
        let key = GroupKey {
            variant: r.variant.clone(),
            workload: r.workload.clone(),
            routing: r.routing,
            policy: r.policy,
        };
        match accs.entry(key) {
            Entry::Occupied(mut e) => e.get_mut().fold(r),
            Entry::Vacant(e) => {
                order.push(e.key().clone());
                e.insert(Acc::new(r)).fold(r);
            }
        }
    }
    order
        .into_iter()
        .map(|key| {
            let acc = accs.remove(&key).expect("every ordered key has an acc");
            acc.finish(key)
        })
        .collect()
}

/// Shared fixture for the analysis test suites: one synthetic report row.
#[cfg(test)]
pub(crate) fn test_row(
    variant: &str,
    workload: &str,
    policy: Policy,
    rep: u32,
    mean: f64,
    completed: u64,
) -> ScenarioRow {
    ScenarioRow {
        scenario: "t".into(),
        variant: variant.into(),
        workload: workload.into(),
        rep,
        policy,
        routing: RoutingPolicy::LeastLoaded,
        nodes: 2,
        services: 4,
        completed,
        failed: 0,
        mean_ms: mean,
        p50_ms: mean * 0.9,
        p99_ms: mean * 2.0,
        cold_starts: 3,
        inplace_scale_ups: 1,
        speculative_resizes: 0,
        mispredictions: 0,
        avg_committed_mcpu: 100.0,
        pods_created: 4,
        pods_unschedulable: 0,
        pods_evicted: 0,
        pods_rescheduled: 0,
        resize_failures: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_row as row;

    #[test]
    fn single_rep_spread_collapses_to_the_value() {
        let groups = aggregate(&[row("", "mix", Policy::Cold, 0, 50.0, 10)]);
        assert_eq!(groups.len(), 1);
        let g = &groups[0];
        assert_eq!(g.reps, 1);
        assert_eq!(g.mean_ms.mean, 50.0);
        assert_eq!(g.mean_ms.min, 50.0);
        assert_eq!(g.mean_ms.max, 50.0);
        assert!(!g.mean_ms.has_spread());
        assert!(g.has_latency());
    }

    #[test]
    fn multi_rep_mean_and_spread() {
        let groups = aggregate(&[
            row("", "mix", Policy::Cold, 0, 40.0, 10),
            row("", "mix", Policy::Cold, 1, 60.0, 12),
            row("", "mix", Policy::Cold, 2, 50.0, 11),
        ]);
        assert_eq!(groups.len(), 1);
        let g = &groups[0];
        assert_eq!(g.reps, 3);
        assert_eq!(g.completed, 33);
        assert_eq!(g.cold_starts, 9);
        assert!((g.mean_ms.mean - 50.0).abs() < 1e-12);
        assert_eq!(g.mean_ms.min, 40.0);
        assert_eq!(g.mean_ms.max, 60.0);
        assert!(g.mean_ms.has_spread());
    }

    #[test]
    fn zero_completion_reps_do_not_poison_latency() {
        // A rep that completed nothing reports 0.0 ms; the aggregate must
        // not show "min 0 ms".
        let groups = aggregate(&[
            row("", "mix", Policy::Cold, 0, 50.0, 10),
            row("", "mix", Policy::Cold, 1, 0.0, 0),
        ]);
        let g = &groups[0];
        assert_eq!(g.reps, 2);
        assert_eq!(g.completed, 10);
        assert_eq!(g.mean_ms.mean, 50.0);
        assert_eq!(g.mean_ms.min, 50.0);
        // All reps empty ⇒ no latency at all, flagged via has_latency.
        let empty = aggregate(&[row("", "mix", Policy::Cold, 0, 0.0, 0)]);
        assert!(!empty[0].has_latency());
        assert_eq!(empty[0].mean_ms.mean, 0.0); // Summary::new() default, not NaN
        assert!(empty[0].mean_ms.mean.is_finite());
    }

    #[test]
    fn keys_keep_first_appearance_order() {
        let rows = vec![
            row("a=1", "mix", Policy::Cold, 0, 10.0, 1),
            row("a=1", "mix", Policy::InPlace, 0, 5.0, 1),
            row("a=2", "mix", Policy::Cold, 0, 20.0, 1),
            row("a=1", "mix", Policy::Cold, 1, 12.0, 1),
        ];
        let groups = aggregate(&rows);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].key.variant, "a=1");
        assert_eq!(groups[0].key.policy, Policy::Cold);
        assert_eq!(groups[0].reps, 2);
        assert_eq!(groups[1].key.policy, Policy::InPlace);
        assert_eq!(groups[2].key.variant, "a=2");
    }

    #[test]
    fn fault_counters_sum_across_reps() {
        let mut a = row("", "mix", Policy::Cold, 0, 50.0, 10);
        a.pods_evicted = 2;
        a.pods_rescheduled = 2;
        a.resize_failures = 1;
        let mut b = row("", "mix", Policy::Cold, 1, 55.0, 10);
        b.pods_evicted = 3;
        b.pods_unschedulable = 1;
        let groups = aggregate(&[a, b]);
        let g = &groups[0];
        assert_eq!(g.pods_evicted, 5);
        assert_eq!(g.pods_rescheduled, 2);
        assert_eq!(g.pods_unschedulable, 1);
        assert_eq!(g.resize_failures, 1);
        assert!(g.has_fault_counters());
        // A clean group reports none.
        let clean = aggregate(&[row("", "mix", Policy::Cold, 0, 50.0, 10)]);
        assert!(!clean[0].has_fault_counters());
    }

    #[test]
    fn key_display_names_the_cell() {
        let g = &aggregate(&[row("rate=2", "mix", Policy::InPlace, 0, 1.0, 1)])[0];
        let s = g.key.to_string();
        assert!(s.contains("rate=2") && s.contains("in-place"), "{s}");
    }
}
