//! Feature gates. The paper's mechanism is gated behind
//! `InPlacePodVerticalScaling` (alpha, Kubernetes 1.27); with the gate off,
//! resize patches are rejected exactly like a pre-1.27 cluster, forcing the
//! restart-based vertical scaling path the paper contrasts against.

use std::collections::BTreeMap;

/// Well-known gate names used by the platform.
pub const IN_PLACE_POD_VERTICAL_SCALING: &str = "InPlacePodVerticalScaling";

/// A set of named boolean feature gates.
#[derive(Debug, Clone, Default)]
pub struct FeatureGates {
    gates: BTreeMap<String, bool>,
}

impl FeatureGates {
    /// Kubernetes 1.27 defaults: the in-place gate exists but is *off*
    /// (alpha features default to disabled).
    pub fn v1_27() -> FeatureGates {
        let mut g = FeatureGates::default();
        g.set(IN_PLACE_POD_VERTICAL_SCALING, false);
        g
    }

    /// The paper's testbed: the gate explicitly enabled.
    pub fn paper_testbed() -> FeatureGates {
        let mut g = FeatureGates::v1_27();
        g.set(IN_PLACE_POD_VERTICAL_SCALING, true);
        g
    }

    pub fn set(&mut self, name: &str, enabled: bool) {
        self.gates.insert(name.to_string(), enabled);
    }

    pub fn enabled(&self, name: &str) -> bool {
        self.gates.get(name).copied().unwrap_or(false)
    }

    pub fn in_place_scaling(&self) -> bool {
        self.enabled(IN_PLACE_POD_VERTICAL_SCALING)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_gate_defaults_off() {
        let g = FeatureGates::v1_27();
        assert!(!g.in_place_scaling());
    }

    #[test]
    fn paper_testbed_enables_gate() {
        assert!(FeatureGates::paper_testbed().in_place_scaling());
    }

    #[test]
    fn unknown_gate_is_off() {
        let g = FeatureGates::default();
        assert!(!g.enabled("NoSuchGate"));
    }

    #[test]
    fn set_toggles() {
        let mut g = FeatureGates::v1_27();
        g.set(IN_PLACE_POD_VERTICAL_SCALING, true);
        assert!(g.in_place_scaling());
        g.set(IN_PLACE_POD_VERTICAL_SCALING, false);
        assert!(!g.in_place_scaling());
    }
}
