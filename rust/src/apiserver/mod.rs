//! The Kubernetes API-server substrate: feature gates, the in-place resize
//! patch endpoint, and a watch/event bus that controllers (autoscaler,
//! activator, kubelet sync driven by the coordinator) subscribe to.

pub mod gates;
pub mod patch;
pub mod watch;

pub use gates::FeatureGates;
pub use patch::{ApiError, ApiServer, ResizePatch};
pub use watch::{Event, EventBus, EventKind};
