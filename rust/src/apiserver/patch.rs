//! The API server: admission + the in-place resize patch endpoint.
//!
//! A [`ResizePatch`] is the `kubectl patch pod ... --subresource resize`
//! call the paper's modified queue-proxy dispatches around each request.
//! Admission validates the gate, the pod's phase, the resize policy and the
//! requested bounds, flips the pod's `status.resize` to `Proposed`, and
//! publishes a watch event for the kubelet sync loop (driven by the
//! coordinator) to act on.

use std::fmt;

use crate::apiserver::gates::FeatureGates;
use crate::apiserver::watch::{EventBus, EventKind};
use crate::cluster::container::ResizePolicy;
use crate::cluster::pod::{PodId, PodPhase, ResizeError};
use crate::cluster::Cluster;
use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;

/// Desired CPU limit change for a pod's main container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResizePatch {
    pub pod: PodId,
    pub new_cpu_limit: MilliCpu,
}

#[derive(Debug, PartialEq)]
pub enum ApiError {
    GateDisabled,
    NoSuchPod(PodId),
    NotRunning(PodId, PodPhase),
    RestartRequired,
    InvalidLimit(MilliCpu),
    Conflict(ResizeError),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::GateDisabled => {
                write!(f, "InPlacePodVerticalScaling feature gate is disabled")
            }
            ApiError::NoSuchPod(p) => write!(f, "no such pod {p:?}"),
            ApiError::NotRunning(p, phase) => {
                write!(f, "pod {p:?} is not running (phase {phase:?})")
            }
            ApiError::RestartRequired => write!(f, "container resize policy requires restart"),
            ApiError::InvalidLimit(l) => write!(f, "invalid cpu limit {l:?}"),
            ApiError::Conflict(e) => write!(f, "resize conflict: {e}"),
        }
    }
}

impl std::error::Error for ApiError {}

/// The API server.
#[derive(Debug, Default)]
pub struct ApiServer {
    pub gates: FeatureGates,
    pub bus: EventBus,
}

impl ApiServer {
    pub fn new(gates: FeatureGates) -> ApiServer {
        ApiServer {
            gates,
            bus: EventBus::default(),
        }
    }

    /// Admission + acceptance of a resize patch. On success the pod is in
    /// `Proposed` and a `ResizeProposed` event is on the bus; the caller
    /// (coordinator) schedules the kubelet sync that applies it.
    pub fn patch_resize(
        &mut self,
        cluster: &mut Cluster,
        patch: ResizePatch,
        now: SimTime,
    ) -> Result<(), ApiError> {
        if !self.gates.in_place_scaling() {
            return Err(ApiError::GateDisabled);
        }
        if patch.new_cpu_limit == MilliCpu::ZERO {
            return Err(ApiError::InvalidLimit(patch.new_cpu_limit));
        }
        let pod = cluster
            .pod_mut(patch.pod)
            .ok_or(ApiError::NoSuchPod(patch.pod))?;
        if pod.status.phase != PodPhase::Running {
            return Err(ApiError::NotRunning(patch.pod, pod.status.phase));
        }
        if pod.main_container().cpu_resize_policy == ResizePolicy::RestartContainer {
            return Err(ApiError::RestartRequired);
        }
        pod.status.begin_resize().map_err(ApiError::Conflict)?;
        // Desired state lands in the spec immediately (that is what the
        // patch writes); status catches up when the kubelet applies it.
        pod.main_container_mut().limits.cpu = patch.new_cpu_limit;
        self.bus
            .publish(now, EventKind::ResizeProposed(patch.pod, patch.new_cpu_limit));
        Ok(())
    }

    /// Marks a proposal in-progress (kubelet picked it up).
    pub fn mark_in_progress(
        &mut self,
        cluster: &mut Cluster,
        pod_id: PodId,
        limit: MilliCpu,
        now: SimTime,
    ) -> Result<(), ApiError> {
        let pod = cluster.pod_mut(pod_id).ok_or(ApiError::NoSuchPod(pod_id))?;
        pod.status.start_applying().map_err(ApiError::Conflict)?;
        self.bus.publish(now, EventKind::ResizeInProgress(pod_id, limit));
        Ok(())
    }

    /// Completes a resize: cgroup write landed on the node.
    pub fn mark_done(
        &mut self,
        cluster: &mut Cluster,
        pod_id: PodId,
        limit: MilliCpu,
        now: SimTime,
    ) -> Result<(), ApiError> {
        let pod = cluster.pod_mut(pod_id).ok_or(ApiError::NoSuchPod(pod_id))?;
        pod.status.finish_resize(limit).map_err(ApiError::Conflict)?;
        self.bus.publish(now, EventKind::ResizeDone(pod_id, limit));
        Ok(())
    }

    /// Rejects a proposal as infeasible on the node.
    pub fn mark_infeasible(
        &mut self,
        cluster: &mut Cluster,
        pod_id: PodId,
        limit: MilliCpu,
        now: SimTime,
    ) -> Result<(), ApiError> {
        let pod = cluster.pod_mut(pod_id).ok_or(ApiError::NoSuchPod(pod_id))?;
        pod.status.mark_infeasible().map_err(ApiError::Conflict)?;
        self.bus
            .publish(now, EventKind::ResizeInfeasible(pod_id, limit));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::{PodSpec, ResizeStatus};
    use crate::util::quantity::{Memory, Resources};

    fn setup() -> (ApiServer, Cluster, PodId) {
        let mut cluster = Cluster::new();
        let node = cluster.add_node("n0", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let pod = cluster.create_pod(PodSpec::single(
            "fn",
            "img",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        ));
        cluster.bind(pod, node).unwrap();
        cluster.pod_mut(pod).unwrap().status.phase = PodPhase::Running;
        (ApiServer::new(FeatureGates::paper_testbed()), cluster, pod)
    }

    #[test]
    fn gate_disabled_rejects_patch() {
        let (_, mut cluster, pod) = setup();
        let mut api = ApiServer::new(FeatureGates::v1_27());
        let err = api
            .patch_resize(
                &mut cluster,
                ResizePatch { pod, new_cpu_limit: MilliCpu(1) },
                SimTime::ZERO,
            )
            .unwrap_err();
        assert_eq!(err, ApiError::GateDisabled);
    }

    #[test]
    fn happy_path_full_cycle() {
        let (mut api, mut cluster, pod) = setup();
        api.patch_resize(
            &mut cluster,
            ResizePatch { pod, new_cpu_limit: MilliCpu(1) },
            SimTime::ZERO,
        )
        .unwrap();
        assert_eq!(
            cluster.pod(pod).unwrap().status.resize,
            Some(ResizeStatus::Proposed)
        );
        // Spec reflects desired state immediately; applied limit lags.
        assert_eq!(cluster.pod(pod).unwrap().main_container().limits.cpu, MilliCpu(1));
        assert_eq!(
            cluster.pod(pod).unwrap().status.applied_cpu_limit,
            MilliCpu(1000)
        );

        api.mark_in_progress(&mut cluster, pod, MilliCpu(1), SimTime::from_millis(10))
            .unwrap();
        api.mark_done(&mut cluster, pod, MilliCpu(1), SimTime::from_millis(60))
            .unwrap();
        let p = cluster.pod(pod).unwrap();
        assert_eq!(p.status.resize, None);
        assert_eq!(p.status.applied_cpu_limit, MilliCpu(1));

        // Bus saw the whole lifecycle.
        let (events, _) = api.bus.poll(crate::apiserver::watch::FRESH_CURSOR);
        let kinds: Vec<_> = events.iter().map(|e| &e.kind).collect();
        assert!(matches!(kinds[0], EventKind::ResizeProposed(_, _)));
        assert!(matches!(kinds[1], EventKind::ResizeInProgress(_, _)));
        assert!(matches!(kinds[2], EventKind::ResizeDone(_, _)));
    }

    #[test]
    fn not_running_pod_rejected() {
        let (mut api, mut cluster, pod) = setup();
        cluster.pod_mut(pod).unwrap().status.phase = PodPhase::Creating;
        let err = api
            .patch_resize(
                &mut cluster,
                ResizePatch { pod, new_cpu_limit: MilliCpu(1) },
                SimTime::ZERO,
            )
            .unwrap_err();
        assert!(matches!(err, ApiError::NotRunning(_, PodPhase::Creating)));
    }

    #[test]
    fn restart_policy_rejected() {
        let (mut api, mut cluster, pod) = setup();
        cluster.pod_mut(pod).unwrap().main_container_mut().cpu_resize_policy =
            ResizePolicy::RestartContainer;
        let err = api
            .patch_resize(
                &mut cluster,
                ResizePatch { pod, new_cpu_limit: MilliCpu(1) },
                SimTime::ZERO,
            )
            .unwrap_err();
        assert_eq!(err, ApiError::RestartRequired);
    }

    #[test]
    fn zero_limit_invalid() {
        let (mut api, mut cluster, pod) = setup();
        let err = api
            .patch_resize(
                &mut cluster,
                ResizePatch { pod, new_cpu_limit: MilliCpu(0) },
                SimTime::ZERO,
            )
            .unwrap_err();
        assert_eq!(err, ApiError::InvalidLimit(MilliCpu(0)));
    }

    #[test]
    fn concurrent_patch_conflicts() {
        let (mut api, mut cluster, pod) = setup();
        api.patch_resize(
            &mut cluster,
            ResizePatch { pod, new_cpu_limit: MilliCpu(1) },
            SimTime::ZERO,
        )
        .unwrap();
        let err = api
            .patch_resize(
                &mut cluster,
                ResizePatch { pod, new_cpu_limit: MilliCpu(500) },
                SimTime::ZERO,
            )
            .unwrap_err();
        assert!(matches!(err, ApiError::Conflict(ResizeError::Busy)));
    }

    #[test]
    fn infeasible_marks_status() {
        let (mut api, mut cluster, pod) = setup();
        api.patch_resize(
            &mut cluster,
            ResizePatch { pod, new_cpu_limit: MilliCpu(6000) },
            SimTime::ZERO,
        )
        .unwrap();
        api.mark_infeasible(&mut cluster, pod, MilliCpu(6000), SimTime::ZERO)
            .unwrap();
        assert_eq!(
            cluster.pod(pod).unwrap().status.resize,
            Some(ResizeStatus::Infeasible)
        );
    }
}
