//! Watch/event bus: a bounded, typed event log controllers poll, mirroring
//! the k8s watch protocol's at-least-once delivery with resourceVersion
//! cursors (simplified to a monotonically increasing sequence).

use std::collections::VecDeque;

use crate::cluster::pod::PodId;
use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;

/// Cursor for a consumer that wants the full retained log. Sequence numbers
/// are 1-based; `poll(FRESH_CURSOR)` returns everything retained.
pub const FRESH_CURSOR: u64 = 0;

/// What happened.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    PodCreated(PodId),
    PodScheduled(PodId),
    PodReady(PodId),
    PodTerminating(PodId),
    PodDeleted(PodId),
    /// Resize patch accepted (desired limit).
    ResizeProposed(PodId, MilliCpu),
    /// Kubelet began applying.
    ResizeInProgress(PodId, MilliCpu),
    /// cgroup write landed; limit in force.
    ResizeDone(PodId, MilliCpu),
    ResizeInfeasible(PodId, MilliCpu),
}

/// A sequenced event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Monotonic sequence number ("resourceVersion"), 1-based.
    pub seq: u64,
    pub at: SimTime,
    pub kind: EventKind,
}

/// Bounded event log with cursor-based consumption.
#[derive(Debug)]
pub struct EventBus {
    log: VecDeque<Event>,
    next_seq: u64,
    capacity: usize,
}

impl Default for EventBus {
    fn default() -> Self {
        EventBus::new(65_536)
    }
}

impl EventBus {
    pub fn new(capacity: usize) -> EventBus {
        EventBus {
            log: VecDeque::new(),
            next_seq: 1,
            capacity: capacity.max(1),
        }
    }

    /// Appends an event; evicts the oldest beyond capacity.
    pub fn publish(&mut self, at: SimTime, kind: EventKind) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.log.push_back(Event { seq, at, kind });
        if self.log.len() > self.capacity {
            self.log.pop_front();
        }
        seq
    }

    /// Events after cursor `since` (exclusive). Returns `(events, cursor)`;
    /// pass the returned cursor to the next poll. If the cursor fell off the
    /// retained window the consumer simply gets everything retained (k8s
    /// would force a relist; our controllers are level-based and tolerate
    /// at-least-once delivery).
    pub fn poll(&self, since: u64) -> (Vec<Event>, u64) {
        let events: Vec<Event> = self
            .log
            .iter()
            .filter(|e| e.seq > since)
            .cloned()
            .collect();
        let cursor = events.last().map(|e| e.seq).unwrap_or(since);
        (events, cursor)
    }

    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    /// Latest sequence number issued (0 when nothing published yet).
    pub fn head(&self) -> u64 {
        self.next_seq - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_and_poll_with_cursor() {
        let mut bus = EventBus::default();
        bus.publish(SimTime::ZERO, EventKind::PodCreated(PodId(1)));
        bus.publish(SimTime::from_millis(1), EventKind::PodReady(PodId(1)));

        let (events, cursor) = bus.poll(FRESH_CURSOR);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, EventKind::PodCreated(PodId(1)));
        assert_eq!(cursor, 2);

        // Nothing new.
        let (events, cursor2) = bus.poll(cursor);
        assert!(events.is_empty());
        assert_eq!(cursor2, cursor);

        // New event appears after the cursor.
        bus.publish(SimTime::from_millis(2), EventKind::PodDeleted(PodId(1)));
        let (events, _) = bus.poll(cursor);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, EventKind::PodDeleted(PodId(1)));
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut bus = EventBus::new(2);
        for i in 0..5u64 {
            bus.publish(SimTime::ZERO, EventKind::PodCreated(PodId(i)));
        }
        assert_eq!(bus.len(), 2);
        let (events, _) = bus.poll(FRESH_CURSOR);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, EventKind::PodCreated(PodId(3)));
    }

    #[test]
    fn sequences_monotonic_and_head_tracks() {
        let mut bus = EventBus::default();
        assert_eq!(bus.head(), 0);
        let a = bus.publish(SimTime::ZERO, EventKind::PodCreated(PodId(0)));
        let b = bus.publish(SimTime::ZERO, EventKind::PodDeleted(PodId(0)));
        assert!(b > a);
        assert_eq!(bus.head(), b);
    }

    #[test]
    fn stale_cursor_degrades_to_retained_window() {
        let mut bus = EventBus::new(3);
        for i in 0..10u64 {
            bus.publish(SimTime::ZERO, EventKind::PodCreated(PodId(i)));
        }
        // Cursor 1 is long evicted; consumer gets the retained 3.
        let (events, _) = bus.poll(1);
        assert_eq!(events.len(), 3);
    }
}
