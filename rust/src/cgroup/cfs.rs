//! Completely Fair Scheduler arbitration.
//!
//! Converts per-container (weight, limit) pairs plus node capacity into
//! *effective CPU rates* — the quantity that stretches request runtimes in
//! the simulation. Implements the §2 semantics the paper describes: CPU
//! requests become proportional shares under contention ("100m vs 50m →
//! two-thirds / one-third"), while `cpu.max` caps what any container may use
//! regardless of idle capacity.
//!
//! The algorithm is weighted water-filling: repeatedly distribute remaining
//! capacity proportionally to weights, freeze entities that hit their cap or
//! their demand, and redistribute the surplus.

use crate::util::quantity::MilliCpu;

/// One runnable entity (container / stressor) from the arbiter's view.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CfsShare {
    /// `cpu.weight`-style proportional share (from the CPU request).
    pub weight: u64,
    /// Bandwidth cap from `cpu.max`; `None` = unlimited.
    pub limit: Option<MilliCpu>,
    /// How much CPU the entity would consume if unconstrained.
    pub demand: MilliCpu,
}

impl CfsShare {
    pub fn new(weight: u64, limit: Option<MilliCpu>, demand: MilliCpu) -> CfsShare {
        CfsShare {
            weight: weight.max(1),
            limit,
            demand,
        }
    }

    /// A fully cpu-hungry entity (demand = node capacity).
    pub fn hungry(weight: u64, limit: Option<MilliCpu>) -> CfsShare {
        CfsShare::new(weight, limit, MilliCpu(u64::MAX / 2))
    }

    fn effective_cap(&self) -> f64 {
        let lim = self.limit.map(|l| l.0).unwrap_or(u64::MAX / 2);
        lim.min(self.demand.0) as f64
    }
}

/// Weighted water-filling CPU arbiter for a single node.
#[derive(Debug, Clone)]
pub struct CfsArbiter {
    capacity: MilliCpu,
}

impl CfsArbiter {
    pub fn new(capacity: MilliCpu) -> CfsArbiter {
        CfsArbiter { capacity }
    }

    pub fn capacity(&self) -> MilliCpu {
        self.capacity
    }

    /// Computes the effective rate (milliCPU) granted to each entity.
    ///
    /// Invariants (property-tested in `rust/tests/prop_invariants.rs`):
    /// * rate_i ≤ min(limit_i, demand_i)
    /// * Σ rate_i ≤ capacity
    /// * work-conserving: if Σ min(limit,demand) ≥ capacity then
    ///   Σ rate_i == capacity (up to rounding)
    /// * under pure contention rates are proportional to weights.
    pub fn allocate(&self, entities: &[CfsShare]) -> Vec<MilliCpu> {
        let n = entities.len();
        if n == 0 {
            return Vec::new();
        }
        let mut rate = vec![0.0f64; n];
        let mut frozen = vec![false; n];
        let mut remaining = self.capacity.0 as f64;

        // Water-filling: at most n rounds (≥1 entity freezes per round).
        for _ in 0..n {
            if remaining <= 0.5 {
                break;
            }
            let active_weight: f64 = entities
                .iter()
                .zip(&frozen)
                .filter(|(_, &f)| !f)
                .map(|(e, _)| e.weight as f64)
                .sum();
            if active_weight == 0.0 {
                break;
            }
            let mut any_frozen = false;
            let mut consumed = 0.0;
            for i in 0..n {
                if frozen[i] {
                    continue;
                }
                let fair = remaining * entities[i].weight as f64 / active_weight;
                let cap = entities[i].effective_cap();
                let head = cap - rate[i];
                if fair >= head {
                    // Entity satisfied: freeze at its cap.
                    consumed += head;
                    rate[i] = cap;
                    frozen[i] = true;
                    any_frozen = true;
                } else {
                    rate[i] += fair;
                    consumed += fair;
                }
            }
            remaining -= consumed;
            if !any_frozen {
                break; // all proportional shares fit under caps — done
            }
        }

        rate.into_iter().map(|r| MilliCpu(r.round() as u64)).collect()
    }

    /// Convenience: the rate a single container gets given background load
    /// expressed as (weight, used) aggregates.
    pub fn rate_for(
        &self,
        target: CfsShare,
        background: &[CfsShare],
    ) -> MilliCpu {
        let mut all = Vec::with_capacity(background.len() + 1);
        all.push(target);
        all.extend_from_slice(background);
        self.allocate(&all)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(v: u64) -> MilliCpu {
        MilliCpu(v)
    }

    #[test]
    fn paper_example_two_thirds_one_third() {
        // §2: requests 100m and 50m under full contention → 2/3 vs 1/3.
        let arb = CfsArbiter::new(m(3000));
        let rates = arb.allocate(&[
            CfsShare::hungry(100, None),
            CfsShare::hungry(50, None),
        ]);
        assert_eq!(rates[0], m(2000));
        assert_eq!(rates[1], m(1000));
    }

    #[test]
    fn limits_cap_rates() {
        let arb = CfsArbiter::new(m(8000));
        let rates = arb.allocate(&[
            CfsShare::hungry(100, Some(m(1000))),
            CfsShare::hungry(100, Some(m(1))),
        ]);
        assert_eq!(rates[0], m(1000));
        assert_eq!(rates[1], m(1));
    }

    #[test]
    fn surplus_redistributes_to_uncapped() {
        let arb = CfsArbiter::new(m(4000));
        let rates = arb.allocate(&[
            CfsShare::hungry(100, Some(m(500))), // capped low
            CfsShare::hungry(100, None),         // picks up the slack
        ]);
        assert_eq!(rates[0], m(500));
        assert_eq!(rates[1], m(3500));
    }

    #[test]
    fn demand_limits_allocation() {
        let arb = CfsArbiter::new(m(4000));
        let rates = arb.allocate(&[
            CfsShare::new(100, None, m(300)), // only wants 300m
            CfsShare::hungry(100, None),
        ]);
        assert_eq!(rates[0], m(300));
        assert_eq!(rates[1], m(3700));
    }

    #[test]
    fn idle_node_grants_full_demand() {
        let arb = CfsArbiter::new(m(8000));
        let rates = arb.allocate(&[CfsShare::new(100, Some(m(1000)), m(1000))]);
        assert_eq!(rates[0], m(1000));
    }

    #[test]
    fn work_conserving_under_contention() {
        let arb = CfsArbiter::new(m(8000));
        let rates = arb.allocate(&[
            CfsShare::hungry(100, None),
            CfsShare::hungry(200, None),
            CfsShare::hungry(300, None),
        ]);
        let total: u64 = rates.iter().map(|r| r.0).sum();
        assert!((total as i64 - 8000).abs() <= 2, "total={total}");
        // proportional to weights
        assert!(rates[2] > rates[1] && rates[1] > rates[0]);
    }

    #[test]
    fn empty_and_zero_cases() {
        let arb = CfsArbiter::new(m(1000));
        assert!(arb.allocate(&[]).is_empty());
        let rates = arb.allocate(&[CfsShare::new(100, Some(m(0)), m(1000))]);
        assert_eq!(rates[0], m(0));
    }

    #[test]
    fn rate_for_with_background() {
        let arb = CfsArbiter::new(m(8000));
        // Container limited to 1000m, stressor eating everything else.
        let r = arb.rate_for(
            CfsShare::hungry(100, Some(m(1000))),
            &[CfsShare::hungry(100, None)],
        );
        // Fair share is 4000m > cap → container still gets its full 1000m.
        assert_eq!(r, m(1000));

        // Parked at 1m against a stressor: gets only 1m.
        let r = arb.rate_for(
            CfsShare::hungry(100, Some(m(1))),
            &[CfsShare::hungry(100, None)],
        );
        assert_eq!(r, m(1));
    }

    #[test]
    fn weights_respected_under_caps_mix() {
        let arb = CfsArbiter::new(m(2000));
        let rates = arb.allocate(&[
            CfsShare::hungry(300, None),
            CfsShare::hungry(100, Some(m(100))),
        ]);
        assert_eq!(rates[1], m(100));
        assert_eq!(rates[0], m(1900));
    }
}
