//! A cgroups-v2 hierarchy with the `cpu` controller files the paper's
//! experiment observes: `cpu.max` (bandwidth limit) and `cpu.weight`.
//!
//! The §4.1 experiment measures "from the time the patch request was
//! dispatched to the point when specified changes were detected within the
//! `cpu.max` file" — so this model keeps a per-file *generation* counter that
//! watchers (the in-container observer, the CFS arbiter) use to detect
//! changes, and records the virtual time of the last write.

use std::collections::HashMap;
use std::fmt;

use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;

/// Identifies a cgroup within a [`CgroupFs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CgroupId(pub u32);

/// cgroups-v2 `cpu.max`: `$MAX $PERIOD` or `max $PERIOD`.
///
/// Kubernetes translates a CPU *limit* of `m` milliCPU into
/// `quota = m * period / 1000` microseconds per period.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuMax {
    /// Quota in microseconds per period; `None` = `max` (unlimited).
    pub quota_us: Option<u64>,
    /// Period in microseconds (Kubernetes default: 100ms).
    pub period_us: u64,
}

pub const DEFAULT_PERIOD_US: u64 = 100_000;

impl CpuMax {
    pub fn unlimited() -> CpuMax {
        CpuMax {
            quota_us: None,
            period_us: DEFAULT_PERIOD_US,
        }
    }

    /// Limit expressed as milliCPU, the k8s convention.
    pub fn from_millicpu(m: MilliCpu) -> CpuMax {
        CpuMax {
            quota_us: Some(m.0 * DEFAULT_PERIOD_US / 1000),
            period_us: DEFAULT_PERIOD_US,
        }
    }

    /// Effective limit in milliCPU (`None` → unlimited).
    pub fn as_millicpu(&self) -> Option<MilliCpu> {
        self.quota_us
            .map(|q| MilliCpu(q * 1000 / self.period_us))
    }

    /// Renders the file content, e.g. `"100000 100000"` or `"max 100000"`.
    pub fn file_content(&self) -> String {
        match self.quota_us {
            Some(q) => format!("{q} {}", self.period_us),
            None => format!("max {}", self.period_us),
        }
    }

    /// Parses file content (the reverse of [`CpuMax::file_content`]).
    pub fn parse(s: &str) -> Result<CpuMax, CgroupError> {
        let mut it = s.split_whitespace();
        let quota = it.next().ok_or(CgroupError::BadCpuMax(s.to_string()))?;
        let period = it
            .next()
            .unwrap_or("100000")
            .parse::<u64>()
            .map_err(|_| CgroupError::BadCpuMax(s.to_string()))?;
        let quota_us = if quota == "max" {
            None
        } else {
            Some(
                quota
                    .parse::<u64>()
                    .map_err(|_| CgroupError::BadCpuMax(s.to_string()))?,
            )
        };
        if period == 0 {
            return Err(CgroupError::BadCpuMax(s.to_string()));
        }
        Ok(CpuMax { quota_us, period_us: period })
    }
}

#[derive(Debug, PartialEq)]
pub enum CgroupError {
    NotFound(CgroupId),
    PathNotFound(String),
    HasChildren(CgroupId),
    BadCpuMax(String),
    BadWeight(u64),
}

impl fmt::Display for CgroupError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CgroupError::NotFound(id) => write!(f, "no such cgroup: {id:?}"),
            CgroupError::PathNotFound(p) => write!(f, "no such cgroup path: {p}"),
            CgroupError::HasChildren(id) => write!(f, "cgroup has children: {id:?}"),
            CgroupError::BadCpuMax(s) => write!(f, "invalid cpu.max content: {s}"),
            CgroupError::BadWeight(w) => write!(f, "invalid cpu.weight: {w}"),
        }
    }
}

impl std::error::Error for CgroupError {}

/// One cgroup node.
#[derive(Debug, Clone)]
pub struct Cgroup {
    pub id: CgroupId,
    pub parent: Option<CgroupId>,
    pub name: String,
    pub cpu_max: CpuMax,
    /// cgroups-v2 `cpu.weight` (1..=10000, default 100). Kubernetes derives
    /// it from the CPU *request*.
    pub weight: u64,
    /// Bumped on every `cpu.max` write; watchers compare generations.
    pub generation: u64,
    /// Virtual time of the last `cpu.max` write.
    pub last_write: SimTime,
    alive: bool,
}

/// The cgroup filesystem for one node.
#[derive(Debug, Default)]
pub struct CgroupFs {
    groups: Vec<Cgroup>,
    by_path: HashMap<String, CgroupId>,
}

impl CgroupFs {
    pub fn new() -> CgroupFs {
        let mut fs = CgroupFs {
            groups: Vec::new(),
            by_path: HashMap::new(),
        };
        // The root cgroup always exists.
        fs.create_internal(None, "");
        fs
    }

    pub fn root(&self) -> CgroupId {
        CgroupId(0)
    }

    fn create_internal(&mut self, parent: Option<CgroupId>, name: &str) -> CgroupId {
        let id = CgroupId(self.groups.len() as u32);
        let path = match parent {
            Some(p) => format!("{}/{}", self.path_of(p), name),
            None => String::new(),
        };
        self.groups.push(Cgroup {
            id,
            parent,
            name: name.to_string(),
            cpu_max: CpuMax::unlimited(),
            weight: 100,
            generation: 0,
            last_write: SimTime::ZERO,
            alive: true,
        });
        self.by_path.insert(path, id);
        id
    }

    /// Creates a child cgroup (mkdir).
    pub fn create(&mut self, parent: CgroupId, name: &str) -> Result<CgroupId, CgroupError> {
        self.get(parent)?;
        Ok(self.create_internal(Some(parent), name))
    }

    /// Removes a leaf cgroup (rmdir).
    pub fn remove(&mut self, id: CgroupId) -> Result<(), CgroupError> {
        self.get(id)?;
        if self
            .groups
            .iter()
            .any(|g| g.alive && g.parent == Some(id))
        {
            return Err(CgroupError::HasChildren(id));
        }
        let path = self.path_of(id);
        self.by_path.remove(&path);
        self.groups[id.0 as usize].alive = false;
        Ok(())
    }

    pub fn get(&self, id: CgroupId) -> Result<&Cgroup, CgroupError> {
        self.groups
            .get(id.0 as usize)
            .filter(|g| g.alive)
            .ok_or(CgroupError::NotFound(id))
    }

    pub fn lookup(&self, path: &str) -> Result<CgroupId, CgroupError> {
        self.by_path
            .get(path)
            .copied()
            .ok_or_else(|| CgroupError::PathNotFound(path.to_string()))
    }

    pub fn path_of(&self, id: CgroupId) -> String {
        let g = &self.groups[id.0 as usize];
        match g.parent {
            Some(p) => format!("{}/{}", self.path_of(p), g.name),
            None => String::new(),
        }
    }

    /// Writes `cpu.max` — the operation whose end-to-end latency the paper
    /// measures. `now` stamps the change for watchers.
    pub fn write_cpu_max(
        &mut self,
        id: CgroupId,
        value: CpuMax,
        now: SimTime,
    ) -> Result<(), CgroupError> {
        self.get(id)?;
        let g = &mut self.groups[id.0 as usize];
        g.cpu_max = value;
        g.generation += 1;
        g.last_write = now;
        Ok(())
    }

    /// Writes `cpu.weight` (derived from the CPU request).
    pub fn write_weight(&mut self, id: CgroupId, weight: u64) -> Result<(), CgroupError> {
        if !(1..=10_000).contains(&weight) {
            return Err(CgroupError::BadWeight(weight));
        }
        self.get(id)?;
        self.groups[id.0 as usize].weight = weight;
        Ok(())
    }

    /// Reads the current `cpu.max` content as the in-container watcher would.
    pub fn read_cpu_max(&self, id: CgroupId) -> Result<String, CgroupError> {
        Ok(self.get(id)?.cpu_max.file_content())
    }

    /// Effective CPU limit of a cgroup: the minimum along its ancestor chain
    /// (cgroups-v2 semantics: a child can never exceed its parent).
    pub fn effective_limit(&self, id: CgroupId) -> Result<Option<MilliCpu>, CgroupError> {
        let mut cur = Some(id);
        let mut limit: Option<MilliCpu> = None;
        while let Some(c) = cur {
            let g = self.get(c)?;
            if let Some(m) = g.cpu_max.as_millicpu() {
                limit = Some(match limit {
                    Some(l) => l.min(m),
                    None => m,
                });
            }
            cur = g.parent;
        }
        Ok(limit)
    }

    /// All live descendants of `id` (for accounting / arbiter scans).
    pub fn descendants(&self, id: CgroupId) -> Vec<CgroupId> {
        let mut out = Vec::new();
        let mut stack = vec![id];
        while let Some(top) = stack.pop() {
            for g in &self.groups {
                if g.alive && g.parent == Some(top) {
                    stack.push(g.id);
                    out.push(g.id);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_max_millicpu_round_trip() {
        let m = CpuMax::from_millicpu(MilliCpu(100));
        assert_eq!(m.quota_us, Some(10_000));
        assert_eq!(m.as_millicpu(), Some(MilliCpu(100)));
        assert_eq!(m.file_content(), "10000 100000");
        assert_eq!(CpuMax::parse("10000 100000").unwrap(), m);
    }

    #[test]
    fn cpu_max_unlimited() {
        let m = CpuMax::unlimited();
        assert_eq!(m.as_millicpu(), None);
        assert_eq!(m.file_content(), "max 100000");
        assert_eq!(CpuMax::parse("max 100000").unwrap(), m);
    }

    #[test]
    fn cpu_max_parse_errors() {
        assert!(CpuMax::parse("").is_err());
        assert!(CpuMax::parse("abc 100000").is_err());
        assert!(CpuMax::parse("1000 xyz").is_err());
        assert!(CpuMax::parse("1000 0").is_err());
    }

    #[test]
    fn hierarchy_paths() {
        let mut fs = CgroupFs::new();
        let kubepods = fs.create(fs.root(), "kubepods").unwrap();
        let pod = fs.create(kubepods, "pod-abc").unwrap();
        let ctr = fs.create(pod, "ctr-1").unwrap();
        assert_eq!(fs.path_of(ctr), "/kubepods/pod-abc/ctr-1");
        assert_eq!(fs.lookup("/kubepods/pod-abc/ctr-1").unwrap(), ctr);
        assert!(fs.lookup("/nope").is_err());
    }

    #[test]
    fn write_bumps_generation_and_time() {
        let mut fs = CgroupFs::new();
        let g = fs.create(fs.root(), "pod").unwrap();
        assert_eq!(fs.get(g).unwrap().generation, 0);
        fs.write_cpu_max(g, CpuMax::from_millicpu(MilliCpu(1000)), SimTime::from_millis(7))
            .unwrap();
        let c = fs.get(g).unwrap();
        assert_eq!(c.generation, 1);
        assert_eq!(c.last_write, SimTime::from_millis(7));
        assert_eq!(fs.read_cpu_max(g).unwrap(), "100000 100000");
    }

    #[test]
    fn effective_limit_takes_ancestor_min() {
        let mut fs = CgroupFs::new();
        let pod = fs.create(fs.root(), "pod").unwrap();
        let ctr = fs.create(pod, "ctr").unwrap();
        fs.write_cpu_max(pod, CpuMax::from_millicpu(MilliCpu(500)), SimTime::ZERO)
            .unwrap();
        fs.write_cpu_max(ctr, CpuMax::from_millicpu(MilliCpu(2000)), SimTime::ZERO)
            .unwrap();
        assert_eq!(fs.effective_limit(ctr).unwrap(), Some(MilliCpu(500)));
        // Unlimited child under limited parent.
        fs.write_cpu_max(ctr, CpuMax::unlimited(), SimTime::ZERO).unwrap();
        assert_eq!(fs.effective_limit(ctr).unwrap(), Some(MilliCpu(500)));
    }

    #[test]
    fn remove_rules() {
        let mut fs = CgroupFs::new();
        let pod = fs.create(fs.root(), "pod").unwrap();
        let ctr = fs.create(pod, "ctr").unwrap();
        assert_eq!(fs.remove(pod), Err(CgroupError::HasChildren(pod)));
        fs.remove(ctr).unwrap();
        fs.remove(pod).unwrap();
        assert!(fs.get(pod).is_err());
        assert!(fs.lookup("/pod").is_err());
    }

    #[test]
    fn weight_validation() {
        let mut fs = CgroupFs::new();
        let g = fs.create(fs.root(), "x").unwrap();
        assert!(fs.write_weight(g, 0).is_err());
        assert!(fs.write_weight(g, 10_001).is_err());
        fs.write_weight(g, 79).unwrap();
        assert_eq!(fs.get(g).unwrap().weight, 79);
    }

    #[test]
    fn descendants_enumerates_subtree() {
        let mut fs = CgroupFs::new();
        let a = fs.create(fs.root(), "a").unwrap();
        let b = fs.create(a, "b").unwrap();
        let c = fs.create(a, "c").unwrap();
        let d = fs.create(b, "d").unwrap();
        let mut ds = fs.descendants(a);
        ds.sort();
        assert_eq!(ds, vec![b, c, d]);
    }
}
