//! The in-place resize latency model, calibrated against the paper's §4.1.
//!
//! The paper measures "from the time the patch request was dispatched to the
//! point when specified changes were detected within the `cpu.max` file",
//! with the watcher running *inside the resized container*. The observed
//! phenomenology:
//!
//! * **Fig 4a** — scaling *up* to 1000m while idle is flat:
//!   56.44 ms ± 8.53 regardless of the starting allocation.
//! * **Fig 2a/2b** — scaling up under a CPU stressor inflates the first two
//!   intervals dramatically (6.06× at 1m→100m, 2.88× at 100m→200m) and
//!   fades for larger targets.
//! * **Fig 3a/3b** — with 1000m steps, all workloads look alike (the targets
//!   are ≥1000m, except the final down-step to 1m).
//! * **Fig 2c/2d, 4b** — scaling *down* gets slower as the target shrinks,
//!   up to 3.95 s at target 1m under CPU stress; the trend exists while
//!   idle too.
//!
//! The mechanistic explanation (which this model encodes): the end-to-end
//! latency is (a) a control-plane term — API-server commit + kubelet sync +
//! CRI `UpdateContainerResources` — that is roughly constant, plus (b) a
//! *detection* term paid by whatever runs inside the container after the new
//! limit applies. Once the new (smaller) budget is in force, the watcher's
//! poll loop itself is throttled to `target` milliCPU, and a co-resident
//! stressor steals most of that tiny budget. Hence the dependence on the
//! **target** allocation, matching all four figures simultaneously — and
//! explaining why the in-place *serving* path (scale up to 1000m) stays
//! cheap even on a busy node, which is what makes the paper's policy viable.
//!
//! All constants live in [`LatencyParams`] and are documented as fits to the
//! paper's reported numbers. Draws are deterministic given the caller's RNG.

use crate::util::rng::Rng;

/// Scale direction (the paper sweeps both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeKind {
    Up,
    Down,
}

/// Node/container load state during the resize.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeLoad {
    /// Fraction of node CPU consumed by co-resident CPU-bound work
    /// (stress-ng cpu stressor ⇒ ~1.0; idle ⇒ 0.0).
    pub cpu_utilization: f64,
    /// I/O-stress present (stress-ng io stressor).
    pub io_stress: bool,
}

impl NodeLoad {
    pub const IDLE: NodeLoad = NodeLoad {
        cpu_utilization: 0.0,
        io_stress: false,
    };

    pub fn stress_cpu() -> NodeLoad {
        NodeLoad {
            cpu_utilization: 1.0,
            io_stress: false,
        }
    }

    pub fn stress_io() -> NodeLoad {
        NodeLoad {
            cpu_utilization: 0.08, // io workers burn a little CPU
            io_stress: true,
        }
    }
}

/// Calibration constants (milliseconds). Defaults reproduce §4.1.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyParams {
    /// API-server patch commit + admission.
    pub api_commit_ms: f64,
    /// Kubelet sync + CRI update, idle mean. Fig 4a: total flat 56.44 ms,
    /// so control-plane mean = 56.44 − api_commit − small detect at 1000m.
    pub sync_mean_ms: f64,
    /// Fig 4a σ = 8.53 ms.
    pub sync_std_ms: f64,
    /// Watcher poll cost at a full CPU (1000m) in ms.
    pub poll_cost_ms: f64,
    /// Detection throttling exponent, scale-up (weak: new budget is large).
    pub alpha_up: f64,
    /// Detection throttling exponent, scale-down (strong: budget shrank).
    pub alpha_down: f64,
    /// Extra detection delay under CPU stress, scale-up (ms at target→0).
    pub stress_up_ms: f64,
    /// Decay of the stress-up term with target milliCPU.
    pub stress_up_tau_m: f64,
    /// Extra detection delay under CPU stress, scale-down (ms at target→0).
    pub stress_down_ms: f64,
    /// Decay of the stress-down term with target milliCPU.
    pub stress_down_tau_m: f64,
    /// Multiplicative penalty when the io stressor is active.
    pub io_mult: f64,
}

impl Default for LatencyParams {
    fn default() -> Self {
        LatencyParams {
            api_commit_ms: 3.0,
            sync_mean_ms: 51.0,
            sync_std_ms: 8.4,
            poll_cost_ms: 2.0,
            alpha_up: 0.35,
            alpha_down: 0.82,
            stress_up_ms: 500.0,
            stress_up_tau_m: 200.0,
            stress_down_ms: 3400.0,
            stress_down_tau_m: 200.0,
            io_mult: 1.06,
        }
    }
}

/// The resize latency model.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyModel {
    pub params: LatencyParams,
}

impl LatencyModel {
    pub fn new(params: LatencyParams) -> LatencyModel {
        LatencyModel { params }
    }

    /// Every time constant scaled by `factor` (shape parameters — exponents,
    /// decay constants, the io multiplier — preserved): the per-node resize
    /// calibration override carried by `NodeShape`.
    pub fn scaled(&self, factor: f64) -> LatencyModel {
        LatencyModel {
            params: LatencyParams {
                api_commit_ms: self.params.api_commit_ms * factor,
                sync_mean_ms: self.params.sync_mean_ms * factor,
                sync_std_ms: self.params.sync_std_ms * factor,
                poll_cost_ms: self.params.poll_cost_ms * factor,
                stress_up_ms: self.params.stress_up_ms * factor,
                stress_down_ms: self.params.stress_down_ms * factor,
                ..self.params.clone()
            },
        }
    }

    /// Mean (noise-free) end-to-end resize latency in ms.
    ///
    /// `cur_m` / `target_m` are the allocations in milliCPU before/after.
    pub fn mean_ms(&self, cur_m: u64, target_m: u64, load: NodeLoad) -> f64 {
        let p = &self.params;
        let kind = if target_m >= cur_m {
            ResizeKind::Up
        } else {
            ResizeKind::Down
        };
        let control = p.api_commit_ms + p.sync_mean_ms;
        let t = target_m.max(1) as f64;
        let (alpha, stress_amp, tau) = match kind {
            ResizeKind::Up => (p.alpha_up, p.stress_up_ms, p.stress_up_tau_m),
            ResizeKind::Down => (p.alpha_down, p.stress_down_ms, p.stress_down_tau_m),
        };
        // Watcher throttled to the *new* budget.
        let detect_idle = p.poll_cost_ms * (1000.0 / t).powf(alpha);
        // Stressor steals the in-container / node budget; decays as the new
        // budget grows.
        let detect_stress =
            stress_amp * load.cpu_utilization.clamp(0.0, 1.0) * (-t / tau).exp();
        let io = if load.io_stress { p.io_mult } else { 1.0 };
        (control + detect_idle + detect_stress) * io
    }

    /// Samples a latency in ms with log-normal control-plane noise.
    pub fn sample_ms(&self, cur_m: u64, target_m: u64, load: NodeLoad, rng: &mut Rng) -> f64 {
        let mean = self.mean_ms(cur_m, target_m, load);
        // Noise fraction mirrors Fig 4a's cv ≈ 8.53/56.44.
        let cv = self.params.sync_std_ms / (self.params.sync_mean_ms + self.params.api_commit_ms);
        rng.lognormal_mean_std(mean, mean * cv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> LatencyModel {
        LatencyModel::default()
    }

    /// Fig 4a: scaling up to 1000m while idle ≈ 56.44 ms, flat in `cur`.
    #[test]
    fn fig4a_idle_up_to_1000_flat() {
        let m = model();
        let mut lats = Vec::new();
        for cur in (5..1000).step_by(5) {
            lats.push(m.mean_ms(cur, 1000, NodeLoad::IDLE));
        }
        let mean = lats.iter().sum::<f64>() / lats.len() as f64;
        assert!((mean - 56.44).abs() < 2.0, "mean={mean}");
        let spread = lats
            .iter()
            .fold(0.0f64, |acc, &x| acc.max((x - mean).abs()));
        assert!(spread < 1.0, "should be flat, spread={spread}");
    }

    /// Fig 2a: under CPU stress, 1m→100m ≈ 6.06× idle; 100m→200m ≈ 2.88×.
    #[test]
    fn fig2a_stress_up_inflation() {
        let m = model();
        let idle_100 = m.mean_ms(1, 100, NodeLoad::IDLE);
        let busy_100 = m.mean_ms(1, 100, NodeLoad::stress_cpu());
        let r1 = busy_100 / idle_100;
        assert!((4.5..8.0).contains(&r1), "1m→100m ratio={r1}");

        let idle_200 = m.mean_ms(100, 200, NodeLoad::IDLE);
        let busy_200 = m.mean_ms(100, 200, NodeLoad::stress_cpu());
        let r2 = busy_200 / idle_200;
        assert!((2.0..4.5).contains(&r2), "100m→200m ratio={r2}");
        assert!(r1 > r2, "inflation must fade with target");

        // Later intervals: "not notable".
        let r5 = m.mean_ms(400, 500, NodeLoad::stress_cpu()) / m.mean_ms(400, 500, NodeLoad::IDLE);
        assert!(r5 < 1.8, "400m→500m ratio={r5}");
    }

    /// Fig 3a: with 1000m steps up, stress barely matters.
    #[test]
    fn fig3a_large_steps_uniform() {
        let m = model();
        for (cur, tgt) in [(1u64, 1000u64), (1000, 2000), (3000, 4000), (5000, 6000)] {
            let ratio =
                m.mean_ms(cur, tgt, NodeLoad::stress_cpu()) / m.mean_ms(cur, tgt, NodeLoad::IDLE);
            assert!(ratio < 1.15, "{cur}→{tgt} ratio={ratio}");
        }
    }

    /// Fig 3b: the exception — the final 1000m→1m down-step is slow.
    #[test]
    fn fig3b_final_downstep_slow() {
        let m = model();
        let normal = m.mean_ms(3000, 2000, NodeLoad::IDLE);
        let last = m.mean_ms(1000, 1, NodeLoad::IDLE);
        assert!(last > 5.0 * normal, "last={last} normal={normal}");
    }

    /// Fig 4b: idle scale-down latency rises as the target shrinks.
    #[test]
    fn fig4b_down_latency_monotone_in_target() {
        let m = model();
        let mut prev = 0.0f64;
        for tgt in [999u64, 500, 100, 50, 10, 5, 1] {
            let lat = m.mean_ms(1000, tgt, NodeLoad::IDLE);
            assert!(lat >= prev - 1e-9, "target={tgt} lat={lat} prev={prev}");
            prev = lat;
        }
        // And the rise is substantial at the bottom of the range.
        assert!(prev > 3.0 * m.mean_ms(1000, 999, NodeLoad::IDLE));
    }

    /// §4.1: "scaling down the CPU took up to 3.95 seconds" under CPU stress.
    #[test]
    fn down_to_1m_under_stress_matches_worst_case() {
        let m = model();
        let lat = m.mean_ms(100, 1, NodeLoad::stress_cpu());
        assert!((3000.0..4800.0).contains(&lat), "lat={lat}");
    }

    /// "While scaling up remains under 1 second."
    #[test]
    fn up_always_under_a_second() {
        let m = model();
        for cur in [1u64, 50, 100, 500, 900] {
            for tgt in [100u64, 200, 500, 1000, 6000] {
                if tgt <= cur {
                    continue;
                }
                let lat = m.mean_ms(cur, tgt, NodeLoad::stress_cpu());
                assert!(lat < 1000.0, "{cur}→{tgt} lat={lat}");
            }
        }
    }

    /// The serving path the policy depends on: 1m→1000m stays ~56 ms even
    /// under load — this is why in-place activation is cheap.
    #[test]
    fn serving_scale_up_cheap_under_load() {
        let m = model();
        let lat = m.mean_ms(1, 1000, NodeLoad::stress_cpu());
        assert!(lat < 75.0, "lat={lat}");
    }

    #[test]
    fn io_stress_mild() {
        let m = model();
        let r = m.mean_ms(1, 100, NodeLoad::stress_io()) / m.mean_ms(1, 100, NodeLoad::IDLE);
        assert!((1.0..1.5).contains(&r), "io ratio={r}");
    }

    /// Per-node calibration: scaling the model scales every mean linearly
    /// while the shape (exponents, decay, io multiplier) is untouched.
    #[test]
    fn scaled_model_scales_means_linearly() {
        let m = model();
        let s = m.scaled(2.0);
        for (cur, tgt) in [(1u64, 1000u64), (1000u64, 1u64), (100, 200)] {
            let a = m.mean_ms(cur, tgt, NodeLoad::stress_cpu());
            let b = s.mean_ms(cur, tgt, NodeLoad::stress_cpu());
            assert!((b - 2.0 * a).abs() < 1e-9, "{cur}->{tgt}: {b} vs 2×{a}");
        }
        assert_eq!(s.params.alpha_up, m.params.alpha_up);
        assert_eq!(s.params.io_mult, m.params.io_mult);
    }

    #[test]
    fn sampling_is_deterministic_and_near_mean() {
        let m = model();
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let x = m.sample_ms(1, 1000, NodeLoad::IDLE, &mut a);
        let y = m.sample_ms(1, 1000, NodeLoad::IDLE, &mut b);
        assert_eq!(x, y);
        // Mean over many samples approaches the analytic mean.
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| m.sample_ms(1, 1000, NodeLoad::IDLE, &mut r))
            .sum::<f64>()
            / n as f64;
        let want = m.mean_ms(1, 1000, NodeLoad::IDLE);
        assert!((mean - want).abs() / want < 0.03, "mean={mean} want={want}");
    }
}
