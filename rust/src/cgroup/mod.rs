//! cgroups-v2 / CFS substrate.
//!
//! The paper's mechanism is a write to a pod cgroup's `cpu.max`; its §4.1
//! experiments measure how long that write takes to land under different
//! step sizes, directions and node load. This module models:
//!
//! * the cgroup hierarchy with `cpu.max` bandwidth limits ([`hierarchy`]),
//! * CFS bandwidth + shares arbitration that converts allocations into
//!   effective CPU rates ([`cfs`]),
//! * the **resize-latency model** calibrated against the paper's Figures
//!   2–4 ([`latency`]),
//! * stress-ng-like CPU / I/O stressors used by the §4.1 experiments
//!   ([`stress`]).

pub mod cfs;
pub mod hierarchy;
pub mod latency;
pub mod stress;

pub use cfs::{CfsArbiter, CfsShare};
pub use hierarchy::{CgroupFs, CgroupId, CpuMax};
pub use latency::{LatencyModel, LatencyParams, NodeLoad, ResizeKind};
pub use stress::{StressKind, Stressor};
