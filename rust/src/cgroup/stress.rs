//! stress-ng-style load generators for the §4.1 experiments.
//!
//! A [`Stressor`] occupies node CPU (or generates I/O wait) for the duration
//! it is attached; the scaling-overhead experiment attaches one to reproduce
//! the paper's Idle / Stress-CPU / Stress-I/O conditions, and the CFS
//! arbiter sees it as a hungry background entity.

use crate::cgroup::cfs::CfsShare;
use crate::cgroup::latency::NodeLoad;
use crate::util::quantity::MilliCpu;

/// Which stress-ng stressor class to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StressKind {
    /// `stress-ng --cpu N`: spin loops saturating N workers.
    Cpu,
    /// `stress-ng --io N`: sync/IO-wait heavy workers with little CPU.
    Io,
}

/// An active stressor instance.
#[derive(Debug, Clone)]
pub struct Stressor {
    pub kind: StressKind,
    /// Worker count (stress-ng `N`).
    pub workers: u32,
    /// Optional cgroup CPU cap applied to the stressor itself.
    pub limit: Option<MilliCpu>,
}

impl Stressor {
    /// CPU stressor sized to saturate a node with `cores` cores.
    pub fn cpu_saturating(cores: u32) -> Stressor {
        Stressor {
            kind: StressKind::Cpu,
            workers: cores,
            limit: None,
        }
    }

    pub fn io(workers: u32) -> Stressor {
        Stressor {
            kind: StressKind::Io,
            workers,
            limit: None,
        }
    }

    /// Demand this stressor places on node CPU.
    pub fn cpu_demand(&self) -> MilliCpu {
        match self.kind {
            StressKind::Cpu => MilliCpu(self.workers as u64 * 1000),
            // I/O workers mostly sleep in D-state; ~8% of a core each.
            StressKind::Io => MilliCpu(self.workers as u64 * 80),
        }
    }

    /// The CFS view of this stressor.
    pub fn as_cfs_share(&self) -> CfsShare {
        CfsShare::new(100, self.limit, self.cpu_demand())
    }

    /// The resize-latency model's load descriptor for a node with `cores`
    /// cores running this stressor set.
    pub fn node_load(stressors: &[Stressor], cores: u32) -> NodeLoad {
        let cap = (cores as f64) * 1000.0;
        let mut cpu = 0.0;
        let mut io = false;
        for s in stressors {
            cpu += s.cpu_demand().0 as f64;
            io |= s.kind == StressKind::Io;
        }
        NodeLoad {
            cpu_utilization: (cpu / cap).min(1.0),
            io_stress: io,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_stressor_saturates() {
        let s = Stressor::cpu_saturating(8);
        assert_eq!(s.cpu_demand(), MilliCpu(8000));
        let load = Stressor::node_load(&[s], 8);
        assert_eq!(load.cpu_utilization, 1.0);
        assert!(!load.io_stress);
    }

    #[test]
    fn io_stressor_light_on_cpu() {
        let s = Stressor::io(4);
        assert_eq!(s.cpu_demand(), MilliCpu(320));
        let load = Stressor::node_load(&[s], 8);
        assert!(load.cpu_utilization < 0.1);
        assert!(load.io_stress);
    }

    #[test]
    fn idle_node_load() {
        let load = Stressor::node_load(&[], 8);
        assert_eq!(load, NodeLoad::IDLE);
    }

    #[test]
    fn mixed_stressors_combine() {
        let load = Stressor::node_load(&[Stressor::cpu_saturating(4), Stressor::io(2)], 8);
        assert!(load.cpu_utilization > 0.5);
        assert!(load.io_stress);
    }

    #[test]
    fn cfs_share_is_hungry_for_cpu_kind() {
        let s = Stressor::cpu_saturating(2);
        let share = s.as_cfs_share();
        assert_eq!(share.demand, MilliCpu(2000));
        assert_eq!(share.limit, None);
    }
}
