//! Generational pod slab: arena storage for [`Pod`]s with ABA-safe
//! handles.
//!
//! The old `Cluster.pods: HashMap<PodId, Pod>` paid a hash + probe per
//! lookup on every dispatch/complete/resize event and iterated in
//! `RandomState` order (never observable, but a standing determinism
//! hazard). The slab stores pods in a flat `Vec` of slots; a [`PodId`] now
//! *packs* a [`PodHandle`] — `(generation << 32) | index` — so every
//! lookup is one bounds check plus one generation compare, and a handle
//! to a freed-and-reused slot can never alias the new tenant: removal
//! bumps the slot's generation, invalidating all outstanding ids for the
//! old pod (the same slot+generation scheme `simclock`'s `EventId` uses
//! for timer cancellation).
//!
//! Pods that are never freed receive ids `0, 1, 2, …` — exactly the
//! monotone uids the old allocator produced — and `iter()` walks slots in
//! index order, so the slab is drop-in deterministic.

use crate::cluster::pod::{Pod, PodId, PodSpec};

/// Unpacked view of a [`PodId`]: slot index + slot generation at
/// allocation time. The id is stale (its pod was freed, and the slot
/// possibly reused) iff the slot's current generation differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PodHandle {
    pub index: u32,
    pub generation: u32,
}

impl PodHandle {
    /// Packs the handle into the ubiquitous [`PodId`] key type.
    pub fn to_id(self) -> PodId {
        PodId(((self.generation as u64) << 32) | self.index as u64)
    }

    /// Unpacks a [`PodId`] produced by [`PodHandle::to_id`].
    pub fn from_id(id: PodId) -> PodHandle {
        PodHandle {
            index: (id.0 & 0xFFFF_FFFF) as u32,
            generation: (id.0 >> 32) as u32,
        }
    }
}

#[derive(Debug)]
enum Slot {
    Vacant { generation: u32 },
    Occupied { generation: u32, pod: Pod },
}

/// The slab. Freed slots are reused LIFO (hot in cache, deterministic).
#[derive(Debug, Default)]
pub struct PodSlab {
    slots: Vec<Slot>,
    free: Vec<u32>,
    len: usize,
}

impl PodSlab {
    pub fn new() -> PodSlab {
        PodSlab::default()
    }

    /// Allocates a slot and constructs the pod in place; returns its id.
    pub fn alloc(&mut self, spec: PodSpec) -> PodId {
        let (index, generation) = match self.free.pop() {
            Some(i) => match self.slots[i as usize] {
                Slot::Vacant { generation } => (i, generation),
                Slot::Occupied { .. } => unreachable!("free list points at occupied slot"),
            },
            None => {
                self.slots.push(Slot::Vacant { generation: 0 });
                ((self.slots.len() - 1) as u32, 0)
            }
        };
        let id = PodHandle { index, generation }.to_id();
        self.slots[index as usize] = Slot::Occupied {
            generation,
            pod: Pod::new(id, spec),
        };
        self.len += 1;
        id
    }

    /// Generation-checked lookup: `None` for stale ids (freed slot, or a
    /// reused slot whose generation moved on) and foreign indices alike.
    pub fn get(&self, id: PodId) -> Option<&Pod> {
        let h = PodHandle::from_id(id);
        match self.slots.get(h.index as usize) {
            Some(Slot::Occupied { generation, pod }) if *generation == h.generation => Some(pod),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, id: PodId) -> Option<&mut Pod> {
        let h = PodHandle::from_id(id);
        match self.slots.get_mut(h.index as usize) {
            Some(Slot::Occupied { generation, pod }) if *generation == h.generation => Some(pod),
            _ => None,
        }
    }

    /// Frees the slot, bumping its generation so every outstanding id for
    /// this pod turns stale. Stale ids are a no-op returning `None`.
    pub fn remove(&mut self, id: PodId) -> Option<Pod> {
        let h = PodHandle::from_id(id);
        let slot = self.slots.get_mut(h.index as usize)?;
        match slot {
            Slot::Occupied { generation, .. } if *generation == h.generation => {
                let next = Slot::Vacant {
                    generation: generation.wrapping_add(1),
                };
                let old = std::mem::replace(slot, next);
                self.free.push(h.index);
                self.len -= 1;
                match old {
                    Slot::Occupied { pod, .. } => Some(pod),
                    Slot::Vacant { .. } => unreachable!(),
                }
            }
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live pods in slot-index order — deterministic, unlike the
    /// `HashMap` iteration this replaced.
    pub fn iter(&self) -> impl Iterator<Item = &Pod> {
        self.slots.iter().filter_map(|s| match s {
            Slot::Occupied { pod, .. } => Some(pod),
            Slot::Vacant { .. } => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu, Resources};

    fn spec() -> PodSpec {
        PodSpec::single(
            "fn",
            "img",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        )
    }

    #[test]
    fn handle_roundtrips_through_pod_id() {
        let h = PodHandle {
            index: 7,
            generation: 3,
        };
        assert_eq!(PodHandle::from_id(h.to_id()), h);
        // Generation 0 ids are plain small integers — the old uid shape.
        let first = PodHandle {
            index: 0,
            generation: 0,
        };
        assert_eq!(first.to_id(), PodId(0));
    }

    #[test]
    fn never_freed_ids_are_monotone_uids() {
        let mut s = PodSlab::new();
        for want in 0..4u64 {
            assert_eq!(s.alloc(spec()), PodId(want));
        }
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn stale_id_rejected_after_free() {
        let mut s = PodSlab::new();
        let a = s.alloc(spec());
        assert!(s.get(a).is_some());
        assert!(s.remove(a).is_some());
        assert!(s.get(a).is_none(), "freed id must read as gone");
        assert!(s.remove(a).is_none(), "double free is a no-op");
    }

    #[test]
    fn reused_slot_does_not_alias_old_id() {
        let mut s = PodSlab::new();
        let a = s.alloc(spec());
        s.remove(a);
        let b = s.alloc(spec());
        // Same slot, bumped generation: distinct ids, no ABA.
        assert_eq!(PodHandle::from_id(b).index, PodHandle::from_id(a).index);
        assert_ne!(a, b);
        assert!(s.get(a).is_none());
        assert!(s.get(b).is_some());
        assert_eq!(s.get(b).unwrap().id, b);
    }

    #[test]
    fn iteration_is_slot_ordered() {
        let mut s = PodSlab::new();
        let ids: Vec<PodId> = (0..5).map(|_| s.alloc(spec())).collect();
        s.remove(ids[1]);
        s.remove(ids[3]);
        let seen: Vec<PodId> = s.iter().map(|p| p.id).collect();
        assert_eq!(seen, vec![ids[0], ids[2], ids[4]]);
        // LIFO reuse: slot 3 comes back first, with generation 1.
        let next = s.alloc(spec());
        let h = PodHandle::from_id(next);
        assert_eq!((h.index, h.generation), (3, 1));
    }
}
