//! Container specs, including the k8s 1.27 `resizePolicy` field that the
//! in-place scaling feature introduced.

use crate::util::quantity::Resources;

/// Per-resource resize policy (k8s 1.27 `ContainerResizePolicy`).
///
/// The paper depends on `NotRequired` for CPU: resizing must not restart the
/// container — that is the whole point of in-place scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResizePolicy {
    /// Apply the new limit in place, no restart (the feature's raison d'être).
    #[default]
    NotRequired,
    /// Container must restart for the change to apply (pre-1.27 behaviour,
    /// and what the VPA did before in-place support).
    RestartContainer,
}

/// Pod-level restart policy (subset used here).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RestartPolicy {
    #[default]
    Always,
    Never,
}

/// A container spec: image + resources + resize policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerSpec {
    pub name: String,
    pub image: String,
    /// Scheduling requests (CPU request → `cpu.weight`).
    pub requests: Resources,
    /// Limits (CPU limit → `cpu.max`).
    pub limits: Resources,
    pub cpu_resize_policy: ResizePolicy,
}

impl ContainerSpec {
    pub fn new(name: &str, image: &str, requests: Resources, limits: Resources) -> ContainerSpec {
        ContainerSpec {
            name: name.to_string(),
            image: image.to_string(),
            requests,
            limits,
            cpu_resize_policy: ResizePolicy::NotRequired,
        }
    }

    pub fn with_resize_policy(mut self, p: ResizePolicy) -> ContainerSpec {
        self.cpu_resize_policy = p;
        self
    }

    /// cgroups-v2 `cpu.weight` derived from the CPU request, following the
    /// kubelet's `sharesToWeight` conversion:
    /// shares = milliCPU*1024/1000, weight = 1 + (shares-2)*9999/262142.
    pub fn cpu_weight(&self) -> u64 {
        let shares = (self.requests.cpu.0 * 1024 / 1000).clamp(2, 262_144);
        1 + (shares - 2) * 9999 / 262_142
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu};

    fn spec(request_m: u64) -> ContainerSpec {
        ContainerSpec::new(
            "c",
            "img",
            Resources::new(MilliCpu(request_m), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        )
    }

    #[test]
    fn default_resize_policy_is_not_required() {
        assert_eq!(spec(100).cpu_resize_policy, ResizePolicy::NotRequired);
        let r = spec(100).with_resize_policy(ResizePolicy::RestartContainer);
        assert_eq!(r.cpu_resize_policy, ResizePolicy::RestartContainer);
    }

    #[test]
    fn cpu_weight_follows_kubelet_conversion() {
        // 1000m → shares 1024 → weight 1 + 1022*9999/262142 = 39.
        assert_eq!(spec(1000).cpu_weight(), 39);
        // Tiny request clamps at shares=2 → weight 1.
        assert_eq!(spec(1).cpu_weight(), 1);
        // Weight grows monotonically with the request.
        assert!(spec(4000).cpu_weight() > spec(1000).cpu_weight());
    }
}
