//! Deployments: the §2 observation the paper builds on —
//!
//! > "Kubernetes allocates pods for deployment with uniform computing
//! >  resources, meaning that instances under the same deployment receive
//! >  identical resource allocations, irrespective of varying external
//! >  factors such as input size."
//!
//! A [`Deployment`] is a replica-count controller over a pod template with
//! *uniform* resources; [`Deployment::reconcile`] computes the create /
//! delete actions to converge the observed replica set — the level-based
//! loop a ReplicaSet controller runs. In-place resize is exactly the escape
//! hatch from this uniformity: per-pod limits may diverge from the template
//! at runtime without recreating pods.

use crate::cluster::pod::{PodId, PodSpec};

/// Desired state: template + replicas.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub name: String,
    pub template: PodSpec,
    pub replicas: u32,
    /// Pods currently owned by this deployment.
    owned: Vec<PodId>,
}

/// Actions the controller wants executed.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Create one pod from the template.
    Create,
    /// Delete this owned pod (scale-in picks the newest first, matching the
    /// ReplicaSet controller's preference for youngest pods).
    Delete(PodId),
}

impl Deployment {
    pub fn new(name: &str, template: PodSpec, replicas: u32) -> Deployment {
        Deployment {
            name: name.to_string(),
            template,
            replicas,
            owned: Vec::new(),
        }
    }

    pub fn owned(&self) -> &[PodId] {
        &self.owned
    }

    /// Records a pod created on this deployment's behalf.
    pub fn adopt(&mut self, pod: PodId) {
        if !self.owned.contains(&pod) {
            self.owned.push(pod);
        }
    }

    /// Forgets a pod (deleted / failed).
    pub fn release(&mut self, pod: PodId) {
        self.owned.retain(|p| *p != pod);
    }

    /// Updates the desired replica count (HPA-style horizontal scaling).
    pub fn scale(&mut self, replicas: u32) {
        self.replicas = replicas;
    }

    /// Level-based reconcile: returns the actions to converge |owned| to
    /// `replicas`. Idempotent — applying the actions and reconciling again
    /// yields nothing.
    pub fn reconcile(&self) -> Vec<Action> {
        let have = self.owned.len() as u32;
        if have < self.replicas {
            (0..self.replicas - have).map(|_| Action::Create).collect()
        } else {
            // Newest-first scale-in.
            self.owned
                .iter()
                .rev()
                .take((have - self.replicas) as usize)
                .map(|p| Action::Delete(*p))
                .collect()
        }
    }

    /// §2's uniformity property: every owned pod was stamped from the same
    /// template, so their *spec* resources are identical by construction.
    /// (Runtime in-place resizes can still diverge `status.applied_*` —
    /// that is the paper's point.)
    pub fn template_cpu_m(&self) -> u64 {
        self.template.total_limits().cpu.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu, Resources};

    fn template() -> PodSpec {
        PodSpec::single(
            "fn",
            "img:v1",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(256)),
        )
    }

    #[test]
    fn scale_out_creates_missing_replicas() {
        let mut d = Deployment::new("web", template(), 3);
        assert_eq!(d.reconcile(), vec![Action::Create; 3]);
        d.adopt(PodId(1));
        d.adopt(PodId(2));
        assert_eq!(d.reconcile(), vec![Action::Create]);
        d.adopt(PodId(3));
        assert!(d.reconcile().is_empty());
    }

    #[test]
    fn scale_in_deletes_newest_first() {
        let mut d = Deployment::new("web", template(), 3);
        for i in 1..=3 {
            d.adopt(PodId(i));
        }
        d.scale(1);
        let actions = d.reconcile();
        assert_eq!(actions, vec![Action::Delete(PodId(3)), Action::Delete(PodId(2))]);
        d.release(PodId(3));
        d.release(PodId(2));
        assert!(d.reconcile().is_empty());
        assert_eq!(d.owned(), &[PodId(1)]);
    }

    #[test]
    fn adopt_is_idempotent() {
        let mut d = Deployment::new("web", template(), 1);
        d.adopt(PodId(5));
        d.adopt(PodId(5));
        assert_eq!(d.owned().len(), 1);
    }

    #[test]
    fn uniform_resources_by_construction() {
        let d = Deployment::new("web", template(), 4);
        assert_eq!(d.template_cpu_m(), 1000);
        // Every create stamps the same template; there is no per-replica
        // sizing — the §2 limitation in-place resize works around.
    }

    #[test]
    fn scale_to_zero() {
        let mut d = Deployment::new("web", template(), 2);
        d.adopt(PodId(1));
        d.adopt(PodId(2));
        d.scale(0);
        assert_eq!(d.reconcile().len(), 2);
    }
}
