//! Kubelet model: the pod startup pipeline (the cold-start anatomy) and
//! in-place resize application.
//!
//! Engine-agnostic: methods return *plans* — `(stage, duration)` sequences —
//! that the coordinator schedules; applying a stage mutates cluster state.

use crate::cgroup::latency::{LatencyModel, NodeLoad};
use crate::cluster::node::Node;
use crate::cluster::pod::PodId;
use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;
use crate::util::rng::Rng;

/// Stages of bringing a pod up, in order. The sum of their durations is the
/// platform's share of cold-start latency (the function runtime's own init
/// is owned by the workload model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartupStage {
    /// kube-scheduler decision + binding round-trip.
    Schedule,
    /// Sandbox (pause container / netns / cgroups) creation.
    Sandbox,
    /// Image pull — near-free when node-cached.
    ImagePull,
    /// Container create + start via the CRI.
    ContainerStart,
    /// Language runtime boot + user code import (per-workload).
    RuntimeInit,
    /// Readiness probe round-trip until the endpoint is routable.
    Readiness,
}

/// Cold-start pipeline latency parameters (milliseconds).
///
/// Defaults are calibrated so a cached-image Python function lands at
/// ≈1.4–1.6 s of platform cold start, matching Table 3's helloworld
/// `Cold/Default = 286.99` against its 5.31 ms runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct StartupParams {
    pub schedule_ms: f64,
    pub sandbox_ms: f64,
    /// Image pull when cached on the node.
    pub image_cached_ms: f64,
    /// Image pull when cold (registry fetch + unpack), per 100 MB.
    pub image_pull_per_100mb_ms: f64,
    pub container_start_ms: f64,
    /// Readiness probe interval (knative queue-proxy probes aggressively).
    pub readiness_period_ms: f64,
    /// Relative jitter (lognormal cv) applied to each stage.
    pub jitter_cv: f64,
}

impl Default for StartupParams {
    fn default() -> Self {
        StartupParams {
            schedule_ms: 55.0,
            sandbox_ms: 480.0,
            image_cached_ms: 25.0,
            image_pull_per_100mb_ms: 3200.0,
            container_start_ms: 240.0,
            readiness_period_ms: 50.0,
            jitter_cv: 0.12,
        }
    }
}

impl StartupParams {
    /// Every stage mean scaled by `factor` (jitter shape preserved) — the
    /// per-node calibration override carried by `NodeShape` for
    /// heterogeneous fleets with genuinely slow/fast machines.
    pub fn scaled(&self, factor: f64) -> StartupParams {
        StartupParams {
            schedule_ms: self.schedule_ms * factor,
            sandbox_ms: self.sandbox_ms * factor,
            image_cached_ms: self.image_cached_ms * factor,
            image_pull_per_100mb_ms: self.image_pull_per_100mb_ms * factor,
            container_start_ms: self.container_start_ms * factor,
            readiness_period_ms: self.readiness_period_ms * factor,
            jitter_cv: self.jitter_cv,
        }
    }
}

/// The kubelet for one node (stateless besides parameters; per-pod resize
/// serialization state lives in `PodStatus`).
#[derive(Debug, Clone, Default)]
pub struct Kubelet {
    pub startup: StartupParams,
    pub latency: LatencyModel,
}

impl Kubelet {
    pub fn new(startup: StartupParams, latency: LatencyModel) -> Kubelet {
        Kubelet { startup, latency }
    }

    fn jitter(&self, mean_ms: f64, rng: &mut Rng) -> SimTime {
        let ms = rng.lognormal_mean_std(mean_ms, mean_ms * self.startup.jitter_cv);
        SimTime::from_millis_f64(ms)
    }

    /// Builds the startup plan for a pod whose image is (or is not) cached
    /// and whose runtime init takes `runtime_init_ms` (workload-specific).
    /// `image_mb` sizes the cold pull.
    pub fn startup_plan(
        &self,
        image_cached: bool,
        image_mb: f64,
        runtime_init_ms: f64,
        rng: &mut Rng,
    ) -> Vec<(StartupStage, SimTime)> {
        let p = &self.startup;
        let pull_ms = if image_cached {
            p.image_cached_ms
        } else {
            p.image_cached_ms + p.image_pull_per_100mb_ms * (image_mb / 100.0)
        };
        // Readiness: uniform phase within one probe period + one round-trip.
        let readiness_ms = rng.range_f64(0.0, p.readiness_period_ms) + 5.0;
        vec![
            (StartupStage::Schedule, self.jitter(p.schedule_ms, rng)),
            (StartupStage::Sandbox, self.jitter(p.sandbox_ms, rng)),
            (StartupStage::ImagePull, self.jitter(pull_ms, rng)),
            (
                StartupStage::ContainerStart,
                self.jitter(p.container_start_ms, rng),
            ),
            (
                StartupStage::RuntimeInit,
                self.jitter(runtime_init_ms.max(1.0), rng),
            ),
            (StartupStage::Readiness, SimTime::from_millis_f64(readiness_ms)),
        ]
    }

    /// Total duration of a startup plan.
    pub fn plan_total(plan: &[(StartupStage, SimTime)]) -> SimTime {
        plan.iter().fold(SimTime::ZERO, |acc, (_, d)| acc + *d)
    }

    /// Feasibility check for an in-place resize: the new limit must fit the
    /// node's capacity (limits may overcommit *allocatable*, not capacity).
    pub fn resize_feasible(node: &Node, new_limit: MilliCpu) -> bool {
        new_limit <= node.capacity().cpu
    }

    /// Samples the end-to-end latency of applying an in-place resize, per
    /// the §4.1-calibrated model.
    pub fn resize_latency(
        &self,
        cur: MilliCpu,
        target: MilliCpu,
        load: NodeLoad,
        rng: &mut Rng,
    ) -> SimTime {
        SimTime::from_millis_f64(self.latency.sample_ms(cur.0, target.0, load, rng))
    }

    /// Graceful pod termination time (SIGTERM → exit), used by scale-to-zero.
    pub fn termination_time(&self, rng: &mut Rng) -> SimTime {
        self.jitter(120.0, rng)
    }
}

/// Marker type re-exported for coordinator bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StartupToken(pub PodId);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NodeId;
    use crate::util::quantity::{Memory, Resources};

    fn kubelet() -> Kubelet {
        Kubelet::default()
    }

    #[test]
    fn cached_cold_start_lands_in_papers_band() {
        let k = kubelet();
        let mut rng = Rng::new(1);
        let mut totals = Vec::new();
        for _ in 0..200 {
            let plan = k.startup_plan(true, 120.0, 420.0, &mut rng);
            totals.push(Kubelet::plan_total(&plan).as_millis_f64());
        }
        let mean = totals.iter().sum::<f64>() / totals.len() as f64;
        // helloworld cold ≈ 286.99 × 5.31ms ≈ 1524ms total; the platform
        // share (minus runtime + proxy hops) should be ≈1.2–1.6s.
        assert!((1100.0..1700.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn uncached_image_dominates() {
        let k = kubelet();
        let mut rng = Rng::new(2);
        let cached = Kubelet::plan_total(&k.startup_plan(true, 500.0, 100.0, &mut rng));
        let cold = Kubelet::plan_total(&k.startup_plan(false, 500.0, 100.0, &mut rng));
        assert!(cold.as_millis_f64() > cached.as_millis_f64() + 10_000.0);
    }

    #[test]
    fn plan_stage_order_fixed() {
        let k = kubelet();
        let mut rng = Rng::new(3);
        let plan = k.startup_plan(true, 100.0, 100.0, &mut rng);
        let stages: Vec<StartupStage> = plan.iter().map(|(s, _)| *s).collect();
        assert_eq!(
            stages,
            vec![
                StartupStage::Schedule,
                StartupStage::Sandbox,
                StartupStage::ImagePull,
                StartupStage::ContainerStart,
                StartupStage::RuntimeInit,
                StartupStage::Readiness,
            ]
        );
    }

    #[test]
    fn resize_feasibility_checks_capacity() {
        let node = Node::new(
            NodeId(0),
            "n",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        );
        assert!(Kubelet::resize_feasible(&node, MilliCpu(6000)));
        assert!(Kubelet::resize_feasible(&node, MilliCpu(8000)));
        assert!(!Kubelet::resize_feasible(&node, MilliCpu(8001)));
    }

    #[test]
    fn resize_latency_reflects_model() {
        let k = kubelet();
        let mut rng = Rng::new(4);
        // Serving scale-up: cheap.
        let up = k.resize_latency(MilliCpu(1), MilliCpu(1000), NodeLoad::IDLE, &mut rng);
        assert!((30.0..120.0).contains(&up.as_millis_f64()), "{up}");
        // Parking scale-down to 1m: slow.
        let down = k.resize_latency(MilliCpu(1000), MilliCpu(1), NodeLoad::IDLE, &mut rng);
        assert!(down.as_millis_f64() > 200.0, "{down}");
    }

    #[test]
    fn deterministic_given_seed() {
        let k = kubelet();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let pa = k.startup_plan(true, 100.0, 300.0, &mut a);
        let pb = k.startup_plan(true, 100.0, 300.0, &mut b);
        assert_eq!(pa, pb);
    }
}
