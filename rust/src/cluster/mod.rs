//! The Kubernetes cluster substrate: nodes, pods, containers, a bin-packing
//! scheduler and a kubelet model — everything the paper's `kind` testbed
//! provided, rebuilt as simulation state.
//!
//! The module is engine-agnostic: operations either mutate state or return
//! *plans* (stage, duration) that the coordinator schedules on the DES
//! engine. That keeps every piece unit-testable without a running platform.

pub mod arena;
pub mod container;
pub mod deployment;
pub mod kubelet;
pub mod node;
pub mod pod;
pub mod scheduler;
pub mod topology;

pub use arena::{PodHandle, PodSlab};
pub use container::{ContainerSpec, ResizePolicy, RestartPolicy};
pub use deployment::{Action as DeploymentAction, Deployment};
pub use kubelet::{Kubelet, StartupParams, StartupStage};
pub use node::{Node, NodeId};
pub use pod::{Pod, PodId, PodPhase, PodSpec, PodStatus, ResizeStatus};
pub use scheduler::{ScheduleError, Scheduler, ScoringPolicy};
pub use topology::{NodeShape, Topology};

use crate::simclock::SimTime;
use crate::util::quantity::{MilliCpu, Resources};

/// The cluster: node table + the generational pod slab.
#[derive(Debug, Default)]
pub struct Cluster {
    nodes: Vec<Node>,
    pods: PodSlab,
}

impl Cluster {
    pub fn new() -> Cluster {
        Cluster::default()
    }

    /// Adds a node; returns its id.
    pub fn add_node(&mut self, name: &str, capacity: Resources) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node::new(id, name, capacity));
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Creates a pod in `Pending`; the scheduler binds it later. The
    /// returned id packs the slab handle (slot + generation), so a stale
    /// id after deletion can never alias a reused slot.
    pub fn create_pod(&mut self, spec: PodSpec) -> PodId {
        self.pods.alloc(spec)
    }

    /// Generation-checked lookup: `None` for deleted/stale ids.
    pub fn pod(&self, id: PodId) -> Option<&Pod> {
        self.pods.get(id)
    }

    pub fn pod_mut(&mut self, id: PodId) -> Option<&mut Pod> {
        self.pods.get_mut(id)
    }

    /// Live pods in slot order (deterministic).
    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.iter()
    }

    /// Binds `pod` to `node`, reserving its requests on the node and
    /// creating its cgroups. Called by the scheduler.
    pub fn bind(&mut self, pod_id: PodId, node_id: NodeId) -> Result<(), ScheduleError> {
        let requests = {
            let pod = self
                .pods
                .get(pod_id)
                .ok_or(ScheduleError::NoSuchPod(pod_id))?;
            if pod.node.is_some() {
                return Err(ScheduleError::AlreadyBound(pod_id));
            }
            pod.spec.total_requests()
        };
        let node = &mut self.nodes[node_id.0 as usize];
        if !requests.fits_in(&node.free()) {
            return Err(ScheduleError::Unschedulable(pod_id));
        }
        node.reserve(requests);
        let spec = self.pods.get(pod_id).unwrap().spec.clone();
        let (cgroup, ctrs) = node.create_pod_cgroups(pod_id, &spec);
        let pod = self.pods.get_mut(pod_id).unwrap();
        pod.node = Some(node_id);
        pod.cgroup = Some(cgroup);
        pod.container_cgroups = ctrs;
        pod.status.phase = PodPhase::Scheduled;
        Ok(())
    }

    /// Removes a terminated pod, releasing node resources and cgroups.
    /// Stale ids (already deleted) are a no-op.
    pub fn delete_pod(&mut self, pod_id: PodId) {
        if let Some(pod) = self.pods.remove(pod_id) {
            if let Some(node_id) = pod.node {
                let node = &mut self.nodes[node_id.0 as usize];
                node.release(pod.reserved());
                if let Some(pod_cg) = pod.cgroup {
                    node.remove_pod_cgroups(pod_cg, &pod.container_cgroups);
                }
            }
        }
    }

    /// Applies an in-place CPU-limit resize to the pod's cgroups on its
    /// node — the write whose propagation §4.1 measures. Returns false
    /// for unbound or stale pods. Pod cgroup ids live on the pod itself
    /// (the per-node `HashMap<PodId, _>` this replaced is gone).
    pub fn apply_cpu_limit(&mut self, pod_id: PodId, new_limit: MilliCpu, now: SimTime) -> bool {
        let Some(pod) = self.pods.get(pod_id) else {
            return false;
        };
        let (Some(node_id), Some(pod_cg)) = (pod.node, pod.cgroup) else {
            return false;
        };
        let Some(&ctr) = pod.container_cgroups.first() else {
            return false;
        };
        self.nodes[node_id.0 as usize].write_cpu_limit(pod_cg, ctr, new_limit, now);
        true
    }

    /// Total CPU currently *reserved* by requests across all nodes — the
    /// "enhanced resource availability" metric the paper's §3 argues for.
    pub fn total_reserved(&self) -> Resources {
        let mut total = Resources::ZERO;
        for n in &self.nodes {
            total += n.reserved();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu};

    fn small_pod() -> PodSpec {
        PodSpec::single(
            "fn",
            "reg/fn:latest",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(256)),
        )
    }

    #[test]
    fn bind_reserves_and_creates_cgroups() {
        let mut c = Cluster::new();
        let n = c.add_node("n0", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let p = c.create_pod(small_pod());
        c.bind(p, n).unwrap();
        assert_eq!(c.pod(p).unwrap().status.phase, PodPhase::Scheduled);
        assert_eq!(c.node(n).reserved().cpu, MilliCpu(100));
        assert!(c.pod(p).unwrap().cgroup.is_some());
    }

    #[test]
    fn bind_rejects_overcommit() {
        let mut c = Cluster::new();
        let n = c.add_node("n0", Resources::new(MilliCpu(150), Memory::from_gib(1)));
        let p1 = c.create_pod(small_pod());
        let p2 = c.create_pod(small_pod());
        c.bind(p1, n).unwrap();
        assert!(matches!(c.bind(p2, n), Err(ScheduleError::Unschedulable(_))));
    }

    #[test]
    fn double_bind_rejected() {
        let mut c = Cluster::new();
        let n = c.add_node("n0", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let p = c.create_pod(small_pod());
        c.bind(p, n).unwrap();
        assert!(matches!(c.bind(p, n), Err(ScheduleError::AlreadyBound(_))));
    }

    #[test]
    fn delete_releases_resources() {
        let mut c = Cluster::new();
        let n = c.add_node("n0", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let p = c.create_pod(small_pod());
        c.bind(p, n).unwrap();
        c.delete_pod(p);
        assert_eq!(c.node(n).reserved(), Resources::ZERO);
        assert!(c.pod(p).is_none());
    }

    #[test]
    fn total_reserved_sums_nodes() {
        let mut c = Cluster::new();
        let n0 = c.add_node("n0", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let n1 = c.add_node("n1", Resources::new(MilliCpu(8000), Memory::from_gib(10)));
        let p0 = c.create_pod(small_pod());
        let p1 = c.create_pod(small_pod());
        c.bind(p0, n0).unwrap();
        c.bind(p1, n1).unwrap();
        assert_eq!(c.total_reserved().cpu, MilliCpu(200));
    }
}
