//! Nodes: allocatable accounting, per-node cgroup filesystem, image cache,
//! and attached stressors (the §4.1 load conditions).

use std::collections::HashSet;

use crate::cgroup::{CgroupFs, CgroupId, CpuMax, Stressor};
use crate::cgroup::latency::NodeLoad;
use crate::cluster::pod::{PodId, PodSpec};
use crate::simclock::SimTime;
use crate::util::quantity::{MilliCpu, Resources};

/// Node index within the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// A worker node.
#[derive(Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    /// Full capacity (the paper's testbed: 8 cores / 10 GB).
    capacity: Resources,
    /// Reserved by pod requests.
    reserved: Resources,
    /// Per-node cgroups-v2 filesystem.
    pub cgfs: CgroupFs,
    /// kubepods root cgroup.
    kubepods: CgroupId,
    /// Pulled images (cold starts hit the pull path once per image).
    /// Lookup-only: never iterated, so `HashSet` order can't leak into
    /// behavior (pinned by the determinism audit in `tests/arena.rs`).
    image_cache: HashSet<String>,
    /// Attached stress-ng style stressors.
    pub stressors: Vec<Stressor>,
    /// Is the node serving? Downed nodes (fault injection) are filtered out
    /// of scheduling until they recover.
    up: bool,
}

impl Node {
    pub fn new(id: NodeId, name: &str, capacity: Resources) -> Node {
        let mut cgfs = CgroupFs::new();
        let kubepods = cgfs.create(cgfs.root(), "kubepods").unwrap();
        Node {
            id,
            name: name.to_string(),
            capacity,
            reserved: Resources::ZERO,
            cgfs,
            kubepods,
            image_cache: HashSet::new(),
            stressors: Vec::new(),
            up: true,
        }
    }

    /// Is the node currently serving (not crashed)?
    pub fn up(&self) -> bool {
        self.up
    }

    /// Marks the node up/down (fault injection: crash / recover).
    pub fn set_up(&mut self, up: bool) {
        self.up = up;
    }

    pub fn capacity(&self) -> Resources {
        self.capacity
    }

    pub fn reserved(&self) -> Resources {
        self.reserved
    }

    pub fn free(&self) -> Resources {
        self.capacity.saturating_sub(&self.reserved)
    }

    pub fn cores(&self) -> u32 {
        (self.capacity.cpu.0 / 1000) as u32
    }

    pub(crate) fn reserve(&mut self, r: Resources) {
        self.reserved += r;
    }

    pub(crate) fn release(&mut self, r: Resources) {
        self.reserved = self.reserved.saturating_sub(&r);
    }

    /// Creates `/kubepods/pod-<uid>` + one child per container, wiring
    /// weights from requests and `cpu.max` from limits. Returns the pod
    /// cgroup id and the per-container cgroup ids — ownership lives on
    /// the [`Pod`](crate::cluster::pod::Pod) itself, not in a per-node
    /// map, so lookups on the resize path are field reads.
    pub fn create_pod_cgroups(&mut self, pod: PodId, spec: &PodSpec) -> (CgroupId, Vec<CgroupId>) {
        let pod_cg = self
            .cgfs
            .create(self.kubepods, &format!("pod-{}", pod.0))
            .expect("kubepods exists");
        // Pod-level cpu.max = sum of container limits (kubelet behaviour).
        let total_limit = spec.total_limits().cpu;
        self.cgfs
            .write_cpu_max(pod_cg, CpuMax::from_millicpu(total_limit), SimTime::ZERO)
            .unwrap();
        let mut ctrs = Vec::new();
        for c in &spec.containers {
            let cg = self.cgfs.create(pod_cg, &c.name).unwrap();
            self.cgfs
                .write_cpu_max(cg, CpuMax::from_millicpu(c.limits.cpu), SimTime::ZERO)
                .unwrap();
            self.cgfs.write_weight(cg, c.cpu_weight().max(1)).unwrap();
            ctrs.push(cg);
        }
        (pod_cg, ctrs)
    }

    /// Tears down the pod's cgroup subtree (ids come from the pod).
    pub fn remove_pod_cgroups(&mut self, pod_cg: CgroupId, ctrs: &[CgroupId]) {
        for &c in ctrs {
            let _ = self.cgfs.remove(c);
        }
        let _ = self.cgfs.remove(pod_cg);
    }

    /// Applies a CPU limit resize to both the pod and main-container
    /// cgroups — the write whose propagation §4.1 measures. Callers go
    /// through [`Cluster::apply_cpu_limit`](crate::cluster::Cluster),
    /// which resolves the ids from the pod.
    pub fn write_cpu_limit(
        &mut self,
        pod_cg: CgroupId,
        ctr: CgroupId,
        new_limit: MilliCpu,
        now: SimTime,
    ) {
        self.cgfs
            .write_cpu_max(pod_cg, CpuMax::from_millicpu(new_limit), now)
            .unwrap();
        self.cgfs
            .write_cpu_max(ctr, CpuMax::from_millicpu(new_limit), now)
            .unwrap();
    }

    // -- image cache --------------------------------------------------------

    pub fn image_cached(&self, image: &str) -> bool {
        self.image_cache.contains(image)
    }

    pub fn cache_image(&mut self, image: &str) {
        self.image_cache.insert(image.to_string());
    }

    /// Drops every pulled image — a crashed node restarts with a cold
    /// image cache, so post-recovery cold starts pay the pull again.
    pub fn clear_image_cache(&mut self) {
        self.image_cache.clear();
    }

    // -- load ----------------------------------------------------------------

    pub fn attach_stressor(&mut self, s: Stressor) {
        self.stressors.push(s);
    }

    pub fn clear_stressors(&mut self) {
        self.stressors.clear();
    }

    /// Load descriptor for the resize-latency model, combining stressors
    /// with `busy_m` milliCPU of request-serving work currently running.
    pub fn load_with_busy(&self, busy_m: MilliCpu) -> NodeLoad {
        let mut load = Stressor::node_load(&self.stressors, self.cores().max(1));
        let cap = (self.cores().max(1) as f64) * 1000.0;
        load.cpu_utilization = (load.cpu_utilization + busy_m.0 as f64 / cap).min(1.0);
        load
    }

    pub fn load(&self) -> NodeLoad {
        self.load_with_busy(MilliCpu::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::Memory;

    fn node() -> Node {
        Node::new(
            NodeId(0),
            "n0",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        )
    }

    fn spec() -> PodSpec {
        PodSpec::single(
            "fn",
            "img:v1",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        )
    }

    #[test]
    fn cgroup_tree_wired_from_spec() {
        let mut n = node();
        let (cg, ctrs) = n.create_pod_cgroups(PodId(7), &spec());
        assert_eq!(n.cgfs.path_of(cg), "/kubepods/pod-7");
        let ctr = ctrs[0];
        assert_eq!(n.cgfs.path_of(ctr), "/kubepods/pod-7/fn");
        assert_eq!(
            n.cgfs.effective_limit(ctr).unwrap(),
            Some(MilliCpu(1000))
        );
    }

    #[test]
    fn write_cpu_limit_updates_both_levels() {
        let mut n = node();
        let (cg, ctrs) = n.create_pod_cgroups(PodId(1), &spec());
        n.write_cpu_limit(cg, ctrs[0], MilliCpu(1), SimTime::from_millis(9));
        assert_eq!(n.cgfs.effective_limit(ctrs[0]).unwrap(), Some(MilliCpu(1)));
        assert_eq!(
            n.cgfs.get(ctrs[0]).unwrap().last_write,
            SimTime::from_millis(9)
        );
        assert_eq!(n.cgfs.effective_limit(cg).unwrap(), Some(MilliCpu(1)));
    }

    #[test]
    fn remove_pod_cgroups_cleans_up() {
        let mut n = node();
        let (cg, ctrs) = n.create_pod_cgroups(PodId(1), &spec());
        n.remove_pod_cgroups(cg, &ctrs);
        assert!(n.cgfs.lookup("/kubepods/pod-1").is_err());
    }

    #[test]
    fn reserve_release_accounting() {
        let mut n = node();
        n.reserve(Resources::cpu_m(3000));
        assert_eq!(n.free().cpu, MilliCpu(5000));
        n.release(Resources::cpu_m(3000));
        assert_eq!(n.free().cpu, MilliCpu(8000));
        // Release never underflows.
        n.release(Resources::cpu_m(999_999));
        assert_eq!(n.free(), n.capacity());
    }

    #[test]
    fn image_cache() {
        let mut n = node();
        assert!(!n.image_cached("img:v1"));
        n.cache_image("img:v1");
        assert!(n.image_cached("img:v1"));
        n.clear_image_cache();
        assert!(!n.image_cached("img:v1"));
    }

    #[test]
    fn nodes_start_up_and_toggle() {
        let mut n = node();
        assert!(n.up());
        n.set_up(false);
        assert!(!n.up());
        n.set_up(true);
        assert!(n.up());
    }

    #[test]
    fn load_combines_stressors_and_busy_work() {
        let mut n = node();
        assert_eq!(n.load(), NodeLoad::IDLE);
        n.attach_stressor(Stressor::cpu_saturating(4));
        let load = n.load();
        assert!((load.cpu_utilization - 0.5).abs() < 1e-9);
        let load = n.load_with_busy(MilliCpu(2000));
        assert!((load.cpu_utilization - 0.75).abs() < 1e-9);
        n.clear_stressors();
        assert_eq!(n.load(), NodeLoad::IDLE);
    }
}
