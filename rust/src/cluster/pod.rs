//! Pods: spec, lifecycle phases, and the in-place resize status machine.

use crate::cgroup::CgroupId;
use crate::cluster::container::{ContainerSpec, RestartPolicy};
use crate::cluster::node::NodeId;
use crate::simclock::SimTime;
use crate::util::quantity::{MilliCpu, Resources};

/// Cluster-unique pod uid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u64);

/// Pod lifecycle (the subset the experiments traverse).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    /// Created, not yet bound to a node.
    Pending,
    /// Bound; kubelet has not started containers yet.
    Scheduled,
    /// Sandbox/image/container startup pipeline running.
    Creating,
    /// Containers up; readiness gate may still be closed.
    Running,
    Terminating,
    Dead,
}

/// k8s 1.27 `status.resize` — the in-place resize state machine.
///
/// Transitions (enforced by [`PodStatus::begin_resize`] /
/// [`PodStatus::finish_resize`], property-tested in the suite):
/// `None → Proposed → InProgress → None(done)`, or `Proposed → Infeasible`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeStatus {
    /// Patch accepted by the API server, kubelet not yet acting.
    Proposed,
    /// Kubelet is applying the new limits.
    InProgress,
    /// Node cannot satisfy the proposal (insufficient allocatable).
    Infeasible,
}

/// A pod spec: containers + restart policy.
#[derive(Debug, Clone, PartialEq)]
pub struct PodSpec {
    pub containers: Vec<ContainerSpec>,
    pub restart_policy: RestartPolicy,
}

impl PodSpec {
    /// Single-container pod (every function pod in the experiments, plus a
    /// queue-proxy sidecar is modelled at the knative layer).
    pub fn single(name: &str, image: &str, requests: Resources, limits: Resources) -> PodSpec {
        PodSpec {
            containers: vec![ContainerSpec::new(name, image, requests, limits)],
            restart_policy: RestartPolicy::Always,
        }
    }

    pub fn total_requests(&self) -> Resources {
        let mut total = Resources::ZERO;
        for c in &self.containers {
            total += c.requests;
        }
        total
    }

    pub fn total_limits(&self) -> Resources {
        let mut total = Resources::ZERO;
        for c in &self.containers {
            total += c.limits;
        }
        total
    }
}

/// Mutable pod status.
#[derive(Debug, Clone, PartialEq)]
pub struct PodStatus {
    pub phase: PodPhase,
    pub ready: bool,
    pub resize: Option<ResizeStatus>,
    /// CPU limit currently *in force* in the cgroup (may lag the spec while
    /// a resize is in flight — exactly the window the paper measures).
    pub applied_cpu_limit: MilliCpu,
    /// Virtual time until which the kubelet's per-pod resize mutex is held.
    /// Back-to-back resizes serialize on this (the in-place policy's
    /// scale-down → scale-up churn).
    pub resize_busy_until: SimTime,
}

#[derive(Debug, PartialEq)]
pub enum ResizeError {
    Busy,
    NotRunning,
    NotResizing,
}

impl std::fmt::Display for ResizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResizeError::Busy => write!(f, "resize already in flight"),
            ResizeError::NotRunning => write!(f, "pod not running"),
            ResizeError::NotResizing => write!(f, "no resize in flight"),
        }
    }
}

impl std::error::Error for ResizeError {}

impl PodStatus {
    fn new(initial_cpu_limit: MilliCpu) -> PodStatus {
        PodStatus {
            phase: PodPhase::Pending,
            ready: false,
            resize: None,
            applied_cpu_limit: initial_cpu_limit,
            resize_busy_until: SimTime::ZERO,
        }
    }

    /// API server accepted a resize patch.
    pub fn begin_resize(&mut self) -> Result<(), ResizeError> {
        if self.phase != PodPhase::Running {
            return Err(ResizeError::NotRunning);
        }
        match self.resize {
            None | Some(ResizeStatus::Infeasible) => {
                self.resize = Some(ResizeStatus::Proposed);
                Ok(())
            }
            Some(_) => Err(ResizeError::Busy),
        }
    }

    /// Kubelet picked the proposal up.
    pub fn start_applying(&mut self) -> Result<(), ResizeError> {
        match self.resize {
            Some(ResizeStatus::Proposed) => {
                self.resize = Some(ResizeStatus::InProgress);
                Ok(())
            }
            _ => Err(ResizeError::NotResizing),
        }
    }

    /// cgroup write landed; the new limit is in force.
    pub fn finish_resize(&mut self, new_limit: MilliCpu) -> Result<(), ResizeError> {
        match self.resize {
            Some(ResizeStatus::InProgress) => {
                self.resize = None;
                self.applied_cpu_limit = new_limit;
                Ok(())
            }
            _ => Err(ResizeError::NotResizing),
        }
    }

    /// Node rejected the proposal.
    pub fn mark_infeasible(&mut self) -> Result<(), ResizeError> {
        match self.resize {
            Some(ResizeStatus::Proposed) => {
                self.resize = Some(ResizeStatus::Infeasible);
                Ok(())
            }
            _ => Err(ResizeError::NotResizing),
        }
    }
}

/// A pod: spec + status + placement.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub spec: PodSpec,
    pub status: PodStatus,
    pub node: Option<NodeId>,
    /// Pod-level cgroup on the node (container cgroups are children).
    pub cgroup: Option<CgroupId>,
    /// Per-container cgroups, in spec order (main container first). Kept
    /// on the pod so resize-path lookups are field reads, not map probes.
    pub container_cgroups: Vec<CgroupId>,
    /// Resources reserved on the node at bind time (requests). In-place
    /// resize of *limits* does not change this — that asymmetry is the
    /// "enhanced resource availability" the paper claims.
    reserved: Resources,
    pub created_at: SimTime,
}

impl Pod {
    pub fn new(id: PodId, spec: PodSpec) -> Pod {
        let limit = spec
            .containers
            .first()
            .map(|c| c.limits.cpu)
            .unwrap_or(MilliCpu::ZERO);
        let reserved = spec.total_requests();
        Pod {
            id,
            spec,
            status: PodStatus::new(limit),
            node: None,
            cgroup: None,
            container_cgroups: Vec::new(),
            reserved,
            created_at: SimTime::ZERO,
        }
    }

    pub fn reserved(&self) -> Resources {
        self.reserved
    }

    /// The pod's primary (function) container.
    pub fn main_container(&self) -> &ContainerSpec {
        &self.spec.containers[0]
    }

    pub fn main_container_mut(&mut self) -> &mut ContainerSpec {
        &mut self.spec.containers[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::Memory;

    fn pod() -> Pod {
        let spec = PodSpec::single(
            "fn",
            "img",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        );
        Pod::new(PodId(0), spec)
    }

    #[test]
    fn initial_status() {
        let p = pod();
        assert_eq!(p.status.phase, PodPhase::Pending);
        assert_eq!(p.status.applied_cpu_limit, MilliCpu(1000));
        assert_eq!(p.status.resize, None);
        assert_eq!(p.reserved().cpu, MilliCpu(100));
    }

    #[test]
    fn resize_state_machine_happy_path() {
        let mut p = pod();
        p.status.phase = PodPhase::Running;
        p.status.begin_resize().unwrap();
        assert_eq!(p.status.resize, Some(ResizeStatus::Proposed));
        p.status.start_applying().unwrap();
        assert_eq!(p.status.resize, Some(ResizeStatus::InProgress));
        p.status.finish_resize(MilliCpu(1)).unwrap();
        assert_eq!(p.status.resize, None);
        assert_eq!(p.status.applied_cpu_limit, MilliCpu(1));
    }

    #[test]
    fn resize_rejected_when_not_running() {
        let mut p = pod();
        assert_eq!(p.status.begin_resize(), Err(ResizeError::NotRunning));
    }

    #[test]
    fn concurrent_resize_rejected() {
        let mut p = pod();
        p.status.phase = PodPhase::Running;
        p.status.begin_resize().unwrap();
        assert_eq!(p.status.begin_resize(), Err(ResizeError::Busy));
        p.status.start_applying().unwrap();
        assert_eq!(p.status.begin_resize(), Err(ResizeError::Busy));
    }

    #[test]
    fn infeasible_path_allows_retry() {
        let mut p = pod();
        p.status.phase = PodPhase::Running;
        p.status.begin_resize().unwrap();
        p.status.mark_infeasible().unwrap();
        assert_eq!(p.status.resize, Some(ResizeStatus::Infeasible));
        // A new proposal may replace an infeasible one.
        p.status.begin_resize().unwrap();
        assert_eq!(p.status.resize, Some(ResizeStatus::Proposed));
    }

    #[test]
    fn out_of_order_transitions_rejected() {
        let mut p = pod();
        p.status.phase = PodPhase::Running;
        assert_eq!(p.status.start_applying(), Err(ResizeError::NotResizing));
        assert_eq!(
            p.status.finish_resize(MilliCpu(1)),
            Err(ResizeError::NotResizing)
        );
        p.status.begin_resize().unwrap();
        assert_eq!(
            p.status.finish_resize(MilliCpu(1)),
            Err(ResizeError::NotResizing)
        );
    }

    #[test]
    fn spec_totals() {
        let spec = PodSpec {
            containers: vec![
                ContainerSpec::new(
                    "a",
                    "img",
                    Resources::cpu_m(100),
                    Resources::cpu_m(1000),
                ),
                ContainerSpec::new("b", "img", Resources::cpu_m(50), Resources::cpu_m(200)),
            ],
            restart_policy: RestartPolicy::Always,
        };
        assert_eq!(spec.total_requests().cpu, MilliCpu(150));
        assert_eq!(spec.total_limits().cpu, MilliCpu(1200));
    }
}
