//! A kube-scheduler-shaped pod scheduler: filter nodes that fit the pod's
//! requests, score the survivors, bind to the winner.

use thiserror::Error;

use crate::cluster::node::{Node, NodeId};
use crate::cluster::pod::PodId;
use crate::util::quantity::Resources;

/// Node scoring policies (kube-scheduler's two classic strategies).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScoringPolicy {
    /// Prefer emptier nodes — spreads load (kube default).
    #[default]
    LeastAllocated,
    /// Prefer fuller nodes — bin-packs, frees whole nodes.
    MostAllocated,
}

#[derive(Debug, Error, PartialEq)]
pub enum ScheduleError {
    #[error("no node fits pod {0:?}")]
    Unschedulable(PodId),
    #[error("pod {0:?} already bound")]
    AlreadyBound(PodId),
    #[error("no such pod {0:?}")]
    NoSuchPod(PodId),
}

/// The scheduler. Stateless between decisions; holds only the policy.
#[derive(Debug, Clone, Default)]
pub struct Scheduler {
    pub policy: ScoringPolicy,
}

impl Scheduler {
    pub fn new(policy: ScoringPolicy) -> Scheduler {
        Scheduler { policy }
    }

    /// Picks the best node for `requests`, or None if nothing fits.
    pub fn pick(&self, nodes: &[Node], requests: Resources) -> Option<NodeId> {
        let mut best: Option<(NodeId, f64)> = None;
        for n in nodes {
            if !requests.fits_in(&n.free()) {
                continue;
            }
            let score = self.score(n, requests);
            match best {
                Some((_, s)) if s >= score => {}
                _ => best = Some((n.id, score)),
            }
        }
        best.map(|(id, _)| id)
    }

    /// Higher is better. Uses CPU as the dominant axis (the paper's
    /// experiments are CPU-centric) with memory as a tiebreaker.
    fn score(&self, node: &Node, requests: Resources) -> f64 {
        let cap = node.capacity();
        if cap.cpu.0 == 0 {
            return 0.0;
        }
        let cpu_after = (node.reserved().cpu.0 + requests.cpu.0) as f64 / cap.cpu.0 as f64;
        let mem_after = if cap.memory.0 == 0 {
            0.0
        } else {
            (node.reserved().memory.0 + requests.memory.0) as f64 / cap.memory.0 as f64
        };
        let utilization = 0.75 * cpu_after + 0.25 * mem_after;
        match self.policy {
            ScoringPolicy::LeastAllocated => 1.0 - utilization,
            ScoringPolicy::MostAllocated => utilization,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu};

    fn node(id: u32, reserved_m: u64) -> Node {
        let mut n = Node::new(
            NodeId(id),
            "n",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        );
        n.reserve(Resources::cpu_m(reserved_m));
        n
    }

    #[test]
    fn least_allocated_prefers_empty_node() {
        let s = Scheduler::new(ScoringPolicy::LeastAllocated);
        let nodes = vec![node(0, 4000), node(1, 1000)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(1)));
    }

    #[test]
    fn most_allocated_prefers_full_node() {
        let s = Scheduler::new(ScoringPolicy::MostAllocated);
        let nodes = vec![node(0, 4000), node(1, 1000)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(0)));
    }

    #[test]
    fn filter_excludes_full_nodes() {
        let s = Scheduler::default();
        let nodes = vec![node(0, 7900), node(1, 1000)];
        // 500m doesn't fit on node 0 (only 100m free).
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(1)));
    }

    #[test]
    fn unschedulable_when_nothing_fits() {
        let s = Scheduler::default();
        let nodes = vec![node(0, 7900), node(1, 7900)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), None);
    }

    #[test]
    fn memory_is_a_tiebreaker() {
        let s = Scheduler::new(ScoringPolicy::LeastAllocated);
        let mut a = node(0, 1000);
        a.reserve(Resources::new(MilliCpu(0), Memory::from_gib(8)));
        let b = node(1, 1000);
        let nodes = vec![a, b];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(100)), Some(NodeId(1)));
    }
}
