//! A kube-scheduler-shaped pod scheduler: filter nodes that fit the pod's
//! requests, score the survivors, bind to the winner.

use std::fmt;

use crate::cluster::node::{Node, NodeId};
use crate::cluster::pod::PodId;
use crate::util::quantity::Resources;

/// Node scoring policies (kube-scheduler's two classic strategies).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScoringPolicy {
    /// Prefer emptier nodes — spreads load (kube default).
    #[default]
    LeastAllocated,
    /// Prefer fuller nodes — bin-packs, frees whole nodes.
    MostAllocated,
}

#[derive(Debug, PartialEq)]
pub enum ScheduleError {
    Unschedulable(PodId),
    AlreadyBound(PodId),
    NoSuchPod(PodId),
}

impl fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScheduleError::Unschedulable(p) => write!(f, "no node fits pod {p:?}"),
            ScheduleError::AlreadyBound(p) => write!(f, "pod {p:?} already bound"),
            ScheduleError::NoSuchPod(p) => write!(f, "no such pod {p:?}"),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// The scheduler. Stateless between decisions; holds only the policy.
#[derive(Debug, Clone, Default)]
pub struct Scheduler {
    pub policy: ScoringPolicy,
}

impl Scheduler {
    pub fn new(policy: ScoringPolicy) -> Scheduler {
        Scheduler { policy }
    }

    /// Picks the best node for `requests`, or None if nothing fits.
    ///
    /// Downed nodes (fault injection) are filtered out alongside nodes the
    /// pod does not fit on. Ties break on the lowest `NodeId` so placement
    /// is deterministic regardless of how the node slice was produced — on
    /// a fresh uniform fleet every scheduler in the simulation agrees on
    /// the same winner.
    pub fn pick(&self, nodes: &[Node], requests: Resources) -> Option<NodeId> {
        let mut best: Option<(NodeId, f64)> = None;
        for n in nodes {
            if !n.up() || !requests.fits_in(&n.free()) {
                continue;
            }
            let score = self.score(n, requests);
            best = match best {
                Some((id, s)) if score > s || (score == s && n.id < id) => Some((n.id, score)),
                None => Some((n.id, score)),
                keep => keep,
            };
        }
        best.map(|(id, _)| id)
    }

    /// Higher is better. Uses CPU as the dominant axis (the paper's
    /// experiments are CPU-centric) with memory as a tiebreaker.
    fn score(&self, node: &Node, requests: Resources) -> f64 {
        let cap = node.capacity();
        if cap.cpu.0 == 0 {
            return 0.0;
        }
        let cpu_after = (node.reserved().cpu.0 + requests.cpu.0) as f64 / cap.cpu.0 as f64;
        let mem_after = if cap.memory.0 == 0 {
            0.0
        } else {
            (node.reserved().memory.0 + requests.memory.0) as f64 / cap.memory.0 as f64
        };
        let utilization = 0.75 * cpu_after + 0.25 * mem_after;
        match self.policy {
            ScoringPolicy::LeastAllocated => 1.0 - utilization,
            ScoringPolicy::MostAllocated => utilization,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::{Memory, MilliCpu};

    fn node(id: u32, reserved_m: u64) -> Node {
        let mut n = Node::new(
            NodeId(id),
            "n",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        );
        n.reserve(Resources::cpu_m(reserved_m));
        n
    }

    #[test]
    fn least_allocated_prefers_empty_node() {
        let s = Scheduler::new(ScoringPolicy::LeastAllocated);
        let nodes = vec![node(0, 4000), node(1, 1000)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(1)));
    }

    #[test]
    fn most_allocated_prefers_full_node() {
        let s = Scheduler::new(ScoringPolicy::MostAllocated);
        let nodes = vec![node(0, 4000), node(1, 1000)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(0)));
    }

    #[test]
    fn filter_excludes_full_nodes() {
        let s = Scheduler::default();
        let nodes = vec![node(0, 7900), node(1, 1000)];
        // 500m doesn't fit on node 0 (only 100m free).
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(1)));
    }

    #[test]
    fn unschedulable_when_nothing_fits() {
        let s = Scheduler::default();
        let nodes = vec![node(0, 7900), node(1, 7900)];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), None);
    }

    #[test]
    fn equal_scores_break_to_lowest_node_id() {
        // Identical reservations on every node ⇒ identical scores; the
        // lowest NodeId must win under both scoring policies, and the
        // winner must not depend on slice order tricks like reversal of
        // equally-scored peers.
        for policy in [ScoringPolicy::LeastAllocated, ScoringPolicy::MostAllocated] {
            let s = Scheduler::new(policy);
            let nodes = vec![node(0, 2000), node(1, 2000), node(2, 2000)];
            assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(0)));
            // Same fleet presented in reverse order: still the lowest id.
            let rev = vec![node(2, 2000), node(1, 2000), node(0, 2000)];
            assert_eq!(s.pick(&rev, Resources::cpu_m(500)), Some(NodeId(0)));
        }
    }

    #[test]
    fn downed_nodes_are_filtered() {
        let s = Scheduler::default();
        // Node 1 would win on score, but it is down (crashed).
        let mut nodes = vec![node(0, 4000), node(1, 1000)];
        nodes[1].set_up(false);
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(0)));
        // Whole fleet down ⇒ unschedulable even though capacity is free.
        nodes[0].set_up(false);
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), None);
        // Recovery restores the original pick.
        nodes[0].set_up(true);
        nodes[1].set_up(true);
        assert_eq!(s.pick(&nodes, Resources::cpu_m(500)), Some(NodeId(1)));
    }

    #[test]
    fn memory_is_a_tiebreaker() {
        let s = Scheduler::new(ScoringPolicy::LeastAllocated);
        let mut a = node(0, 1000);
        a.reserve(Resources::new(MilliCpu(0), Memory::from_gib(8)));
        let b = node(1, 1000);
        let nodes = vec![a, b];
        assert_eq!(s.pick(&nodes, Resources::cpu_m(100)), Some(NodeId(1)));
    }
}
