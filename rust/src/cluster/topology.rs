//! Cluster topologies: the shape of the fleet the platform runs on.
//!
//! The paper's testbed is a single 8-core / 10 GB `kind` node —
//! [`Topology::paper`] reproduces it exactly. Everything beyond the paper
//! (the fleet experiments, the multi-node scheduler path, heterogeneous
//! node pools) is expressed as a [`Topology`]: an ordered list of
//! [`NodeShape`]s that [`Topology::build`] materializes into a
//! [`Cluster`]. Node order is placement order — [`NodeId`]s are assigned
//! ascending, which is what the scheduler's lowest-id tie-break keys on.

use crate::cgroup::latency::LatencyModel;
use crate::cluster::kubelet::StartupParams;
use crate::cluster::{Cluster, NodeId};
use crate::util::quantity::{Memory, MilliCpu, Resources};

/// One node's shape: a name prefix, its capacity, and optional per-node
/// calibration overrides over the shared `PlatformParams` — a fleet may mix
/// genuinely slow and fast machines (different startup pipelines, different
/// resize propagation) without forking the platform-wide calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeShape {
    pub name: String,
    pub capacity: Resources,
    /// Cold-start pipeline override for this node's kubelet
    /// (`None` ⇒ the shared `PlatformParams::startup`, possibly scaled by
    /// [`NodeShape::calibration_scale`]).
    pub startup: Option<StartupParams>,
    /// Resize-propagation override for this node's kubelet
    /// (`None` ⇒ the shared `PlatformParams::resize`, possibly scaled by
    /// [`NodeShape::calibration_scale`]).
    pub resize: Option<LatencyModel>,
    /// Relative speed of this node: both shared pipelines are scaled by
    /// this factor at platform build time (`> 1` ⇒ slower, `< 1` ⇒ faster).
    /// Unlike the explicit overrides above, the scale composes with
    /// whatever `PlatformParams` the platform actually runs.
    pub calibration_scale: Option<f64>,
}

impl NodeShape {
    pub fn new(name: &str, capacity: Resources) -> NodeShape {
        NodeShape {
            name: name.to_string(),
            capacity,
            startup: None,
            resize: None,
            calibration_scale: None,
        }
    }

    /// The paper's worker shape: 8 cores, 10 GB.
    pub fn paper_worker(name: &str) -> NodeShape {
        NodeShape::new(name, Resources::new(MilliCpu(8000), Memory::from_gib(10)))
    }

    /// Overrides this node's cold-start pipeline calibration.
    pub fn with_startup(mut self, startup: StartupParams) -> NodeShape {
        self.startup = Some(startup);
        self
    }

    /// Overrides this node's resize-propagation calibration.
    pub fn with_resize(mut self, resize: LatencyModel) -> NodeShape {
        self.resize = Some(resize);
        self
    }

    /// Convenience: both pipelines at `factor` × the platform's shared
    /// calibration (`factor > 1` ⇒ a slower node, `< 1` ⇒ faster
    /// hardware). Applied against the actual `PlatformParams` at build
    /// time, so custom calibrations stay the baseline.
    pub fn calibrated(mut self, factor: f64) -> NodeShape {
        self.calibration_scale = Some(factor);
        self
    }

    /// The startup pipeline this node's kubelet runs, given the shared
    /// platform calibration: explicit override > scaled shared > shared.
    pub fn effective_startup(&self, shared: &StartupParams) -> StartupParams {
        if let Some(s) = &self.startup {
            return s.clone();
        }
        match self.calibration_scale {
            Some(f) => shared.scaled(f),
            None => shared.clone(),
        }
    }

    /// The resize-latency model this node's kubelet runs, given the shared
    /// platform calibration: explicit override > scaled shared > shared.
    pub fn effective_resize(&self, shared: &LatencyModel) -> LatencyModel {
        if let Some(m) = &self.resize {
            return m.clone();
        }
        match self.calibration_scale {
            Some(f) => shared.scaled(f),
            None => shared.clone(),
        }
    }
}

/// An ordered fleet description.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    nodes: Vec<NodeShape>,
}

impl Topology {
    /// The paper's testbed: exactly one 8-core / 10 GB `kind` worker.
    pub fn paper() -> Topology {
        Topology {
            nodes: vec![NodeShape::paper_worker("kind-worker")],
        }
    }

    /// `n` identical nodes of the given capacity, named `node-0..node-n`.
    pub fn uniform(n: usize, capacity: Resources) -> Topology {
        assert!(n > 0, "a topology needs at least one node");
        Topology {
            nodes: (0..n)
                .map(|i| NodeShape::new(&format!("node-{i}"), capacity))
                .collect(),
        }
    }

    /// `n` paper-shaped workers — the fleet the §3 policies are swept over.
    pub fn uniform_paper(n: usize) -> Topology {
        Topology::uniform(n, Resources::new(MilliCpu(8000), Memory::from_gib(10)))
    }

    /// An explicit list of node shapes (heterogeneous pools).
    pub fn heterogeneous(nodes: Vec<NodeShape>) -> Topology {
        assert!(!nodes.is_empty(), "a topology needs at least one node");
        Topology { nodes }
    }

    /// A mixed pool alternating large (16-core / 32 GiB), paper (8-core /
    /// 10 GB) and small (4-core / 8 GiB) shapes — the heterogeneous preset
    /// behind `--topology hetero`. The shapes are genuinely heterogeneous
    /// in *time* too: large nodes run faster pipelines (0.85× the shared
    /// startup/resize calibration), small nodes slower ones (1.5×), while
    /// the paper shape keeps the shared `PlatformParams` unscaled.
    pub fn hetero_preset(n: usize) -> Topology {
        assert!(n > 0, "a topology needs at least one node");
        let nodes = (0..n)
            .map(|i| {
                let name = format!("node-{i}");
                match i % 3 {
                    0 => NodeShape::new(
                        &name,
                        Resources::new(MilliCpu(16_000), Memory::from_gib(32)),
                    )
                    .calibrated(0.85),
                    1 => NodeShape::new(&name, Resources::new(MilliCpu(8000), Memory::from_gib(10))),
                    _ => NodeShape::new(&name, Resources::new(MilliCpu(4000), Memory::from_gib(8)))
                        .calibrated(1.5),
                }
            })
            .collect();
        Topology { nodes }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn shapes(&self) -> &[NodeShape] {
        &self.nodes
    }

    /// Sum of node capacities.
    pub fn total_capacity(&self) -> Resources {
        let mut total = Resources::ZERO;
        for n in &self.nodes {
            total += n.capacity;
        }
        total
    }

    /// Materializes the fleet: nodes are added in order, so `NodeId(i)`
    /// corresponds to `shapes()[i]`.
    pub fn build(&self) -> Cluster {
        let mut cluster = Cluster::new();
        for shape in &self.nodes {
            cluster.add_node(&shape.name, shape.capacity);
        }
        cluster
    }

    /// Capacity of node `i` (panics on out-of-range, like `Cluster::node`).
    pub fn capacity_of(&self, id: NodeId) -> Resources {
        self.nodes[id.0 as usize].capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_seed_testbed() {
        let t = Topology::paper();
        assert_eq!(t.len(), 1);
        let c = t.build();
        assert_eq!(c.nodes().len(), 1);
        assert_eq!(c.node(NodeId(0)).name, "kind-worker");
        assert_eq!(c.node(NodeId(0)).capacity().cpu, MilliCpu(8000));
        assert_eq!(c.node(NodeId(0)).capacity().memory, Memory::from_gib(10));
    }

    #[test]
    fn uniform_builds_n_identical_nodes() {
        let t = Topology::uniform_paper(10);
        assert_eq!(t.len(), 10);
        let c = t.build();
        assert_eq!(c.nodes().len(), 10);
        for (i, n) in c.nodes().iter().enumerate() {
            assert_eq!(n.id, NodeId(i as u32));
            assert_eq!(n.capacity().cpu, MilliCpu(8000));
        }
        assert_eq!(t.total_capacity().cpu, MilliCpu(80_000));
    }

    #[test]
    fn heterogeneous_preserves_order_and_shapes() {
        let t = Topology::heterogeneous(vec![
            NodeShape::new("big", Resources::new(MilliCpu(16_000), Memory::from_gib(32))),
            NodeShape::new("small", Resources::new(MilliCpu(2000), Memory::from_gib(4))),
        ]);
        let c = t.build();
        assert_eq!(c.node(NodeId(0)).name, "big");
        assert_eq!(c.node(NodeId(1)).capacity().cpu, MilliCpu(2000));
        assert_eq!(t.capacity_of(NodeId(1)).cpu, MilliCpu(2000));
    }

    #[test]
    fn hetero_preset_cycles_shapes() {
        let t = Topology::hetero_preset(7);
        assert_eq!(t.len(), 7);
        assert_eq!(t.shapes()[0].capacity.cpu, MilliCpu(16_000));
        assert_eq!(t.shapes()[1].capacity.cpu, MilliCpu(8000));
        assert_eq!(t.shapes()[2].capacity.cpu, MilliCpu(4000));
        assert_eq!(t.shapes()[3].capacity.cpu, MilliCpu(16_000));
        // Large nodes are calibrated fast, small slow, paper shape shared.
        assert_eq!(t.shapes()[0].calibration_scale, Some(0.85));
        assert_eq!(t.shapes()[1].calibration_scale, None);
        assert_eq!(t.shapes()[2].calibration_scale, Some(1.5));
        let shared = StartupParams::default();
        let fast = t.shapes()[0].effective_startup(&shared);
        let slow = t.shapes()[2].effective_startup(&shared);
        assert!(fast.sandbox_ms < shared.sandbox_ms && shared.sandbox_ms < slow.sandbox_ms);
    }

    #[test]
    fn paper_topology_carries_no_calibration_overrides() {
        // The golden reproduction path must keep sharing PlatformParams.
        for shape in Topology::paper()
            .shapes()
            .iter()
            .chain(Topology::uniform_paper(4).shapes())
        {
            assert!(shape.startup.is_none());
            assert!(shape.resize.is_none());
            assert!(shape.calibration_scale.is_none());
            let shared = StartupParams::default();
            assert_eq!(shape.effective_startup(&shared), shared);
        }
    }

    #[test]
    fn calibration_scales_the_shared_params_not_the_defaults() {
        let shape = NodeShape::paper_worker("n").calibrated(2.0);
        // A custom (non-default) platform calibration stays the baseline.
        let shared = StartupParams {
            sandbox_ms: 100.0,
            ..StartupParams::default()
        };
        let s = shape.effective_startup(&shared);
        assert!((s.sandbox_ms - 200.0).abs() < 1e-9);
        assert!((s.schedule_ms - 2.0 * shared.schedule_ms).abs() < 1e-9);
        // Jitter shape is preserved, only means scale.
        assert!((s.jitter_cv - shared.jitter_cv).abs() < 1e-12);
        let base = LatencyModel::new(crate::cgroup::latency::LatencyParams {
            sync_mean_ms: 10.0,
            ..Default::default()
        });
        let r = shape.effective_resize(&base);
        assert!((r.params.sync_mean_ms - 20.0).abs() < 1e-9);
        assert!((r.params.alpha_down - base.params.alpha_down).abs() < 1e-12);
        // An explicit override beats the scale.
        let shape = shape.with_startup(StartupParams::default());
        assert_eq!(shape.effective_startup(&shared), StartupParams::default());
    }
}
