//! Cluster topologies: the shape of the fleet the platform runs on.
//!
//! The paper's testbed is a single 8-core / 10 GB `kind` node —
//! [`Topology::paper`] reproduces it exactly. Everything beyond the paper
//! (the fleet experiments, the multi-node scheduler path, heterogeneous
//! node pools) is expressed as a [`Topology`]: an ordered list of
//! [`NodeShape`]s that [`Topology::build`] materializes into a
//! [`Cluster`]. Node order is placement order — [`NodeId`]s are assigned
//! ascending, which is what the scheduler's lowest-id tie-break keys on.

use crate::cluster::{Cluster, NodeId};
use crate::util::quantity::{Memory, MilliCpu, Resources};

/// One node's shape: a name prefix and its capacity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeShape {
    pub name: String,
    pub capacity: Resources,
}

impl NodeShape {
    pub fn new(name: &str, capacity: Resources) -> NodeShape {
        NodeShape {
            name: name.to_string(),
            capacity,
        }
    }

    /// The paper's worker shape: 8 cores, 10 GB.
    pub fn paper_worker(name: &str) -> NodeShape {
        NodeShape::new(name, Resources::new(MilliCpu(8000), Memory::from_gib(10)))
    }
}

/// An ordered fleet description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    nodes: Vec<NodeShape>,
}

impl Topology {
    /// The paper's testbed: exactly one 8-core / 10 GB `kind` worker.
    pub fn paper() -> Topology {
        Topology {
            nodes: vec![NodeShape::paper_worker("kind-worker")],
        }
    }

    /// `n` identical nodes of the given capacity, named `node-0..node-n`.
    pub fn uniform(n: usize, capacity: Resources) -> Topology {
        assert!(n > 0, "a topology needs at least one node");
        Topology {
            nodes: (0..n)
                .map(|i| NodeShape::new(&format!("node-{i}"), capacity))
                .collect(),
        }
    }

    /// `n` paper-shaped workers — the fleet the §3 policies are swept over.
    pub fn uniform_paper(n: usize) -> Topology {
        Topology::uniform(n, Resources::new(MilliCpu(8000), Memory::from_gib(10)))
    }

    /// An explicit list of node shapes (heterogeneous pools).
    pub fn heterogeneous(nodes: Vec<NodeShape>) -> Topology {
        assert!(!nodes.is_empty(), "a topology needs at least one node");
        Topology { nodes }
    }

    /// A mixed pool alternating large (16-core / 32 GiB), paper (8-core /
    /// 10 GB) and small (4-core / 8 GiB) shapes — the heterogeneous preset
    /// behind `--topology hetero`.
    pub fn hetero_preset(n: usize) -> Topology {
        assert!(n > 0, "a topology needs at least one node");
        let shapes = [
            Resources::new(MilliCpu(16_000), Memory::from_gib(32)),
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
            Resources::new(MilliCpu(4000), Memory::from_gib(8)),
        ];
        Topology {
            nodes: (0..n)
                .map(|i| NodeShape::new(&format!("node-{i}"), shapes[i % shapes.len()]))
                .collect(),
        }
    }

    /// Parses a `--topology` CLI value: `paper`, `uniform`, or `hetero`
    /// (`nodes` sizes the latter two).
    pub fn from_cli(spec: &str, nodes: usize) -> Result<Topology, String> {
        match spec.to_ascii_lowercase().as_str() {
            "paper" => Ok(Topology::paper()),
            "uniform" => Ok(Topology::uniform_paper(nodes.max(1))),
            "hetero" | "heterogeneous" => Ok(Topology::hetero_preset(nodes.max(1))),
            other => Err(format!(
                "unknown topology: {other} (expected paper|uniform|hetero)"
            )),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn shapes(&self) -> &[NodeShape] {
        &self.nodes
    }

    /// Sum of node capacities.
    pub fn total_capacity(&self) -> Resources {
        let mut total = Resources::ZERO;
        for n in &self.nodes {
            total += n.capacity;
        }
        total
    }

    /// Materializes the fleet: nodes are added in order, so `NodeId(i)`
    /// corresponds to `shapes()[i]`.
    pub fn build(&self) -> Cluster {
        let mut cluster = Cluster::new();
        for shape in &self.nodes {
            cluster.add_node(&shape.name, shape.capacity);
        }
        cluster
    }

    /// Capacity of node `i` (panics on out-of-range, like `Cluster::node`).
    pub fn capacity_of(&self, id: NodeId) -> Resources {
        self.nodes[id.0 as usize].capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_seed_testbed() {
        let t = Topology::paper();
        assert_eq!(t.len(), 1);
        let c = t.build();
        assert_eq!(c.nodes().len(), 1);
        assert_eq!(c.node(NodeId(0)).name, "kind-worker");
        assert_eq!(c.node(NodeId(0)).capacity().cpu, MilliCpu(8000));
        assert_eq!(c.node(NodeId(0)).capacity().memory, Memory::from_gib(10));
    }

    #[test]
    fn uniform_builds_n_identical_nodes() {
        let t = Topology::uniform_paper(10);
        assert_eq!(t.len(), 10);
        let c = t.build();
        assert_eq!(c.nodes().len(), 10);
        for (i, n) in c.nodes().iter().enumerate() {
            assert_eq!(n.id, NodeId(i as u32));
            assert_eq!(n.capacity().cpu, MilliCpu(8000));
        }
        assert_eq!(t.total_capacity().cpu, MilliCpu(80_000));
    }

    #[test]
    fn heterogeneous_preserves_order_and_shapes() {
        let t = Topology::heterogeneous(vec![
            NodeShape::new("big", Resources::new(MilliCpu(16_000), Memory::from_gib(32))),
            NodeShape::new("small", Resources::new(MilliCpu(2000), Memory::from_gib(4))),
        ]);
        let c = t.build();
        assert_eq!(c.node(NodeId(0)).name, "big");
        assert_eq!(c.node(NodeId(1)).capacity().cpu, MilliCpu(2000));
        assert_eq!(t.capacity_of(NodeId(1)).cpu, MilliCpu(2000));
    }

    #[test]
    fn hetero_preset_cycles_shapes() {
        let t = Topology::hetero_preset(7);
        assert_eq!(t.len(), 7);
        assert_eq!(t.shapes()[0].capacity.cpu, MilliCpu(16_000));
        assert_eq!(t.shapes()[1].capacity.cpu, MilliCpu(8000));
        assert_eq!(t.shapes()[2].capacity.cpu, MilliCpu(4000));
        assert_eq!(t.shapes()[3].capacity.cpu, MilliCpu(16_000));
    }

    #[test]
    fn cli_parsing() {
        assert_eq!(Topology::from_cli("paper", 99).unwrap(), Topology::paper());
        assert_eq!(Topology::from_cli("uniform", 10).unwrap().len(), 10);
        assert_eq!(Topology::from_cli("hetero", 5).unwrap().len(), 5);
        assert!(Topology::from_cli("ring", 3).is_err());
    }
}
