//! The locality-aware fleet hot path: routing policies and incremental
//! per-node accounting.
//!
//! Before this module, every hot-path consumer of fleet state paid a full
//! scan: `node_load` walked every pod of every service to find busy CPU on
//! one node, `committed_changed` re-summed every applied limit on each
//! resize landing, and the activator's `pick_pod` knew nothing about
//! placement. On a 100-node fleet that is O(total pods) per *event*.
//!
//! [`FleetAccounting`] replaces the scans with counters maintained
//! incrementally at the five places fleet state actually changes —
//! dispatch, complete, resize landing, pod up, pod teardown — so every
//! read is O(1). The differential property test in
//! `tests/prop_invariants.rs` pins the counters to a from-scratch rescan
//! ([`Platform::rescan_accounting`]) after randomized event sequences.
//!
//! [`RoutingPolicy`] is the knob the activator's scored
//! [`pick_pod_with`](crate::coordinator::Service::pick_pod_with) reads:
//! `least-loaded` reproduces Knative's in-flight-count balancing exactly
//! (the seeded paper metrics are pinned to it), `locality` routes to the
//! pod on the node with the most free capacity per in-flight request, and
//! `hybrid` blends pod load, node pressure and resize state.

use crate::cluster::pod::{PodId, PodPhase};
use crate::cluster::topology::Topology;
use crate::cluster::NodeId;
use crate::coordinator::platform::Platform;
use crate::util::nohash::IdHashMap;
use crate::util::quantity::MilliCpu;

/// How the activator picks among a service's ready pods.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoutingPolicy {
    /// Knative's stock activator: fewest in-flight requests, lowest pod
    /// index on ties. The paper-reproduction default — golden metrics are
    /// pinned under this policy.
    LeastLoaded,
    /// Placement-aware: prefer the pod whose node has the lowest pressure
    /// (in-flight per milliCPU of capacity), then pod load, then pods not
    /// mid-resize.
    Locality,
    /// Weighted blend: pod in-flight dominates, node pressure and resize
    /// state break near-ties.
    Hybrid,
}

impl RoutingPolicy {
    pub const ALL: [RoutingPolicy; 3] = [
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::Locality,
        RoutingPolicy::Hybrid,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::Locality => "locality",
            RoutingPolicy::Hybrid => "hybrid",
        }
    }
}

impl std::str::FromStr for RoutingPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "least-loaded" | "leastloaded" | "least_loaded" => Ok(RoutingPolicy::LeastLoaded),
            "locality" => Ok(RoutingPolicy::Locality),
            "hybrid" => Ok(RoutingPolicy::Hybrid),
            other => Err(format!(
                "unknown routing policy: {other} (expected least-loaded|locality|hybrid)"
            )),
        }
    }
}

/// The [`RoutingPolicy::Hybrid`] blend weights — scenario-tunable so the
/// routing-saturation sweep can search the weight space instead of
/// recompiling. Score (lower wins):
/// `in_flight × in_flight_w + node_pressure / pressure_div + resize × resize_w`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HybridWeights {
    /// Weight on the pod's own in-flight count (dominant term).
    pub in_flight: u64,
    /// Divisor applied to the node-pressure signal (smaller ⇒ stronger).
    pub pressure_div: u64,
    /// Penalty added while a resize is pending/retrying on the pod.
    pub resize: u64,
}

impl Default for HybridWeights {
    /// The constants the hybrid score shipped with — the golden baseline.
    fn default() -> HybridWeights {
        HybridWeights {
            in_flight: 1000,
            pressure_div: 4,
            resize: 500,
        }
    }
}

/// Incrementally maintained per-node aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeCounters {
    /// Requests in flight (active + queued) on pods bound to this node.
    pub in_flight: u64,
    /// Σ applied CPU limits of pods currently serving at least one request
    /// — the `busy` input of the resize-latency model's `NodeLoad`.
    pub busy_mcpu: MilliCpu,
    /// Σ applied CPU limits of live (Running, non-terminating) pods.
    pub committed_mcpu: MilliCpu,
    /// Static node capacity, captured from the topology at build time.
    pub capacity_mcpu: MilliCpu,
}

impl NodeCounters {
    fn new(capacity: MilliCpu) -> NodeCounters {
        NodeCounters {
            in_flight: 0,
            busy_mcpu: MilliCpu::ZERO,
            committed_mcpu: MilliCpu::ZERO,
            capacity_mcpu: capacity,
        }
    }

    /// Load pressure for locality scoring: in-flight requests per unit of
    /// capacity (×10⁶ to stay integral). Bigger nodes absorb more load
    /// before looking pressured — the heterogeneous-fleet affinity signal.
    pub fn pressure(&self) -> u64 {
        self.in_flight
            .saturating_mul(1_000_000)
            .checked_div(self.capacity_mcpu.0)
            .unwrap_or(u64::MAX)
    }
}

/// One tracked pod: alive from `pod_up` (readiness) until terminating or
/// deletion. Terminating pods are dropped immediately — they are idle by
/// construction and excluded from every aggregate, matching the scans this
/// subsystem replaced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PodEntry {
    node: NodeId,
    applied: MilliCpu,
    in_flight: u32,
}

/// O(1) per-event fleet accounting (see module docs).
#[derive(Debug, Clone)]
pub struct FleetAccounting {
    nodes: Vec<NodeCounters>,
    pods: IdHashMap<PodId, PodEntry>,
    committed: MilliCpu,
}

impl FleetAccounting {
    /// Zeroed counters for every node of `topology`.
    pub fn for_topology(topology: &Topology) -> FleetAccounting {
        FleetAccounting {
            nodes: topology
                .shapes()
                .iter()
                .map(|s| NodeCounters::new(s.capacity.cpu))
                .collect(),
            pods: IdHashMap::default(),
            committed: MilliCpu::ZERO,
        }
    }

    pub fn node(&self, id: NodeId) -> &NodeCounters {
        &self.nodes[id.0 as usize]
    }

    pub fn nodes(&self) -> &[NodeCounters] {
        &self.nodes
    }

    /// Total committed CPU (Σ applied limits of live pods) — what
    /// `committed_changed` used to recompute by scanning every service.
    pub fn committed_total(&self) -> MilliCpu {
        self.committed
    }

    /// Number of tracked (live, non-terminating) pods.
    pub fn tracked_pods(&self) -> usize {
        self.pods.len()
    }

    // ------------------------------------------------------------- events

    /// A pod became ready on `node` with `applied` CPU limit in force.
    pub fn pod_up(&mut self, pod: PodId, node: NodeId, applied: MilliCpu) {
        self.nodes[node.0 as usize].committed_mcpu += applied;
        self.committed += applied;
        self.pods.insert(
            pod,
            PodEntry {
                node,
                applied,
                in_flight: 0,
            },
        );
    }

    /// A pod entered termination (scale-to-zero). Terminating pods are idle,
    /// but fold out any residual load defensively so the counters can never
    /// drift from the rescan definitions.
    pub fn pod_terminating(&mut self, pod: PodId) {
        if let Some(e) = self.pods.remove(&pod) {
            let n = &mut self.nodes[e.node.0 as usize];
            n.in_flight = n.in_flight.saturating_sub(e.in_flight as u64);
            if e.in_flight > 0 {
                n.busy_mcpu = n.busy_mcpu.saturating_sub(e.applied);
            }
            n.committed_mcpu = n.committed_mcpu.saturating_sub(e.applied);
            self.committed = self.committed.saturating_sub(e.applied);
        }
    }

    /// A pod was deleted. No-op when termination already untracked it.
    pub fn pod_gone(&mut self, pod: PodId) {
        self.pod_terminating(pod);
    }

    /// A request was admitted into the pod's queue-proxy (active or queued).
    pub fn dispatched(&mut self, pod: PodId) {
        if let Some(e) = self.pods.get_mut(&pod) {
            e.in_flight += 1;
            let n = &mut self.nodes[e.node.0 as usize];
            n.in_flight += 1;
            if e.in_flight == 1 {
                n.busy_mcpu += e.applied;
            }
        }
    }

    /// A request left the pod's queue-proxy.
    pub fn completed(&mut self, pod: PodId) {
        if let Some(e) = self.pods.get_mut(&pod) {
            e.in_flight = e.in_flight.saturating_sub(1);
            let n = &mut self.nodes[e.node.0 as usize];
            n.in_flight = n.in_flight.saturating_sub(1);
            if e.in_flight == 0 {
                n.busy_mcpu = n.busy_mcpu.saturating_sub(e.applied);
            }
        }
    }

    /// An in-place resize landed: the pod's applied limit changed.
    pub fn resize_landed(&mut self, pod: PodId, new: MilliCpu) {
        if let Some(e) = self.pods.get_mut(&pod) {
            let n = &mut self.nodes[e.node.0 as usize];
            if e.in_flight > 0 {
                n.busy_mcpu = (n.busy_mcpu + new).saturating_sub(e.applied);
            }
            n.committed_mcpu = (n.committed_mcpu + new).saturating_sub(e.applied);
            self.committed = (self.committed + new).saturating_sub(e.applied);
            e.applied = new;
        }
    }

    // ---------------------------------------------------------- diffing

    fn sorted_pods(&self) -> Vec<(PodId, PodEntry)> {
        let mut v: Vec<(PodId, PodEntry)> = self.pods.iter().map(|(k, v)| (*k, *v)).collect();
        v.sort_by_key(|(k, _)| *k);
        v
    }

    /// First discrepancy against `oracle` (a from-scratch rescan), or
    /// `None` when the two agree exactly. Drives the differential test.
    pub fn diff(&self, oracle: &FleetAccounting) -> Option<String> {
        if self.committed != oracle.committed {
            return Some(format!(
                "committed total: incremental {} vs rescan {}",
                self.committed, oracle.committed
            ));
        }
        for (i, (a, b)) in self.nodes.iter().zip(&oracle.nodes).enumerate() {
            if a != b {
                return Some(format!(
                    "node {i}: incremental {a:?} vs rescan {b:?}"
                ));
            }
        }
        let (a, b) = (self.sorted_pods(), oracle.sorted_pods());
        if a != b {
            for (x, y) in a.iter().zip(&b) {
                if x != y {
                    return Some(format!("pod entry: incremental {x:?} vs rescan {y:?}"));
                }
            }
            return Some(format!(
                "tracked pod sets differ: incremental {} pods vs rescan {}",
                a.len(),
                b.len()
            ));
        }
        None
    }
}

impl PartialEq for FleetAccounting {
    fn eq(&self, other: &FleetAccounting) -> bool {
        self.diff(other).is_none()
    }
}

impl Platform {
    /// From-scratch recomputation of the fleet counters — the O(total pods)
    /// scan the incremental path replaced. Kept as the test oracle and for
    /// the `fleet_scale` bench's speedup report.
    pub fn rescan_accounting(&self) -> FleetAccounting {
        let mut acct = FleetAccounting::for_topology(&self.topology);
        for svc in self.services.values() {
            for sp in &svc.pods {
                if sp.terminating {
                    continue;
                }
                let Some(node) = sp.node else { continue };
                let Some(pod) = self.cluster.pod(sp.pod) else { continue };
                if pod.status.phase != PodPhase::Running {
                    continue;
                }
                let applied = pod.status.applied_cpu_limit;
                let in_flight = sp.proxy.in_flight() as u32;
                let n = &mut acct.nodes[node.0 as usize];
                n.in_flight += in_flight as u64;
                if in_flight > 0 {
                    n.busy_mcpu += applied;
                }
                n.committed_mcpu += applied;
                acct.committed += applied;
                acct.pods.insert(
                    sp.pod,
                    PodEntry {
                        node,
                        applied,
                        in_flight,
                    },
                );
            }
        }
        acct
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acct2() -> FleetAccounting {
        FleetAccounting::for_topology(&Topology::uniform_paper(2))
    }

    #[test]
    fn pod_lifecycle_updates_counters() {
        let mut a = acct2();
        a.pod_up(PodId(1), NodeId(0), MilliCpu(1000));
        assert_eq!(a.committed_total(), MilliCpu(1000));
        assert_eq!(a.node(NodeId(0)).committed_mcpu, MilliCpu(1000));
        assert_eq!(a.node(NodeId(0)).busy_mcpu, MilliCpu::ZERO);

        a.dispatched(PodId(1));
        assert_eq!(a.node(NodeId(0)).in_flight, 1);
        assert_eq!(a.node(NodeId(0)).busy_mcpu, MilliCpu(1000));
        // Second request on the same pod does not double-count busy CPU.
        a.dispatched(PodId(1));
        assert_eq!(a.node(NodeId(0)).in_flight, 2);
        assert_eq!(a.node(NodeId(0)).busy_mcpu, MilliCpu(1000));

        a.completed(PodId(1));
        a.completed(PodId(1));
        assert_eq!(a.node(NodeId(0)).in_flight, 0);
        assert_eq!(a.node(NodeId(0)).busy_mcpu, MilliCpu::ZERO);

        a.pod_terminating(PodId(1));
        assert_eq!(a.committed_total(), MilliCpu::ZERO);
        assert_eq!(a.tracked_pods(), 0);
        // Deletion after termination is a no-op.
        a.pod_gone(PodId(1));
        assert_eq!(a.committed_total(), MilliCpu::ZERO);
    }

    #[test]
    fn resize_landing_moves_committed_and_busy() {
        let mut a = acct2();
        a.pod_up(PodId(3), NodeId(1), MilliCpu(1000));
        // Park while idle: committed follows, busy stays zero.
        a.resize_landed(PodId(3), MilliCpu(1));
        assert_eq!(a.committed_total(), MilliCpu(1));
        assert_eq!(a.node(NodeId(1)).busy_mcpu, MilliCpu::ZERO);
        // Serve: dispatch at parked allocation, then the scale-up lands.
        a.dispatched(PodId(3));
        assert_eq!(a.node(NodeId(1)).busy_mcpu, MilliCpu(1));
        a.resize_landed(PodId(3), MilliCpu(1000));
        assert_eq!(a.node(NodeId(1)).busy_mcpu, MilliCpu(1000));
        assert_eq!(a.committed_total(), MilliCpu(1000));
    }

    #[test]
    fn pressure_normalizes_by_capacity() {
        let mut a = FleetAccounting::for_topology(&Topology::hetero_preset(2));
        // Node 0 is the 16-core shape, node 1 the 8-core paper shape.
        a.pod_up(PodId(1), NodeId(0), MilliCpu(1000));
        a.pod_up(PodId(2), NodeId(1), MilliCpu(1000));
        a.dispatched(PodId(1));
        a.dispatched(PodId(2));
        assert!(a.node(NodeId(0)).pressure() < a.node(NodeId(1)).pressure());
    }

    #[test]
    fn diff_reports_first_mismatch() {
        let mut a = acct2();
        let b = acct2();
        assert_eq!(a.diff(&b), None);
        assert_eq!(a, b);
        a.pod_up(PodId(1), NodeId(0), MilliCpu(7));
        let d = a.diff(&b).expect("must differ");
        assert!(d.contains("committed"), "{d}");
    }

    #[test]
    fn routing_policy_parses() {
        assert_eq!(
            "least-loaded".parse::<RoutingPolicy>().unwrap(),
            RoutingPolicy::LeastLoaded
        );
        assert_eq!(
            "LOCALITY".parse::<RoutingPolicy>().unwrap(),
            RoutingPolicy::Locality
        );
        assert_eq!("hybrid".parse::<RoutingPolicy>().unwrap(), RoutingPolicy::Hybrid);
        assert!("random".parse::<RoutingPolicy>().is_err());
        assert_eq!(RoutingPolicy::ALL.len(), 3);
        assert_eq!(RoutingPolicy::Locality.name(), "locality");
    }
}
