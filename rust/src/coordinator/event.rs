//! The platform's typed event alphabet and its dispatch table.
//!
//! Every deferred action in the simulation — proxy forward hops, startup
//! pipelines, idle timers, resize hooks and landings, speculation cycles,
//! VU think-time chains — is one variant of [`Event`], dispatched by a
//! single `match` in [`World::handle`]. Scheduling an event moves a few
//! words into the calendar queue: service fields are interned
//! [`ServiceId`]s (`Copy` u32s), so the steady-state loop neither
//! allocates nor touches an `Arc` refcount per event — the last string
//! traffic left the hot path with the intern table (`util::intern`).
//!
//! [`Event::Call`] is the escape hatch for examples and one-off test
//! drivers that genuinely want an ad-hoc closure; platform code never
//! schedules it.

use std::sync::Arc;

use crate::cluster::pod::PodId;
use crate::cluster::NodeId;
use crate::coordinator::platform::{Eng, Platform};
use crate::knative::activator::RequestId;
use crate::loadgen::runner::Runner;
use crate::simclock::{SimTime, World};
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;

/// One scheduled occurrence in the platform world.
pub enum Event {
    /// Load generation: submit a fresh request to `service`.
    Submit { service: ServiceId },
    /// The proxy forward hop delivered `req` to the activator.
    Arrive { req: RequestId },
    /// `req`'s execution reaches its ETA under the current CFS share.
    Complete { req: RequestId },
    /// The kubelet startup pipeline finished; the pod joins the service.
    PodReady {
        service: ServiceId,
        pod: PodId,
        node: NodeId,
        image: Arc<str>,
    },
    /// Stable-window idle timer fired (cold / pooled scale-down check).
    IdleCheck { service: ServiceId, pod: PodId },
    /// Termination grace elapsed; remove the pod from the fleet.
    PodGone { service: ServiceId, pod: PodId },
    /// Queue-proxy resize hook dispatch cost elapsed; try the patch.
    ResizeHook { service: ServiceId, pod: PodId },
    /// Conflict backoff elapsed; clear the pending flag and re-try.
    ResizeRetry { service: ServiceId, pod: PodId },
    /// Kubelet propagation done; the new CPU limit is in force.
    ResizeLanded {
        service: ServiceId,
        pod: PodId,
        target: MilliCpu,
    },
    /// Closed-loop VU think time elapsed; issue the next iteration.
    VuIterate {
        service: ServiceId,
        remaining: u32,
        think: SimTime,
    },
    /// Forecast-driven speculative pre-resize (generation-stamped).
    Speculate {
        service: ServiceId,
        generation: u64,
    },
    /// Misprediction watchdog: re-park if no arrival claimed the window.
    SpeculationRepark {
        service: ServiceId,
        generation: u64,
    },
    /// Fault injection: the node goes down, killing every resident pod.
    NodeCrash { node: NodeId },
    /// Fault injection: the node comes back (with a cold image cache).
    NodeRecover { node: NodeId },
    /// Fault injection: a straggler window opens on the node — its kubelet
    /// pipelines slow down by the given factors until `StragglerEnd`.
    StragglerStart {
        node: NodeId,
        startup_factor: f64,
        resize_factor: f64,
    },
    /// Fault injection: the straggler window closes.
    StragglerEnd { node: NodeId },
    /// Sharded execution: a sibling cell crashed with no surviving local
    /// capacity; reschedule `pods` replacement pods for `service` here.
    /// Delivered at a window barrier, always ≥ one lookahead after emit.
    /// The id is *this* cell's — the runtime translates the wire-format
    /// service name into the target cell's intern table at delivery.
    XShardReschedule { service: ServiceId, pods: u32 },
    /// Observability: cadence tick of the timeline gauge sampler. The
    /// handler is strictly read-only over simulation state (it only appends
    /// to the armed obs buffers), so its presence in the queue never
    /// changes simulation behavior.
    ObsTick,
    /// Escape hatch for examples/tests; never used by platform code.
    Call(Box<dyn FnOnce(&mut Platform, &mut Eng) + Send>),
}

impl Event {
    /// Display names of every variant, indexed by [`Event::kind_index`] —
    /// the label table of the self-profiling plane.
    pub const KINDS: [&'static str; 19] = [
        "Submit",
        "Arrive",
        "Complete",
        "PodReady",
        "IdleCheck",
        "PodGone",
        "ResizeHook",
        "ResizeRetry",
        "ResizeLanded",
        "VuIterate",
        "Speculate",
        "SpeculationRepark",
        "NodeCrash",
        "NodeRecover",
        "StragglerStart",
        "StragglerEnd",
        "XShardReschedule",
        "ObsTick",
        "Call",
    ];

    /// Index of this variant into [`Event::KINDS`].
    pub fn kind_index(&self) -> usize {
        match self {
            Event::Submit { .. } => 0,
            Event::Arrive { .. } => 1,
            Event::Complete { .. } => 2,
            Event::PodReady { .. } => 3,
            Event::IdleCheck { .. } => 4,
            Event::PodGone { .. } => 5,
            Event::ResizeHook { .. } => 6,
            Event::ResizeRetry { .. } => 7,
            Event::ResizeLanded { .. } => 8,
            Event::VuIterate { .. } => 9,
            Event::Speculate { .. } => 10,
            Event::SpeculationRepark { .. } => 11,
            Event::NodeCrash { .. } => 12,
            Event::NodeRecover { .. } => 13,
            Event::StragglerStart { .. } => 14,
            Event::StragglerEnd { .. } => 15,
            Event::XShardReschedule { .. } => 16,
            Event::ObsTick => 17,
            Event::Call(_) => 18,
        }
    }

    /// Wraps an ad-hoc closure as an event (examples/tests only).
    pub fn call<F>(f: F) -> Event
    where
        F: FnOnce(&mut Platform, &mut Eng) + Send + 'static,
    {
        Event::Call(Box::new(f))
    }
}

impl World for Platform {
    type Event = Event;

    fn handle(&mut self, ev: Event, eng: &mut Eng) {
        // Self-profiling wrapper: measured dispatch only when armed, so
        // the unobserved hot path keeps its single-match shape with one
        // extra branch. Cadence ticks trail the workload by up to one
        // period, so the observed end-of-run clock (which feeds the
        // report's time-averaged gauges) tracks the last *real* event.
        let profiled = match &mut self.obs {
            Some(obs) => {
                if !matches!(ev, Event::ObsTick) {
                    obs.note_real_event(eng.now());
                }
                obs.profile_enabled()
            }
            None => false,
        };
        if profiled {
            let kind = ev.kind_index();
            let t0 = std::time::Instant::now();
            self.dispatch(ev, eng);
            let wall = t0.elapsed();
            if let Some(obs) = &mut self.obs {
                obs.profile_mut().record(kind, wall);
            }
            return;
        }
        self.dispatch(ev, eng);
    }
}

impl Platform {
    /// The event dispatch table proper.
    fn dispatch(&mut self, ev: Event, eng: &mut Eng) {
        match ev {
            Event::Submit { service } => {
                self.submit_id(eng, service);
            }
            Event::Arrive { req } => Self::arrive(self, eng, req),
            Event::Complete { req } => Self::complete(self, eng, req),
            Event::PodReady {
                service,
                pod,
                node,
                image,
            } => Self::pod_ready(self, eng, service, pod, node, &image),
            Event::IdleCheck { service, pod } => Self::idle_check(self, eng, service, pod),
            Event::PodGone { service, pod } => Self::pod_teardown(self, eng, service, pod),
            Event::ResizeHook { service, pod } => Self::try_patch(self, eng, service, pod),
            Event::ResizeRetry { service, pod } => Self::retry_patch(self, eng, service, pod),
            Event::ResizeLanded {
                service,
                pod,
                target,
            } => Self::resize_landed(self, eng, service, pod, target),
            Event::VuIterate {
                service,
                remaining,
                think,
            } => Runner::vu_iterate(self, eng, service, remaining, think),
            Event::Speculate {
                service,
                generation,
            } => Self::speculative_resize(self, eng, service, generation),
            Event::SpeculationRepark {
                service,
                generation,
            } => Self::speculation_repark(self, eng, service, generation),
            Event::NodeCrash { node } => Self::node_crash(self, eng, node),
            Event::NodeRecover { node } => Self::node_recover(self, eng, node),
            Event::StragglerStart {
                node,
                startup_factor,
                resize_factor,
            } => Self::straggler_start(self, eng, node, startup_factor, resize_factor),
            Event::StragglerEnd { node } => Self::straggler_end(self, eng, node),
            Event::XShardReschedule { service, pods } => {
                Self::xshard_reschedule(self, eng, service, pods)
            }
            Event::ObsTick => Self::obs_tick(self, eng),
            Event::Call(f) => f(self, eng),
        }
    }
}
