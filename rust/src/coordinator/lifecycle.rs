//! Pod lifecycle: creation through the scheduler, the kubelet startup
//! pipeline, post-request policy hooks (park / idle timers), scale-to-zero
//! teardown, and event-driven KPA scale-out.
//!
//! Every pod binds through [`Scheduler::pick`](crate::cluster::Scheduler)
//! against the whole fleet, and its startup/termination latencies are drawn
//! from the kubelet of the node it landed on — the per-node state the
//! multi-node topologies exercise.

use crate::cluster::kubelet::Kubelet;
use crate::cluster::pod::{PodId, PodPhase, PodSpec};
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform, StartingPod};
use crate::coordinator::service::ServicePod;
use crate::faults::inflate;
use crate::policy::Policy;
use crate::simclock::SimTime;
use crate::util::intern::ServiceId;
use crate::util::quantity::{Memory, MilliCpu, Resources};

/// How long KPA scale-out backs off after an unschedulable pod-start
/// attempt — re-trying a placement that cannot succeed on every
/// concurrency tick is pure churn.
pub(crate) const UNSCHEDULABLE_BACKOFF: SimTime = SimTime(5_000_000_000); // 5 s

impl Platform {
    /// Creates and starts a pod for the service. `on_demand` marks a
    /// cold-start (request-triggered) creation. Returns whether a pod
    /// actually entered its startup pipeline — false when the service is
    /// unknown or no node can fit the pod.
    pub(crate) fn start_pod(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        on_demand: bool,
    ) -> bool {
        let (spec, image, image_mb, init_ms) = {
            let Some(svc) = w.services.get(svc_id) else { return false };
            let p = &svc.profile;
            let requests = Resources::new(
                // Parking pods (the in-place hook policies) reserve only a
                // small request — the paper's resource-availability
                // advantage; warm/cold/pooled reserve the full serving CPU
                // (Guaranteed-ish QoS).
                if svc.policy.inplace_hooks() {
                    MilliCpu(100)
                } else {
                    svc.cfg.serving_cpu
                },
                Memory::from_mib(256),
            );
            let limits = Resources::new(svc.cfg.serving_cpu, Memory::from_mib(512));
            (
                PodSpec::single(&svc.profile.name, &p.image, requests, limits),
                p.image.clone(),
                p.image_mb,
                p.runtime_init_ms,
            )
        };

        let pod_id = w.cluster.create_pod(spec);
        let Some(node_id) = w.scheduler.pick(
            w.cluster.nodes(),
            w.cluster.pod(pod_id).unwrap().spec.total_requests(),
        ) else {
            // Unschedulable: count it and back KPA scale-out off — nothing
            // will fit until capacity frees up, so re-trying every
            // concurrency tick is pure churn. Cold-start attempts are not
            // gated by the backoff, so a request arriving after capacity
            // frees still gets its pod immediately.
            w.cluster.delete_pod(pod_id);
            w.metrics.pods_unschedulable += 1;
            if let Some(svc) = w.services.get_mut(svc_id) {
                svc.sched_backoff_until = eng.now() + UNSCHEDULABLE_BACKOFF;
            }
            return false;
        };
        if w.cluster.bind(pod_id, node_id).is_err() {
            w.cluster.delete_pod(pod_id);
            return false;
        }
        w.metrics.pods_created += 1;
        {
            let svc = w.services.get_mut(svc_id).unwrap();
            svc.starting += 1;
        }
        let _ = on_demand;

        // Run the startup pipeline as chained events, timed by the kubelet
        // of the node the pod landed on.
        let cached = w.cluster.node(node_id).image_cached(&image);
        let plan =
            w.kubelets[node_id.0 as usize].startup_plan(cached, image_mb, init_ms, &mut w.rng);
        // Fault injection: straggler windows and global startup inflation
        // stretch the pipeline (a no-op returning the exact input when the
        // factor is 1 — the fault-free byte-identity guard).
        let total = inflate(Kubelet::plan_total(&plan), w.faults.startup_factor(node_id));
        {
            let pod = w.cluster.pod_mut(pod_id).unwrap();
            pod.status.phase = PodPhase::Creating;
            pod.created_at = eng.now();
        }
        let s = eng.schedule_in(
            total,
            Event::PodReady {
                service: svc_id,
                pod: pod_id,
                node: node_id,
                image: std::sync::Arc::from(image.as_str()),
            },
        );
        // Track the in-flight startup so a node crash can cancel it.
        w.starting_pods.insert(
            pod_id,
            StartingPod {
                service: svc_id,
                node: node_id,
                ready_event: s.id,
            },
        );
        true
    }

    pub(crate) fn pod_ready(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
        node_id: crate::cluster::NodeId,
        image: &str,
    ) {
        w.starting_pods.remove(pod_id);
        w.cluster.node_mut(node_id).cache_image(image);
        {
            let Some(pod) = w.cluster.pod_mut(pod_id) else { return };
            pod.status.phase = PodPhase::Running;
            pod.status.ready = true;
        }
        let (hooks, climit) = {
            let Some(svc) = w.services.get(svc_id) else { return };
            (svc.policy.inplace_hooks(), svc.cfg.concurrency_limit())
        };
        {
            let svc = w.services.get_mut(svc_id).unwrap();
            svc.starting = svc.starting.saturating_sub(1);
            let mut sp = ServicePod::new(pod_id, climit, hooks);
            sp.ready = true;
            sp.node = Some(node_id);
            svc.pods.push(sp);
            svc.ready_count += 1;
        }
        let applied = w.applied_limit(pod_id).unwrap_or(MilliCpu::ZERO);
        w.fleet.pod_up(pod_id, node_id, applied);
        Self::committed_changed(w, eng);
        Self::drain_activator(w, eng, svc_id);

        // A fresh pod with nothing to do behaves exactly like one a request
        // just left: in-place parks immediately, cold arms its idle timer.
        Self::post_request_hooks(w, eng, svc_id, pod_id);
    }

    /// Policy post-hooks after a request leaves a pod.
    pub(crate) fn post_request_hooks(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
    ) {
        let (policy, idle, parked, stable_window) = {
            let Some(svc) = w.services.get(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            (
                svc.policy,
                svc.pods[idx].proxy.idle(),
                svc.cfg.parked_cpu,
                svc.cfg.stable_window,
            )
        };
        match policy {
            Policy::InPlace | Policy::PredictiveInPlace => {
                if idle {
                    // The paper's post-hook: deallocate back to 1 m. For
                    // the predictive policy the driver may speculatively
                    // re-raise the pod ahead of the next forecast arrival.
                    Self::request_resize(w, eng, svc_id, pod_id, parked);
                }
            }
            Policy::Cold | Policy::Pooled => {
                // Arm the idle timer (stable window). Cold pods scale to
                // zero with it; pooled pods use the same timer but
                // `idle_check` only retires pods above the pool target.
                if idle {
                    let s = eng.schedule_in(
                        stable_window,
                        Event::IdleCheck {
                            service: svc_id,
                            pod: pod_id,
                        },
                    );
                    let svc = w.services.get_mut(svc_id).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        if let Some(old) = svc.pods[idx].idle_timer.replace(s.id) {
                            eng.cancel(old);
                        }
                    }
                }
            }
            Policy::Warm => {}
        }
    }

    /// Cold policy: scale this pod to zero if its stable window stayed quiet.
    pub(crate) fn idle_check(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, pod_id: PodId) {
        let idle = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].idle_timer = None;
            svc.pods[idx].proxy.idle() && !svc.pods[idx].terminating
        };
        if !idle {
            return;
        }
        // Pooled: the pool itself never retires — only pods above the
        // target trim down (recounted at fire time, so concurrent timers
        // stop as soon as the pool is back at size).
        {
            let svc = &w.services[svc_id];
            if svc.policy == Policy::Pooled
                && (svc.idle_ready_pods().count() as u32) <= svc.cfg.forecast.pool_size.max(1)
            {
                return;
            }
        }
        // The pod must still exist and be bound — its node's kubelet times
        // the teardown. (Unbound here would mean inconsistent state; bail
        // rather than guess another node's pipeline.)
        let Some(node_id) = w.cluster.pod(pod_id).and_then(|p| p.node) else {
            return;
        };
        // Begin termination.
        {
            let svc = w.services.get_mut(svc_id).unwrap();
            let idx = svc.pod_index(pod_id).unwrap();
            svc.pods[idx].terminating = true;
            svc.ready_count = svc.ready_count.saturating_sub(1);
        }
        if let Some(pod) = w.cluster.pod_mut(pod_id) {
            pod.status.phase = PodPhase::Terminating;
            pod.status.ready = false;
        }
        w.fleet.pod_terminating(pod_id);
        Self::committed_changed(w, eng);
        let term = w.kubelets[node_id.0 as usize].termination_time(&mut w.rng);
        eng.schedule_in(
            term,
            Event::PodGone {
                service: svc_id,
                pod: pod_id,
            },
        );
    }

    /// Termination grace elapsed: remove the pod from cluster, fleet
    /// counters and the service's pod list. Pod-scoped timers (idle timer,
    /// pending resize retry) are cancelled and the in-flight resize record
    /// cleared — stale events firing against a dead `PodId` would inflate
    /// the calendar queue's exact `pending()` forever.
    pub(crate) fn pod_teardown(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, pod_id: PodId) {
        Self::clear_resize_state(w, eng, svc_id, pod_id);
        if let Some(svc) = w.services.get_mut(svc_id) {
            if let Some(idx) = svc.pod_index(pod_id) {
                if let Some(t) = svc.pods[idx].idle_timer.take() {
                    eng.cancel(t);
                }
                svc.pods.remove(idx);
            }
        }
        w.cluster.delete_pod(pod_id);
        w.fleet.pod_gone(pod_id);
        w.metrics.pods_deleted += 1;
    }

    /// Event-driven KPA evaluation: scale up when the decision demands it.
    pub(crate) fn maybe_scale_up(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let (desired, live) = {
            let Some(svc) = w.services.get(svc_id) else { return };
            // Recent unschedulable attempt: nothing fits, don't churn.
            if eng.now() < svc.sched_backoff_until {
                return;
            }
            // `ready_count` mirrors `ready_pods()` incrementally (pinned by
            // the differential property test), and `ready_count + starting`
            // mirrors `live_pods()` — no pod scan on this path.
            let d = svc.autoscaler.decide(eng.now(), svc.ready_count);
            (d.desired, svc.ready_count + svc.starting)
        };
        for _ in live..desired {
            if !Self::start_pod(w, eng, svc_id, true) {
                // Unschedulable — the rest of this decision can't fit
                // either; the backoff just armed suppresses re-tries.
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::Topology;
    use crate::coordinator::platform::Simulation;
    use crate::workload::registry::{WorkloadKind, WorkloadProfile};

    /// Satellite regression: tearing a pod down must cancel its pod-scoped
    /// timers instead of leaving stale events to fire against a dead
    /// `PodId` — `pending()` is exact, so the leak is directly observable.
    #[test]
    fn teardown_cancels_pod_scoped_timers() {
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        sim.run_to_quiescence();
        // The request completed; post-request hooks armed the idle timer.
        let svc = &sim.world.services["fn"];
        assert_eq!(svc.pods.len(), 1);
        assert!(svc.pods[0].idle_timer.is_some(), "idle timer armed");
        let pod = svc.pods[0].pod;
        let fn_id = sim.world.services.id_of("fn").unwrap();
        let before = sim.engine.pending();
        Platform::pod_teardown(&mut sim.world, &mut sim.engine, fn_id, pod);
        assert_eq!(
            sim.engine.pending(),
            before - 1,
            "teardown must cancel the armed idle timer"
        );
        // Whatever remains drains cleanly against the now-dead pod.
        sim.run();
        assert_eq!(sim.engine.pending(), 0);
    }

    /// Satellite regression: unschedulable pod-start attempts are counted
    /// and arm a KPA backoff instead of vanishing silently.
    #[test]
    fn unschedulable_attempts_are_counted_and_back_off() {
        // One 8-core node fits 8 × 1000 m warm pods; the 9th can't fit.
        let mut sim = Simulation::fleet(Topology::uniform_paper(1), 5);
        for i in 0..9 {
            sim.deploy(
                &format!("svc-{i}"),
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::Warm,
            );
        }
        sim.run();
        assert_eq!(sim.world.metrics.pods_unschedulable, 1);
        let ready: usize = sim.world.services.values().map(|s| s.ready_pods()).sum();
        assert_eq!(ready, 8);
        // The starved service armed its backoff window.
        let svc = &sim.world.services["svc-8"];
        assert!(svc.sched_backoff_until > crate::simclock::SimTime::ZERO);
        assert!(svc.pods.is_empty());
    }
}
