//! Pod lifecycle: creation through the scheduler, the kubelet startup
//! pipeline, post-request policy hooks (park / idle timers), scale-to-zero
//! teardown, and event-driven KPA scale-out.
//!
//! Every pod binds through [`Scheduler::pick`](crate::cluster::Scheduler)
//! against the whole fleet, and its startup/termination latencies are drawn
//! from the kubelet of the node it landed on — the per-node state the
//! multi-node topologies exercise.

use crate::cluster::kubelet::Kubelet;
use crate::cluster::pod::{PodId, PodPhase, PodSpec};
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform};
use crate::coordinator::service::ServicePod;
use crate::policy::Policy;
use crate::util::quantity::{Memory, MilliCpu, Resources};

impl Platform {
    /// Creates and starts a pod for `svc_name`. `on_demand` marks a
    /// cold-start (request-triggered) creation.
    pub(crate) fn start_pod(w: &mut Platform, eng: &mut Eng, svc_name: &str, on_demand: bool) {
        let (spec, image, image_mb, init_ms) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let p = &svc.profile;
            let requests = Resources::new(
                // Parking pods (the in-place hook policies) reserve only a
                // small request — the paper's resource-availability
                // advantage; warm/cold/pooled reserve the full serving CPU
                // (Guaranteed-ish QoS).
                if svc.policy.inplace_hooks() {
                    MilliCpu(100)
                } else {
                    svc.cfg.serving_cpu
                },
                Memory::from_mib(256),
            );
            let limits = Resources::new(svc.cfg.serving_cpu, Memory::from_mib(512));
            (
                PodSpec::single(&svc.profile.name, &p.image, requests, limits),
                p.image.clone(),
                p.image_mb,
                p.runtime_init_ms,
            )
        };

        let pod_id = w.cluster.create_pod(spec);
        let Some(node_id) = w.scheduler.pick(
            w.cluster.nodes(),
            w.cluster.pod(pod_id).unwrap().spec.total_requests(),
        ) else {
            // Unschedulable — drop the pod; buffered requests will time out.
            w.cluster.delete_pod(pod_id);
            return;
        };
        if w.cluster.bind(pod_id, node_id).is_err() {
            w.cluster.delete_pod(pod_id);
            return;
        }
        w.metrics.pods_created += 1;
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            svc.starting += 1;
        }
        let _ = on_demand;

        // Run the startup pipeline as chained events, timed by the kubelet
        // of the node the pod landed on.
        let cached = w.cluster.node(node_id).image_cached(&image);
        let plan =
            w.kubelets[node_id.0 as usize].startup_plan(cached, image_mb, init_ms, &mut w.rng);
        let total = Kubelet::plan_total(&plan);
        {
            let pod = w.cluster.pod_mut(pod_id).unwrap();
            pod.status.phase = PodPhase::Creating;
            pod.created_at = eng.now();
        }
        eng.schedule_in(
            total,
            Event::PodReady {
                service: std::sync::Arc::from(svc_name),
                pod: pod_id,
                node: node_id,
                image: std::sync::Arc::from(image.as_str()),
            },
        );
    }

    pub(crate) fn pod_ready(
        w: &mut Platform,
        eng: &mut Eng,
        svc_name: &str,
        pod_id: PodId,
        node_id: crate::cluster::NodeId,
        image: &str,
    ) {
        w.cluster.node_mut(node_id).cache_image(image);
        {
            let Some(pod) = w.cluster.pod_mut(pod_id) else { return };
            pod.status.phase = PodPhase::Running;
            pod.status.ready = true;
        }
        let (hooks, climit) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            (svc.policy.inplace_hooks(), svc.cfg.concurrency_limit())
        };
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            svc.starting = svc.starting.saturating_sub(1);
            let mut sp = ServicePod::new(pod_id, climit, hooks);
            sp.ready = true;
            sp.node = Some(node_id);
            svc.pods.push(sp);
            svc.ready_count += 1;
        }
        let applied = w.applied_limit(pod_id).unwrap_or(MilliCpu::ZERO);
        w.fleet.pod_up(pod_id, node_id, applied);
        Self::committed_changed(w, eng);
        Self::drain_activator(w, eng, svc_name);

        // A fresh pod with nothing to do behaves exactly like one a request
        // just left: in-place parks immediately, cold arms its idle timer.
        Self::post_request_hooks(w, eng, svc_name, pod_id);
    }

    /// Policy post-hooks after a request leaves a pod.
    pub(crate) fn post_request_hooks(
        w: &mut Platform,
        eng: &mut Eng,
        svc_name: &str,
        pod_id: PodId,
    ) {
        let (policy, idle, parked, stable_window) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            (
                svc.policy,
                svc.pods[idx].proxy.idle(),
                svc.cfg.parked_cpu,
                svc.cfg.stable_window,
            )
        };
        match policy {
            Policy::InPlace | Policy::PredictiveInPlace => {
                if idle {
                    // The paper's post-hook: deallocate back to 1 m. For
                    // the predictive policy the driver may speculatively
                    // re-raise the pod ahead of the next forecast arrival.
                    Self::request_resize(w, eng, svc_name, pod_id, parked);
                }
            }
            Policy::Cold | Policy::Pooled => {
                // Arm the idle timer (stable window). Cold pods scale to
                // zero with it; pooled pods use the same timer but
                // `idle_check` only retires pods above the pool target.
                if idle {
                    let s = eng.schedule_in(
                        stable_window,
                        Event::IdleCheck {
                            service: std::sync::Arc::from(svc_name),
                            pod: pod_id,
                        },
                    );
                    let svc = w.services.get_mut(svc_name).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        if let Some(old) = svc.pods[idx].idle_timer.replace(s.id) {
                            eng.cancel(old);
                        }
                    }
                }
            }
            Policy::Warm => {}
        }
    }

    /// Cold policy: scale this pod to zero if its stable window stayed quiet.
    pub(crate) fn idle_check(w: &mut Platform, eng: &mut Eng, svc_name: &str, pod_id: PodId) {
        let idle = {
            let Some(svc) = w.services.get_mut(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].idle_timer = None;
            svc.pods[idx].proxy.idle() && !svc.pods[idx].terminating
        };
        if !idle {
            return;
        }
        // Pooled: the pool itself never retires — only pods above the
        // target trim down (recounted at fire time, so concurrent timers
        // stop as soon as the pool is back at size).
        {
            let svc = &w.services[svc_name];
            if svc.policy == Policy::Pooled
                && (svc.idle_ready_pods().count() as u32) <= svc.cfg.forecast.pool_size.max(1)
            {
                return;
            }
        }
        // The pod must still exist and be bound — its node's kubelet times
        // the teardown. (Unbound here would mean inconsistent state; bail
        // rather than guess another node's pipeline.)
        let Some(node_id) = w.cluster.pod(pod_id).and_then(|p| p.node) else {
            return;
        };
        // Begin termination.
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            let idx = svc.pod_index(pod_id).unwrap();
            svc.pods[idx].terminating = true;
            svc.ready_count = svc.ready_count.saturating_sub(1);
        }
        if let Some(pod) = w.cluster.pod_mut(pod_id) {
            pod.status.phase = PodPhase::Terminating;
            pod.status.ready = false;
        }
        w.fleet.pod_terminating(pod_id);
        Self::committed_changed(w, eng);
        let term = w.kubelets[node_id.0 as usize].termination_time(&mut w.rng);
        eng.schedule_in(
            term,
            Event::PodGone {
                service: std::sync::Arc::from(svc_name),
                pod: pod_id,
            },
        );
    }

    /// Termination grace elapsed: remove the pod from cluster, fleet
    /// counters and the service's pod list.
    pub(crate) fn pod_teardown(w: &mut Platform, _eng: &mut Eng, svc_name: &str, pod_id: PodId) {
        w.cluster.delete_pod(pod_id);
        w.fleet.pod_gone(pod_id);
        w.metrics.pods_deleted += 1;
        if let Some(svc) = w.services.get_mut(svc_name) {
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods.remove(idx);
            }
        }
    }

    /// Event-driven KPA evaluation: scale up when the decision demands it.
    pub(crate) fn maybe_scale_up(w: &mut Platform, eng: &mut Eng, svc_name: &str) {
        let (desired, live) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            // `ready_count` mirrors `ready_pods()` incrementally (pinned by
            // the differential property test), and `ready_count + starting`
            // mirrors `live_pods()` — no pod scan on this path.
            let d = svc.autoscaler.decide(eng.now(), svc.ready_count);
            (d.desired, svc.ready_count + svc.starting)
        };
        for _ in live..desired {
            Self::start_pod(w, eng, svc_name, true);
        }
    }
}
