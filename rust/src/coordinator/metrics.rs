//! Platform metrics: per-service latency samples, request counters, and the
//! committed-CPU integral backing the paper's "enhanced resource
//! availability" claim (§3 advantage 2).
//!
//! Per-service rows live in a flat `Vec` indexed by [`ServiceId`] — the
//! hot path ([`Metrics::row_mut`]) is one bounds-checked index, not the
//! `BTreeMap<String, _>` walk (plus `to_string` allocation) every event
//! used to pay. Rendering stays in lexicographic name order through the
//! side index [`Metrics::services`] walks, so reports are byte-identical
//! to the map era.

use std::collections::BTreeMap;

use crate::simclock::SimTime;
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;
use crate::util::stats::{Samples, StreamStats};

/// Latency + outcome accounting for one service.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// End-to-end request latencies, milliseconds.
    pub latency_ms: Samples,
    /// Streaming twin of `latency_ms` (count/sum/min/max + fixed buckets),
    /// consumed by the observability artifacts. Reports keep reading the
    /// exact reservoir, so this field adds no bytes to any report.
    pub latency_stream: StreamStats,
    pub completed: u64,
    pub failed: u64,
    /// Requests that experienced a cold start (pod created on their behalf).
    pub cold_starts: u64,
    /// Requests that triggered an in-place scale-up.
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes issued ahead of forecast
    /// arrivals (predictive-inplace).
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival — the pod was
    /// re-parked (predictive-inplace).
    pub mispredictions: u64,
}

/// Time-integral of committed CPU (Σ applied limits of live pods), the
/// resource-reservation cost of keeping capacity ready.
#[derive(Debug, Default)]
pub struct CommittedCpuIntegral {
    last_at: SimTime,
    current_m: u64,
    /// Accumulated milliCPU·ms.
    acc_mcpu_ms: f64,
}

impl CommittedCpuIntegral {
    /// Records a change in total committed CPU at `now`.
    pub fn update(&mut self, now: SimTime, committed: MilliCpu) {
        let dt = now.saturating_sub(self.last_at).as_millis_f64();
        self.acc_mcpu_ms += self.current_m as f64 * dt;
        self.current_m = committed.0;
        self.last_at = now;
    }

    /// Integral up to `now` in CPU·seconds.
    pub fn cpu_seconds(&self, now: SimTime) -> f64 {
        let dt = now.saturating_sub(self.last_at).as_millis_f64();
        (self.acc_mcpu_ms + self.current_m as f64 * dt) / 1000.0 / 1000.0
    }

    /// Average committed milliCPU over `[0, now]`.
    pub fn average_mcpu(&self, now: SimTime) -> f64 {
        let total_ms = now.as_millis_f64();
        if total_ms == 0.0 {
            return self.current_m as f64;
        }
        self.cpu_seconds(now) * 1000.0 * 1000.0 / total_ms
    }

    pub fn current(&self) -> MilliCpu {
        MilliCpu(self.current_m)
    }
}

/// All platform metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Per-service rows, indexed by `ServiceId` (registration order).
    rows: Vec<ServiceMetrics>,
    /// `ServiceId` → name, aligned with `rows` (render boundary).
    names: Vec<String>,
    /// name → row index, iterated for the canonical name-sorted render.
    by_name: BTreeMap<String, u32>,
    pub committed_cpu: CommittedCpuIntegral,
    /// Pods created / deleted (cold-start churn).
    pub pods_created: u64,
    pub pods_deleted: u64,
    /// Pod-start attempts no node could fit (previously dropped silently).
    pub pods_unschedulable: u64,
    /// Pods killed by node crashes (fault injection) — distinct from
    /// `pods_deleted`, which counts orderly scale-to-zero teardowns.
    pub pods_evicted: u64,
    /// Crash-evicted pods successfully re-placed through the scheduler.
    pub pods_rescheduled: u64,
    /// Resize patches accepted / conflicted (hook churn).
    pub resizes_accepted: u64,
    pub resize_conflicts: u64,
    /// Resize patches rejected by injected faults (beyond the modelled
    /// conflict path).
    pub resize_failures: u64,
}

impl Metrics {
    /// Registers the row for a freshly interned service. The platform
    /// interner is the sole id allocator and registers every id it hands
    /// out, so rows and ids stay aligned by construction; re-registering
    /// an existing id is a no-op.
    pub fn register(&mut self, id: ServiceId, name: &str) {
        if id.index() < self.rows.len() {
            debug_assert_eq!(self.names[id.index()], name, "metrics row misaligned");
            return;
        }
        assert_eq!(
            id.index(),
            self.rows.len(),
            "ServiceId {id:?} registered out of order (rows={})",
            self.rows.len()
        );
        self.rows.push(ServiceMetrics::default());
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id.0);
    }

    /// Hot-path row access: one index, no hashing, no allocation.
    #[inline]
    pub fn row_mut(&mut self, id: ServiceId) -> &mut ServiceMetrics {
        &mut self.rows[id.index()]
    }

    #[inline]
    pub fn row(&self, id: ServiceId) -> &ServiceMetrics {
        &self.rows[id.index()]
    }

    /// Name-addressed row for tests and boundary code. Creates the row on
    /// demand (the map era's `entry()` behavior) — platform code uses
    /// [`Metrics::row_mut`] with a registered id instead.
    pub fn service(&mut self, name: &str) -> &mut ServiceMetrics {
        let i = match self.by_name.get(name) {
            Some(&i) => i as usize,
            None => {
                let i = self.rows.len();
                self.register(ServiceId(i as u32), name);
                i
            }
        };
        &mut self.rows[i]
    }

    pub fn service_ref(&self, name: &str) -> Option<&ServiceMetrics> {
        self.by_name.get(name).map(|&i| &self.rows[i as usize])
    }

    /// Rows in lexicographic name order — the canonical render pass every
    /// report/merge walks (byte-identical to the old `BTreeMap` order).
    pub fn services(&self) -> impl Iterator<Item = (&String, &ServiceMetrics)> {
        self.by_name
            .iter()
            .map(|(n, &i)| (n, &self.rows[i as usize]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn committed_integral_accumulates() {
        let mut c = CommittedCpuIntegral::default();
        c.update(SimTime::ZERO, MilliCpu(1000));
        c.update(SimTime::from_secs(10), MilliCpu(1));
        // 10 s at 1 CPU = 10 CPU·s; then 10 s at 1 m ≈ 0.01 CPU·s.
        let total = c.cpu_seconds(SimTime::from_secs(20));
        assert!((total - 10.01).abs() < 1e-6, "total={total}");
        let avg = c.average_mcpu(SimTime::from_secs(20));
        assert!((avg - 500.5).abs() < 1e-6, "avg={avg}");
    }

    #[test]
    fn warm_vs_inplace_reservation_gap() {
        // Warm: 1000 m for 60 s. In-place: 1 m parked except two 2.5 s
        // serving bursts at 1000 m.
        let mut warm = CommittedCpuIntegral::default();
        warm.update(SimTime::ZERO, MilliCpu(1000));
        let warm_cpu_s = warm.cpu_seconds(SimTime::from_secs(60));

        let mut inp = CommittedCpuIntegral::default();
        inp.update(SimTime::ZERO, MilliCpu(1));
        inp.update(SimTime::from_secs(10), MilliCpu(1000));
        inp.update(SimTime::from_millis(12_500), MilliCpu(1));
        inp.update(SimTime::from_secs(40), MilliCpu(1000));
        inp.update(SimTime::from_millis(42_500), MilliCpu(1));
        let inp_cpu_s = inp.cpu_seconds(SimTime::from_secs(60));

        // The in-place reservation is an order of magnitude cheaper.
        assert!(warm_cpu_s / inp_cpu_s > 10.0, "warm={warm_cpu_s} inp={inp_cpu_s}");
    }

    #[test]
    fn service_metrics_keyed_by_name() {
        let mut m = Metrics::default();
        m.service("a").latency_ms.record(1.0);
        m.service("a").completed += 1;
        m.service("b").completed += 2;
        assert_eq!(m.service_ref("a").unwrap().completed, 1);
        assert_eq!(m.service_ref("b").unwrap().completed, 2);
        assert!(m.service_ref("c").is_none());
        assert_eq!(m.services().count(), 2);
    }

    #[test]
    fn latency_stream_twins_the_reservoir() {
        let mut m = Metrics::default();
        for x in [12.0, 310.0, 4.5] {
            let row = m.service("a");
            row.latency_ms.record(x);
            row.latency_stream.record(x);
        }
        let row = m.service_ref("a").unwrap();
        assert_eq!(row.latency_stream.count(), row.latency_ms.len() as u64);
        assert!((row.latency_stream.mean() - row.latency_ms.mean()).abs() < 1e-12);
    }

    #[test]
    fn rows_align_with_ids_and_render_name_sorted() {
        let mut m = Metrics::default();
        // Deploy order b, a — ids 0, 1; render must come back a, b.
        m.register(ServiceId(0), "b");
        m.register(ServiceId(1), "a");
        m.register(ServiceId(0), "b"); // idempotent
        m.row_mut(ServiceId(0)).completed += 3;
        m.row_mut(ServiceId(1)).failed += 1;
        assert_eq!(m.row(ServiceId(0)).completed, 3);
        assert_eq!(m.service("b").completed, 3, "name path hits the same row");
        let order: Vec<&str> = m.services().map(|(n, _)| n.as_str()).collect();
        assert_eq!(order, vec!["a", "b"]);
    }
}
