//! The L3 coordinator: the public API of the platform.
//!
//! [`Simulation`] owns the event engine + [`Platform`] world state;
//! [`Platform::deploy`] installs a service under one of the paper's three
//! policies and [`Platform::submit`] drives requests through the full
//! serverless path (ingress → activator/queue-proxy → container under CFS →
//! response), with the in-place resize hooks on the request path exactly as
//! §4.2 describes.
//!
//! Behaviour is split by concern — `event` the typed event alphabet and its
//! dispatch `match`, `platform` state + wiring, `routing` the request hot
//! path, `lifecycle` pod start/park/idle/teardown, `resize` the in-place
//! patch hooks, `sim` the engine+world harness — all contributing
//! `impl Platform` blocks to the one coordinator type.

pub mod accounting;
pub mod event;
pub mod metrics;
pub mod platform;
pub mod request;
pub mod service;
pub mod sim;

mod lifecycle;
mod resize;
mod routing;

pub use accounting::{FleetAccounting, NodeCounters, RoutingPolicy};
pub use event::Event;
pub use metrics::{CommittedCpuIntegral, Metrics, ServiceMetrics};
pub use platform::{Eng, Platform};
pub use request::RequestState;
pub use service::{Service, ServicePod};
pub use sim::Simulation;
