//! The L3 coordinator: the public API of the platform.
//!
//! [`Simulation`] owns the event engine + [`Platform`] world state;
//! [`Platform::deploy`] installs a service under one of the paper's three
//! policies and [`Platform::submit`] drives requests through the full
//! serverless path (ingress → activator/queue-proxy → container under CFS →
//! response), with the in-place resize hooks on the request path exactly as
//! §4.2 describes.

pub mod metrics;
pub mod platform;
pub mod request;
pub mod service;

pub use metrics::{CommittedCpuIntegral, Metrics, ServiceMetrics};
pub use platform::{Eng, Platform, Simulation};
pub use request::RequestState;
pub use service::{Service, ServicePod};
