//! The platform: the L3 coordinator that wires cluster, API server, Knative
//! layer and policies onto the discrete-event engine.
//!
//! All transitions run as events; handlers are associated functions taking
//! `(&mut Platform, &mut Eng)`. The request hot path is:
//!
//! ```text
//! submit → [forward] → arrive → dispatch → (in-place: resize hook ‖ exec)
//!        → exec under CFS shares → complete → [respond] → metrics
//!                                     ↘ post-hook: park / idle-timer
//! ```

use std::collections::BTreeMap;

use crate::util::nohash::IdHashMap;

use crate::apiserver::{ApiServer, FeatureGates, ResizePatch};
use crate::cluster::kubelet::Kubelet;
use crate::cluster::pod::{PodId, PodPhase, PodSpec};
use crate::cluster::scheduler::Scheduler;
use crate::cluster::{Cluster, NodeId};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::RequestState;
use crate::coordinator::service::{Service, ServicePod};
use crate::knative::activator::RequestId;
use crate::policy::{PlatformParams, Policy};
use crate::simclock::{Engine, SimTime};
use crate::util::quantity::{Memory, MilliCpu, Resources};
use crate::util::rng::Rng;
use crate::workload::exec::Execution;
use crate::workload::registry::WorkloadProfile;

/// Engine type alias used across the coordinator.
pub type Eng = Engine<Platform>;

/// The world state driven by the event engine.
pub struct Platform {
    pub cluster: Cluster,
    pub api: ApiServer,
    pub kubelet: Kubelet,
    pub scheduler: Scheduler,
    pub params: PlatformParams,
    pub services: BTreeMap<String, Service>,
    requests: IdHashMap<RequestId, RequestState>,
    next_request: u64,
    pub rng: Rng,
    pub metrics: Metrics,
    /// One-shot continuations fired when a request completes (or fails) —
    /// how closed-loop virtual users chain their iterations.
    completion_hooks: IdHashMap<RequestId, Box<dyn FnOnce(&mut Platform, &mut Eng)>>,
    /// Scratch buffer reused by `recompute_pod` (hot path: one regime change
    /// per request start/finish/resize; avoids a per-event allocation).
    scratch_active: Vec<RequestId>,
}

impl Platform {
    /// A platform with the paper's testbed: one 8-core / 10 GB node and the
    /// `InPlacePodVerticalScaling` gate enabled.
    pub fn paper_testbed(params: PlatformParams) -> Platform {
        let mut cluster = Cluster::new();
        cluster.add_node(
            "kind-worker",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        );
        let rng = Rng::new(params.seed);
        Platform {
            cluster,
            api: ApiServer::new(FeatureGates::paper_testbed()),
            kubelet: Kubelet::new(params.startup.clone(), params.resize.clone()),
            scheduler: Scheduler::default(),
            params,
            services: BTreeMap::new(),
            requests: IdHashMap::default(),
            next_request: 1,
            rng,
            metrics: Metrics::default(),
            completion_hooks: IdHashMap::default(),
            scratch_active: Vec::with_capacity(64),
        }
    }

    // ---------------------------------------------------------------- deploy

    /// Deploys a service; pre-creates `min_scale` pods. Images are
    /// side-loaded onto every node at deploy time (the paper's `kind load`
    /// setup), so cold starts pay container start + init, not a registry
    /// pull.
    pub fn deploy(&mut self, eng: &mut Eng, svc: Service) {
        let name = svc.name.clone();
        let min = svc.cfg.min_scale;
        let image = svc.profile.image.clone();
        for i in 0..self.cluster.nodes().len() {
            self.cluster
                .node_mut(crate::cluster::NodeId(i as u32))
                .cache_image(&image);
        }
        self.services.insert(name.clone(), svc);
        for _ in 0..min {
            Self::start_pod(self, eng, &name, false);
        }
    }

    /// Convenience: deploy a paper workload under a policy.
    pub fn deploy_workload(
        &mut self,
        eng: &mut Eng,
        name: &str,
        profile: WorkloadProfile,
        policy: Policy,
    ) {
        self.deploy(eng, Service::new(name, profile, policy));
    }

    // ---------------------------------------------------------------- submit

    /// Submits a request now; returns its id.
    pub fn submit(&mut self, eng: &mut Eng, service: &str) -> RequestId {
        let id = RequestId(self.next_request);
        self.next_request += 1;
        let req = RequestState::new(id, service, eng.now());
        self.requests.insert(id, req);
        let fwd = self.params.proxy.sample_forward(&mut self.rng);
        eng.schedule_in(fwd, move |w: &mut Platform, eng| {
            Self::arrive(w, eng, id);
        });
        id
    }

    /// Schedules a submission at an absolute virtual time (load generation).
    pub fn submit_at(&mut self, eng: &mut Eng, at: SimTime, service: &str) {
        let service = service.to_string();
        eng.schedule_at(at, move |w: &mut Platform, eng| {
            w.submit(eng, &service);
        });
    }

    /// Submits a request and registers a one-shot continuation invoked when
    /// it completes or fails (closed-loop load generation).
    pub fn submit_with_hook<F>(&mut self, eng: &mut Eng, service: &str, hook: F) -> RequestId
    where
        F: FnOnce(&mut Platform, &mut Eng) + 'static,
    {
        let id = self.submit(eng, service);
        self.completion_hooks.insert(id, Box::new(hook));
        id
    }

    fn fire_hook(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        if let Some(hook) = w.completion_hooks.remove(&req) {
            hook(w, eng);
        }
    }

    pub fn request(&self, id: RequestId) -> Option<&RequestState> {
        self.requests.get(&id)
    }

    pub fn in_flight(&self) -> usize {
        self.requests.len()
    }

    // ---------------------------------------------------------------- arrive

    fn arrive(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        let svc_name = match w.requests.get(&req) {
            Some(r) => r.service.clone(),
            None => return,
        };
        let Some(svc) = w.services.get_mut(&*svc_name) else {
            // Unknown service: fail fast.
            Self::fail_request(w, eng, req);
            return;
        };

        if let Some(idx) = svc.pick_pod() {
            Self::dispatch(w, eng, &svc_name, req, idx);
        } else {
            // Buffer at the activator; start a pod if none is coming up.
            let now = eng.now();
            if svc.activator.buffer(req, now).is_err() {
                Self::fail_request(w, eng, req);
                return;
            }
            let needs_pod = svc.live_pods() == 0;
            if needs_pod {
                if let Some(r) = w.requests.get_mut(&req) {
                    r.cold_start = true;
                }
                Self::start_pod(w, eng, &svc_name, true);
            } else {
                Self::maybe_scale_up(w, eng, &svc_name);
            }
        }
        Self::record_concurrency(w, eng, &svc_name);
    }

    fn fail_request(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        if let Some(r) = w.requests.remove(&req) {
            w.metrics.service(&r.service).failed += 1;
        }
        Self::fire_hook(w, eng, req);
    }

    // -------------------------------------------------------------- dispatch

    /// Admits `req` into pod `idx` of `svc` and (policy-dependent) fires the
    /// pre-request resize hook before redirecting.
    fn dispatch(w: &mut Platform, eng: &mut Eng, svc_name: &str, req: RequestId, idx: usize) {
        let (pod_id, hooks, serving, applied) = {
            let svc = w.services.get_mut(svc_name).unwrap();
            let serving = svc.cfg.serving_cpu;
            let sp = &mut svc.pods[idx];
            sp.proxy.offer(req);
            let pod_id = sp.pod;
            let applied = w
                .cluster
                .pod(pod_id)
                .map(|p| p.status.applied_cpu_limit)
                .unwrap_or(MilliCpu::ZERO);
            (pod_id, sp.proxy.inplace_hooks, serving, applied)
        };
        if let Some(r) = w.requests.get_mut(&req) {
            r.pod = Some(pod_id);
        }
        // Cancel any pending idle scale-down for this pod.
        let svc = w.services.get_mut(svc_name).unwrap();
        if let Some(t) = svc.pods[idx].idle_timer.take() {
            eng.cancel(t);
        }

        // A park may be in flight (status shows a resize) or already desired;
        // a new request must claim the serving allocation either way.
        let resize_in_flight = w
            .cluster
            .pod(pod_id)
            .map(|p| p.status.resize.is_some())
            .unwrap_or(false);
        let park_desired = {
            let svc = &w.services[svc_name];
            svc.pod_index(pod_id)
                .and_then(|i| svc.pods[i].desired_limit)
                .map(|d| d < serving)
                .unwrap_or(false)
        };
        if hooks && (applied < serving || resize_in_flight || park_desired) {
            // The paper's pre-hook: dispatch the scale-up patch, then
            // redirect immediately — the request starts at the parked
            // allocation and speeds up when the resize lands.
            if let Some(r) = w.requests.get_mut(&req) {
                r.scaled_up = true;
            }
            w.metrics.service(svc_name).inplace_scale_ups += 1;
            Self::request_resize(w, eng, svc_name, pod_id, serving);
        }
        Self::begin_exec(w, eng, svc_name, req, pod_id);
    }

    fn begin_exec(w: &mut Platform, eng: &mut Eng, svc_name: &str, req: RequestId, pod: PodId) {
        let profile = w.services[svc_name].profile.clone();
        if let Some(r) = w.requests.get_mut(&req) {
            r.exec = Some(Execution::start(&profile, eng.now()));
        }
        Self::recompute_pod(w, eng, svc_name, pod);
    }

    // ------------------------------------------------------------- execution

    /// Re-integrates progress for every active request on `pod` and
    /// reschedules their completion events under the current allocation.
    /// Called on every regime change: request start/finish, resize landing.
    fn recompute_pod(w: &mut Platform, eng: &mut Eng, svc_name: &str, pod: PodId) {
        let now = eng.now();
        let Some(svc) = w.services.get(svc_name) else { return };
        let Some(idx) = svc.pod_index(pod) else { return };
        // Reuse the platform scratch buffer instead of allocating per event.
        let mut active = std::mem::take(&mut w.scratch_active);
        active.clear();
        active.extend_from_slice(w.services[svc_name].pods[idx].proxy.active_requests());
        let _ = svc;
        if active.is_empty() {
            w.scratch_active = active;
            return;
        }
        let alloc = w
            .cluster
            .pod(pod)
            .map(|p| p.status.applied_cpu_limit)
            .unwrap_or(MilliCpu::ZERO);
        // Equal CFS split among in-container requests.
        let share = MilliCpu((alloc.0 / active.len() as u64).max(1));
        for &id in &active {
            let Some(r) = w.requests.get_mut(&id) else { continue };
            let Some(exec) = r.exec.as_mut() else { continue };
            // Integrate the interval just ended under the old share.
            exec.advance(now, r.share.max(MilliCpu(1)));
            r.share = share;
            if let Some(ev) = r.completion.take() {
                eng.cancel(ev);
            }
            if exec.done() {
                // Finished exactly at this boundary.
                let s = eng.schedule_in(SimTime::ZERO, move |w: &mut Platform, eng| {
                    Self::complete(w, eng, id);
                });
                r.completion = Some(s.id);
            } else {
                let eta = exec.eta(share);
                let s = eng.schedule_in(eta, move |w: &mut Platform, eng| {
                    Self::complete(w, eng, id);
                });
                r.completion = Some(s.id);
            }
        }
        w.scratch_active = active;
    }

    fn complete(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        let now = eng.now();
        let Some(r) = w.requests.get_mut(&req) else { return };
        let svc_name = r.service.clone();
        let pod = r.pod;
        if let Some(exec) = r.exec.as_mut() {
            exec.advance(now, r.share.max(MilliCpu(1)));
        }
        r.completion = None;

        // Response proxy hop is part of the measured latency.
        let respond = w.params.proxy.sample_respond(&mut w.rng);
        let latency_ms = (now + respond).saturating_sub(r.submitted_at).as_millis_f64();
        let r = w.requests.remove(&req).unwrap();
        {
            let m = w.metrics.service(&svc_name);
            m.latency_ms.record(latency_ms);
            m.completed += 1;
            if r.cold_start {
                m.cold_starts += 1;
            }
        }

        let Some(pod_id) = pod else { return };
        // Free the concurrency slot; promote a queued request if any.
        let promoted = {
            let Some(svc) = w.services.get_mut(&*svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].proxy.complete(req)
        };
        if let Some(next) = promoted {
            Self::begin_exec(w, eng, &svc_name, next, pod_id);
        } else {
            Self::recompute_pod(w, eng, &svc_name, pod_id);
        }

        Self::post_request_hooks(w, eng, &svc_name, pod_id);
        Self::record_concurrency(w, eng, &svc_name);
        Self::drain_activator(w, eng, &svc_name);
        Self::fire_hook(w, eng, req);
    }

    /// Policy post-hooks after a request leaves a pod.
    fn post_request_hooks(w: &mut Platform, eng: &mut Eng, svc_name: &str, pod_id: PodId) {
        let (policy, idle, parked, stable_window) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            (
                svc.policy,
                svc.pods[idx].proxy.idle(),
                svc.cfg.parked_cpu,
                svc.cfg.stable_window,
            )
        };
        match policy {
            Policy::InPlace => {
                if idle {
                    // The paper's post-hook: deallocate back to 1 m.
                    Self::request_resize(w, eng, svc_name, pod_id, parked);
                }
            }
            Policy::Cold => {
                if idle {
                    // Arm the scale-to-zero timer (stable window).
                    let name = svc_name.to_string();
                    let s = eng.schedule_in(stable_window, move |w: &mut Platform, eng| {
                        Self::idle_check(w, eng, &name, pod_id);
                    });
                    let svc = w.services.get_mut(svc_name).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        if let Some(old) = svc.pods[idx].idle_timer.replace(s.id) {
                            eng.cancel(old);
                        }
                    }
                }
            }
            Policy::Warm => {}
        }
    }

    // ---------------------------------------------------------------- resize

    /// Fires the queue-proxy resize hook: after the dispatch cost, try the
    /// patch; on conflict (kubelet busy with a previous resize) retry on a
    /// short period — the churn that penalizes back-to-back in-place
    /// activations.
    fn request_resize(
        w: &mut Platform,
        eng: &mut Eng,
        svc_name: &str,
        pod_id: PodId,
        target: MilliCpu,
    ) {
        // Record the latest desire; older pending desires are superseded.
        {
            let Some(svc) = w.services.get_mut(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].desired_limit = Some(target);
        }
        let hook = w.params.proxy.sample_hook(&mut w.rng);
        let name: std::sync::Arc<str> = std::sync::Arc::from(svc_name);
        eng.schedule_in(hook, move |w: &mut Platform, eng| {
            Self::try_patch(w, eng, &name, pod_id);
        });
    }

    fn try_patch(w: &mut Platform, eng: &mut Eng, svc_name: &str, pod_id: PodId) {
        let target = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            match svc.pods[idx].desired_limit {
                Some(t) => t,
                None => return,
            }
        };
        let applied = match w.cluster.pod(pod_id) {
            Some(p) => p.status.applied_cpu_limit,
            None => return,
        };
        if applied == target && w.cluster.pod(pod_id).unwrap().status.resize.is_none() {
            // Already there.
            let svc = w.services.get_mut(svc_name).unwrap();
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods[idx].desired_limit = None;
            }
            return;
        }
        let now = eng.now();
        match w.api.patch_resize(
            &mut w.cluster,
            ResizePatch {
                pod: pod_id,
                new_cpu_limit: target,
            },
            now,
        ) {
            Ok(()) => {
                w.metrics.resizes_accepted += 1;
                {
                    let svc = w.services.get_mut(svc_name).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        svc.pods[idx].desired_limit = None;
                        svc.pods[idx].retry_pending = false;
                    }
                }
                let _ = w.api.mark_in_progress(&mut w.cluster, pod_id, target, now);
                // Sample propagation latency under current node load.
                let node_id = w.cluster.pod(pod_id).unwrap().node.unwrap();
                let load = Self::node_load(w, node_id);
                let lat = w.kubelet.resize_latency(applied, target, load, &mut w.rng);
                let name: std::sync::Arc<str> = std::sync::Arc::from(svc_name);
                eng.schedule_in(lat, move |w: &mut Platform, eng| {
                    Self::resize_landed(w, eng, &name, pod_id, target);
                });
            }
            Err(e) => {
                let transient = matches!(
                    e,
                    crate::apiserver::ApiError::Conflict(_)
                        | crate::apiserver::ApiError::NotRunning(_, _)
                );
                if !transient {
                    // Permanent rejection (gate disabled, restart-required
                    // policy, invalid limit): drop the desire — the pod
                    // simply keeps its current allocation.
                    let svc = w.services.get_mut(svc_name).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        svc.pods[idx].desired_limit = None;
                    }
                    return;
                }
                // Kubelet busy applying a previous resize (or pod still
                // coming up): retry shortly unless one is already scheduled.
                w.metrics.resize_conflicts += 1;
                let retry = w.params.resize_retry;
                let svc = w.services.get_mut(svc_name).unwrap();
                let Some(idx) = svc.pod_index(pod_id) else { return };
                if !svc.pods[idx].retry_pending {
                    svc.pods[idx].retry_pending = true;
                    let name: std::sync::Arc<str> = std::sync::Arc::from(svc_name);
                    eng.schedule_in(retry, move |w: &mut Platform, eng| {
                        if let Some(svc) = w.services.get_mut(&*name) {
                            if let Some(i) = svc.pod_index(pod_id) {
                                svc.pods[i].retry_pending = false;
                            }
                        }
                        Self::try_patch(w, eng, &name, pod_id);
                    });
                }
            }
        }
    }

    fn resize_landed(
        w: &mut Platform,
        eng: &mut Eng,
        svc_name: &str,
        pod_id: PodId,
        target: MilliCpu,
    ) {
        let now = eng.now();
        let Some(pod) = w.cluster.pod(pod_id) else { return };
        let Some(node_id) = pod.node else { return };
        w.cluster
            .node_mut(node_id)
            .apply_cpu_limit(pod_id, target, now);
        let _ = w.api.mark_done(&mut w.cluster, pod_id, target, now);
        Self::committed_changed(w, eng);
        Self::recompute_pod(w, eng, svc_name, pod_id);
        // A newer desire may have raced in (up while down was landing).
        let pending = {
            let svc = w.services.get(svc_name);
            svc.and_then(|s| s.pod_index(pod_id))
                .and_then(|i| w.services[svc_name].pods[i].desired_limit)
        };
        if let Some(t) = pending {
            if t != target {
                let name: std::sync::Arc<str> = std::sync::Arc::from(svc_name);
                eng.schedule_in(SimTime::ZERO, move |w: &mut Platform, eng| {
                    Self::try_patch(w, eng, &name, pod_id);
                });
            }
        }
    }

    /// Node load for the latency model: stressors + busy serving capacity.
    fn node_load(w: &Platform, node: NodeId) -> crate::cgroup::latency::NodeLoad {
        let mut busy = MilliCpu::ZERO;
        for svc in w.services.values() {
            for sp in &svc.pods {
                if sp.proxy.active_count() > 0 {
                    if let Some(pod) = w.cluster.pod(sp.pod) {
                        if pod.node == Some(node) {
                            busy += pod.status.applied_cpu_limit;
                        }
                    }
                }
            }
        }
        w.cluster.node(node).load_with_busy(busy)
    }

    // ------------------------------------------------------------ pod lifecycle

    /// Creates and starts a pod for `svc_name`. `on_demand` marks a
    /// cold-start (request-triggered) creation.
    fn start_pod(w: &mut Platform, eng: &mut Eng, svc_name: &str, on_demand: bool) {
        let (spec, image, image_mb, init_ms) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let p = &svc.profile;
            let requests = Resources::new(
                // In-place pods reserve only a small request — the paper's
                // resource-availability advantage; warm/cold reserve the
                // full serving CPU (Guaranteed-ish QoS).
                if svc.policy == Policy::InPlace {
                    MilliCpu(100)
                } else {
                    svc.cfg.serving_cpu
                },
                Memory::from_mib(256),
            );
            let limits = Resources::new(svc.cfg.serving_cpu, Memory::from_mib(512));
            (
                PodSpec::single(&svc.profile.name, &p.image, requests, limits),
                p.image.clone(),
                p.image_mb,
                p.runtime_init_ms,
            )
        };

        let pod_id = w.cluster.create_pod(spec);
        let Some(node_id) = w.scheduler.pick(w.cluster.nodes(), w.cluster.pod(pod_id).unwrap().spec.total_requests())
        else {
            // Unschedulable — drop the pod; buffered requests will time out.
            w.cluster.delete_pod(pod_id);
            return;
        };
        if w.cluster.bind(pod_id, node_id).is_err() {
            w.cluster.delete_pod(pod_id);
            return;
        }
        w.metrics.pods_created += 1;
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            svc.starting += 1;
        }
        let _ = on_demand;

        // Run the startup pipeline as chained events.
        let cached = w.cluster.node(node_id).image_cached(&image);
        let plan = w
            .kubelet
            .startup_plan(cached, image_mb, init_ms, &mut w.rng);
        let total = Kubelet::plan_total(&plan);
        {
            let pod = w.cluster.pod_mut(pod_id).unwrap();
            pod.status.phase = PodPhase::Creating;
            pod.created_at = eng.now();
        }
        let name = svc_name.to_string();
        eng.schedule_in(total, move |w: &mut Platform, eng| {
            Self::pod_ready(w, eng, &name, pod_id, node_id, image.clone());
        });
    }

    fn pod_ready(
        w: &mut Platform,
        eng: &mut Eng,
        svc_name: &str,
        pod_id: PodId,
        node_id: NodeId,
        image: String,
    ) {
        w.cluster.node_mut(node_id).cache_image(&image);
        {
            let Some(pod) = w.cluster.pod_mut(pod_id) else { return };
            pod.status.phase = PodPhase::Running;
            pod.status.ready = true;
        }
        let (hooks, climit) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            (svc.policy.inplace_hooks(), svc.cfg.concurrency_limit())
        };
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            svc.starting = svc.starting.saturating_sub(1);
            let mut sp = ServicePod::new(pod_id, climit, hooks);
            sp.ready = true;
            svc.pods.push(sp);
        }
        Self::committed_changed(w, eng);
        Self::drain_activator(w, eng, svc_name);

        // A fresh in-place pod with nothing to do parks immediately.
        let idle = {
            let svc = &w.services[svc_name];
            let idx = svc.pod_index(pod_id).unwrap();
            svc.pods[idx].proxy.idle()
        };
        if hooks && idle {
            let parked = w.services[svc_name].cfg.parked_cpu;
            Self::request_resize(w, eng, svc_name, pod_id, parked);
        }
        // Cold pods with nothing to do arm their idle timer right away.
        let (policy, stable_window) = {
            let svc = &w.services[svc_name];
            (svc.policy, svc.cfg.stable_window)
        };
        if policy == Policy::Cold && idle {
            let name = svc_name.to_string();
            let s = eng.schedule_in(stable_window, move |w: &mut Platform, eng| {
                Self::idle_check(w, eng, &name, pod_id);
            });
            let svc = w.services.get_mut(svc_name).unwrap();
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods[idx].idle_timer = Some(s.id);
            }
        }
    }

    /// Dispatches as many buffered requests as capacity allows.
    fn drain_activator(w: &mut Platform, eng: &mut Eng, svc_name: &str) {
        loop {
            let (idx, buffered) = {
                let Some(svc) = w.services.get_mut(svc_name) else { return };
                let Some(idx) = svc.pick_pod() else { return };
                let (mut out, dead) = svc.activator.drain(1, eng.now());
                for d in dead {
                    Self::fail_request(w, eng, d.request);
                    return; // re-enter loop via next call; keep simple
                }
                match out.pop() {
                    Some(b) => (idx, b),
                    None => return,
                }
            };
            Self::dispatch(w, eng, svc_name, buffered.request, idx);
        }
    }

    /// Cold policy: scale this pod to zero if its stable window stayed quiet.
    fn idle_check(w: &mut Platform, eng: &mut Eng, svc_name: &str, pod_id: PodId) {
        let idle = {
            let Some(svc) = w.services.get_mut(svc_name) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].idle_timer = None;
            svc.pods[idx].proxy.idle() && !svc.pods[idx].terminating
        };
        if !idle {
            return;
        }
        // Begin termination.
        {
            let svc = w.services.get_mut(svc_name).unwrap();
            let idx = svc.pod_index(pod_id).unwrap();
            svc.pods[idx].terminating = true;
        }
        if let Some(pod) = w.cluster.pod_mut(pod_id) {
            pod.status.phase = PodPhase::Terminating;
            pod.status.ready = false;
        }
        Self::committed_changed(w, eng);
        let term = w.kubelet.termination_time(&mut w.rng);
        let name = svc_name.to_string();
        eng.schedule_in(term, move |w: &mut Platform, _eng| {
            w.cluster.delete_pod(pod_id);
            w.metrics.pods_deleted += 1;
            if let Some(svc) = w.services.get_mut(&name) {
                if let Some(idx) = svc.pod_index(pod_id) {
                    svc.pods.remove(idx);
                }
            }
        });
    }

    /// Event-driven KPA evaluation: scale up when the decision demands it.
    fn maybe_scale_up(w: &mut Platform, eng: &mut Eng, svc_name: &str) {
        let (desired, live) = {
            let Some(svc) = w.services.get(svc_name) else { return };
            let d = svc.autoscaler.decide(eng.now(), svc.ready_pods() as u32);
            (d.desired, svc.live_pods() as u32)
        };
        for _ in live..desired {
            Self::start_pod(w, eng, svc_name, true);
        }
    }

    fn record_concurrency(w: &mut Platform, eng: &mut Eng, svc_name: &str) {
        let now = eng.now();
        let overloaded = if let Some(svc) = w.services.get_mut(svc_name) {
            // One pass over the pod list for concurrency + readiness.
            let mut in_flight = svc.activator.len();
            let mut ready = 0usize;
            for p in &svc.pods {
                in_flight += p.proxy.in_flight();
                if p.ready && !p.terminating {
                    ready += 1;
                }
            }
            svc.autoscaler.record(now, in_flight as u32);
            // Level-triggered KPA: consider scale-out whenever observed
            // concurrency exceeds what the current fleet targets — skipped
            // entirely for the common single-pod-capped revision.
            (svc.live_pods() as u32) < svc.cfg.max_scale
                && in_flight as f64 > svc.cfg.target_concurrency * ready.max(1) as f64
        } else {
            false
        };
        if overloaded {
            Self::maybe_scale_up(w, eng, svc_name);
        }
    }

    /// Recomputes the committed-CPU metric (Σ applied limits of live pods).
    fn committed_changed(w: &mut Platform, eng: &mut Eng) {
        let mut total = MilliCpu::ZERO;
        for svc in w.services.values() {
            for sp in &svc.pods {
                if sp.terminating {
                    continue;
                }
                if let Some(pod) = w.cluster.pod(sp.pod) {
                    if pod.status.phase == PodPhase::Running {
                        total += pod.status.applied_cpu_limit;
                    }
                }
            }
        }
        w.metrics.committed_cpu.update(eng.now(), total);
    }
}

// ============================================================ Simulation

/// Owns the engine + platform pair; the entry point examples and benches use.
pub struct Simulation {
    pub engine: Eng,
    pub world: Platform,
}

impl Simulation {
    /// Paper testbed with default calibration.
    pub fn paper(seed: u64) -> Simulation {
        Simulation {
            engine: Engine::new(),
            world: Platform::paper_testbed(PlatformParams::with_seed(seed)),
        }
    }

    pub fn with_params(params: PlatformParams) -> Simulation {
        Simulation {
            engine: Engine::new(),
            world: Platform::paper_testbed(params),
        }
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    pub fn deploy(&mut self, name: &str, profile: WorkloadProfile, policy: Policy) {
        self.world
            .deploy_workload(&mut self.engine, name, profile, policy);
    }

    pub fn deploy_service(&mut self, svc: Service) {
        self.world.deploy(&mut self.engine, svc);
    }

    pub fn submit(&mut self, service: &str) -> RequestId {
        self.world.submit(&mut self.engine, service)
    }

    pub fn submit_at(&mut self, at: SimTime, service: &str) {
        self.world.submit_at(&mut self.engine, at, service);
    }

    /// Runs until the event queue drains.
    pub fn run(&mut self) -> u64 {
        self.engine.run(&mut self.world)
    }

    pub fn run_until(&mut self, deadline: SimTime) -> u64 {
        self.engine.run_until(&mut self.world, deadline)
    }

    /// Runs until all submitted requests completed (or the queue drained).
    pub fn run_to_quiescence(&mut self) {
        // Idle timers may keep the queue alive; step until no requests
        // remain in flight.
        while self.world.in_flight() > 0 {
            if self.engine.step(&mut self.world).is_none() {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry::WorkloadKind;

    fn sim_with(policy: Policy, kind: WorkloadKind) -> Simulation {
        let mut sim = Simulation::paper(7);
        sim.deploy("fn", WorkloadProfile::paper(kind), policy);
        // Let pre-created pods come up.
        sim.run_to_quiescence();
        let settle = sim.now() + SimTime::from_secs(30);
        sim.run_until(settle);
        sim
    }

    fn mean_latency(sim: &mut Simulation, svc: &str) -> f64 {
        sim.world.metrics.service(svc).latency_ms.mean()
    }

    #[test]
    fn warm_request_close_to_default_runtime() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // helloworld 5.31 ms + ~15 ms proxy.
        assert!((12.0..40.0).contains(&m), "warm latency {m}");
        assert_eq!(sim.world.metrics.service("fn").completed, 1);
    }

    #[test]
    fn cold_request_pays_startup_pipeline() {
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // Pipeline ≈1.2–1.7 s (image cold on first pull adds more).
        assert!(m > 1000.0, "cold latency {m}");
        assert_eq!(sim.world.metrics.service("fn").cold_starts, 1);
    }

    #[test]
    fn inplace_request_pays_scale_up_only() {
        let mut sim = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // ≈ 5.31 runtime + ~15 proxy + ~2 hook + ~56 resize + dead window.
        assert!((40.0..220.0).contains(&m), "in-place latency {m}");
        assert_eq!(sim.world.metrics.service("fn").inplace_scale_ups, 1);
        assert!(sim.world.metrics.resizes_accepted >= 2); // park + up
    }

    #[test]
    fn policy_ordering_matches_paper() {
        let mut results = Vec::new();
        for policy in [Policy::Cold, Policy::InPlace, Policy::Warm] {
            let mut sim = sim_with(policy, WorkloadKind::HelloWorld);
            sim.submit("fn");
            sim.run_to_quiescence();
            results.push(mean_latency(&mut sim, "fn"));
        }
        let (cold, inplace, warm) = (results[0], results[1], results[2]);
        assert!(cold > inplace, "cold={cold} inplace={inplace}");
        assert!(inplace > warm, "inplace={inplace} warm={warm}");
    }

    #[test]
    fn cold_pod_scales_to_zero_after_stable_window() {
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        sim.run_to_quiescence();
        // After the request, 6 s stable window + termination passes.
        let deadline = sim.now() + SimTime::from_secs(10);
        sim.run_until(deadline);
        assert_eq!(sim.world.services["fn"].pods.len(), 0);
        assert_eq!(sim.world.metrics.pods_deleted, 1);
        // A second request pays another cold start.
        sim.submit("fn");
        sim.run_to_quiescence();
        assert_eq!(sim.world.metrics.service("fn").cold_starts, 2);
    }

    #[test]
    fn inplace_pod_parks_between_requests() {
        let mut sim = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        // Let the park resize land.
        let deadline = sim.now() + SimTime::from_secs(5);
        sim.run_until(deadline);
        let pod = sim.world.services["fn"].pods[0].pod;
        let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
        assert_eq!(applied, MilliCpu(1), "pod should be parked at 1m");
    }

    #[test]
    fn warm_pod_stays_at_serving_allocation() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let pod = sim.world.services["fn"].pods[0].pod;
        let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
        assert_eq!(applied, MilliCpu(1000));
    }

    #[test]
    fn committed_cpu_reflects_policies() {
        // Warm commits 1000 m always; in-place parks at 1 m.
        let mut warm = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        let mut inp = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        let horizon = SimTime::from_secs(120);
        warm.run_until(warm.now() + horizon);
        inp.run_until(inp.now() + horizon);
        let now_w = warm.now();
        let now_i = inp.now();
        let warm_avg = warm.world.metrics.committed_cpu.average_mcpu(now_w);
        let inp_avg = inp.world.metrics.committed_cpu.average_mcpu(now_i);
        assert!(warm_avg > 900.0, "warm avg {warm_avg}");
        assert!(inp_avg < 120.0, "in-place avg {inp_avg}");
    }

    #[test]
    fn concurrent_requests_share_cpu() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::Cpu);
        // Two simultaneous cpu-bound requests on one 1000 m pod: each sees
        // ~500 m ⇒ each takes ~2× the default runtime.
        sim.submit("fn");
        sim.submit("fn");
        sim.run_to_quiescence();
        let mut lat = sim.world.metrics.service("fn").latency_ms.clone();
        assert_eq!(lat.len(), 2);
        let min = lat.min();
        assert!(min > 4000.0, "each should be ~2×2465 ms, min={min}");
    }

    #[test]
    fn deterministic_across_runs() {
        let run = |seed: u64| {
            let mut sim = sim_with(Policy::InPlace, WorkloadKind::Cpu);
            let _ = seed;
            for _ in 0..5 {
                sim.submit("fn");
            }
            sim.run_to_quiescence();
            sim.world.metrics.service("fn").latency_ms.mean()
        };
        assert_eq!(run(1).to_bits(), run(1).to_bits());
    }
}
