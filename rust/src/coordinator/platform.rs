//! The platform: the L3 coordinator that wires cluster, API server, Knative
//! layer and policies onto the discrete-event engine.
//!
//! This file owns the world *state* and event wiring only; behaviour is
//! split by concern across sibling modules, all contributing `impl
//! Platform` blocks:
//!
//! * [`routing`](super::routing) — the request hot path
//!   (`submit → [forward] → arrive → dispatch → exec under CFS → complete`),
//! * [`lifecycle`](super::lifecycle) — pod start/park/idle/teardown and
//!   event-driven KPA scale-out,
//! * [`resize`](super::resize) — the in-place patch hooks and their
//!   conflict/retry churn,
//! * [`sim`](super::sim) — the [`Simulation`] harness owning the engine +
//!   platform pair.
//!
//! The fleet shape is a [`Topology`]: the paper's single 8-core `kind`
//! node is `Topology::paper()`, and everything multi-node (uniform or
//! heterogeneous pools, per-node kubelets, the scheduler's filter/score
//! path) flows from the same constructor.

use std::sync::Arc;

use crate::util::intern::{Interner, ServiceId};
use crate::util::nohash::IdHashMap;

use crate::apiserver::{ApiServer, FeatureGates};
use crate::cluster::kubelet::Kubelet;
use crate::cluster::pod::PodId;
use crate::cluster::scheduler::Scheduler;
use crate::cluster::topology::Topology;
use crate::cluster::{Cluster, NodeId};
use crate::coordinator::accounting::{FleetAccounting, HybridWeights, RoutingPolicy};
use crate::coordinator::event::Event;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Continuation, RequestState};
use crate::coordinator::service::Service;
use crate::faults::FaultState;
use crate::knative::activator::RequestId;
use crate::obs::{ObsState, ObserveConfig, TimelineSample};
use crate::policy::{PlatformParams, Policy};
use crate::simclock::{Engine, EventId, SimTime};
use crate::util::quantity::MilliCpu;
use crate::util::rng::Rng;
use crate::workload::registry::WorkloadProfile;

pub use crate::coordinator::sim::Simulation;

/// Engine type alias used across the coordinator.
pub type Eng = Engine<Platform>;

/// A pod whose startup pipeline is still in flight, tracked in
/// [`Platform::starting_pods`]. Tracked so node-crash fault handling can
/// cancel the pending `PodReady` and unwind the owning service's
/// `starting` counter — the service is not derivable from the cluster
/// pod (its spec carries the workload profile name, not the service).
#[derive(Debug)]
pub(crate) struct StartingPod {
    pub service: ServiceId,
    pub node: NodeId,
    pub ready_event: EventId,
}

/// In-flight startup pipelines in insertion order — the same order the
/// old `BTreeMap<PodId, _>` iterated in (pod uids were monotone), kept
/// explicit now that slab ids pack a generation and no longer sort by
/// creation time.
#[derive(Debug, Default)]
pub(crate) struct StartingPods(Vec<(PodId, StartingPod)>);

impl StartingPods {
    pub fn insert(&mut self, pod: PodId, s: StartingPod) {
        debug_assert!(self.0.iter().all(|(p, _)| *p != pod));
        self.0.push((pod, s));
    }

    /// Removes by pod id, preserving insertion order of the rest.
    pub fn remove(&mut self, pod: PodId) -> Option<StartingPod> {
        let i = self.0.iter().position(|(p, _)| *p == pod)?;
        Some(self.0.remove(i).1)
    }

    pub fn iter(&self) -> impl Iterator<Item = (PodId, &StartingPod)> {
        self.0.iter().map(|(p, s)| (*p, s))
    }

    pub fn values(&self) -> impl Iterator<Item = &StartingPod> {
        self.0.iter().map(|(_, s)| s)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// The service directory: the intern table plus a dense slot per id.
///
/// A slot is `None` for names that were interned (submitted against,
/// messaged about) but never deployed — exactly the set the old
/// `BTreeMap<String, Service>` simply had no entry for. Iteration
/// ([`Services::values`], [`Services::keys`], [`Services::ids_by_name`])
/// stays in lexicographic name order, matching the map era everywhere an
/// iteration order can reach the RNG or a report.
#[derive(Default)]
pub struct Services {
    interner: Interner,
    slots: Vec<Option<Service>>,
}

impl Services {
    /// Interns a name (allocating its dense id on first sight) without
    /// deploying anything. Platform code goes through
    /// [`Platform::intern_service`], which also registers the metrics row.
    pub(crate) fn intern(&mut self, name: &str) -> ServiceId {
        let id = self.interner.intern(name);
        if self.slots.len() <= id.index() {
            self.slots.resize_with(id.index() + 1, || None);
        }
        id
    }

    pub fn id_of(&self, name: &str) -> Option<ServiceId> {
        self.interner.get(name)
    }

    /// The name behind an id (render/boundary use).
    pub fn name(&self, id: ServiceId) -> &Arc<str> {
        self.interner.name(id)
    }

    /// The deployed service behind an id (`None` if interned-only).
    #[inline]
    pub fn get(&self, id: ServiceId) -> Option<&Service> {
        self.slots.get(id.index())?.as_ref()
    }

    #[inline]
    pub fn get_mut(&mut self, id: ServiceId) -> Option<&mut Service> {
        self.slots.get_mut(id.index())?.as_mut()
    }

    pub fn get_by_name(&self, name: &str) -> Option<&Service> {
        self.get(self.id_of(name)?)
    }

    /// Is a service with this name deployed?
    pub fn contains_key(&self, name: &str) -> bool {
        self.get_by_name(name).is_some()
    }

    pub(crate) fn insert(&mut self, id: ServiceId, svc: Service) {
        self.slots[id.index()] = Some(svc);
    }

    /// Deployed services in name order.
    pub fn values(&self) -> impl Iterator<Item = &Service> {
        self.interner
            .ids_by_name()
            .filter_map(|id| self.slots[id.index()].as_ref())
    }

    /// Deployed service names in name order.
    pub fn keys(&self) -> impl Iterator<Item = &Arc<str>> {
        self.interner
            .iter_by_name()
            .filter(|(_, id)| self.slots[id.index()].is_some())
            .map(|(n, _)| n)
    }

    /// Deployed service ids in name order — the canonical sweep order for
    /// RNG-bearing loops (crash recovery, scale-up sweeps), where deploy
    /// order (`fn-0, fn-1, fn-10, …` interleaves differently) would
    /// silently reorder RNG draws.
    pub fn ids_by_name(&self) -> impl Iterator<Item = ServiceId> + '_ {
        self.interner
            .ids_by_name()
            .filter(|id| self.slots[id.index()].is_some())
    }

    /// Number of deployed services.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Index<ServiceId> for Services {
    type Output = Service;

    fn index(&self, id: ServiceId) -> &Service {
        self.get(id).expect("service not deployed")
    }
}

impl std::ops::Index<&str> for Services {
    type Output = Service;

    fn index(&self, name: &str) -> &Service {
        self.get_by_name(name)
            .unwrap_or_else(|| panic!("service {name:?} not deployed"))
    }
}

/// Map-style iteration in name order — the `&BTreeMap<String, Service>`
/// surface tests and debug sweeps loop over.
impl<'a> IntoIterator for &'a Services {
    type Item = (&'a Arc<str>, &'a Service);
    type IntoIter = std::vec::IntoIter<(&'a Arc<str>, &'a Service)>;

    fn into_iter(self) -> Self::IntoIter {
        self.interner
            .iter_by_name()
            .filter_map(|(n, id)| self.slots[id.index()].as_ref().map(|s| (n, s)))
            .collect::<Vec<_>>()
            .into_iter()
    }
}

/// A pending cross-shard reschedule request emitted by a cell whose only
/// node crashed with no surviving local capacity. Collected in
/// [`Platform::xshard_outbox`] and delivered by the sharded runtime at the
/// next window barrier (see `crate::shard`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XShardMsg {
    /// Local virtual time of emission (the crash instant).
    pub at: SimTime,
    pub service: std::sync::Arc<str>,
    pub pods: u32,
}

/// The world state driven by the event engine.
pub struct Platform {
    pub cluster: Cluster,
    /// The fleet shape the cluster was built from.
    pub topology: Topology,
    pub api: ApiServer,
    /// One kubelet per node, indexed by `NodeId` — per-node startup and
    /// resize pipelines (today they share calibration; heterogeneous
    /// per-node parameters plug in here).
    pub(crate) kubelets: Vec<Kubelet>,
    pub scheduler: Scheduler,
    pub params: PlatformParams,
    /// Activator pod-selection policy (default: Knative's least-loaded).
    pub routing: RoutingPolicy,
    /// Blend weights for [`RoutingPolicy::Hybrid`] — scenario-tunable; the
    /// default reproduces the original hard-wired score.
    pub hybrid_weights: HybridWeights,
    /// Incremental per-node busy/committed/in-flight counters — the O(1)
    /// fleet state behind `node_load`, `committed_changed` and the
    /// placement-aware routing scores.
    pub fleet: FleetAccounting,
    /// Fault-injection state: latency multipliers, the crash request
    /// policy and the dedicated fault RNG. Inert (all factors 1, p = 0)
    /// unless [`Platform::install_faults`] armed it.
    pub faults: FaultState,
    /// Pods whose startup pipeline is still running (insert in
    /// `start_pod`, remove in `pod_ready`). Insertion-ordered for
    /// deterministic iteration when a crash sweeps a node.
    pub(crate) starting_pods: StartingPods,
    pub services: Services,
    pub(crate) requests: IdHashMap<RequestId, RequestState>,
    pub(crate) next_request: u64,
    pub rng: Rng,
    pub metrics: Metrics,
    /// One-shot continuations fired when a request completes (or fails) —
    /// how closed-loop virtual users chain their iterations.
    pub(crate) completion_hooks:
        IdHashMap<RequestId, Box<dyn FnOnce(&mut Platform, &mut Eng) + Send>>,
    /// Scratch buffer reused by `recompute_pod` (hot path: one regime change
    /// per request start/finish/resize; avoids a per-event allocation).
    pub(crate) scratch_active: Vec<RequestId>,
    /// Cross-shard reschedule outbox. `None` (the default) means this
    /// platform is a standalone world and node crashes reschedule locally;
    /// `Some` marks it as one cell of a sharded run, where a crash with no
    /// surviving local capacity escalates to the sharded runtime instead.
    pub(crate) xshard_outbox: Option<Vec<XShardMsg>>,
    /// Observation plane (`None` unless a spec/CLI armed it). Boxed so the
    /// unobserved platform pays one pointer of state; every hook site is a
    /// read-only stamp behind `if let Some(..)`, so arming never perturbs
    /// RNG draws or event ordering.
    pub obs: Option<Box<ObsState>>,
}

impl Platform {
    /// A platform with the paper's testbed: one 8-core / 10 GB node and the
    /// `InPlacePodVerticalScaling` gate enabled.
    pub fn paper_testbed(params: PlatformParams) -> Platform {
        Platform::with_topology(Topology::paper(), params)
    }

    /// A platform over an arbitrary fleet shape. `Topology::paper()`
    /// reproduces [`Platform::paper_testbed`] exactly (same node, same RNG
    /// stream, byte-identical seeded metrics).
    pub fn with_topology(topology: Topology, params: PlatformParams) -> Platform {
        let cluster = topology.build();
        // Per-node calibration: a NodeShape may override or scale the
        // shared startup/resize pipelines (heterogeneous fleets with
        // slow/fast nodes); shapes without either share `PlatformParams`
        // as before.
        let kubelets: Vec<Kubelet> = topology
            .shapes()
            .iter()
            .map(|shape| {
                Kubelet::new(
                    shape.effective_startup(&params.startup),
                    shape.effective_resize(&params.resize),
                )
            })
            .collect();
        let fleet = FleetAccounting::for_topology(&topology);
        let faults = FaultState::inert(kubelets.len(), params.seed);
        let rng = Rng::new(params.seed);
        Platform {
            cluster,
            topology,
            api: ApiServer::new(FeatureGates::paper_testbed()),
            kubelets,
            scheduler: Scheduler::default(),
            params,
            routing: RoutingPolicy::LeastLoaded,
            hybrid_weights: HybridWeights::default(),
            fleet,
            faults,
            starting_pods: StartingPods::default(),
            services: Services::default(),
            requests: IdHashMap::default(),
            next_request: 1,
            rng,
            metrics: Metrics::default(),
            completion_hooks: IdHashMap::default(),
            scratch_active: Vec::with_capacity(64),
            xshard_outbox: None,
            obs: None,
        }
    }

    // ----------------------------------------------------------- observation

    /// Arms the observation plane: request-lifecycle spans, timeline
    /// gauges and event self-profiling per `cfg`. `seed` feeds the
    /// deterministic span sampler only — the simulation RNG is untouched.
    /// `origin` (the current simulation time, i.e. the end of the settle
    /// run) re-bases every exported timestamp onto the measured window,
    /// which is what keeps sharded span output identical at any shard
    /// count despite per-cell settle jitter. Call sites that want timeline
    /// gauges must also schedule the first [`Event::ObsTick`] at one
    /// cadence from now.
    pub fn arm_obs(&mut self, cfg: ObserveConfig, seed: u64, origin: crate::simclock::SimTime) {
        self.obs = Some(Box::new(ObsState::new(cfg, seed, Event::KIND_COUNT, origin)));
    }

    /// Detaches the observation state for harvesting (no-op when unarmed).
    pub fn take_obs(&mut self) -> Option<Box<ObsState>> {
        self.obs.take()
    }

    /// The end-of-run clock when observation is armed: the time of the
    /// last real (non-`ObsTick`) event. Harvest sites must prefer this
    /// over the engine clock — trailing cadence ticks run past the
    /// workload, and time-averaged report gauges must cover exactly the
    /// span an unobserved run covers (byte identity).
    pub fn obs_end_clock(&self) -> Option<crate::simclock::SimTime> {
        self.obs.as_ref().map(|o| o.last_real_event())
    }

    /// [`Event::ObsTick`] handler: append one gauge sample and re-arm the
    /// cadence. Strictly read-only over simulation state.
    pub(crate) fn obs_tick(w: &mut Platform, eng: &mut Eng) {
        let Some(obs) = w.obs.as_ref() else { return };
        if !obs.timeline_enabled() {
            return;
        }
        let cadence = obs.cfg().timeline_cadence;
        let sample = w.sample_timeline(eng.now());
        if let Some(obs) = w.obs.as_mut() {
            obs.record_timeline(sample);
        }
        // Re-arm only while simulation work remains: `Engine::run` drains
        // the queue to empty, so an unconditional self-reschedule would
        // keep every run alive forever. At most one trailing sample lands
        // after the last workload event.
        if eng.pending() > 0 {
            eng.schedule_in(cadence, Event::ObsTick);
        }
    }

    /// One timeline gauge sample: pods by state per node, activator queue
    /// depth, in-flight concurrency and the summed KPA signal.
    fn sample_timeline(&self, at: SimTime) -> TimelineSample {
        let nodes = self.cluster.nodes().len();
        let mut node_ready = vec![0u32; nodes];
        let mut node_starting = vec![0u32; nodes];
        let mut activator_depth = 0u64;
        let mut in_flight = 0u64;
        let mut kpa_signal = 0.0f64;
        for svc in self.services.values() {
            activator_depth += svc.buffered() as u64;
            in_flight += svc.total_in_flight() as u64;
            kpa_signal += f64::from(svc.observed_concurrency());
            for sp in &svc.pods {
                if let (Some(node), true) = (sp.node, sp.ready) {
                    node_ready[node.0 as usize] += 1;
                }
            }
        }
        for s in self.starting_pods.values() {
            node_starting[s.node.0 as usize] += 1;
        }
        TimelineSample {
            at,
            node_ready,
            node_starting,
            activator_depth,
            in_flight,
            kpa_signal,
        }
    }

    // ---------------------------------------------------------- sharded runs

    /// Marks this platform as one cell of a sharded run: node crashes with
    /// no surviving local capacity push [`XShardMsg`]s instead of burning
    /// local reschedule attempts (see `crate::shard`).
    pub fn arm_xshard_outbox(&mut self) {
        self.xshard_outbox = Some(Vec::new());
    }

    /// Drains the cross-shard outbox (empty for standalone platforms).
    pub fn take_xshard_msgs(&mut self) -> Vec<XShardMsg> {
        match self.xshard_outbox.as_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }

    // ---------------------------------------------------------------- deploy

    /// Deploys a service; pre-creates `min_scale` pods. Images are
    /// side-loaded onto every node at deploy time (the paper's `kind load`
    /// setup), so cold starts pay container start + init, not a registry
    /// pull.
    pub fn deploy(&mut self, eng: &mut Eng, svc: Service) {
        let min = svc.cfg.min_scale;
        let image = svc.profile.image.clone();
        for i in 0..self.cluster.nodes().len() {
            self.cluster
                .node_mut(NodeId(i as u32))
                .cache_image(&image);
        }
        let id = self.intern_service(&svc.name);
        self.services.insert(id, svc);
        for _ in 0..min {
            Self::start_pod(self, eng, id, false);
        }
    }

    /// Interns a service name (the string → [`ServiceId`] boundary) and
    /// registers its metrics row — the sole id allocator, so the intern
    /// table and the metrics rows stay aligned by construction.
    pub fn intern_service(&mut self, name: &str) -> ServiceId {
        let id = self.services.intern(name);
        self.metrics.register(id, name);
        id
    }

    /// Convenience: deploy a paper workload under a policy.
    pub fn deploy_workload(
        &mut self,
        eng: &mut Eng,
        name: &str,
        profile: WorkloadProfile,
        policy: Policy,
    ) {
        self.deploy(eng, Service::new(name, profile, policy));
    }

    // ---------------------------------------------------------------- submit

    /// Submits a request now; returns its id. Name-addressed boundary —
    /// the event loop uses [`Platform::submit_id`].
    pub fn submit(&mut self, eng: &mut Eng, service: &str) -> RequestId {
        let svc = self.intern_service(service);
        self.submit_id(eng, svc)
    }

    /// Submits a request against an interned service id (the hot path:
    /// no string hashing, no allocation).
    pub fn submit_id(&mut self, eng: &mut Eng, service: ServiceId) -> RequestId {
        let id = RequestId(self.next_request);
        self.next_request += 1;
        let req = RequestState::new(id, service, eng.now());
        self.requests.insert(id, req);
        if let Some(obs) = &mut self.obs {
            let name: &str = self.services.name(service);
            obs.on_submit(id.0, service.index(), name, eng.now());
        }
        let fwd = self.params.proxy.sample_forward(&mut self.rng);
        eng.schedule_in(fwd, Event::Arrive { req: id });
        id
    }

    /// Schedules a submission at an absolute virtual time (load generation).
    pub fn submit_at(&mut self, eng: &mut Eng, at: SimTime, service: &str) {
        let service = self.intern_service(service);
        eng.schedule_at(at, Event::Submit { service });
    }

    /// Submits a request and registers a one-shot continuation invoked when
    /// it completes or fails (closed-loop load generation).
    pub fn submit_with_hook<F>(&mut self, eng: &mut Eng, service: &str, hook: F) -> RequestId
    where
        F: FnOnce(&mut Platform, &mut Eng) + Send + 'static,
    {
        let id = self.submit(eng, service);
        self.completion_hooks.insert(id, Box::new(hook));
        id
    }

    pub(crate) fn fire_hook(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        if let Some(hook) = w.completion_hooks.remove(&req) {
            hook(w, eng);
        }
    }

    /// Fires a typed completion continuation (the alloc-free counterpart of
    /// `fire_hook` used by the closed-loop load generator).
    pub(crate) fn fire_continuation(eng: &mut Eng, cont: Option<Continuation>) {
        if let Some(Continuation::VuNext {
            service,
            remaining,
            think,
        }) = cont
        {
            if remaining > 1 {
                eng.schedule_in(
                    think,
                    Event::VuIterate {
                        service,
                        remaining: remaining - 1,
                        think,
                    },
                );
            }
        }
    }

    pub fn request(&self, id: RequestId) -> Option<&RequestState> {
        self.requests.get(&id)
    }

    /// CPU limit currently in force for `pod`, if the pod still exists —
    /// the single definition of "applied" the hot path and the fleet
    /// counters share.
    pub fn applied_limit(&self, pod: PodId) -> Option<MilliCpu> {
        self.cluster.pod(pod).map(|p| p.status.applied_cpu_limit)
    }

    pub fn in_flight(&self) -> usize {
        self.requests.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantity::MilliCpu;
    use crate::workload::registry::WorkloadKind;

    fn sim_with(policy: Policy, kind: WorkloadKind) -> Simulation {
        let mut sim = Simulation::paper(7);
        sim.deploy("fn", WorkloadProfile::paper(kind), policy);
        // Let pre-created pods come up.
        sim.run_to_quiescence();
        let settle = sim.now() + SimTime::from_secs(30);
        sim.run_until(settle);
        sim
    }

    fn mean_latency(sim: &mut Simulation, svc: &str) -> f64 {
        sim.world.metrics.service(svc).latency_ms.mean()
    }

    #[test]
    fn warm_request_close_to_default_runtime() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // helloworld 5.31 ms + ~15 ms proxy.
        assert!((12.0..40.0).contains(&m), "warm latency {m}");
        assert_eq!(sim.world.metrics.service("fn").completed, 1);
    }

    #[test]
    fn cold_request_pays_startup_pipeline() {
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // Pipeline ≈1.2–1.7 s (image cold on first pull adds more).
        assert!(m > 1000.0, "cold latency {m}");
        assert_eq!(sim.world.metrics.service("fn").cold_starts, 1);
    }

    #[test]
    fn inplace_request_pays_scale_up_only() {
        let mut sim = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let m = mean_latency(&mut sim, "fn");
        // ≈ 5.31 runtime + ~15 proxy + ~2 hook + ~56 resize + dead window.
        assert!((40.0..220.0).contains(&m), "in-place latency {m}");
        assert_eq!(sim.world.metrics.service("fn").inplace_scale_ups, 1);
        assert!(sim.world.metrics.resizes_accepted >= 2); // park + up
    }

    #[test]
    fn policy_ordering_matches_paper() {
        let mut results = Vec::new();
        for policy in [Policy::Cold, Policy::InPlace, Policy::Warm] {
            let mut sim = sim_with(policy, WorkloadKind::HelloWorld);
            sim.submit("fn");
            sim.run_to_quiescence();
            results.push(mean_latency(&mut sim, "fn"));
        }
        let (cold, inplace, warm) = (results[0], results[1], results[2]);
        assert!(cold > inplace, "cold={cold} inplace={inplace}");
        assert!(inplace > warm, "inplace={inplace} warm={warm}");
    }

    #[test]
    fn cold_pod_scales_to_zero_after_stable_window() {
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        sim.run_to_quiescence();
        // After the request, 6 s stable window + termination passes.
        let deadline = sim.now() + SimTime::from_secs(10);
        sim.run_until(deadline);
        assert_eq!(sim.world.services["fn"].pods.len(), 0);
        assert_eq!(sim.world.metrics.pods_deleted, 1);
        // A second request pays another cold start.
        sim.submit("fn");
        sim.run_to_quiescence();
        assert_eq!(sim.world.metrics.service("fn").cold_starts, 2);
    }

    #[test]
    fn inplace_pod_parks_between_requests() {
        let mut sim = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        // Let the park resize land.
        let deadline = sim.now() + SimTime::from_secs(5);
        sim.run_until(deadline);
        let pod = sim.world.services["fn"].pods[0].pod;
        let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
        assert_eq!(applied, MilliCpu(1), "pod should be parked at 1m");
    }

    #[test]
    fn warm_pod_stays_at_serving_allocation() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        sim.submit("fn");
        sim.run_to_quiescence();
        let pod = sim.world.services["fn"].pods[0].pod;
        let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
        assert_eq!(applied, MilliCpu(1000));
    }

    #[test]
    fn committed_cpu_reflects_policies() {
        // Warm commits 1000 m always; in-place parks at 1 m.
        let mut warm = sim_with(Policy::Warm, WorkloadKind::HelloWorld);
        let mut inp = sim_with(Policy::InPlace, WorkloadKind::HelloWorld);
        let horizon = SimTime::from_secs(120);
        warm.run_until(warm.now() + horizon);
        inp.run_until(inp.now() + horizon);
        let now_w = warm.now();
        let now_i = inp.now();
        let warm_avg = warm.world.metrics.committed_cpu.average_mcpu(now_w);
        let inp_avg = inp.world.metrics.committed_cpu.average_mcpu(now_i);
        assert!(warm_avg > 900.0, "warm avg {warm_avg}");
        assert!(inp_avg < 120.0, "in-place avg {inp_avg}");
    }

    #[test]
    fn concurrent_requests_share_cpu() {
        let mut sim = sim_with(Policy::Warm, WorkloadKind::Cpu);
        // Two simultaneous cpu-bound requests on one 1000 m pod: each sees
        // ~500 m ⇒ each takes ~2× the default runtime.
        sim.submit("fn");
        sim.submit("fn");
        sim.run_to_quiescence();
        let mut lat = sim.world.metrics.service("fn").latency_ms.clone();
        assert_eq!(lat.len(), 2);
        let min = lat.min();
        assert!(min > 4000.0, "each should be ~2×2465 ms, min={min}");
    }

    #[test]
    fn deterministic_across_runs() {
        let run = |seed: u64| {
            let mut sim = sim_with(Policy::InPlace, WorkloadKind::Cpu);
            let _ = seed;
            for _ in 0..5 {
                sim.submit("fn");
            }
            sim.run_to_quiescence();
            sim.world.metrics.service("fn").latency_ms.mean()
        };
        assert_eq!(run(1).to_bits(), run(1).to_bits());
    }

    #[test]
    fn paper_topology_platform_matches_paper_testbed() {
        // `with_topology(Topology::paper(), ..)` and `paper_testbed(..)`
        // must be the same platform: same fleet, same seeded results.
        let run = |mk: fn(PlatformParams) -> Platform| {
            let mut sim = Simulation {
                engine: Engine::new(),
                world: mk(PlatformParams::with_seed(7)),
            };
            sim.deploy(
                "fn",
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::InPlace,
            );
            sim.run();
            for _ in 0..4 {
                sim.submit("fn");
            }
            sim.run();
            sim.world.metrics.service("fn").latency_ms.mean().to_bits()
        };
        let direct = run(Platform::paper_testbed);
        let via_topology = run(|p| Platform::with_topology(Topology::paper(), p));
        assert_eq!(direct, via_topology);
    }

    #[test]
    fn multi_node_fleet_spreads_warm_pods() {
        // 4 nodes, 12 warm services: pods must spread (LeastAllocated) and
        // every node must respect its capacity.
        let mut sim = Simulation::fleet(Topology::uniform_paper(4), 9);
        for i in 0..12 {
            sim.deploy(
                &format!("svc-{i}"),
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::Warm,
            );
        }
        sim.run();
        let ready: usize = sim.world.services.values().map(|s| s.ready_pods()).sum();
        assert_eq!(ready, 12, "4×8-core fleet fits 12 warm pods");
        for node in sim.world.cluster.nodes() {
            assert!(
                node.reserved().cpu <= node.capacity().cpu,
                "node {:?} over-committed",
                node.id
            );
        }
        // LeastAllocated spreads: every node hosts exactly 3 of the 12.
        for node in sim.world.cluster.nodes() {
            let hosted = sim
                .world
                .cluster
                .pods()
                .filter(|p| p.node == Some(node.id))
                .count();
            assert_eq!(hosted, 3, "node {:?} hosts {hosted}", node.id);
        }
    }
}
