//! Per-request state tracked by the platform.

use crate::cluster::pod::PodId;
use crate::knative::activator::RequestId;
use crate::simclock::{EventId, SimTime};
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;
use crate::workload::exec::Execution;

/// How a request ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Completed,
    Failed,
}

/// Typed one-shot continuation fired when the request finishes (completed
/// or failed) — the alloc-free replacement for boxed completion hooks on
/// the load-generation hot path.
#[derive(Debug, Clone, Copy)]
pub enum Continuation {
    /// Closed-loop VU: after `think`, issue the next of `remaining`
    /// iterations against `service`.
    VuNext {
        service: ServiceId,
        remaining: u32,
        think: SimTime,
    },
}

/// A request in flight through the platform.
#[derive(Debug)]
pub struct RequestState {
    pub id: RequestId,
    /// Owning service — an interned id, so per-request copies on the hot
    /// path are plain `u32` moves (not even the `Arc<str>` refcount bump
    /// this replaced).
    pub service: ServiceId,
    pub pod: Option<PodId>,
    pub submitted_at: SimTime,
    /// Execution progress once dispatched into a container.
    pub exec: Option<Execution>,
    /// CFS share currently granted (container limit / active requests).
    pub share: MilliCpu,
    /// Scheduled completion event (cancelled + rescheduled on regime change).
    pub completion: Option<EventId>,
    /// Typed continuation fired when the request finishes.
    pub continuation: Option<Continuation>,
    /// The request caused a pod to be created (cold start).
    pub cold_start: bool,
    /// The request triggered an in-place scale-up.
    pub scaled_up: bool,
}

impl RequestState {
    pub fn new(id: RequestId, service: ServiceId, submitted_at: SimTime) -> RequestState {
        RequestState {
            id,
            service,
            pod: None,
            submitted_at,
            exec: None,
            share: MilliCpu::ZERO,
            completion: None,
            continuation: None,
            cold_start: false,
            scaled_up: false,
        }
    }

    pub fn executing(&self) -> bool {
        self.exec.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_request_state() {
        let r = RequestState::new(RequestId(1), ServiceId(0), SimTime::from_millis(5));
        assert!(!r.executing());
        assert!(!r.cold_start);
        assert_eq!(r.submitted_at, SimTime::from_millis(5));
        assert_eq!(r.service, ServiceId(0));
    }
}
