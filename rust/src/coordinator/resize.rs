//! In-place resize hooks: the queue-proxy patch dispatch, the kubelet's
//! conflict/retry serialization, resize landing, and the committed-CPU /
//! node-load accounting the latency model feeds on.

use crate::apiserver::ResizePatch;
use crate::cluster::pod::PodId;
use crate::cluster::NodeId;
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform};
use crate::knative::activator::RequestId;
use crate::obs::Phase;
use crate::simclock::SimTime;
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;

impl Platform {
    /// Fires the queue-proxy resize hook: after the dispatch cost, try the
    /// patch; on conflict (kubelet busy with a previous resize) retry on a
    /// short period — the churn that penalizes back-to-back in-place
    /// activations.
    pub(crate) fn request_resize(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
        target: MilliCpu,
    ) {
        // Record the latest desire; older pending desires are superseded.
        {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            svc.pods[idx].desired_limit = Some(target);
        }
        let hook = w.params.proxy.sample_hook(&mut w.rng);
        eng.schedule_in(
            hook,
            Event::ResizeHook {
                service: svc_id,
                pod: pod_id,
            },
        );
    }

    pub(crate) fn try_patch(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, pod_id: PodId) {
        let target = {
            let Some(svc) = w.services.get(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            match svc.pods[idx].desired_limit {
                Some(t) => t,
                None => return,
            }
        };
        let Some(applied) = w.applied_limit(pod_id) else { return };
        if applied == target && w.cluster.pod(pod_id).unwrap().status.resize.is_none() {
            // Already there.
            let svc = w.services.get_mut(svc_id).unwrap();
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods[idx].desired_limit = None;
            }
            return;
        }
        let now = eng.now();
        // Fault injection: probabilistic patch rejection beyond the
        // modelled conflict path. Drawn from the dedicated fault RNG so a
        // zero-probability config touches neither RNG stream.
        if w.faults.resize_failure_p > 0.0 && w.faults.rng.chance(w.faults.resize_failure_p) {
            w.metrics.resize_failures += 1;
            // Permanent rejection semantics: the desire is dropped and the
            // pod keeps its current allocation (same as the non-transient
            // API errors below).
            let svc = w.services.get_mut(svc_id).unwrap();
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods[idx].desired_limit = None;
            }
            return;
        }
        match w.api.patch_resize(
            &mut w.cluster,
            ResizePatch {
                pod: pod_id,
                new_cpu_limit: target,
            },
            now,
        ) {
            Ok(()) => {
                w.metrics.resizes_accepted += 1;
                {
                    let svc = w.services.get_mut(svc_id).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        svc.pods[idx].desired_limit = None;
                        if let Some(t) = svc.pods[idx].retry_timer.take() {
                            eng.cancel(t);
                        }
                    }
                }
                let _ = w.api.mark_in_progress(&mut w.cluster, pod_id, target, now);
                // Sample propagation latency under current node load, from
                // the kubelet owning the pod's node — stretched by any
                // straggler window on that node (factor 1 ⇒ exact input).
                let node_id = w.cluster.pod(pod_id).unwrap().node.unwrap();
                let load = Self::node_load(w, node_id);
                let lat = crate::faults::inflate(
                    w.kubelets[node_id.0 as usize]
                        .resize_latency(applied, target, load, &mut w.rng),
                    w.faults.resize_factor(node_id),
                );
                eng.schedule_in(
                    lat,
                    Event::ResizeLanded {
                        service: svc_id,
                        pod: pod_id,
                        target,
                    },
                );
            }
            Err(e) => {
                let transient = matches!(
                    e,
                    crate::apiserver::ApiError::Conflict(_)
                        | crate::apiserver::ApiError::NotRunning(_, _)
                );
                if !transient {
                    // Permanent rejection (gate disabled, restart-required
                    // policy, invalid limit): drop the desire — the pod
                    // simply keeps its current allocation.
                    let svc = w.services.get_mut(svc_id).unwrap();
                    if let Some(idx) = svc.pod_index(pod_id) {
                        svc.pods[idx].desired_limit = None;
                    }
                    return;
                }
                // Kubelet busy applying a previous resize (or pod still
                // coming up): retry shortly unless one is already scheduled.
                w.metrics.resize_conflicts += 1;
                let retry = w.params.resize_retry;
                let svc = w.services.get_mut(svc_id).unwrap();
                let Some(idx) = svc.pod_index(pod_id) else { return };
                if svc.pods[idx].retry_timer.is_none() {
                    let s = eng.schedule_in(
                        retry,
                        Event::ResizeRetry {
                            service: svc_id,
                            pod: pod_id,
                        },
                    );
                    svc.pods[idx].retry_timer = Some(s.id);
                }
            }
        }
    }

    /// Conflict backoff elapsed: clear the stored timer (it just fired)
    /// and re-attempt the patch.
    pub(crate) fn retry_patch(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, pod_id: PodId) {
        if let Some(svc) = w.services.get_mut(svc_id) {
            if let Some(i) = svc.pod_index(pod_id) {
                svc.pods[i].retry_timer = None;
            }
        }
        Self::try_patch(w, eng, svc_id, pod_id);
    }

    /// Clears every trace of an in-flight resize for `pod_id`: the
    /// service-side desire, a pending retry timer, and the pod's
    /// `status.resize` record. Called on teardown/eviction paths — the pod
    /// is about to leave the cluster, so `resize_landed`'s `mark_done`
    /// will never run and the record would otherwise stay in-progress
    /// forever.
    pub(crate) fn clear_resize_state(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
    ) {
        if let Some(svc) = w.services.get_mut(svc_id) {
            if let Some(idx) = svc.pod_index(pod_id) {
                svc.pods[idx].desired_limit = None;
                if let Some(t) = svc.pods[idx].retry_timer.take() {
                    eng.cancel(t);
                }
            }
        }
        if let Some(pod) = w.cluster.pod_mut(pod_id) {
            pod.status.resize = None;
        }
    }

    pub(crate) fn resize_landed(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
        target: MilliCpu,
    ) {
        let now = eng.now();
        if !w.cluster.apply_cpu_limit(pod_id, target, now) {
            return;
        }
        let _ = w.api.mark_done(&mut w.cluster, pod_id, target, now);
        // Mirror whatever limit is actually in force (mark_done may reject
        // pathological state transitions), so the counters track the
        // cluster, not the intent.
        let applied = w.applied_limit(pod_id).unwrap_or(target);
        w.fleet.resize_landed(pod_id, applied);
        // Observation probe: the landing only knows the pod, so find the
        // sampled in-flight requests riding on it via the request table
        // (two-phase to keep the obs and request borrows disjoint).
        if w.obs.is_some() {
            let affected: Vec<u64> = w
                .obs
                .as_ref()
                .map(|o| o.open_ids())
                .unwrap_or_default()
                .into_iter()
                .filter(|id| {
                    w.requests
                        .get(&RequestId(*id))
                        .is_some_and(|r| r.pod == Some(pod_id))
                })
                .collect();
            if let Some(obs) = w.obs.as_mut() {
                for id in affected {
                    obs.mark(id, Phase::ResizeLanded, now);
                }
            }
        }
        Self::committed_changed(w, eng);
        Self::recompute_pod(w, eng, svc_id, pod_id);
        // A newer desire may have raced in (up while down was landing).
        let pending = {
            let svc = w.services.get(svc_id);
            svc.and_then(|s| s.pod_index(pod_id))
                .and_then(|i| w.services[svc_id].pods[i].desired_limit)
        };
        if let Some(t) = pending {
            if t != target {
                eng.schedule_in(
                    SimTime::ZERO,
                    Event::ResizeHook {
                        service: svc_id,
                        pod: pod_id,
                    },
                );
            }
        }
    }

    /// Node load for the latency model: stressors + busy serving capacity.
    /// O(1): reads the incrementally maintained per-node busy counter
    /// instead of rescanning every pod of every service per resize patch.
    /// Debug builds cross-check the counter against the placement-filtered
    /// scan (`Service::pods_on`) it replaced — a drift tripwire on the very
    /// path whose RNG draws the golden metrics are pinned to.
    pub(crate) fn node_load(w: &Platform, node: NodeId) -> crate::cgroup::latency::NodeLoad {
        let busy = w.fleet.node(node).busy_mcpu;
        #[cfg(debug_assertions)]
        {
            let mut scan = MilliCpu::ZERO;
            for svc in w.services.values() {
                for sp in svc.pods_on(node) {
                    if sp.proxy.active_count() > 0 {
                        if let Some(pod) = w.cluster.pod(sp.pod) {
                            scan += pod.status.applied_cpu_limit;
                        }
                    }
                }
            }
            debug_assert_eq!(
                scan, busy,
                "incremental busy counter drifted from pods_on scan for {node:?}"
            );
        }
        w.cluster.node(node).load_with_busy(busy)
    }

    /// Updates the committed-CPU metric (Σ applied limits of live pods).
    /// O(1): the total is maintained incrementally on pod up/teardown and
    /// resize landings instead of re-summed over the whole fleet here.
    pub(crate) fn committed_changed(w: &mut Platform, eng: &mut Eng) {
        w.metrics
            .committed_cpu
            .update(eng.now(), w.fleet.committed_total())
    }
}
