//! Request routing: the submit → arrive → dispatch → exec → complete hot
//! path, including activator buffering, CFS share recomputation and the
//! level-triggered concurrency bookkeeping.
//!
//! ```text
//! submit → [forward] → arrive → dispatch → (in-place: resize hook ‖ exec)
//!        → exec under CFS shares → complete → [respond] → metrics
//!                                     ↘ post-hook: park / idle-timer
//! ```
//!
//! All handlers are associated functions on [`Platform`] taking
//! `(&mut Platform, &mut Eng)`; state lives in
//! [`platform`](super::platform). Services are addressed by interned
//! [`ServiceId`]s end to end — no string hashing, cloning, or `Arc`
//! refcount traffic anywhere on this path (pinned by the grep gate in
//! `tests/interning.rs`).

use crate::cluster::pod::PodId;
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform};
use crate::knative::activator::RequestId;
use crate::obs::{Phase, SpanOutcome};
use crate::simclock::SimTime;
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;
use crate::workload::exec::Execution;

impl Platform {
    // ---------------------------------------------------------------- arrive

    pub(crate) fn arrive(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        let svc_id = match w.requests.get(&req) {
            Some(r) => r.service,
            None => return,
        };
        // Driver-managed policies learn the arrival stream here — the
        // activator's view, after the forward hop — and schedule the next
        // speculation cycle. A no-op for the §3 triple.
        Self::forecast_observe(w, eng, svc_id);
        // Placement-aware selection: the scored pick reads the per-node
        // counters, so the service borrow must be shared here.
        let Some(pick) = w
            .services
            .get(svc_id)
            .map(|svc| svc.pick_pod_with(w.routing, &w.fleet, w.hybrid_weights))
        else {
            // Unknown service: fail fast.
            Self::fail_request(w, eng, req);
            return;
        };

        if let Some(idx) = pick {
            if let Some(obs) = &mut w.obs {
                obs.mark(req.0, Phase::Scheduled, eng.now());
            }
            Self::dispatch(w, eng, svc_id, req, idx);
        } else {
            // Buffer at the activator; start a pod if none is coming up.
            let now = eng.now();
            let svc = w.services.get_mut(svc_id).unwrap();
            if svc.activator.buffer(req, now).is_err() {
                Self::fail_request(w, eng, req);
                return;
            }
            let needs_pod = svc.live_pods() == 0;
            if let Some(obs) = &mut w.obs {
                obs.mark(req.0, Phase::Buffered, now);
            }
            if needs_pod {
                if let Some(r) = w.requests.get_mut(&req) {
                    r.cold_start = true;
                }
                Self::start_pod(w, eng, svc_id, true);
                if let Some(obs) = &mut w.obs {
                    obs.mark(req.0, Phase::StartupWait, now);
                }
            } else {
                Self::maybe_scale_up(w, eng, svc_id);
                // An exhausted warm pool refills proactively too (bounded
                // by the same scale ceiling the KPA respects).
                Self::pool_refill(w, eng, svc_id);
            }
        }
        Self::record_concurrency(w, eng, svc_id);
    }

    pub(crate) fn fail_request(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        let mut cont = None;
        if let Some(mut r) = w.requests.remove(&req) {
            cont = r.continuation.take();
            w.metrics.row_mut(r.service).failed += 1;
        }
        if let Some(obs) = &mut w.obs {
            obs.close(req.0, SpanOutcome::Failed, None, eng.now());
        }
        Self::fire_hook(w, eng, req);
        Self::fire_continuation(eng, cont);
    }

    // -------------------------------------------------------------- dispatch

    /// Admits `req` into pod `idx` of the service and (policy-dependent)
    /// fires the pre-request resize hook before redirecting.
    pub(crate) fn dispatch(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        req: RequestId,
        idx: usize,
    ) {
        if let Some(obs) = &mut w.obs {
            if obs.last_mark_is(req.0, Phase::Requeued) {
                obs.mark(req.0, Phase::Rescheduled, eng.now());
            }
            obs.mark(req.0, Phase::Dispatched, eng.now());
        }
        let (pod_id, hooks, serving) = {
            let svc = w.services.get_mut(svc_id).unwrap();
            let serving = svc.cfg.serving_cpu;
            let sp = &mut svc.pods[idx];
            sp.proxy.offer(req);
            let pod_id = sp.pod;
            let hooks = sp.proxy.inplace_hooks;
            svc.in_flight_pods += 1;
            (pod_id, hooks, serving)
        };
        w.fleet.dispatched(pod_id);
        let applied = w.applied_limit(pod_id).unwrap_or(MilliCpu::ZERO);
        if let Some(r) = w.requests.get_mut(&req) {
            r.pod = Some(pod_id);
        }
        // Cancel any pending idle scale-down for this pod.
        let svc = w.services.get_mut(svc_id).unwrap();
        if let Some(t) = svc.pods[idx].idle_timer.take() {
            eng.cancel(t);
        }

        // A park may be in flight (status shows a resize) or already desired;
        // a new request must claim the serving allocation either way.
        let resize_in_flight = w
            .cluster
            .pod(pod_id)
            .map(|p| p.status.resize.is_some())
            .unwrap_or(false);
        let park_desired = {
            let svc = &w.services[svc_id];
            svc.pod_index(pod_id)
                .and_then(|i| svc.pods[i].desired_limit)
                .map(|d| d < serving)
                .unwrap_or(false)
        };
        if hooks && (applied < serving || resize_in_flight || park_desired) {
            // The paper's pre-hook: dispatch the scale-up patch, then
            // redirect immediately — the request starts at the parked
            // allocation and speeds up when the resize lands.
            if let Some(r) = w.requests.get_mut(&req) {
                r.scaled_up = true;
            }
            w.metrics.row_mut(svc_id).inplace_scale_ups += 1;
            if let Some(obs) = &mut w.obs {
                obs.mark(req.0, Phase::ResizeWait, eng.now());
            }
            Self::request_resize(w, eng, svc_id, pod_id, serving);
        }
        // Pooled: this dispatch consumed a pool pod — top the pool back up
        // so the next burst still finds warm capacity. No-op otherwise.
        Self::pool_refill(w, eng, svc_id);
        Self::begin_exec(w, eng, svc_id, req, pod_id);
    }

    pub(crate) fn begin_exec(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        req: RequestId,
        pod: PodId,
    ) {
        let profile = w.services[svc_id].profile.clone();
        if let Some(r) = w.requests.get_mut(&req) {
            r.exec = Some(Execution::start(&profile, eng.now()));
        }
        Self::recompute_pod(w, eng, svc_id, pod);
    }

    // ------------------------------------------------------------- execution

    /// Re-integrates progress for every active request on `pod` and
    /// reschedules their completion events under the current allocation.
    /// Called on every regime change: request start/finish, resize landing.
    pub(crate) fn recompute_pod(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, pod: PodId) {
        let now = eng.now();
        let Some(svc) = w.services.get(svc_id) else { return };
        let Some(idx) = svc.pod_index(pod) else { return };
        // Reuse the platform scratch buffer instead of allocating per event.
        let mut active = std::mem::take(&mut w.scratch_active);
        active.clear();
        active.extend_from_slice(w.services[svc_id].pods[idx].proxy.active_requests());
        if active.is_empty() {
            w.scratch_active = active;
            return;
        }
        let alloc = w
            .cluster
            .pod(pod)
            .map(|p| p.status.applied_cpu_limit)
            .unwrap_or(MilliCpu::ZERO);
        // Equal CFS split among in-container requests.
        let share = MilliCpu((alloc.0 / active.len() as u64).max(1));
        for &id in &active {
            let Some(r) = w.requests.get_mut(&id) else { continue };
            let Some(exec) = r.exec.as_mut() else { continue };
            // Integrate the interval just ended under the old share.
            exec.advance(now, r.share.max(MilliCpu(1)));
            r.share = share;
            if let Some(ev) = r.completion.take() {
                eng.cancel(ev);
            }
            if exec.done() {
                // Finished exactly at this boundary.
                let s = eng.schedule_in(SimTime::ZERO, Event::Complete { req: id });
                r.completion = Some(s.id);
            } else {
                let eta = exec.eta(share);
                let s = eng.schedule_in(eta, Event::Complete { req: id });
                r.completion = Some(s.id);
            }
        }
        w.scratch_active = active;
    }

    pub(crate) fn complete(w: &mut Platform, eng: &mut Eng, req: RequestId) {
        let now = eng.now();
        let Some(r) = w.requests.get_mut(&req) else { return };
        let svc_id = r.service;
        let pod = r.pod;
        if let Some(exec) = r.exec.as_mut() {
            exec.advance(now, r.share.max(MilliCpu(1)));
        }
        r.completion = None;

        // Response proxy hop is part of the measured latency.
        let respond = w.params.proxy.sample_respond(&mut w.rng);
        let latency_ms = (now + respond).saturating_sub(r.submitted_at).as_millis_f64();
        let mut r = w.requests.remove(&req).unwrap();
        // Taken now so the early-return paths below drop it un-fired —
        // exactly where the boxed hooks never ran either.
        let cont = r.continuation.take();
        if let Some(obs) = &mut w.obs {
            obs.close(req.0, SpanOutcome::Completed, Some(latency_ms), now);
        }
        {
            let m = w.metrics.row_mut(svc_id);
            m.latency_ms.record(latency_ms);
            m.latency_stream.record(latency_ms);
            m.completed += 1;
            if r.cold_start {
                m.cold_starts += 1;
            }
        }

        let Some(pod_id) = pod else { return };
        // Free the concurrency slot; promote a queued request if any.
        let promoted = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            // Net one request leaves the pod whether or not a queued one is
            // promoted into the freed slot.
            svc.in_flight_pods = svc.in_flight_pods.saturating_sub(1);
            svc.pods[idx].proxy.complete(req)
        };
        w.fleet.completed(pod_id);
        if let Some(next) = promoted {
            Self::begin_exec(w, eng, svc_id, next, pod_id);
        } else {
            Self::recompute_pod(w, eng, svc_id, pod_id);
        }

        Self::post_request_hooks(w, eng, svc_id, pod_id);
        Self::record_concurrency(w, eng, svc_id);
        Self::drain_activator(w, eng, svc_id);
        Self::fire_hook(w, eng, req);
        Self::fire_continuation(eng, cont);
    }

    /// Dispatches as many buffered requests as capacity allows, failing
    /// timed-out entries as they surface.
    pub(crate) fn drain_activator(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let policy = w.routing;
        let weights = w.hybrid_weights;
        loop {
            let (next, dead) = {
                let Some(svc) = w.services.get_mut(svc_id) else { return };
                if svc.pick_pod_with(policy, &w.fleet, weights).is_none() {
                    return;
                }
                let (mut out, dead) = svc.activator.drain(1, eng.now());
                (out.pop(), dead)
            };
            // `drain` pops timed-out head entries alongside the dispatchable
            // one; every popped request must be failed or dispatched —
            // returning before consuming `next` would leak it in flight.
            for d in dead {
                Self::fail_request(w, eng, d.request);
            }
            let Some(b) = next else { return };
            // Re-pick after failing dead entries: their completion hooks may
            // have mutated pod state.
            let Some(idx) = w
                .services
                .get(svc_id)
                .and_then(|s| s.pick_pod_with(policy, &w.fleet, weights))
            else {
                // Capacity vanished under us (a hook claimed it): re-buffer
                // the request with its original enqueue time. If even the
                // buffer is full now, the request must fail — it was already
                // popped, so dropping it here would leak it in flight.
                let requeued = w
                    .services
                    .get_mut(svc_id)
                    .map(|svc| svc.activator.buffer(b.request, b.enqueued_at).is_ok())
                    .unwrap_or(false);
                if !requeued {
                    Self::fail_request(w, eng, b.request);
                }
                return;
            };
            Self::dispatch(w, eng, svc_id, b.request, idx);
        }
    }

    /// Level-triggered concurrency bookkeeping after every arrival and
    /// completion: records the KPA sample and considers scale-out whenever
    /// observed concurrency exceeds what the current fleet targets.
    pub(crate) fn record_concurrency(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let now = eng.now();
        let overloaded = if let Some(svc) = w.services.get_mut(svc_id) {
            // O(1): the per-service counters maintained on dispatch/complete
            // and pod ready/terminating transitions replace the former
            // per-tick scan over every pod. `kpa_signal_matches_scan` (in
            // tests/integration_platform.rs) pins the recorded signal to the
            // scan it replaced.
            let in_flight = svc.activator.len() + svc.in_flight_pods as usize;
            let ready = svc.ready_count as usize;
            svc.autoscaler.record(now, in_flight as u32);
            // Level-triggered KPA: consider scale-out whenever observed
            // concurrency exceeds what the current fleet targets — skipped
            // entirely for the common single-pod-capped revision.
            // `ready_count + starting` equals `live_pods()`: pods join the
            // list ready, so the non-terminating ones are exactly the
            // ready ones — no pod scan on this path either.
            (svc.ready_count + svc.starting) < svc.cfg.max_scale
                && in_flight as f64 > svc.cfg.target_concurrency * ready.max(1) as f64
        } else {
            false
        };
        if overloaded {
            Self::maybe_scale_up(w, eng, svc_id);
        }
    }
}
