//! Per-service (Knative revision) runtime state inside the platform.

use crate::cluster::pod::PodId;
use crate::cluster::NodeId;
use crate::knative::activator::{Activator, RequestId};
use crate::knative::autoscaler::Autoscaler;
use crate::knative::config::RevisionConfig;
use crate::knative::queue_proxy::QueueProxy;
use crate::policy::Policy;
use crate::simclock::EventId;
use crate::util::quantity::MilliCpu;
use crate::workload::registry::WorkloadProfile;

/// A function pod from the service's point of view.
#[derive(Debug)]
pub struct ServicePod {
    pub pod: PodId,
    /// Node the pod was bound to (set when the pod comes up) — placement
    /// the fleet experiments and per-node accounting read without a
    /// cluster lookup.
    pub node: Option<NodeId>,
    pub proxy: QueueProxy,
    /// Idle scale-to-zero timer (cold policy).
    pub idle_timer: Option<EventId>,
    /// Desired CPU limit the hooks most recently asked for; retried while
    /// the kubelet's per-pod resize pipeline is busy.
    pub desired_limit: Option<MilliCpu>,
    /// A retry event is already scheduled.
    pub retry_pending: bool,
    pub ready: bool,
    pub terminating: bool,
}

impl ServicePod {
    pub fn new(pod: PodId, concurrency_limit: u32, hooks: bool) -> ServicePod {
        ServicePod {
            pod,
            node: None,
            proxy: QueueProxy::new(concurrency_limit, hooks),
            idle_timer: None,
            desired_limit: None,
            retry_pending: false,
            ready: false,
            terminating: false,
        }
    }
}

/// A deployed service.
#[derive(Debug)]
pub struct Service {
    pub name: String,
    pub profile: WorkloadProfile,
    pub policy: Policy,
    pub cfg: RevisionConfig,
    pub autoscaler: Autoscaler,
    pub activator: Activator,
    pub pods: Vec<ServicePod>,
    /// Pods whose startup pipeline is still running.
    pub starting: u32,
}

impl Service {
    pub fn new(name: &str, profile: WorkloadProfile, policy: Policy) -> Service {
        let cfg = policy.revision_config();
        Service::with_config(name, profile, policy, cfg)
    }

    pub fn with_config(
        name: &str,
        profile: WorkloadProfile,
        policy: Policy,
        cfg: RevisionConfig,
    ) -> Service {
        Service {
            name: name.to_string(),
            profile,
            policy,
            cfg: cfg.clone(),
            autoscaler: Autoscaler::new(cfg),
            activator: Activator::default(),
            pods: Vec::new(),
            starting: 0,
        }
    }

    /// Ready pod with a free concurrency slot, preferring the least loaded
    /// (knative's activator load-balances by in-flight count).
    pub fn pick_pod(&self) -> Option<usize> {
        self.pods
            .iter()
            .enumerate()
            .filter(|(_, p)| p.ready && !p.terminating)
            .filter(|(_, p)| (p.proxy.active_count() as u32) < self.cfg.concurrency_limit())
            .min_by_key(|(_, p)| p.proxy.in_flight())
            .map(|(i, _)| i)
    }

    /// Any live (ready or starting-up, non-terminating) pod exists?
    pub fn live_pods(&self) -> usize {
        self.pods.iter().filter(|p| !p.terminating).count() + self.starting as usize
    }

    pub fn ready_pods(&self) -> usize {
        self.pods.iter().filter(|p| p.ready && !p.terminating).count()
    }

    /// Total in-flight requests across pods + buffered in the activator.
    pub fn total_in_flight(&self) -> usize {
        self.pods.iter().map(|p| p.proxy.in_flight()).sum::<usize>() + self.activator.len()
    }

    pub fn pod_index(&self, pod: PodId) -> Option<usize> {
        self.pods.iter().position(|p| p.pod == pod)
    }

    /// Live pods of this service placed on `node`.
    pub fn pods_on(&self, node: NodeId) -> impl Iterator<Item = &ServicePod> {
        self.pods
            .iter()
            .filter(move |p| p.node == Some(node) && !p.terminating)
    }

    /// Buffered request ids waiting in the activator (for tests/debugging).
    pub fn buffered(&self) -> usize {
        self.activator.len()
    }

    pub fn slot_available(&self) -> bool {
        self.pick_pod().is_some()
    }

    /// Concurrency as the autoscaler should see it (active + queued).
    pub fn observed_concurrency(&self) -> u32 {
        self.total_in_flight() as u32
    }

    pub fn next_request_target(&self) -> Option<RequestId> {
        None // placeholder for multi-revision routing; single revision here
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry::WorkloadKind;

    fn svc(policy: Policy) -> Service {
        Service::new(
            "hello",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            policy,
        )
    }

    #[test]
    fn pick_pod_prefers_least_loaded_ready() {
        let mut s = svc(Policy::Warm);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        s.pods.push(ServicePod::new(PodId(1), 10, false));
        s.pods[0].ready = true;
        s.pods[1].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        assert_eq!(s.pick_pod(), Some(1));
        s.pods[1].terminating = true;
        assert_eq!(s.pick_pod(), Some(0));
    }

    #[test]
    fn pick_pod_respects_concurrency_limit() {
        let mut s = svc(Policy::Warm);
        s.cfg.container_concurrency = 1;
        s.pods.push(ServicePod::new(PodId(0), 1, false));
        s.pods[0].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        assert_eq!(s.pick_pod(), None);
    }

    #[test]
    fn unready_pods_not_picked() {
        let mut s = svc(Policy::Cold);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        assert_eq!(s.pick_pod(), None);
        assert_eq!(s.ready_pods(), 0);
        assert_eq!(s.live_pods(), 1);
    }

    #[test]
    fn in_flight_counts_pods_and_activator() {
        let mut s = svc(Policy::InPlace);
        s.pods.push(ServicePod::new(PodId(0), 10, true));
        s.pods[0].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        s.activator
            .buffer(RequestId(2), crate::simclock::SimTime::ZERO)
            .unwrap();
        assert_eq!(s.total_in_flight(), 2);
        assert_eq!(s.observed_concurrency(), 2);
    }
}
