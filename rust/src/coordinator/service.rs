//! Per-service (Knative revision) runtime state inside the platform.

use crate::cluster::pod::PodId;
use crate::cluster::NodeId;
use crate::coordinator::accounting::{FleetAccounting, HybridWeights, RoutingPolicy};
use crate::forecast::ServicePredictor;
use crate::knative::activator::{Activator, RequestId};
use crate::knative::autoscaler::Autoscaler;
use crate::knative::config::RevisionConfig;
use crate::knative::queue_proxy::QueueProxy;
use crate::policy::Policy;
use crate::simclock::{EventId, SimTime};
use crate::util::quantity::MilliCpu;
use crate::workload::registry::WorkloadProfile;

/// A function pod from the service's point of view.
#[derive(Debug)]
pub struct ServicePod {
    pub pod: PodId,
    /// Node the pod was bound to (set when the pod comes up) — placement
    /// the fleet experiments and per-node accounting read without a
    /// cluster lookup.
    pub node: Option<NodeId>,
    pub proxy: QueueProxy,
    /// Idle scale-to-zero timer (cold policy).
    pub idle_timer: Option<EventId>,
    /// Desired CPU limit the hooks most recently asked for; retried while
    /// the kubelet's per-pod resize pipeline is busy.
    pub desired_limit: Option<MilliCpu>,
    /// The scheduled `ResizeRetry` event, if one is pending — stored as an
    /// id so teardown/eviction can cancel it instead of leaving a stale
    /// event to fire against a dead pod.
    pub retry_timer: Option<EventId>,
    pub ready: bool,
    pub terminating: bool,
}

impl ServicePod {
    pub fn new(pod: PodId, concurrency_limit: u32, hooks: bool) -> ServicePod {
        ServicePod {
            pod,
            node: None,
            proxy: QueueProxy::new(concurrency_limit, hooks),
            idle_timer: None,
            desired_limit: None,
            retry_timer: None,
            ready: false,
            terminating: false,
        }
    }
}

/// A deployed service.
#[derive(Debug)]
pub struct Service {
    pub name: String,
    pub profile: WorkloadProfile,
    pub policy: Policy,
    pub cfg: RevisionConfig,
    pub autoscaler: Autoscaler,
    pub activator: Activator,
    pub pods: Vec<ServicePod>,
    /// Pods whose startup pipeline is still running.
    pub starting: u32,
    /// Σ `proxy.in_flight()` over `pods`, maintained on dispatch/complete —
    /// the KPA concurrency signal without the per-tick pod scan.
    pub in_flight_pods: u32,
    /// Count of ready, non-terminating pods, maintained on pod
    /// ready/terminating transitions.
    pub ready_count: u32,
    /// KPA scale-out is suppressed until this time after an unschedulable
    /// pod-start attempt — without it every concurrency tick re-attempts a
    /// placement that cannot succeed.
    pub sched_backoff_until: SimTime,
    /// Arrival predictor + speculation bookkeeping — present exactly when
    /// the policy is driver-managed ([`Policy::predictive`]).
    pub predictor: Option<ServicePredictor>,
}

impl Service {
    pub fn new(name: &str, profile: WorkloadProfile, policy: Policy) -> Service {
        let cfg = policy.revision_config();
        Service::with_config(name, profile, policy, cfg)
    }

    pub fn with_config(
        name: &str,
        profile: WorkloadProfile,
        policy: Policy,
        cfg: RevisionConfig,
    ) -> Service {
        let forecast = cfg.forecast;
        Service {
            name: name.to_string(),
            profile,
            policy,
            cfg: cfg.clone(),
            autoscaler: Autoscaler::new(cfg),
            activator: Activator::default(),
            pods: Vec::new(),
            starting: 0,
            in_flight_pods: 0,
            ready_count: 0,
            sched_backoff_until: SimTime::ZERO,
            predictor: policy
                .predictive()
                .then(|| ServicePredictor::new(forecast)),
        }
    }

    /// Ready pods with a free concurrency slot — the candidate set every
    /// routing policy draws from (concurrency limits are enforced here, so
    /// no score can override them).
    fn candidates(&self) -> impl Iterator<Item = (usize, &ServicePod)> {
        self.pods
            .iter()
            .enumerate()
            .filter(|(_, p)| p.ready && !p.terminating)
            .filter(|(_, p)| (p.proxy.active_count() as u32) < self.cfg.concurrency_limit())
    }

    /// Ready pod with a free concurrency slot, preferring the least loaded
    /// (knative's activator load-balances by in-flight count). Ties break
    /// to the lowest pod index — `min_by_key` keeps the first minimum.
    pub fn pick_pod(&self) -> Option<usize> {
        self.candidates()
            .min_by_key(|(_, p)| p.proxy.in_flight())
            .map(|(i, _)| i)
    }

    /// Scored, placement-aware pod selection. `LeastLoaded` reproduces
    /// [`Service::pick_pod`] bit-for-bit (the golden paper metrics are
    /// pinned to it); `Locality` and `Hybrid` additionally weigh the
    /// per-node pressure from [`FleetAccounting`] and the pod's resize
    /// state — the hybrid blend under scenario-tunable [`HybridWeights`].
    /// All policies are deterministic: lowest index wins ties.
    pub fn pick_pod_with(
        &self,
        policy: RoutingPolicy,
        fleet: &FleetAccounting,
        weights: HybridWeights,
    ) -> Option<usize> {
        match policy {
            RoutingPolicy::LeastLoaded => self.pick_pod(),
            RoutingPolicy::Locality => self
                .candidates()
                .min_by_key(|(i, p)| {
                    (
                        node_pressure(fleet, p),
                        p.proxy.in_flight(),
                        resize_penalty(p),
                        *i,
                    )
                })
                .map(|(i, _)| i),
            RoutingPolicy::Hybrid => self
                .candidates()
                .min_by_key(|(i, p)| {
                    let score = p.proxy.in_flight() as u64 * weights.in_flight
                        + node_pressure(fleet, p) / weights.pressure_div.max(1)
                        + resize_penalty(p) * weights.resize;
                    (score, *i)
                })
                .map(|(i, _)| i),
        }
    }

    /// Any live (ready or starting-up, non-terminating) pod exists?
    pub fn live_pods(&self) -> usize {
        self.pods.iter().filter(|p| !p.terminating).count() + self.starting as usize
    }

    pub fn ready_pods(&self) -> usize {
        self.pods.iter().filter(|p| p.ready && !p.terminating).count()
    }

    /// Total in-flight requests across pods + buffered in the activator.
    pub fn total_in_flight(&self) -> usize {
        self.pods.iter().map(|p| p.proxy.in_flight()).sum::<usize>() + self.activator.len()
    }

    pub fn pod_index(&self, pod: PodId) -> Option<usize> {
        self.pods.iter().position(|p| p.pod == pod)
    }

    /// Ready, non-terminating pods with no traffic at all — the warm-pool
    /// stock (`pooled`) and the speculation targets (`predictive-inplace`).
    pub fn idle_ready_pods(&self) -> impl Iterator<Item = &ServicePod> {
        self.pods
            .iter()
            .filter(|p| p.ready && !p.terminating && p.proxy.idle())
    }

    /// Live pods of this service placed on `node`.
    pub fn pods_on(&self, node: NodeId) -> impl Iterator<Item = &ServicePod> {
        self.pods
            .iter()
            .filter(move |p| p.node == Some(node) && !p.terminating)
    }

    /// Buffered request ids waiting in the activator (for tests/debugging).
    pub fn buffered(&self) -> usize {
        self.activator.len()
    }

    pub fn slot_available(&self) -> bool {
        self.pick_pod().is_some()
    }

    /// Concurrency as the autoscaler should see it (active + queued).
    pub fn observed_concurrency(&self) -> u32 {
        self.total_in_flight() as u32
    }

    pub fn next_request_target(&self) -> Option<RequestId> {
        None // placeholder for multi-revision routing; single revision here
    }
}

/// Pressure of the node hosting `p` (unplaced pods sort last).
fn node_pressure(fleet: &FleetAccounting, p: &ServicePod) -> u64 {
    p.node.map(|n| fleet.node(n).pressure()).unwrap_or(u64::MAX)
}

/// Pods with a resize pending or retrying score worse: a request routed
/// there queues behind the kubelet's per-pod resize serialization.
fn resize_penalty(p: &ServicePod) -> u64 {
    u64::from(p.desired_limit.is_some() || p.retry_timer.is_some())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry::WorkloadKind;

    fn svc(policy: Policy) -> Service {
        Service::new(
            "hello",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            policy,
        )
    }

    #[test]
    fn pick_pod_prefers_least_loaded_ready() {
        let mut s = svc(Policy::Warm);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        s.pods.push(ServicePod::new(PodId(1), 10, false));
        s.pods[0].ready = true;
        s.pods[1].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        assert_eq!(s.pick_pod(), Some(1));
        s.pods[1].terminating = true;
        assert_eq!(s.pick_pod(), Some(0));
    }

    #[test]
    fn pick_pod_respects_concurrency_limit() {
        let mut s = svc(Policy::Warm);
        s.cfg.container_concurrency = 1;
        s.pods.push(ServicePod::new(PodId(0), 1, false));
        s.pods[0].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        assert_eq!(s.pick_pod(), None);
    }

    #[test]
    fn unready_pods_not_picked() {
        let mut s = svc(Policy::Cold);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        assert_eq!(s.pick_pod(), None);
        assert_eq!(s.ready_pods(), 0);
        assert_eq!(s.live_pods(), 1);
    }

    fn fleet2() -> FleetAccounting {
        FleetAccounting::for_topology(&crate::cluster::topology::Topology::uniform_paper(2))
    }

    /// Two ready pods at equal load on nodes 0/1; node 0 carries foreign
    /// traffic. Locality must pick the pod on the quiet node, while
    /// least-loaded (index tie-break) keeps picking pod 0.
    #[test]
    fn locality_beats_remote_at_equal_load() {
        let mut s = svc(Policy::Warm);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        s.pods.push(ServicePod::new(PodId(1), 10, false));
        s.pods[0].ready = true;
        s.pods[0].node = Some(NodeId(0));
        s.pods[1].ready = true;
        s.pods[1].node = Some(NodeId(1));

        let mut fleet = fleet2();
        fleet.pod_up(PodId(99), NodeId(0), MilliCpu(1000));
        fleet.dispatched(PodId(99)); // foreign load on node 0

        assert_eq!(s.pick_pod_with(RoutingPolicy::LeastLoaded, &fleet, HybridWeights::default()), Some(0));
        assert_eq!(s.pick_pod_with(RoutingPolicy::Locality, &fleet, HybridWeights::default()), Some(1));
        assert_eq!(s.pick_pod_with(RoutingPolicy::Hybrid, &fleet, HybridWeights::default()), Some(1));
    }

    /// Concurrency limits bound every policy: a full pod on the preferred
    /// node is skipped no matter how good its locality score is.
    #[test]
    fn scored_pick_respects_concurrency_limit() {
        let mut s = svc(Policy::Warm);
        s.cfg.container_concurrency = 1;
        s.pods.push(ServicePod::new(PodId(0), 1, false));
        s.pods.push(ServicePod::new(PodId(1), 1, false));
        s.pods[0].ready = true;
        s.pods[0].node = Some(NodeId(1)); // quiet node, but pod is full
        s.pods[1].ready = true;
        s.pods[1].node = Some(NodeId(0));
        s.pods[0].proxy.offer(RequestId(1));

        let mut fleet = fleet2();
        fleet.pod_up(PodId(99), NodeId(0), MilliCpu(1000));
        fleet.dispatched(PodId(99));

        for policy in RoutingPolicy::ALL {
            assert_eq!(s.pick_pod_with(policy, &fleet, HybridWeights::default()), Some(1), "{policy:?}");
        }
        s.pods[1].proxy.offer(RequestId(2));
        for policy in RoutingPolicy::ALL {
            assert_eq!(s.pick_pod_with(policy, &fleet, HybridWeights::default()), None, "{policy:?}");
        }
    }

    /// Identical pods on identical nodes: every policy deterministically
    /// breaks the tie to the lowest index.
    #[test]
    fn scored_pick_tie_breaks_to_lowest_index() {
        let mut s = svc(Policy::Warm);
        for i in 0..3 {
            s.pods.push(ServicePod::new(PodId(i), 10, false));
            s.pods[i as usize].ready = true;
            s.pods[i as usize].node = Some(NodeId((i % 2) as u32));
        }
        let fleet = fleet2();
        for policy in RoutingPolicy::ALL {
            assert_eq!(s.pick_pod_with(policy, &fleet, HybridWeights::default()), Some(0), "{policy:?}");
        }
    }

    /// A pending resize (park in flight / retry scheduled) demotes a pod
    /// under the placement-aware policies.
    #[test]
    fn resize_state_demotes_pod() {
        let mut s = svc(Policy::InPlace);
        s.pods.push(ServicePod::new(PodId(0), 10, true));
        s.pods.push(ServicePod::new(PodId(1), 10, true));
        s.pods[0].ready = true;
        s.pods[0].node = Some(NodeId(0));
        s.pods[0].desired_limit = Some(MilliCpu(1)); // park dispatched
        s.pods[1].ready = true;
        s.pods[1].node = Some(NodeId(0));
        let fleet = fleet2();
        assert_eq!(s.pick_pod_with(RoutingPolicy::LeastLoaded, &fleet, HybridWeights::default()), Some(0));
        assert_eq!(s.pick_pod_with(RoutingPolicy::Locality, &fleet, HybridWeights::default()), Some(1));
        assert_eq!(s.pick_pod_with(RoutingPolicy::Hybrid, &fleet, HybridWeights::default()), Some(1));
    }

    /// Tuned weights genuinely reshape the hybrid blend: pod 0 carries one
    /// extra request but sits on the quiet node. With the stock weights the
    /// in-flight term dominates (1000 > pressure), so hybrid routes to the
    /// idle pod 1 on the pressured node; weighting node pressure strongly
    /// (pressure_div 1, in_flight 1) flips the pick back to pod 0.
    #[test]
    fn hybrid_weights_reshape_the_blend() {
        let mut s = svc(Policy::Warm);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        s.pods.push(ServicePod::new(PodId(1), 10, false));
        s.pods[0].ready = true;
        s.pods[0].node = Some(NodeId(0));
        s.pods[0].proxy.offer(RequestId(7));
        s.pods[1].ready = true;
        s.pods[1].node = Some(NodeId(1));

        let mut fleet = fleet2();
        fleet.pod_up(PodId(99), NodeId(1), MilliCpu(1000));
        for r in 0..3 {
            let _ = r;
            fleet.dispatched(PodId(99)); // heavy foreign load on node 1
        }

        assert_eq!(
            s.pick_pod_with(RoutingPolicy::Hybrid, &fleet, HybridWeights::default()),
            Some(1)
        );
        let node_first = HybridWeights {
            in_flight: 1,
            pressure_div: 1,
            resize: 500,
        };
        assert_eq!(
            s.pick_pod_with(RoutingPolicy::Hybrid, &fleet, node_first),
            Some(0)
        );
    }

    #[test]
    fn pods_on_filters_by_node() {
        let mut s = svc(Policy::Warm);
        s.pods.push(ServicePod::new(PodId(0), 10, false));
        s.pods.push(ServicePod::new(PodId(1), 10, false));
        s.pods.push(ServicePod::new(PodId(2), 10, false));
        s.pods[0].node = Some(NodeId(0));
        s.pods[1].node = Some(NodeId(1));
        s.pods[2].node = Some(NodeId(0));
        assert_eq!(s.pods_on(NodeId(0)).count(), 2);
        assert_eq!(s.pods_on(NodeId(1)).count(), 1);
        assert_eq!(s.pods_on(NodeId(7)).count(), 0);
        // Terminating pods are excluded.
        s.pods[2].terminating = true;
        assert_eq!(s.pods_on(NodeId(0)).count(), 1);
        assert_eq!(s.pods_on(NodeId(0)).next().unwrap().pod, PodId(0));
    }

    #[test]
    fn predictor_present_only_for_driver_managed_policies() {
        for policy in Policy::PAPER {
            assert!(svc(policy).predictor.is_none(), "{policy:?}");
        }
        assert!(svc(Policy::Pooled).predictor.is_some());
        assert!(svc(Policy::PredictiveInPlace).predictor.is_some());
    }

    #[test]
    fn idle_ready_pods_excludes_busy_unready_and_terminating() {
        let mut s = svc(Policy::Pooled);
        for i in 0..4 {
            s.pods.push(ServicePod::new(PodId(i), 10, false));
        }
        s.pods[0].ready = true; // idle + ready → counted
        s.pods[1].ready = true;
        s.pods[1].proxy.offer(RequestId(1)); // busy
        s.pods[2].ready = true;
        s.pods[2].terminating = true; // terminating
        // pods[3] not ready.
        let idle: Vec<PodId> = s.idle_ready_pods().map(|p| p.pod).collect();
        assert_eq!(idle, vec![PodId(0)]);
    }

    #[test]
    fn in_flight_counts_pods_and_activator() {
        let mut s = svc(Policy::InPlace);
        s.pods.push(ServicePod::new(PodId(0), 10, true));
        s.pods[0].ready = true;
        s.pods[0].proxy.offer(RequestId(1));
        s.activator
            .buffer(RequestId(2), crate::simclock::SimTime::ZERO)
            .unwrap();
        assert_eq!(s.total_in_flight(), 2);
        assert_eq!(s.observed_concurrency(), 2);
    }
}
