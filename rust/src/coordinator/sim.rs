//! The [`Simulation`] harness: owns the engine + platform pair; the entry
//! point examples, experiments and benches use.

use crate::cluster::topology::Topology;
use crate::coordinator::platform::{Eng, Platform};
use crate::coordinator::service::Service;
use crate::knative::activator::RequestId;
use crate::policy::{PlatformParams, Policy};
use crate::simclock::{Engine, SimTime};
use crate::workload::registry::WorkloadProfile;

/// Owns the engine + platform pair.
pub struct Simulation {
    pub engine: Eng,
    pub world: Platform,
}

impl Simulation {
    /// Paper testbed with default calibration.
    pub fn paper(seed: u64) -> Simulation {
        Simulation {
            engine: Engine::new(),
            world: Platform::paper_testbed(PlatformParams::with_seed(seed)),
        }
    }

    pub fn with_params(params: PlatformParams) -> Simulation {
        Simulation {
            engine: Engine::new(),
            world: Platform::paper_testbed(params),
        }
    }

    /// A simulation over an arbitrary fleet shape with default calibration.
    pub fn fleet(topology: Topology, seed: u64) -> Simulation {
        Simulation::fleet_with_params(topology, PlatformParams::with_seed(seed))
    }

    pub fn fleet_with_params(topology: Topology, params: PlatformParams) -> Simulation {
        Simulation {
            engine: Engine::new(),
            world: Platform::with_topology(topology, params),
        }
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    pub fn deploy(&mut self, name: &str, profile: WorkloadProfile, policy: Policy) {
        self.world
            .deploy_workload(&mut self.engine, name, profile, policy);
    }

    pub fn deploy_service(&mut self, svc: Service) {
        self.world.deploy(&mut self.engine, svc);
    }

    pub fn submit(&mut self, service: &str) -> RequestId {
        self.world.submit(&mut self.engine, service)
    }

    pub fn submit_at(&mut self, at: SimTime, service: &str) {
        self.world.submit_at(&mut self.engine, at, service);
    }

    /// Runs until the event queue drains.
    pub fn run(&mut self) -> u64 {
        self.engine.run(&mut self.world)
    }

    pub fn run_until(&mut self, deadline: SimTime) -> u64 {
        self.engine.run_until(&mut self.world, deadline)
    }

    /// Runs until all submitted requests completed (or the queue drained).
    pub fn run_to_quiescence(&mut self) {
        // Idle timers may keep the queue alive; step until no requests
        // remain in flight.
        while self.world.in_flight() > 0 {
            if self.engine.step(&mut self.world).is_none() {
                break;
            }
        }
    }
}
