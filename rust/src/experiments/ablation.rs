//! Ablations of the in-place policy's design choices (DESIGN.md §6d).
//!
//! The paper fixes three knobs without exploring them; each ablation sweeps
//! one and reports the latency/reservation trade-off:
//!
//! * **Parked allocation** — the paper parks at 1 m. Sweeping 1 m → 500 m
//!   shows the trade: a larger park costs standing reservation but (a)
//!   shortens the dead window (the request progresses while the resize
//!   lands) and (b) avoids the slow deep-down-scale tail (Fig 4b).
//! * **Cold stable window** — the paper sets 6 s (Knative's minimum).
//!   Sweeping 6 s → 120 s trades cold-start frequency against reservation.
//! * **Resize-retry period** — the queue-proxy hook's retry cadence when
//!   the kubelet is busy; governs the up-after-down serialization penalty
//!   for back-to-back in-place activations.

use crate::coordinator::platform::Simulation;
use crate::coordinator::service::Service;
use crate::loadgen::runner::{Runner, Scenario};
use crate::policy::{PlatformParams, Policy};
use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// One point of an ablation sweep.
#[derive(Debug, Clone)]
pub struct AblationPoint {
    pub x: f64,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub avg_committed_mcpu: f64,
    pub cold_starts: u64,
    pub resize_conflicts: u64,
}

/// Sweep of the parked CPU allocation under the in-place policy.
pub fn parked_cpu_sweep(kind: WorkloadKind, parked: &[u64], seed: u64) -> Vec<AblationPoint> {
    parked
        .iter()
        .map(|&m| {
            let mut sim = Simulation::with_params(PlatformParams::with_seed(seed));
            let mut cfg = Policy::InPlace.revision_config();
            cfg.parked_cpu = MilliCpu(m);
            sim.deploy_service(Service::with_config(
                "fn",
                WorkloadProfile::paper(kind),
                Policy::InPlace,
                cfg,
            ));
            sim.run();
            let r = Runner::run(
                &mut sim,
                "fn",
                &Scenario::closed_with_think(1, 8, SimTime::from_secs(8)),
            );
            AblationPoint {
                x: m as f64,
                mean_ms: r.mean_ms,
                p99_ms: r.p99_ms,
                avg_committed_mcpu: r.avg_committed_mcpu,
                cold_starts: r.cold_starts,
                resize_conflicts: sim.world.metrics.resize_conflicts,
            }
        })
        .collect()
}

/// Sweep of the cold policy's stable window (scale-to-zero threshold) under
/// arrivals with a fixed inter-arrival gap.
pub fn stable_window_sweep(
    windows_s: &[u64],
    gap: SimTime,
    seed: u64,
) -> Vec<AblationPoint> {
    windows_s
        .iter()
        .map(|&w| {
            let mut sim = Simulation::with_params(PlatformParams::with_seed(seed));
            let mut cfg = Policy::Cold.revision_config();
            cfg.stable_window = SimTime::from_secs(w);
            sim.deploy_service(Service::with_config(
                "fn",
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::Cold,
                cfg,
            ));
            sim.run();
            let r = Runner::run(
                &mut sim,
                "fn",
                &Scenario::closed_with_think(1, 10, gap),
            );
            AblationPoint {
                x: w as f64,
                mean_ms: r.mean_ms,
                p99_ms: r.p99_ms,
                avg_committed_mcpu: r.avg_committed_mcpu,
                cold_starts: r.cold_starts,
                resize_conflicts: 0,
            }
        })
        .collect()
}

/// Sweep of the hook retry period for back-to-back in-place activations
/// (no think time ⇒ every request races the previous park).
pub fn retry_period_sweep(retries_ms: &[u64], seed: u64) -> Vec<AblationPoint> {
    retries_ms
        .iter()
        .map(|&ms| {
            let mut params = PlatformParams::with_seed(seed);
            params.resize_retry = SimTime::from_millis(ms);
            let mut sim = Simulation::with_params(params);
            sim.deploy(
                "fn",
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::InPlace,
            );
            sim.run();
            let r = Runner::run(&mut sim, "fn", &Scenario::closed(1, 12));
            AblationPoint {
                x: ms as f64,
                mean_ms: r.mean_ms,
                p99_ms: r.p99_ms,
                avg_committed_mcpu: r.avg_committed_mcpu,
                cold_starts: r.cold_starts,
                resize_conflicts: sim.world.metrics.resize_conflicts,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parked_sweep_trades_reservation_for_latency() {
        let pts = parked_cpu_sweep(WorkloadKind::HelloWorld, &[1, 100, 500], 3);
        // Reservation grows with the parked level...
        assert!(pts[0].avg_committed_mcpu < pts[1].avg_committed_mcpu);
        assert!(pts[1].avg_committed_mcpu < pts[2].avg_committed_mcpu);
        // ...and latency never gets *worse* with a larger park (the dead
        // window shrinks; helloworld at 100m parked serves almost fully).
        assert!(pts[2].mean_ms <= pts[0].mean_ms * 1.1);
        // No cold starts anywhere — it's still the in-place policy.
        assert!(pts.iter().all(|p| p.cold_starts == 0));
    }

    #[test]
    fn stable_window_controls_cold_start_frequency() {
        // 10 requests, 20 s apart: a 6 s window cold-starts every time; a
        // 60 s window keeps the pod warm after the first.
        let pts = stable_window_sweep(&[6, 60], SimTime::from_secs(20), 5);
        assert_eq!(pts[0].cold_starts, 10);
        assert_eq!(pts[1].cold_starts, 1);
        assert!(pts[1].mean_ms < pts[0].mean_ms / 3.0);
        // The warm-held pod commits more CPU on average.
        assert!(pts[1].avg_committed_mcpu > pts[0].avg_committed_mcpu);
    }

    #[test]
    fn retry_period_affects_back_to_back_latency() {
        let pts = retry_period_sweep(&[5, 25, 200], 7);
        // Conflicts occur in all configurations (park races the next
        // request)…
        assert!(pts.iter().all(|p| p.resize_conflicts > 0));
        // …and a 40× coarser retry cannot be faster than the fine one.
        assert!(pts[2].mean_ms >= pts[0].mean_ms * 0.9);
    }
}
