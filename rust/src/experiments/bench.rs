//! `kinetic bench` — the fixed scale ladder behind the per-PR perf
//! trajectory (`BENCH_<n>.json` at the repo root).
//!
//! Six rungs, smallest to largest, each exercising a different layer of
//! the hot path:
//!
//! | rung              | what it measures                                  |
//! |-------------------|---------------------------------------------------|
//! | engine-raw        | typed-event calendar-queue throughput, no platform |
//! | paper-closed-loop | §3 testbed, closed-loop VUs, in-place policy       |
//! | fleet-100         | 100 uniform nodes, one tenant each, open-loop      |
//! | azure-replay      | Azure-sample trace replay, one service per rank    |
//! | fleet-sharded     | same fleet under the sharded runtime, 1/2/4 shards |
//! | state-layer       | generational pod slab vs map oracle, raw lookups   |
//!
//! The ladder is *append-only*: existing rung names, topologies and
//! workloads never change across PRs (new rungs may be appended), so
//! `BENCH_5.json` vs `BENCH_6.json` is a like-for-like comparison on the
//! shared prefix. `smoke` shrinks every rung to CI size (same shape,
//! tiny counts) — CI runs `KINETIC_SMOKE=1 kinetic bench` and schema-
//! validates the output; real numbers come from a release build on a
//! quiet machine.
//!
//! A report with `measured: false` is a placeholder (committed when the
//! build environment cannot run the ladder); validation only requires
//! positive throughput when `measured` is true, so placeholders are
//! schema-valid but visibly unmeasured.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::cluster::topology::Topology;
use crate::coordinator::event::Event;
use crate::coordinator::platform::Simulation;
use crate::experiments::fleet::FleetConfig;
use crate::loadgen::arrival::Arrival;
use crate::loadgen::runner::{Runner, Scenario};
use crate::obs::export::profile_doc;
use crate::obs::ObserveConfig;
use crate::policy::Policy;
use crate::simclock::{Engine, SimTime, World};
use crate::trace::generator::TraceGenerator;
use crate::trace::loader::load_azure_csv;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// Version of the bench-report JSON layout.
pub const SCHEMA_VERSION: u64 = 1;
/// Document discriminator, so a ScenarioReport can never pass as a bench.
pub const KIND: &str = "kinetic-bench";

/// One rung of the ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct RungResult {
    pub name: String,
    pub description: String,
    /// Simulated requests completed (0 for the raw-engine rung).
    pub requests: u64,
    /// Engine events processed during the timed section.
    pub events: u64,
    /// Host wall time of the timed section, milliseconds.
    pub wall_ms: f64,
    /// Events per host second — the headline throughput number.
    pub events_per_sec: f64,
    /// Simulator self-profile (per-event-kind dispatch counts/wall time +
    /// calendar-queue internals) for rungs that drive the platform engine.
    /// Absent on raw/state rungs and on pre-profile reports (BENCH_≤9) —
    /// the field is optional so the trajectory stays comparable.
    pub profile: Option<Json>,
}

impl RungResult {
    fn timed(name: &str, description: &str, requests: u64, events: u64, wall: Duration) -> RungResult {
        let secs = wall.as_secs_f64();
        RungResult {
            name: name.to_string(),
            description: description.to_string(),
            requests,
            events,
            wall_ms: secs * 1000.0,
            events_per_sec: if secs > 0.0 { events as f64 / secs } else { 0.0 },
            profile: None,
        }
    }

    fn with_profile(mut self, profile: Option<Json>) -> RungResult {
        self.profile = profile;
        self
    }

    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", self.name.as_str().into()),
            ("description", self.description.as_str().into()),
            ("requests", self.requests.into()),
            ("events", self.events.into()),
            ("wall_ms", self.wall_ms.into()),
            ("events_per_sec", self.events_per_sec.into()),
        ];
        if let Some(p) = &self.profile {
            pairs.push(("profile", p.clone()));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json, path: &str) -> Result<RungResult, String> {
        if j.as_obj().is_none() {
            return Err(format!("{path} must be an object"));
        }
        let ctx = |e: crate::util::json::JsonError| format!("{path}: {e}");
        let profile = match j.get("profile") {
            None => None,
            Some(p) => {
                crate::obs::export::validate_profile(p)
                    .map_err(|e| format!("{path}.profile: {e}"))?;
                Some(p.clone())
            }
        };
        Ok(RungResult {
            name: j.req_str("name").map_err(ctx)?.to_string(),
            description: j.req_str("description").map_err(ctx)?.to_string(),
            requests: j.req_u64("requests").map_err(ctx)?,
            events: j.req_u64("events").map_err(ctx)?,
            wall_ms: j.req_f64("wall_ms").map_err(ctx)?,
            events_per_sec: j.req_f64("events_per_sec").map_err(ctx)?,
            profile,
        })
    }
}

/// The perf-trajectory document (`BENCH_<n>.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// True when the rungs ran at CI smoke sizes.
    pub smoke: bool,
    /// False marks a placeholder whose numbers are not real measurements.
    pub measured: bool,
    pub rungs: Vec<RungResult>,
}

impl BenchReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", KIND.into()),
            ("schema_version", SCHEMA_VERSION.into()),
            ("smoke", self.smoke.into()),
            ("measured", self.measured.into()),
            ("rungs", Json::arr(self.rungs.iter().map(RungResult::to_json))),
        ])
    }

    /// Parses and validates a document in one pass.
    pub fn from_json(j: &Json) -> Result<BenchReport, String> {
        let m = j.as_obj().ok_or("bench report must be a JSON object")?;
        for key in ["kind", "schema_version", "smoke", "measured", "rungs"] {
            if !m.contains_key(key) {
                return Err(format!("missing top-level field '{key}'"));
            }
        }
        let kind = j.req_str("kind").map_err(|e| e.to_string())?;
        if kind != KIND {
            return Err(format!("kind '{kind}' is not '{KIND}'"));
        }
        let version = j.req_u64("schema_version").map_err(|e| e.to_string())?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} unsupported (expected {SCHEMA_VERSION})"
            ));
        }
        let smoke = j
            .get("smoke")
            .and_then(Json::as_bool)
            .ok_or("'smoke' must be a boolean")?;
        let measured = j
            .get("measured")
            .and_then(Json::as_bool)
            .ok_or("'measured' must be a boolean")?;
        let rungs = j
            .req_arr("rungs")
            .map_err(|e| e.to_string())?
            .iter()
            .enumerate()
            .map(|(i, r)| RungResult::from_json(r, &format!("rungs[{i}]")))
            .collect::<Result<Vec<_>, _>>()?;
        if rungs.is_empty() {
            return Err("'rungs' must not be empty".to_string());
        }
        if measured {
            for r in &rungs {
                if r.events == 0 || r.events_per_sec <= 0.0 {
                    return Err(format!(
                        "measured report has a zero-throughput rung '{}'",
                        r.name
                    ));
                }
            }
        }
        Ok(BenchReport { smoke, measured, rungs })
    }

    pub fn validate(j: &Json) -> Result<(), String> {
        BenchReport::from_json(j).map(|_| ())
    }

    /// Writes the pretty JSON to `path` (exact path — the caller names it
    /// `BENCH_<n>.json`; no slugging, unlike the results-dir reports).
    pub fn save(&self, path: &Path) -> std::io::Result<PathBuf> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(path.to_path_buf())
    }

    /// Loads and validates a saved bench report.
    pub fn load(path: &Path) -> Result<BenchReport, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        BenchReport::from_json(&j)
    }

    pub fn table(&self) -> Table {
        let mode = match (self.measured, self.smoke) {
            (false, _) => " (placeholder — not measured)",
            (true, true) => " (smoke sizes)",
            (true, false) => "",
        };
        let mut t = Table::new(vec!["Rung", "Requests", "Events", "Wall (ms)", "Events/s"])
            .title(format!("kinetic bench: scale ladder{mode}"));
        for r in &self.rungs {
            t.row(vec![
                r.name.clone(),
                r.requests.to_string(),
                r.events.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.0}", r.events_per_sec),
            ]);
        }
        t
    }
}

/// Minimal world for the raw-engine rung: every event bumps a counter.
struct Counter(u64);

struct Tick;

impl World for Counter {
    type Event = Tick;

    fn handle(&mut self, _ev: Tick, _eng: &mut Engine<Counter>) {
        self.0 += 1;
    }
}

/// Drains the profile-only observation state armed over a platform rung's
/// timed section into the rung's `profile` JSON.
fn harvest_profile(sim: &mut Simulation) -> Option<Json> {
    let queue = sim.engine.queue_stats();
    let processed = sim.engine.processed();
    sim.world
        .take_obs()
        .map(|o| o.finish(queue, processed))
        .map(|b| profile_doc(&b.profile, &Event::KINDS))
}

/// Runs the fixed ladder. `smoke` shrinks counts to CI size; `trace` is
/// the Azure-sample CSV the last rung replays. Platform rungs run with the
/// profile-only observation plane armed (spans/timeline off), so each
/// carries a per-event-kind dispatch self-profile; the per-event
/// `Instant` reads are part of the measured section on every rung alike,
/// keeping the trajectory like-for-like from this report onward.
pub fn run_ladder(smoke: bool, trace: &Path) -> Result<BenchReport, String> {
    let mut rungs = Vec::new();

    // Rung 1: raw engine throughput — schedule + drain N trivial events.
    {
        let n: u64 = if smoke { 20_000 } else { 1_000_000 };
        let mut eng: Engine<Counter> = Engine::new();
        let mut world = Counter(0);
        let t0 = Instant::now();
        for i in 0..n {
            eng.schedule_at(SimTime::from_nanos(i), Tick);
        }
        let events = eng.run(&mut world);
        let wall = t0.elapsed();
        debug_assert_eq!(world.0, n);
        rungs.push(RungResult::timed(
            "engine-raw",
            "typed-event calendar queue, schedule+drain, no platform",
            0,
            events,
            wall,
        ));
    }

    // Rung 2: the paper testbed under a closed-loop VU scenario.
    {
        let (vus, iterations) = if smoke { (4, 10) } else { (8, 250) };
        let mut sim = Simulation::paper(7);
        sim.deploy(
            "helloworld",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::InPlace,
        );
        sim.run(); // pod up and parked
        let origin = sim.now();
        sim.world.arm_obs(ObserveConfig::profile_only(), 7, origin);
        let ev0 = sim.engine.processed();
        let t0 = Instant::now();
        let report = Runner::run(&mut sim, "helloworld", &Scenario::closed(vus, iterations));
        let wall = t0.elapsed();
        let profile = harvest_profile(&mut sim);
        rungs.push(
            RungResult::timed(
                "paper-closed-loop",
                "paper topology, helloworld in-place, closed-loop VUs",
                report.completed,
                sim.engine.processed() - ev0,
                wall,
            )
            .with_profile(profile),
        );
    }

    // Rung 3: a 100-node uniform fleet, one tenant per node, open-loop
    // Poisson arrivals.
    {
        let nodes = if smoke { 10 } else { 100 };
        let horizon = SimTime::from_secs(if smoke { 5 } else { 60 });
        let mut sim = Simulation::fleet(Topology::uniform_paper(nodes), 42);
        for i in 0..nodes {
            sim.deploy(
                &format!("svc-{i}"),
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::InPlace,
            );
        }
        sim.run(); // fleet up
        let start = sim.now();
        let arrival = Arrival::Poisson { rate_per_sec: 0.2 };
        let mut rng = sim.world.rng.fork();
        let mut submitted: u64 = 0;
        for i in 0..nodes {
            for t in arrival.times(horizon, &mut rng) {
                sim.submit_at(start + t, &format!("svc-{i}"));
                submitted += 1;
            }
        }
        let origin = sim.now();
        sim.world.arm_obs(ObserveConfig::profile_only(), 42, origin);
        let ev0 = sim.engine.processed();
        let t0 = Instant::now();
        sim.run();
        let wall = t0.elapsed();
        let profile = harvest_profile(&mut sim);
        rungs.push(
            RungResult::timed(
                "fleet-100",
                "uniform 100-node fleet, 1 tenant/node, Poisson open loop",
                submitted,
                sim.engine.processed() - ev0,
                wall,
            )
            .with_profile(profile),
        );
    }

    // Rung 4: Azure-sample trace replay, one service per popularity rank.
    {
        let loaded = load_azure_csv(trace, 1.0)?;
        let mut sim = Simulation::paper(3);
        for rank in 0..loaded.functions {
            sim.deploy(
                &format!("fn-{rank}"),
                TraceGenerator::profile_for(rank),
                Policy::InPlace,
            );
        }
        sim.run(); // min-scale pods up
        let start = sim.now();
        for ev in &loaded.events {
            sim.submit_at(start + ev.at, &format!("fn-{}", ev.function));
        }
        let origin = sim.now();
        sim.world.arm_obs(ObserveConfig::profile_only(), 3, origin);
        let ev0 = sim.engine.processed();
        let t0 = Instant::now();
        sim.run();
        let wall = t0.elapsed();
        let profile = harvest_profile(&mut sim);
        rungs.push(
            RungResult::timed(
                "azure-replay",
                "Azure-sample minute-count trace, 1 service/rank, in-place",
                loaded.events.len() as u64,
                sim.engine.processed() - ev0,
                wall,
            )
            .with_profile(profile),
        );
    }

    // Rung 5: the sharded multi-coordinator runtime over the rung-3 fleet
    // shape — one full pass per shard count (1, 2, 4), with the
    // byte-identity contract asserted inline: the merged row must be the
    // same at every count or the rung fails outright.
    {
        let nodes = if smoke { 10 } else { 100 };
        let horizon = SimTime::from_secs(if smoke { 5 } else { 60 });
        let cfg = FleetConfig {
            services: nodes,
            rate_per_service: 0.2,
            horizon,
            ..FleetConfig::base(Topology::uniform_paper(nodes), 42)
        };
        let mut events: u64 = 0;
        let mut requests: u64 = 0;
        let mut baseline: Option<String> = None;
        let mut profile: Option<Json> = None;
        let profile_cfg = ObserveConfig::profile_only();
        let t0 = Instant::now();
        for shards in [1u32, 2, 4] {
            let (row, ev, bundle) = crate::shard::run_policy_sharded_observed(
                &cfg,
                Policy::InPlace,
                shards,
                Some(&profile_cfg),
            );
            events += ev;
            requests = row.completed + row.failed;
            // Keep the 4-shard pass's merged profile: it exercises the
            // most cells (dispatch counts are summed across them).
            profile = bundle
                .map(|b| profile_doc(&b.profile, &Event::KINDS))
                .or(profile);
            let fingerprint = format!("{row:?}");
            match &baseline {
                None => baseline = Some(fingerprint),
                Some(b) if *b != fingerprint => {
                    return Err(format!(
                        "fleet-sharded rung: merged row diverged at {shards} shard(s)"
                    ));
                }
                Some(_) => {}
            }
        }
        let wall = t0.elapsed();
        rungs.push(
            RungResult::timed(
                "fleet-sharded",
                "rung-3 fleet under the sharded runtime at 1/2/4 shards",
                requests,
                events,
                wall,
            )
            .with_profile(profile),
        );
    }

    // Rung 6: the state layer in isolation — generational-slab pod
    // lookups (the arena overhaul's hot-path primitive) against a map
    // oracle over the same churned id set, with agreement asserted. The
    // paired timing lands in `cargo bench --bench fleet_scale -- arena`;
    // this rung keeps the slab number on the per-PR trajectory.
    {
        use std::collections::HashMap;

        use crate::cluster::arena::PodSlab;
        use crate::cluster::pod::{PodId, PodSpec};
        use crate::util::quantity::{Memory, MilliCpu, Resources};
        use crate::util::rng::Rng;

        let pods: usize = if smoke { 512 } else { 8192 };
        let iters: u64 = if smoke { 50 } else { 2000 };
        let spec = PodSpec::single(
            "fn",
            "img",
            Resources::new(MilliCpu(100), Memory::from_mib(64)),
            Resources::new(MilliCpu(1000), Memory::from_mib(128)),
        );
        let mut slab = PodSlab::new();
        let mut live: Vec<PodId> = (0..pods).map(|_| slab.alloc(spec.clone())).collect();
        let mut rng = Rng::new(13);
        // Retire and replace a third of the fleet: real generation churn.
        for _ in 0..pods / 3 {
            let i = rng.below(live.len() as u64) as usize;
            slab.remove(live.swap_remove(i));
            live.push(slab.alloc(spec.clone()));
        }
        let map: HashMap<PodId, u64> = live.iter().map(|&id| (id, id.0)).collect();
        let mut probes = live.clone();
        rng.shuffle(&mut probes);
        let lookups = iters * probes.len() as u64;
        let mut slab_hits = 0u64;
        let mut map_hits = 0u64;
        let t0 = Instant::now();
        for _ in 0..iters {
            for &id in &probes {
                if slab.get(id).is_some() {
                    slab_hits += 1;
                }
            }
        }
        for _ in 0..iters {
            for &id in &probes {
                if map.get(&id).is_some() {
                    map_hits += 1;
                }
            }
        }
        let wall = t0.elapsed();
        if slab_hits != map_hits || slab_hits != lookups {
            return Err(format!(
                "state-layer rung: slab saw {slab_hits}/{lookups} hits, map oracle {map_hits}"
            ));
        }
        rungs.push(RungResult::timed(
            "state-layer",
            "generational pod slab vs map oracle, randomized lookups",
            0,
            lookups * 2,
            wall,
        ));
    }

    Ok(BenchReport {
        smoke,
        measured: true,
        rungs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        BenchReport {
            smoke: true,
            measured: true,
            rungs: vec![RungResult {
                name: "engine-raw".to_string(),
                description: "d".to_string(),
                requests: 0,
                events: 100,
                wall_ms: 2.0,
                events_per_sec: 50_000.0,
            }],
        }
    }

    #[test]
    fn json_round_trips() {
        let r = sample();
        let j = r.to_json();
        assert_eq!(BenchReport::from_json(&j).unwrap(), r);
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        let mut r = sample();
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("kind".to_string(), "scenario".into());
        }
        assert!(BenchReport::from_json(&j).unwrap_err().contains("kind"));

        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".to_string(), 999u64.into());
        }
        assert!(BenchReport::from_json(&j)
            .unwrap_err()
            .contains("schema_version"));

        r.rungs.clear();
        assert!(BenchReport::from_json(&r.to_json())
            .unwrap_err()
            .contains("rungs"));
    }

    #[test]
    fn measured_reports_need_positive_throughput() {
        let mut r = sample();
        r.rungs[0].events = 0;
        r.rungs[0].events_per_sec = 0.0;
        assert!(BenchReport::from_json(&r.to_json())
            .unwrap_err()
            .contains("zero-throughput"));
        // The same zeros are fine in a placeholder.
        r.measured = false;
        assert!(BenchReport::from_json(&r.to_json()).is_ok());
    }

    /// The committed perf-trajectory documents at the repo root must
    /// always schema-validate (cargo runs tests with cwd = rust/). The
    /// ladder is append-only: BENCH_9 grew the state-layer rung.
    #[test]
    fn committed_bench_json_validates() {
        let r = BenchReport::load(Path::new("../BENCH_8.json")).expect("BENCH_8.json validates");
        assert_eq!(r.rungs.len(), 5);
        let r9 = BenchReport::load(Path::new("../BENCH_9.json")).expect("BENCH_9.json validates");
        assert_eq!(r9.rungs.len(), 6);
        assert_eq!(r9.rungs[5].name, "state-layer");
    }

    #[test]
    fn smoke_ladder_runs_end_to_end() {
        let r = run_ladder(true, Path::new("../examples/scenarios/azure_sample.csv")).unwrap();
        assert!(r.smoke && r.measured);
        assert_eq!(r.rungs.len(), 6);
        for rung in &r.rungs {
            assert!(rung.events > 0, "{} processed no events", rung.name);
        }
        // Every trace invocation completes on the small sample.
        let azure = &r.rungs[3];
        assert!(azure.requests > 0);
        BenchReport::validate(&r.to_json()).unwrap();
        // Platform rungs carry a schema-valid self-profile (non-empty
        // per-event-kind counts — validate_profile enforces count > 0);
        // the raw-engine and state-layer rungs never do.
        for i in [1usize, 2, 3, 4] {
            let p = r.rungs[i].profile.as_ref().unwrap_or_else(|| {
                panic!("rung '{}' is missing its self-profile", r.rungs[i].name)
            });
            crate::obs::export::validate_profile(p).unwrap();
        }
        assert!(r.rungs[0].profile.is_none());
        assert!(r.rungs[5].profile.is_none());
    }

    /// A malformed profile section is rejected, not silently carried.
    #[test]
    fn profile_sections_are_validated_on_load() {
        let mut r = sample();
        r.rungs[0].profile = Some(Json::obj(vec![("events", Json::Arr(vec![]))]));
        assert!(BenchReport::from_json(&r.to_json())
            .unwrap_err()
            .contains("profile"));
    }
}
