//! Fleet-scale policy comparison: the §3 policies swept over multi-node
//! topologies with a mixed-workload, multi-tenant request stream — the
//! regime the paper's single-node testbed cannot express.
//!
//! Per related work (Li et al., arXiv:1911.07449; Lin & Glikson,
//! arXiv:1903.12221), cold-start policy trade-offs shift once requests
//! spread over a fleet: per-function arrival rates thin out, so warm pools
//! hold far more idle reservation and scale-to-zero pays far more cold
//! starts. This experiment quantifies that shift: `kinetic fleet
//! --nodes 10..100 --topology uniform|hetero` emits the same per-policy
//! latency table as Table 3, but aggregated over the whole fleet.

use crate::cluster::topology::Topology;
use crate::coordinator::accounting::{HybridWeights, RoutingPolicy};
use crate::coordinator::event::Event;
use crate::coordinator::service::Service;
use crate::coordinator::sim::Simulation;
use crate::forecast::ForecastConfig;
use crate::knative::config::ScaleKnobs;
use crate::loadgen::arrival::Arrival;
use crate::obs::{ObsBundle, ObserveConfig};
use crate::policy::{PlatformParams, Policy};
use crate::simclock::SimTime;
use crate::util::stats::Samples;
use crate::util::table::{fmt_ms, Table};
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// The workload mix cycled across fleet services: mostly tiny functions
/// with a tail of cpu-, io- and video-bound tenants (the shape of real
/// multi-tenant traffic per the open-source-platform studies).
pub const FLEET_MIX: [WorkloadKind; 6] = [
    WorkloadKind::HelloWorld,
    WorkloadKind::HelloWorld,
    WorkloadKind::Cpu,
    WorkloadKind::Io,
    WorkloadKind::HelloWorld,
    WorkloadKind::Video10s,
];

/// Configuration of one fleet sweep.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub topology: Topology,
    /// Deployed services (tenants); workloads cycle through [`FleetConfig::mix`].
    pub services: usize,
    /// Open-loop Poisson arrivals per service, requests/second.
    pub rate_per_service: f64,
    /// Virtual-time horizon of the arrival stream.
    pub horizon: SimTime,
    pub seed: u64,
    /// Activator pod-selection policy threaded into the platform.
    pub routing: RoutingPolicy,
    /// Workload cycle across tenants (default: [`FLEET_MIX`]).
    pub mix: Vec<WorkloadKind>,
    /// Per-scenario autoscaler knobs (default: the old hard-wired values).
    pub knobs: ScaleKnobs,
    /// Hybrid routing blend weights threaded into the platform.
    pub hybrid: HybridWeights,
    /// Predictor/driver knobs for the forecast-driven policies (inert for
    /// the §3 triple; defaults keep them bit-identical).
    pub forecast: ForecastConfig,
    /// Fault-injection schedule (crashes, stragglers, resize failures).
    /// The default is inert: installation is a no-op and the run is
    /// bit-identical to a build without the fault subsystem.
    pub faults: crate::faults::FaultsConfig,
}

impl FleetConfig {
    /// The canonical shape everything else overrides: two tenants per
    /// node, 0.05 rps each over 300 virtual seconds, least-loaded routing,
    /// [`FLEET_MIX`] workloads and the pre-redesign autoscaler knobs.
    pub fn base(topology: Topology, seed: u64) -> FleetConfig {
        let services = (2 * topology.len()).max(1);
        FleetConfig {
            topology,
            services,
            rate_per_service: 0.05,
            horizon: SimTime::from_secs(300),
            seed,
            routing: RoutingPolicy::LeastLoaded,
            mix: FLEET_MIX.to_vec(),
            knobs: ScaleKnobs::fleet_default(),
            hybrid: HybridWeights::default(),
            forecast: ForecastConfig::default(),
            faults: crate::faults::FaultsConfig::default(),
        }
    }

    /// A 10-node uniform fleet with two tenants per node — the smallest
    /// configuration the acceptance sweep runs.
    pub fn default_10_node(seed: u64) -> FleetConfig {
        FleetConfig::base(Topology::uniform_paper(10), seed)
    }
}

/// One policy's aggregate outcome over the fleet.
#[derive(Debug, Clone)]
pub struct FleetRow {
    pub policy: Policy,
    pub routing: RoutingPolicy,
    pub nodes: usize,
    pub services: usize,
    pub completed: u64,
    pub failed: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_starts: u64,
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes (predictive-inplace).
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival (re-parked).
    pub mispredictions: u64,
    /// Average committed CPU over the run, milliCPU (reservation cost).
    pub avg_committed_mcpu: f64,
    pub pods_created: u64,
    /// Scheduling attempts that found no feasible node (fault runs).
    pub pods_unschedulable: u64,
    /// Pods killed by node crashes.
    pub pods_evicted: u64,
    /// Replacement pods started by crash recovery.
    pub pods_rescheduled: u64,
    /// Resize patches rejected by injected API failures.
    pub resize_failures: u64,
}

/// Runs one policy over the configured fleet and aggregates every tenant's
/// metrics.
pub fn run_policy(cfg: &FleetConfig, policy: Policy) -> FleetRow {
    run_policy_observed(cfg, policy, None).0
}

/// [`run_policy`] with the observation plane optionally armed over the
/// measured window. `None` is exactly the legacy run — arming never
/// perturbs RNG draws or the relative order of simulation events, so the
/// returned row is byte-identical either way (pinned by `tests/obs.rs`).
pub fn run_policy_observed(
    cfg: &FleetConfig,
    policy: Policy,
    observe: Option<&ObserveConfig>,
) -> (FleetRow, Option<ObsBundle>) {
    let mut sim = Simulation::fleet_with_params(
        cfg.topology.clone(),
        PlatformParams::with_seed(cfg.seed),
    );
    sim.world.routing = cfg.routing;
    sim.world.hybrid_weights = cfg.hybrid;
    let mix: &[WorkloadKind] = if cfg.mix.is_empty() { &FLEET_MIX } else { &cfg.mix };
    for i in 0..cfg.services {
        let kind = mix[i % mix.len()];
        let mut rc = policy.revision_config();
        // Tenants may fan out horizontally under load; the per-scenario
        // knobs bound per-pod concurrency so the KPA path is exercised at
        // scale (defaults reproduce the old hard-wired 4 / 2.0 / 4).
        cfg.knobs.apply(&mut rc);
        cfg.forecast.apply(&mut rc, policy);
        let svc = Service::with_config(
            &format!("fn-{i}"),
            WorkloadProfile::paper(kind),
            policy,
            rc,
        );
        sim.deploy_service(svc);
    }
    sim.run(); // bring up min-scale pods / let in-place pods park

    // Arm observation at the start of the measured window (after the
    // settle run) so spans and gauges cover the arrival stream only.
    if let Some(oc) = observe {
        let origin = sim.now();
        sim.world.arm_obs(oc.clone(), cfg.seed, origin);
        if oc.timeline {
            sim.engine.schedule_in(oc.timeline_cadence, Event::ObsTick);
        }
    }

    // Open-loop Poisson stream per tenant, seeded independently of the
    // platform RNG so arrival times are identical across the three
    // policies (same seed).
    let start = sim.now();
    for i in 0..cfg.services {
        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ (0xF1EE7 + i as u64));
        let arrival = Arrival::Poisson {
            rate_per_sec: cfg.rate_per_service,
        };
        let name = format!("fn-{i}");
        for t in arrival.times(cfg.horizon, &mut rng) {
            sim.submit_at(start + t, &name);
        }
    }
    // Install the fault schedule after the settle run so crash/straggler
    // offsets are measured from the same origin as the arrival stream.
    // Inert configs return before touching any state (bit-identity).
    sim.world.install_faults(&mut sim.engine, &cfg.faults);
    sim.run();

    // Observed runs harvest at the last *real* event: trailing ObsTicks
    // advance the engine clock past the workload, and the time-averaged
    // gauges below must cover exactly the unobserved run's span.
    let now = sim.world.obs_end_clock().unwrap_or_else(|| sim.now());
    let bundle = sim
        .world
        .take_obs()
        .map(|o| o.finish(sim.engine.queue_stats(), sim.engine.processed()));
    let mut lat = Samples::new();
    let (mut completed, mut failed, mut cold, mut ups) = (0u64, 0u64, 0u64, 0u64);
    let (mut spec_ups, mut mispred) = (0u64, 0u64);
    for (_, m) in sim.world.metrics.services() {
        completed += m.completed;
        failed += m.failed;
        cold += m.cold_starts;
        ups += m.inplace_scale_ups;
        spec_ups += m.speculative_resizes;
        mispred += m.mispredictions;
        for &v in m.latency_ms.values() {
            lat.record(v);
        }
    }
    let row = FleetRow {
        policy,
        routing: cfg.routing,
        nodes: cfg.topology.len(),
        services: cfg.services,
        completed,
        failed,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        cold_starts: cold,
        inplace_scale_ups: ups,
        speculative_resizes: spec_ups,
        mispredictions: mispred,
        avg_committed_mcpu: sim.world.metrics.committed_cpu.average_mcpu(now),
        pods_created: sim.world.metrics.pods_created,
        pods_unschedulable: sim.world.metrics.pods_unschedulable,
        pods_evicted: sim.world.metrics.pods_evicted,
        pods_rescheduled: sim.world.metrics.pods_rescheduled,
        resize_failures: sim.world.metrics.resize_failures,
    };
    (row, bundle)
}

/// The paper's §3 policy triple over one fleet — the default comparison
/// (the predictive policies join through an explicit scenario `policies`
/// list, never implicitly, so legacy outputs stay bit-identical).
pub fn run_all(cfg: &FleetConfig) -> Vec<FleetRow> {
    Policy::PAPER.iter().map(|&p| run_policy(cfg, p)).collect()
}

/// Every routing policy × every §3 policy over one fleet — the
/// placement-aware sweep, typically over `Topology::hetero_preset` so the
/// per-node calibration overrides (fast large nodes, slow small nodes)
/// give locality something real to exploit.
pub fn routing_sweep(cfg: &FleetConfig) -> Vec<FleetRow> {
    RoutingPolicy::ALL
        .iter()
        .flat_map(|&routing| {
            let mut c = cfg.clone();
            c.routing = routing;
            run_all(&c)
        })
        .collect()
}

/// One table builder for both renderings, so the two CLI views can never
/// drift in schema: the routing sweep is the same table with a leading
/// `Routing` column.
fn table_with(rows: &[FleetRow], title: String, with_routing: bool) -> Table {
    let mut headers = vec![
        "Policy",
        "Completed",
        "Failed",
        "Mean (ms)",
        "p50 (ms)",
        "p99 (ms)",
        "Cold starts",
        "Committed (mCPU)",
        "Pods created",
    ];
    if with_routing {
        headers.insert(0, "Routing");
    }
    let mut t = Table::new(headers).title(title);
    for r in rows {
        let mut cells = vec![
            r.policy.name().to_string(),
            r.completed.to_string(),
            r.failed.to_string(),
            fmt_ms(r.mean_ms),
            fmt_ms(r.p50_ms),
            fmt_ms(r.p99_ms),
            r.cold_starts.to_string(),
            format!("{:.0}", r.avg_committed_mcpu),
            r.pods_created.to_string(),
        ];
        if with_routing {
            cells.insert(0, r.routing.name().to_string());
        }
        t.row(cells);
    }
    t
}

fn fleet_dims(rows: &[FleetRow]) -> (usize, usize) {
    rows.first().map(|r| (r.nodes, r.services)).unwrap_or((0, 0))
}

/// Renders the per-policy fleet latency table.
pub fn fleet_table(rows: &[FleetRow]) -> Table {
    let (nodes, services) = fleet_dims(rows);
    table_with(
        rows,
        format!(
            "Fleet: per-policy latency over {nodes} nodes / {services} services (mixed workloads)"
        ),
        false,
    )
}

/// Renders the routing-sweep table (routing policy × §3 policy).
pub fn routing_table(rows: &[FleetRow]) -> Table {
    let (nodes, services) = fleet_dims(rows);
    table_with(
        rows,
        format!("Fleet routing sweep over {nodes} nodes / {services} services"),
        true,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(nodes: usize, services: usize) -> FleetConfig {
        FleetConfig {
            services,
            rate_per_service: 0.1,
            horizon: SimTime::from_secs(60),
            ..FleetConfig::base(Topology::uniform_paper(nodes), 11)
        }
    }

    #[test]
    fn ten_node_fleet_produces_per_policy_table() {
        let cfg = quick_cfg(10, 10);
        let rows = run_all(&cfg);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.nodes, 10);
            assert_eq!(r.failed, 0, "{:?} failed requests", r.policy);
            assert!(r.completed > 0, "{:?} completed nothing", r.policy);
        }
        let t = fleet_table(&rows);
        assert_eq!(t.n_rows(), 3);
        let ascii = t.to_ascii();
        assert!(ascii.contains("in-place"), "{ascii}");
        assert!(ascii.contains("10 nodes"), "{ascii}");
    }

    #[test]
    fn fleet_preserves_policy_ordering() {
        // The paper's ordering must survive the fleet: cold slowest,
        // warm fastest, in-place between; in-place reserves far less
        // than warm.
        let cfg = quick_cfg(10, 12);
        let cold = run_policy(&cfg, Policy::Cold);
        let warm = run_policy(&cfg, Policy::Warm);
        let inp = run_policy(&cfg, Policy::InPlace);
        assert!(
            warm.mean_ms < inp.mean_ms && inp.mean_ms < cold.mean_ms,
            "warm={} inp={} cold={}",
            warm.mean_ms,
            inp.mean_ms,
            cold.mean_ms
        );
        assert!(
            inp.avg_committed_mcpu < warm.avg_committed_mcpu / 3.0,
            "inp={} warm={}",
            inp.avg_committed_mcpu,
            warm.avg_committed_mcpu
        );
        assert!(cold.cold_starts > 0);
        assert_eq!(inp.cold_starts, 0);
    }

    #[test]
    fn heterogeneous_fleet_schedules_everything() {
        let cfg = FleetConfig {
            services: 12,
            rate_per_service: 0.1,
            horizon: SimTime::from_secs(30),
            ..FleetConfig::base(Topology::hetero_preset(6), 5)
        };
        let r = run_policy(&cfg, Policy::Warm);
        assert_eq!(r.failed, 0);
        assert!(r.completed > 0);
    }

    /// The routing sweep over a calibrated heterogeneous fleet: every
    /// routing policy completes the identical arrival stream without
    /// failures, and results are deterministic per (routing, seed).
    #[test]
    fn routing_sweep_over_calibrated_hetero_fleet() {
        let cfg = FleetConfig {
            services: 12,
            rate_per_service: 0.1,
            horizon: SimTime::from_secs(30),
            ..FleetConfig::base(Topology::hetero_preset(6), 5)
        };
        let rows = routing_sweep(&cfg);
        assert_eq!(rows.len(), 9, "3 routing × 3 §3 policies");
        for r in &rows {
            assert_eq!(r.failed, 0, "{:?}/{:?} failed", r.routing, r.policy);
            assert!(r.completed > 0, "{:?}/{:?}", r.routing, r.policy);
        }
        // Same arrival stream ⇒ same completion count under every routing.
        for chunk in rows.chunks(3).skip(1) {
            for (a, b) in chunk.iter().zip(&rows[0..3]) {
                assert_eq!(a.policy, b.policy);
                assert_eq!(
                    a.completed, b.completed,
                    "{:?} vs {:?}",
                    a.routing, b.routing
                );
            }
        }
        let t = routing_table(&rows);
        assert_eq!(t.n_rows(), 9);
        let ascii = t.to_ascii();
        assert!(ascii.contains("locality"), "{ascii}");
        assert!(ascii.contains("hybrid"), "{ascii}");
    }

    /// Single-node paper topology, warm pods (no resize state): the scored
    /// policies degenerate to least-loaded bit-for-bit — one node means no
    /// placement signal and warm pods carry no resize penalty, so every
    /// score ordering collapses to (in-flight, index). The paper
    /// reproduction cannot drift under a routing flag.
    #[test]
    fn routing_policies_agree_on_paper_topology() {
        let base = FleetConfig {
            services: 3,
            rate_per_service: 0.2,
            horizon: SimTime::from_secs(30),
            ..FleetConfig::base(Topology::paper(), 17)
        };
        let want = run_policy(&base, Policy::Warm);
        for routing in [RoutingPolicy::Locality, RoutingPolicy::Hybrid] {
            let mut cfg = base.clone();
            cfg.routing = routing;
            let got = run_policy(&cfg, Policy::Warm);
            assert_eq!(got.completed, want.completed, "{routing:?}");
            assert_eq!(
                got.mean_ms.to_bits(),
                want.mean_ms.to_bits(),
                "{routing:?} drifted the paper topology"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(4, 6);
        let a = run_policy(&cfg, Policy::InPlace);
        let b = run_policy(&cfg, Policy::InPlace);
        assert_eq!(a.mean_ms.to_bits(), b.mean_ms.to_bits());
        assert_eq!(a.completed, b.completed);
    }
}
