//! Future-work exploration (paper §6): in-place **memory** scaling.
//!
//! The paper restricts itself to CPU because "reducing memory may trigger
//! Out Of Memory (OOM) issues, which we plan to investigate in the future."
//! This module quantifies that concern: CPU under-provision *throttles*
//! (the request crawls, §4.1's detection delays), but memory
//! under-provision *kills* — if a request's peak working set exceeds the
//! limit before the scale-up lands, the kernel OOM-kills the container and
//! the platform pays a full restart.
//!
//! Model: an in-place-style memory policy parks a pod at `parked_mb` and
//! patches it to `serving_mb` when a request arrives (resize latency from
//! the §4.1-calibrated model — memory limits propagate through the same
//! kubelet/cgroup pipeline). The request's memory ramps up over its runtime
//! toward a lognormal peak; if the ramp crosses the *currently applied*
//! limit, the container is OOM-killed, the pod restarts (cold-start
//! pipeline) and the request is retried once.

use crate::cgroup::latency::{LatencyModel, NodeLoad};
use crate::cluster::kubelet::Kubelet;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// Memory behaviour of a workload (MiB).
#[derive(Debug, Clone, Copy)]
pub struct MemoryProfile {
    /// Idle footprint (runtime + imports).
    pub idle_mb: f64,
    /// Mean peak working set during a request.
    pub peak_mean_mb: f64,
    /// Peak variability (σ of the lognormal).
    pub peak_std_mb: f64,
    /// Fraction of the runtime after which the peak is reached.
    pub ramp_frac: f64,
}

impl MemoryProfile {
    /// Rough memory shapes for the paper's workloads.
    pub fn for_kind(kind: WorkloadKind) -> MemoryProfile {
        match kind {
            WorkloadKind::HelloWorld => MemoryProfile {
                idle_mb: 38.0,
                peak_mean_mb: 42.0,
                peak_std_mb: 2.0,
                ramp_frac: 0.5,
            },
            WorkloadKind::Cpu => MemoryProfile {
                idle_mb: 55.0,
                peak_mean_mb: 96.0,
                peak_std_mb: 10.0,
                ramp_frac: 0.3,
            },
            WorkloadKind::Io => MemoryProfile {
                idle_mb: 50.0,
                peak_mean_mb: 160.0,
                peak_std_mb: 30.0,
                ramp_frac: 0.2,
            },
            // ffmpeg buffers frames: big, variable peaks.
            _ => MemoryProfile {
                idle_mb: 120.0,
                peak_mean_mb: 420.0,
                peak_std_mb: 90.0,
                ramp_frac: 0.15,
            },
        }
    }
}

/// Outcome of one memory-policy configuration.
#[derive(Debug, Clone)]
pub struct MemoryOutcome {
    pub parked_mb: f64,
    pub requests: u32,
    pub ooms: u32,
    pub latency: Summary,
    /// Average committed memory (MiB) over the run.
    pub avg_committed_mb: f64,
}

/// Simulates `requests` sequential requests (8 s apart, the §4.2 scenario)
/// under an in-place *memory* policy that parks at `parked_mb` and scales to
/// `serving_mb` on arrival.
pub fn run_memory_policy(
    kind: WorkloadKind,
    parked_mb: f64,
    serving_mb: f64,
    requests: u32,
    seed: u64,
) -> MemoryOutcome {
    let wl = WorkloadProfile::paper(kind);
    let mem = MemoryProfile::for_kind(kind);
    let kubelet = Kubelet::default();
    let resize = LatencyModel::default();
    let mut rng = Rng::new(seed);

    let mut latency = Summary::new();
    let mut ooms = 0u32;
    let mut committed_integral_mb_ms = 0.0f64;
    let mut elapsed_ms = 0.0f64;

    for _ in 0..requests {
        // Request arrives at a parked pod: dispatch the memory scale-up and
        // redirect immediately (the paper's CPU hook, applied to memory).
        // Memory limits traverse the same patch→kubelet→cgroup pipeline;
        // use the calibrated model with the *CPU-equivalent* of the target
        // (propagation is dominated by the kubelet sync, which the model's
        // large-target regime captures: ~57 ms).
        let resize_ms = resize.sample_ms(1000, 1000, NodeLoad::IDLE, &mut rng);
        let runtime_ms = rng.lognormal_mean_std(wl.runtime_1cpu_ms, wl.runtime_1cpu_ms * 0.015);
        let peak_mb = rng.lognormal_mean_std(mem.peak_mean_mb, mem.peak_std_mb);
        // The ramp crosses the parked limit at:
        //   t_cross = ramp_frac * runtime * (parked - idle)/(peak - idle)
        let t_cross_ms = if peak_mb <= parked_mb {
            f64::INFINITY
        } else {
            let frac = ((parked_mb - mem.idle_mb) / (peak_mb - mem.idle_mb)).clamp(0.0, 1.0);
            mem.ramp_frac * runtime_ms * frac
        };

        let mut this_latency;
        if t_cross_ms < resize_ms {
            // OOM: the working set outgrew the parked limit before the
            // scale-up landed. Container killed; full restart, then retry.
            ooms += 1;
            let restart = Kubelet::plan_total(&kubelet.startup_plan(
                true,
                wl.image_mb,
                wl.runtime_init_ms,
                &mut rng,
            ))
            .as_millis_f64();
            // Retry succeeds: pod restarts at serving_mb.
            let retry_runtime =
                rng.lognormal_mean_std(wl.runtime_1cpu_ms, wl.runtime_1cpu_ms * 0.015);
            this_latency = t_cross_ms + restart + retry_runtime;
            committed_integral_mb_ms += serving_mb * (restart + retry_runtime);
        } else {
            this_latency = resize_ms.min(t_cross_ms) * 0.0 + runtime_ms + resize_ms.min(20.0);
            // Serving period commits serving_mb.
            committed_integral_mb_ms += serving_mb * runtime_ms;
        }
        // Proxy hops as elsewhere.
        this_latency += 15.0;
        latency.record(this_latency);

        // Between requests (8 s), the pod parks at parked_mb.
        let gap_ms = 8000.0;
        committed_integral_mb_ms += parked_mb * gap_ms;
        elapsed_ms += this_latency + gap_ms;
    }

    MemoryOutcome {
        parked_mb,
        requests,
        ooms,
        latency,
        avg_committed_mb: committed_integral_mb_ms / elapsed_ms.max(1.0),
    }
}

/// The sweep the paper's future work calls for: parked memory level vs
/// OOM rate and reservation.
pub fn parked_memory_sweep(
    kind: WorkloadKind,
    parked_levels_mb: &[f64],
    seed: u64,
) -> Vec<MemoryOutcome> {
    parked_levels_mb
        .iter()
        .map(|&mb| run_memory_policy(kind, mb, 512.0, 200, seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generous_park_never_ooms() {
        // Parked above every conceivable peak: no kills, latency ≈ runtime.
        let out = run_memory_policy(WorkloadKind::Cpu, 512.0, 512.0, 100, 3);
        assert_eq!(out.ooms, 0);
        let want = WorkloadProfile::paper(WorkloadKind::Cpu).runtime_1cpu_ms;
        assert!((out.latency.mean() - want).abs() < 0.1 * want);
    }

    #[test]
    fn aggressive_park_ooms_fast_rampers() {
        // Parking the io workload (fast ramp, 160 MiB peaks) just above its
        // idle footprint: the ramp beats the ~57 ms resize almost always.
        let out = run_memory_policy(WorkloadKind::Io, 56.0, 512.0, 200, 5);
        assert!(
            out.ooms > 150,
            "expected pervasive OOM kills, got {}",
            out.ooms
        );
        // And each OOM costs a restart: mean latency blows past 2× runtime.
        let runtime = WorkloadProfile::paper(WorkloadKind::Io).runtime_1cpu_ms;
        assert!(out.latency.mean() > 1.5 * runtime);
    }

    #[test]
    fn sweep_is_monotone_in_safety_and_cost() {
        let sweep = parked_memory_sweep(WorkloadKind::Io, &[64.0, 128.0, 256.0, 512.0], 7);
        // OOMs fall as the parked level rises…
        for w in sweep.windows(2) {
            assert!(w[1].ooms <= w[0].ooms, "{} -> {}", w[0].ooms, w[1].ooms);
        }
        // …but committed memory rises.
        for w in sweep.windows(2) {
            assert!(w[1].avg_committed_mb > w[0].avg_committed_mb);
        }
        // The safe end has zero OOMs (unlike CPU, there is no "slow but
        // correct" middle ground for memory — the paper's deferral reason).
        assert_eq!(sweep.last().unwrap().ooms, 0);
        assert!(sweep[0].ooms > 0);
    }

    #[test]
    fn slow_rampers_survive_lower_parks() {
        // helloworld's tiny, slow-ramping footprint tolerates a 64 MiB park.
        let out = run_memory_policy(WorkloadKind::HelloWorld, 64.0, 512.0, 200, 9);
        assert_eq!(out.ooms, 0);
    }
}
