//! Reproduction of every table and figure in the paper's evaluation.
//!
//! | id   | paper artifact                      | module              |
//! |------|-------------------------------------|---------------------|
//! | t1   | Table 1 (experiment matrix)         | `scaling_overhead`  |
//! | fig2 | Fig 2a–d (step 100 m, up/down)      | `scaling_overhead`  |
//! | fig3 | Fig 3a–b (step 1000 m)              | `scaling_overhead`  |
//! | fig4 | Fig 4a–b (5 m granularity)          | `scaling_overhead`  |
//! | t2   | Table 2 (runtimes @ 1 CPU)          | `policies`          |
//! | t3   | Table 3 + Fig 5 (policy latencies)  | `policies`          |
//! | fig6 | Fig 6 (runtime vs in-place effect)  | `policies`          |
//! | fleet| beyond-paper: policies over a fleet | `fleet`             |
//! | bench| beyond-paper: perf scale ladder     | `bench`             |
//!
//! Each experiment renders the same rows/series the paper reports and is
//! reachable from both `kinetic exp <id>` and `cargo bench`; the fleet
//! sweep additionally hangs off `kinetic fleet --nodes N --topology ...`.

pub mod ablation;
pub mod bench;
pub mod fleet;
pub mod memory;
pub mod policies;
pub mod report;
pub mod scaling_overhead;

pub use ablation::AblationPoint;
pub use bench::{BenchReport, RungResult};
pub use fleet::{FleetConfig, FleetRow};
pub use memory::{MemoryOutcome, MemoryProfile};
pub use policies::{PolicyExperiment, PolicyRow};
pub use report::ExperimentReport;
pub use scaling_overhead::{OverheadConfig, OverheadExperiment, OverheadPoint, WorkState};
