//! §4.2 — policy comparison (Table 2, Table 3 / Figure 5, Figure 6).
//!
//! Methodology mirrors the paper: k6-style load (single VU, sequential
//! iterations, 8 s think time — longer than the 6 s stable window, so under
//! the cold policy every request arrives after scale-down, which is the
//! §3 definition of the cold path) against each of the six Table-2
//! workloads under each policy, normalized by the *Default* baseline
//! (direct function execution at 1 CPU, no platform in front).

use crate::coordinator::accounting::RoutingPolicy;
use crate::coordinator::platform::Simulation;
use crate::loadgen::runner::{LoadReport, Runner, Scenario};
use crate::policy::{PlatformParams, Policy};
use crate::simclock::SimTime;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// One row of Table 3 (plus the absolute means behind the ratios).
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub function: String,
    pub default_ms: f64,
    pub cold_ms: f64,
    pub inplace_ms: f64,
    pub warm_ms: f64,
    /// Ratios vs default (the paper's Table 3 cells).
    pub cold: f64,
    pub inplace: f64,
    pub warm: f64,
}

impl PolicyRow {
    /// The headline: how much faster in-place is than cold.
    pub fn improvement(&self) -> f64 {
        self.cold / self.inplace
    }
}

/// Experiment driver.
#[derive(Debug, Clone)]
pub struct PolicyExperiment {
    /// Iterations per (workload, policy) cell.
    pub iterations: u32,
    /// Think time between iterations (> stable window forces cold starts).
    pub think: SimTime,
    pub seed: u64,
    /// Activator routing policy (the golden paper table is pinned under
    /// the default `LeastLoaded`; single-node single-VU cells are
    /// routing-invariant, which `tests/golden_paper.rs` asserts).
    pub routing: RoutingPolicy,
}

impl Default for PolicyExperiment {
    fn default() -> Self {
        PolicyExperiment {
            iterations: 8,
            think: SimTime::from_secs(8),
            seed: 42,
            routing: RoutingPolicy::LeastLoaded,
        }
    }
}

impl PolicyExperiment {
    /// Table 2: default runtime measurements at 1 CPU. These are direct
    /// executions of the function (no platform hop) with measurement noise;
    /// the means are the calibration anchors from the paper.
    pub fn table2(&self, samples: u32) -> Vec<(WorkloadKind, Summary)> {
        let mut rng = Rng::new(self.seed ^ 0x7AB1E_2);
        let mut out = Vec::new();
        for kind in WorkloadKind::ALL {
            let p = WorkloadProfile::paper(kind);
            let mut s = Summary::new();
            for _ in 0..samples {
                // Direct invocation at exactly 1000 m; ±1.5% runtime noise.
                let ms = rng.lognormal_mean_std(p.runtime_1cpu_ms, p.runtime_1cpu_ms * 0.015);
                s.record(ms);
            }
            out.push((kind, s));
        }
        out
    }

    fn iterations_for(&self, kind: WorkloadKind) -> u32 {
        match kind {
            // The 2- and 10-minute videos get fewer reps (as any real
            // harness would); virtual time is free but keep event counts sane.
            WorkloadKind::Video10m => self.iterations.min(4).max(2),
            WorkloadKind::Video1m => self.iterations.min(6).max(3),
            _ => self.iterations,
        }
    }

    /// Runs one (workload, policy) cell and returns the full load report —
    /// the scenario engine's entry point into the closed-loop rig.
    pub fn measure_cell_report(&self, kind: WorkloadKind, policy: Policy) -> LoadReport {
        let mut sim = Simulation::with_params(PlatformParams::with_seed(
            self.seed ^ cell_hash(kind, policy),
        ));
        sim.world.routing = self.routing;
        sim.deploy("fn", WorkloadProfile::paper(kind), policy);
        sim.run(); // bring up min-scale pods / let them park
        let scenario =
            Scenario::closed_with_think(1, self.iterations_for(kind), self.think);
        let report = Runner::run(&mut sim, "fn", &scenario);
        assert_eq!(report.failed, 0, "{kind:?}/{policy:?} had failures");
        report
    }

    /// Measures the mean end-to-end latency for one (workload, policy) cell
    /// (the golden-pinned value).
    pub fn measure_cell(&self, kind: WorkloadKind, policy: Policy) -> f64 {
        self.measure_cell_report(kind, policy).mean_ms
    }

    /// Table 3 / Fig 5: all workloads × all policies, normalized by Default.
    pub fn table3(&self) -> Vec<PolicyRow> {
        let defaults = self.table2(32);
        let mut rows = Vec::new();
        for (kind, d) in defaults {
            let default_ms = d.mean();
            let cold_ms = self.measure_cell(kind, Policy::Cold);
            let inplace_ms = self.measure_cell(kind, Policy::InPlace);
            let warm_ms = self.measure_cell(kind, Policy::Warm);
            rows.push(PolicyRow {
                function: kind.name().to_string(),
                default_ms,
                cold_ms,
                inplace_ms,
                warm_ms,
                cold: cold_ms / default_ms,
                inplace: inplace_ms / default_ms,
                warm: warm_ms / default_ms,
            });
        }
        rows
    }

    /// Fig 6: (default runtime, in-place relative latency) series — the
    /// inverse relationship the paper highlights.
    pub fn fig6(rows: &[PolicyRow]) -> Vec<(f64, f64)> {
        let mut pts: Vec<(f64, f64)> = rows.iter().map(|r| (r.default_ms, r.inplace)).collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        pts
    }
}

fn cell_hash(kind: WorkloadKind, policy: Policy) -> u64 {
    let k = kind
        .name()
        .bytes()
        .fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
    let p = match policy {
        Policy::Cold => 3,
        Policy::Warm => 5,
        Policy::InPlace => 7,
        Policy::Pooled => 11,
        Policy::PredictiveInPlace => 13,
    };
    k.wrapping_mul(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> PolicyExperiment {
        PolicyExperiment {
            iterations: 4,
            think: SimTime::from_secs(8),
            seed: 9,
            routing: RoutingPolicy::LeastLoaded,
        }
    }

    #[test]
    fn table2_means_match_paper() {
        let t2 = quick().table2(64);
        for (kind, s) in t2 {
            let want = WorkloadProfile::paper(kind).runtime_1cpu_ms;
            let got = s.mean();
            assert!(
                (got - want).abs() / want < 0.02,
                "{kind:?}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn helloworld_row_matches_paper_shape() {
        let exp = quick();
        let d = 5.31;
        let cold = exp.measure_cell(WorkloadKind::HelloWorld, Policy::Cold) / d;
        let inp = exp.measure_cell(WorkloadKind::HelloWorld, Policy::InPlace) / d;
        let warm = exp.measure_cell(WorkloadKind::HelloWorld, Policy::Warm) / d;
        // Paper: 286.99 / 15.81 / 3.87.
        assert!((150.0..450.0).contains(&cold), "cold={cold}");
        assert!((8.0..30.0).contains(&inp), "inplace={inp}");
        assert!((2.0..7.0).contains(&warm), "warm={warm}");
        // Ordering.
        assert!(cold > inp && inp > warm && warm > 1.0);
        // Headline improvement: paper reports ≈18.15× for helloworld.
        let improvement = cold / inp;
        assert!((8.0..35.0).contains(&improvement), "improvement={improvement}");
    }

    #[test]
    fn cpu_row_ordering_and_bands() {
        let exp = quick();
        let d = 2465.18;
        let cold = exp.measure_cell(WorkloadKind::Cpu, Policy::Cold) / d;
        let inp = exp.measure_cell(WorkloadKind::Cpu, Policy::InPlace) / d;
        let warm = exp.measure_cell(WorkloadKind::Cpu, Policy::Warm) / d;
        // Paper: 2.00 / 1.31 / 1.13 — we require the ordering and rough zone.
        assert!(cold > inp && inp > warm, "cold={cold} inp={inp} warm={warm}");
        assert!((1.2..3.0).contains(&cold), "cold={cold}");
        assert!((1.0..1.6).contains(&inp), "inp={inp}");
        assert!((1.0..1.3).contains(&warm), "warm={warm}");
    }

    #[test]
    fn fig6_inverse_relationship() {
        // In-place relative latency must fall as runtime grows (endpoints).
        let exp = quick();
        let hello = exp.measure_cell(WorkloadKind::HelloWorld, Policy::InPlace) / 5.31;
        let video = exp.measure_cell(WorkloadKind::Video1m, Policy::InPlace) / 13888.03;
        assert!(
            hello > 3.0 * video,
            "hello={hello} video={video}: effect must shrink with runtime"
        );
    }
}
