//! Rendering experiment results as the paper's tables/figures (ASCII for
//! the terminal, markdown + CSV under `results/` for EXPERIMENTS.md).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::experiments::policies::PolicyRow;
use crate::experiments::scaling_overhead::{OverheadPoint, WorkState};
use crate::util::table::{fmt_ms, fmt_ratio, Table};

/// Accumulates rendered sections and writes them out.
#[derive(Debug, Default)]
pub struct ExperimentReport {
    sections: Vec<(String, String, String)>, // (id, ascii, markdown)
}

impl ExperimentReport {
    pub fn new() -> ExperimentReport {
        ExperimentReport::default()
    }

    pub fn add_table(&mut self, id: &str, table: &Table) {
        self.sections
            .push((id.to_string(), table.to_ascii(), table.to_markdown()));
    }

    /// Prints every section to stdout.
    pub fn print(&self) {
        for (id, ascii, _) in &self.sections {
            println!("\n## {id}\n{ascii}");
        }
    }

    /// Writes `results/<id>.md` + a combined `results/experiments.md`.
    pub fn write_dir(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let combined = dir.join("experiments.md");
        let mut all = std::fs::File::create(&combined)?;
        for (id, _, md) in &self.sections {
            writeln!(all, "## {id}\n\n{md}")?;
            std::fs::write(dir.join(format!("{id}.md")), md)?;
        }
        Ok(combined)
    }

    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }
}

/// Renders one §4.1 sweep (a Fig 2/3 panel): rows = intervals, columns =
/// mean latency per work state.
pub fn overhead_table(title: &str, points: &[OverheadPoint]) -> Table {
    let mut intervals: Vec<(u64, u64)> = points
        .iter()
        .map(|p| (p.from_m, p.to_m))
        .collect::<Vec<_>>();
    intervals.dedup();
    let mut t = Table::new(vec![
        "Interval",
        "Idle (ms)",
        "Stress-CPU (ms)",
        "Stress-I/O (ms)",
        "CPU/Idle ×",
    ])
    .title(title);
    for (from, to) in intervals {
        let find = |state: WorkState| -> Option<&OverheadPoint> {
            points
                .iter()
                .find(|p| p.from_m == from && p.to_m == to && p.state == state)
        };
        let idle = find(WorkState::Idle).map(|p| p.stats.mean());
        let cpu = find(WorkState::StressCpu).map(|p| p.stats.mean());
        let io = find(WorkState::StressIo).map(|p| p.stats.mean());
        let ratio = match (idle, cpu) {
            (Some(i), Some(c)) if i > 0.0 => fmt_ratio(c / i),
            _ => "-".to_string(),
        };
        t.row(vec![
            format!("{from}m→{to}m"),
            idle.map(fmt_ms).unwrap_or_else(|| "-".into()),
            cpu.map(fmt_ms).unwrap_or_else(|| "-".into()),
            io.map(fmt_ms).unwrap_or_else(|| "-".into()),
            ratio,
        ]);
    }
    t
}

/// Renders a single-state sweep (Fig 4 panels).
pub fn overhead_series_table(title: &str, points: &[OverheadPoint]) -> Table {
    let mut t = Table::new(vec!["Interval", "Mean (ms)", "Std (ms)"]).title(title);
    for p in points {
        t.row(vec![
            format!("{}m→{}m", p.from_m, p.to_m),
            fmt_ms(p.stats.mean()),
            fmt_ms(p.stats.std_dev()),
        ]);
    }
    t
}

/// Renders Table 3 (relative latencies, `Default = 1.00`).
pub fn table3_table(rows: &[PolicyRow]) -> Table {
    let mut t = Table::new(vec!["Function", "Cold", "In-place", "Warm", "Default"])
        .title("Table 3: Relative latency vs Default (paper: 286.99/15.81/3.87 for helloworld)");
    for r in rows {
        t.row(vec![
            r.function.clone(),
            fmt_ratio(r.cold),
            fmt_ratio(r.inplace),
            fmt_ratio(r.warm),
            "1.00".to_string(),
        ]);
    }
    t
}

/// Renders the absolute means behind Table 3 (Fig 5's bars).
pub fn fig5_table(rows: &[PolicyRow]) -> Table {
    let mut t = Table::new(vec![
        "Function",
        "Default (ms)",
        "Warm (ms)",
        "In-place (ms)",
        "Cold (ms)",
        "Cold/In-place ×",
    ])
    .title("Fig 5: Average latency per scheduling policy (absolute)");
    for r in rows {
        t.row(vec![
            r.function.clone(),
            fmt_ms(r.default_ms),
            fmt_ms(r.warm_ms),
            fmt_ms(r.inplace_ms),
            fmt_ms(r.cold_ms),
            fmt_ratio(r.improvement()),
        ]);
    }
    t
}

/// Renders Fig 6 (runtime vs in-place effect).
pub fn fig6_table(pts: &[(f64, f64)]) -> Table {
    let mut t = Table::new(vec!["Default runtime (ms)", "In-place relative latency"])
        .title("Fig 6: Runtime vs In-place effect (inverse relationship)");
    for (rt, ratio) in pts {
        t.row(vec![fmt_ms(*rt), fmt_ratio(*ratio)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scaling_overhead::Pattern;
    use crate::util::stats::Summary;

    fn pt(from: u64, to: u64, state: WorkState, mean: f64) -> OverheadPoint {
        let mut stats = Summary::new();
        stats.record(mean);
        OverheadPoint {
            from_m: from,
            to_m: to,
            state,
            pattern: Pattern::Incremental,
            stats,
        }
    }

    #[test]
    fn overhead_table_includes_ratio() {
        let points = vec![
            pt(1, 100, WorkState::Idle, 56.0),
            pt(1, 100, WorkState::StressCpu, 340.0),
            pt(1, 100, WorkState::StressIo, 60.0),
        ];
        let t = overhead_table("Fig 2a", &points);
        let s = t.to_ascii();
        assert!(s.contains("1m→100m"));
        assert!(s.contains("6.07")); // 340/56
    }

    #[test]
    fn report_writes_files() {
        let mut rep = ExperimentReport::new();
        let mut t = Table::new(vec!["a"]).title("x");
        t.row(vec!["1"]);
        rep.add_table("t1", &t);
        assert!(!rep.is_empty());
        let dir = std::env::temp_dir().join(format!("kinetic-rep-{}", std::process::id()));
        let combined = rep.write_dir(&dir).unwrap();
        let body = std::fs::read_to_string(combined).unwrap();
        assert!(body.contains("## t1"));
        assert!(dir.join("t1.md").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table3_renders_paper_columns() {
        let rows = vec![PolicyRow {
            function: "helloworld".into(),
            default_ms: 5.31,
            cold_ms: 1523.9,
            inplace_ms: 83.9,
            warm_ms: 20.5,
            cold: 286.99,
            inplace: 15.81,
            warm: 3.87,
        }];
        let s = table3_table(&rows).to_ascii();
        assert!(s.contains("286.99"));
        assert!(s.contains("15.81"));
        let f5 = fig5_table(&rows).to_ascii();
        assert!(f5.contains("18.15")); // 286.99 / 15.81 — the paper's headline
    }
}
