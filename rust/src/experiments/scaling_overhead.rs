//! §4.1 — in-place scaling overhead (Table 1, Figures 2, 3, 4).
//!
//! Reproduces the paper's methodology end-to-end on the simulated substrate:
//! a single pod on the 8-core node, a watcher exec'd into its cgroup, and a
//! sequence of resize patches following the Incremental / Cumulative
//! patterns in both directions, under Idle / Stress-CPU / Stress-I/O
//! conditions. Durations are measured from patch dispatch to the `cpu.max`
//! change landing (the `ResizeDone` watch event), exactly as the paper
//! defines them — through the real API-server → kubelet → cgroup pipeline,
//! not by sampling the latency model directly.

use crate::apiserver::{ApiServer, FeatureGates, ResizePatch};
use crate::cgroup::latency::NodeLoad;
use crate::cgroup::Stressor;
use crate::cluster::kubelet::Kubelet;
use crate::cluster::pod::{PodId, PodPhase, PodSpec};
use crate::cluster::{Cluster, NodeId};
use crate::simclock::{Engine, SimTime, World};
use crate::util::quantity::{Memory, MilliCpu, Resources};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Workload condition during the measurement (paper's Idle / Busy states).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkState {
    Idle,
    StressCpu,
    StressIo,
}

impl WorkState {
    pub const ALL: [WorkState; 3] = [WorkState::Idle, WorkState::StressCpu, WorkState::StressIo];

    pub fn name(&self) -> &'static str {
        match self {
            WorkState::Idle => "idle",
            WorkState::StressCpu => "stress-cpu",
            WorkState::StressIo => "stress-io",
        }
    }

    fn stressors(&self, cores: u32) -> Vec<Stressor> {
        match self {
            WorkState::Idle => vec![],
            WorkState::StressCpu => vec![Stressor::cpu_saturating(cores)],
            WorkState::StressIo => vec![Stressor::io(4)],
        }
    }
}

/// Scaling pattern (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Each step builds on the previous value: 1→100→200→…
    Incremental,
    /// Reset to base between steps: 1→100, 1→200, …
    Cumulative,
}

impl Pattern {
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Incremental => "incremental",
            Pattern::Cumulative => "cumulative",
        }
    }
}

/// One measured transition.
#[derive(Debug, Clone)]
pub struct OverheadPoint {
    /// Interval label, e.g. "1m-100m".
    pub from_m: u64,
    pub to_m: u64,
    pub state: WorkState,
    pub pattern: Pattern,
    pub stats: Summary,
}

/// Experiment configuration.
#[derive(Debug, Clone)]
pub struct OverheadConfig {
    /// Repetitions per interval (the paper averages repeated runs).
    pub reps: u32,
    pub seed: u64,
}

impl Default for OverheadConfig {
    fn default() -> Self {
        OverheadConfig { reps: 30, seed: 42 }
    }
}

// --------------------------------------------------------------------------
// A minimal world for the §4.1 rig: one pod, no serving stack.

struct Rig {
    cluster: Cluster,
    api: ApiServer,
    kubelet: Kubelet,
    rng: Rng,
    node: NodeId,
    pod: PodId,
    /// Completed (dispatch, landed) times for the in-flight patch.
    landed_at: Option<SimTime>,
}

type REng = Engine<Rig>;

/// The rig's one-event alphabet: a dispatched patch lands on the cgroup.
enum RigEvent {
    Landed { pod: PodId, target: MilliCpu },
}

impl World for Rig {
    type Event = RigEvent;

    fn handle(&mut self, ev: RigEvent, eng: &mut REng) {
        match ev {
            RigEvent::Landed { pod, target } => {
                let now = eng.now();
                self.cluster.apply_cpu_limit(pod, target, now);
                self.api
                    .mark_done(&mut self.cluster, pod, target, now)
                    .expect("resize done");
                self.landed_at = Some(now);
            }
        }
    }
}

impl Rig {
    fn new(seed: u64, state: WorkState) -> Rig {
        let mut cluster = Cluster::new();
        let node = cluster.add_node(
            "kind-worker",
            Resources::new(MilliCpu(8000), Memory::from_gib(10)),
        );
        for s in state.stressors(8) {
            cluster.node_mut(node).attach_stressor(s);
        }
        // The paper's rig: a single plain container, request small, limit
        // adjustable; 6000m sweeps need capacity headroom.
        let pod = cluster.create_pod(PodSpec::single(
            "target",
            "kinetic/rig:v1",
            Resources::new(MilliCpu(100), Memory::from_mib(128)),
            Resources::new(MilliCpu(1), Memory::from_mib(512)),
        ));
        cluster.bind(pod, node).unwrap();
        cluster.pod_mut(pod).unwrap().status.phase = PodPhase::Running;
        Rig {
            cluster,
            api: ApiServer::new(FeatureGates::paper_testbed()),
            kubelet: Kubelet::default(),
            rng: Rng::new(seed),
            node,
            pod,
            landed_at: None,
        }
    }

    fn load(&self) -> NodeLoad {
        self.cluster.node(self.node).load()
    }

    /// Sets the applied limit directly (preparing an interval start).
    fn force_limit(&mut self, m: MilliCpu, now: SimTime) {
        let pod = self.cluster.pod_mut(self.pod).unwrap();
        pod.status.applied_cpu_limit = m;
        pod.main_container_mut().limits.cpu = m;
        self.cluster.apply_cpu_limit(self.pod, m, now);
    }

}

/// Drives one measured resize on a (rig, engine) pair.
fn measure(rig: &mut Rig, eng: &mut REng, target: MilliCpu) -> SimTime {
    let dispatched = eng.now();
    rig.landed_at = None;
    let cur = rig.cluster.pod(rig.pod).unwrap().status.applied_cpu_limit;
    rig.api
        .patch_resize(
            &mut rig.cluster,
            ResizePatch {
                pod: rig.pod,
                new_cpu_limit: target,
            },
            dispatched,
        )
        .expect("patch accepted");
    let _ = rig
        .api
        .mark_in_progress(&mut rig.cluster, rig.pod, target, dispatched);
    let load = rig.load();
    let lat = rig.kubelet.resize_latency(cur, target, load, &mut rig.rng);
    let pod = rig.pod;
    eng.schedule_in(lat, RigEvent::Landed { pod, target });
    eng.run(rig);
    eng.now() - dispatched
}

// --------------------------------------------------------------------------

/// The §4.1 experiment driver.
pub struct OverheadExperiment {
    pub cfg: OverheadConfig,
}

impl OverheadExperiment {
    pub fn new(cfg: OverheadConfig) -> OverheadExperiment {
        OverheadExperiment { cfg }
    }

    /// Interval endpoints for a sweep, e.g. step 100: [1,100,200,…,1000].
    fn sweep_points(step: u64, max: u64) -> Vec<u64> {
        let mut pts = vec![1u64];
        let mut v = step;
        while v <= max {
            pts.push(v);
            v += step;
        }
        pts
    }

    /// Runs one (step, pattern, direction, state) cell of Table 1 and
    /// returns per-interval stats.
    pub fn run_cell(
        &self,
        step: u64,
        max: u64,
        pattern: Pattern,
        up: bool,
        state: WorkState,
    ) -> Vec<OverheadPoint> {
        let mut pts = Self::sweep_points(step, max);
        if !up {
            pts.reverse();
        }
        let base = pts[0];
        let mut out: Vec<OverheadPoint> = pts
            .windows(2)
            .map(|w| OverheadPoint {
                from_m: w[0],
                to_m: w[1],
                state,
                pattern,
                stats: Summary::new(),
            })
            .collect();

        for rep in 0..self.cfg.reps {
            let mut rig = Rig::new(
                self.cfg.seed ^ (rep as u64) << 17 ^ hash_state(state, pattern, up, step),
                state,
            );
            let mut eng: REng = Engine::new();
            match pattern {
                Pattern::Incremental => {
                    rig.force_limit(MilliCpu(base), eng.now());
                    for (i, w) in pts.windows(2).enumerate() {
                        let d = measure(&mut rig, &mut eng, MilliCpu(w[1]));
                        out[i].stats.record(d.as_millis_f64());
                    }
                }
                Pattern::Cumulative => {
                    for (i, w) in pts.windows(2).enumerate() {
                        rig.force_limit(MilliCpu(base), eng.now());
                        let d = measure(&mut rig, &mut eng, MilliCpu(w[1]));
                        out[i].stats.record(d.as_millis_f64());
                    }
                }
            }
        }
        out
    }

    /// Fig 2: step 100 m over 1 m↔1000 m, all states, both patterns and
    /// directions. Returns (pattern, up, state) → points.
    pub fn fig2(&self) -> Vec<(Pattern, bool, Vec<OverheadPoint>)> {
        let mut out = Vec::new();
        for pattern in [Pattern::Incremental, Pattern::Cumulative] {
            for up in [true, false] {
                let mut merged: Vec<OverheadPoint> = Vec::new();
                for state in WorkState::ALL {
                    merged.extend(self.run_cell(100, 1000, pattern, up, state));
                }
                out.push((pattern, up, merged));
            }
        }
        out
    }

    /// Fig 3: step 1000 m over 1 m↔6000 m.
    pub fn fig3(&self) -> Vec<(bool, Vec<OverheadPoint>)> {
        let mut out = Vec::new();
        for up in [true, false] {
            let mut merged = Vec::new();
            for state in WorkState::ALL {
                merged.extend(self.run_cell(1000, 6000, Pattern::Incremental, up, state));
            }
            out.push((up, merged));
        }
        out
    }

    /// Fig 4: idle, 5 m granularity. (a) increments ending at 1000 m,
    /// (b) decrements from 1000 m toward 5 m.
    pub fn fig4(&self) -> (Vec<OverheadPoint>, Vec<OverheadPoint>) {
        let up = self.run_cell(5, 1000, Pattern::Incremental, true, WorkState::Idle);
        let down = self.run_cell(5, 1000, Pattern::Incremental, false, WorkState::Idle);
        (up, down)
    }
}

fn hash_state(state: WorkState, pattern: Pattern, up: bool, step: u64) -> u64 {
    let s = match state {
        WorkState::Idle => 1,
        WorkState::StressCpu => 2,
        WorkState::StressIo => 3,
    };
    let p = match pattern {
        Pattern::Incremental => 5,
        Pattern::Cumulative => 7,
    };
    s * 1_000_003 + p * 10_007 + (up as u64) * 97 + step
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> OverheadExperiment {
        OverheadExperiment::new(OverheadConfig { reps: 12, seed: 3 })
    }

    #[test]
    fn sweep_points_shape() {
        assert_eq!(
            OverheadExperiment::sweep_points(100, 1000),
            vec![1, 100, 200, 300, 400, 500, 600, 700, 800, 900, 1000]
        );
        assert_eq!(
            OverheadExperiment::sweep_points(1000, 6000),
            vec![1, 1000, 2000, 3000, 4000, 5000, 6000]
        );
    }

    #[test]
    fn fig2a_first_intervals_inflate_under_cpu_stress() {
        let exp = quick();
        let idle = exp.run_cell(100, 1000, Pattern::Incremental, true, WorkState::Idle);
        let busy = exp.run_cell(100, 1000, Pattern::Incremental, true, WorkState::StressCpu);
        // 1m→100m: mean ratio in the paper is 6.06×.
        let r0 = busy[0].stats.mean() / idle[0].stats.mean();
        assert!((3.5..9.5).contains(&r0), "1m→100m ratio {r0}");
        // 100m→200m: 2.88×.
        let r1 = busy[1].stats.mean() / idle[1].stats.mean();
        assert!((1.8..4.8).contains(&r1), "100m→200m ratio {r1}");
        // Later intervals: not notable.
        let r8 = busy[8].stats.mean() / idle[8].stats.mean();
        assert!(r8 < 1.6, "800m→900m ratio {r8}");
    }

    #[test]
    fn fig3_large_steps_uniform_but_final_downstep_slow() {
        let exp = quick();
        let fig3 = exp.fig3();
        let (_, up_points) = &fig3[0];
        // Up: idle vs stress-cpu similar on every interval.
        let idle: Vec<&OverheadPoint> = up_points
            .iter()
            .filter(|p| p.state == WorkState::Idle)
            .collect();
        let busy: Vec<&OverheadPoint> = up_points
            .iter()
            .filter(|p| p.state == WorkState::StressCpu)
            .collect();
        for (i, b) in idle.iter().zip(&busy) {
            let r = b.stats.mean() / i.stats.mean();
            assert!(r < 1.6, "up interval {}→{} ratio {r}", i.from_m, i.to_m);
        }
        let (_, down_points) = &fig3[1];
        let idle_down: Vec<&OverheadPoint> = down_points
            .iter()
            .filter(|p| p.state == WorkState::Idle)
            .collect();
        // Final 1000m→1m step dominates the others.
        let last = idle_down.last().unwrap();
        assert_eq!(last.to_m, 1);
        let mid = &idle_down[2];
        assert!(
            last.stats.mean() > 4.0 * mid.stats.mean(),
            "last={} mid={}",
            last.stats.mean(),
            mid.stats.mean()
        );
    }

    #[test]
    fn fig4a_flat_mean_near_56ms() {
        let exp = OverheadExperiment::new(OverheadConfig { reps: 6, seed: 5 });
        let (up, down) = exp.fig4();
        let mut all = Summary::new();
        for p in &up {
            all.record(p.stats.mean());
        }
        // Paper: 56.44 ms ± 8.53.
        assert!((all.mean() - 56.44).abs() < 6.0, "mean={}", all.mean());
        // Down: rising toward small targets.
        let head = &down[0]; // 1000m→995m
        let tail = down.last().unwrap(); // →5m? last interval ends at 1? ends at 5.
        assert!(
            tail.stats.mean() > 2.0 * head.stats.mean(),
            "head={} tail={}",
            head.stats.mean(),
            tail.stats.mean()
        );
    }

    #[test]
    fn deterministic_runs() {
        let exp = quick();
        let a = exp.run_cell(1000, 6000, Pattern::Cumulative, true, WorkState::Idle);
        let b = exp.run_cell(1000, 6000, Pattern::Cumulative, true, WorkState::Idle);
        assert_eq!(a[0].stats.mean().to_bits(), b[0].stats.mean().to_bits());
    }
}
