//! Fault injection: seeded, schedulable fault processes driven through the
//! calendar-queue engine — node crash/recover with pod eviction and
//! rescheduling, straggler windows that inflate a node's startup/resize
//! pipelines, global startup inflation, and probabilistic resize failures.
//!
//! Faults are declared in the strict `faults` section of a
//! [`ScenarioSpec`](crate::scenario::ScenarioSpec) and installed onto a
//! built platform with [`Platform::install_faults`] after deployment
//! settles, so the crash/straggler clock starts with the measured window.
//! Everything stays deterministic and byte-identical across `--threads N`:
//! fault schedules are fixed points on the virtual clock, and the only
//! probabilistic fault (resize failure) draws from a dedicated RNG stream
//! so a spec without faults leaves the platform's main RNG — and therefore
//! every report byte — exactly as a fault-free build produced it
//! (pinned by `tests/faults.rs`).
//!
//! Every RNG-bearing sweep here (crash eviction, recovery rescheduling)
//! walks services in *name* order via `Services::ids_by_name` — interned
//! ids are assigned in deploy order, which differs from name order, and
//! reordering the sweeps would reorder RNG draws and break byte-identity.

use std::collections::BTreeMap;

use crate::cluster::pod::PodId;
use crate::cluster::NodeId;
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform, XShardMsg};
use crate::knative::activator::RequestId;
use crate::obs::Phase;
use crate::simclock::SimTime;
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;
use crate::util::rng::Rng;

/// Salt XORed into the scenario seed for the dedicated fault RNG, so the
/// resize-failure stream is decorrelated from the platform stream built
/// from the same seed.
const FAULT_RNG_SALT: u64 = 0xFA17_1D1C_ED5E_ED00;

/// What happens to requests resident on a crashed node's pods.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CrashRequestPolicy {
    /// Fail them outright (clients see errors).
    Fail,
    /// Re-buffer them at the activator; they re-dispatch to surviving
    /// capacity and only fail if the buffer overflows.
    #[default]
    Requeue,
}

impl CrashRequestPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            CrashRequestPolicy::Fail => "fail",
            CrashRequestPolicy::Requeue => "requeue",
        }
    }
}

impl std::str::FromStr for CrashRequestPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fail" => Ok(CrashRequestPolicy::Fail),
            "requeue" => Ok(CrashRequestPolicy::Requeue),
            other => Err(format!(
                "unknown crash_requests policy '{other}' (expected 'fail' or 'requeue')"
            )),
        }
    }
}

/// One node crash: the node goes down at `at` (killing every resident
/// pod) and recovers `down` later, restarting with a cold image cache.
/// Times are relative to fault installation (i.e. the start of the
/// measured window).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeCrash {
    pub node: u32,
    pub at: SimTime,
    pub down: SimTime,
}

/// A straggler window: between `from` and `until` the node's kubelet
/// pipelines run slower by the given factors (startup plans and resize
/// propagation respectively).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub node: u32,
    pub from: SimTime,
    pub until: SimTime,
    pub startup_factor: f64,
    pub resize_factor: f64,
}

/// The scenario `faults` section (strictly parsed in
/// [`scenario::spec`](crate::scenario)).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    pub node_crashes: Vec<NodeCrash>,
    /// Applied to in-flight requests on every crashed pod.
    pub crash_requests: CrashRequestPolicy,
    pub stragglers: Vec<Straggler>,
    /// Global startup-time multiplier (1.0 = off) — container creation
    /// under infrastructure-wide slowness. Composes multiplicatively with
    /// per-node straggler windows.
    pub startup_inflation: f64,
    /// Probability each resize patch is rejected outright, beyond the
    /// modelled conflict path. Drawn from the dedicated fault RNG.
    pub resize_failure_p: f64,
}

impl Default for FaultsConfig {
    fn default() -> FaultsConfig {
        FaultsConfig {
            node_crashes: Vec::new(),
            crash_requests: CrashRequestPolicy::default(),
            stragglers: Vec::new(),
            startup_inflation: 1.0,
            resize_failure_p: 0.0,
        }
    }
}

impl FaultsConfig {
    /// True when installing the config changes nothing: no events get
    /// scheduled and every multiplier stays at 1 — the byte-identity
    /// guard for fault-free specs.
    pub fn is_inert(&self) -> bool {
        self.node_crashes.is_empty()
            && self.stragglers.is_empty()
            && self.startup_inflation == 1.0
            && self.resize_failure_p == 0.0
    }

    /// Highest node index referenced by any crash or straggler entry —
    /// validated against the variant's topology at scenario compile time.
    pub fn max_node(&self) -> Option<u32> {
        self.node_crashes
            .iter()
            .map(|c| c.node)
            .chain(self.stragglers.iter().map(|s| s.node))
            .max()
    }
}

/// Runtime fault state carried by every [`Platform`] (inert by default;
/// [`Platform::install_faults`] arms it).
#[derive(Debug)]
pub struct FaultState {
    /// Global startup multiplier from `startup_inflation`.
    pub base_startup: f64,
    /// Per-node straggler startup multipliers (1.0 = window closed).
    straggler_startup: Vec<f64>,
    /// Per-node straggler resize multipliers (1.0 = window closed).
    straggler_resize: Vec<f64>,
    /// Per-patch rejection probability.
    pub resize_failure_p: f64,
    pub crash_requests: CrashRequestPolicy,
    /// Dedicated RNG for probabilistic faults. Creating it draws nothing,
    /// and the resize path only consults it when `resize_failure_p > 0`,
    /// so fault-free runs never touch it.
    pub rng: Rng,
}

impl FaultState {
    pub fn inert(nodes: usize, seed: u64) -> FaultState {
        FaultState {
            base_startup: 1.0,
            straggler_startup: vec![1.0; nodes],
            straggler_resize: vec![1.0; nodes],
            resize_failure_p: 0.0,
            crash_requests: CrashRequestPolicy::default(),
            rng: Rng::new(seed ^ FAULT_RNG_SALT),
        }
    }

    /// Effective startup multiplier for pods landing on `node`.
    pub fn startup_factor(&self, node: NodeId) -> f64 {
        self.base_startup
            * self
                .straggler_startup
                .get(node.0 as usize)
                .copied()
                .unwrap_or(1.0)
    }

    /// Effective resize-propagation multiplier for pods on `node`.
    pub fn resize_factor(&self, node: NodeId) -> f64 {
        self.straggler_resize
            .get(node.0 as usize)
            .copied()
            .unwrap_or(1.0)
    }

    fn set_straggler(&mut self, node: NodeId, startup: f64, resize: f64) {
        let i = node.0 as usize;
        if i < self.straggler_startup.len() {
            self.straggler_startup[i] = startup;
            self.straggler_resize[i] = resize;
        }
    }
}

/// Scales a latency by a straggler/inflation factor. Factor 1.0 returns
/// the input bit-identically (no float round-trip) — the fault-free
/// byte-identity guard on the startup and resize paths.
pub fn inflate(t: SimTime, factor: f64) -> SimTime {
    if factor == 1.0 {
        t
    } else {
        SimTime::from_nanos((t.as_nanos() as f64 * factor) as u64)
    }
}

impl Platform {
    /// Arms the fault state and schedules every crash and straggler window
    /// as typed events, with times relative to `eng.now()`. Call after the
    /// deploy settle (and after arrival scheduling), before the measured
    /// run. An inert config schedules nothing and touches nothing, so
    /// event sequence numbers and both RNG streams stay exactly as without
    /// a `faults` section.
    pub fn install_faults(&mut self, eng: &mut Eng, cfg: &FaultsConfig) {
        if cfg.is_inert() {
            return;
        }
        self.faults.base_startup = cfg.startup_inflation;
        self.faults.resize_failure_p = cfg.resize_failure_p;
        self.faults.crash_requests = cfg.crash_requests;
        let t0 = eng.now();
        for c in &cfg.node_crashes {
            eng.schedule_at(t0 + c.at, Event::NodeCrash { node: NodeId(c.node) });
            eng.schedule_at(
                t0 + c.at + c.down,
                Event::NodeRecover { node: NodeId(c.node) },
            );
        }
        for s in &cfg.stragglers {
            eng.schedule_at(
                t0 + s.from,
                Event::StragglerStart {
                    node: NodeId(s.node),
                    startup_factor: s.startup_factor,
                    resize_factor: s.resize_factor,
                },
            );
            eng.schedule_at(t0 + s.until, Event::StragglerEnd { node: NodeId(s.node) });
        }
    }

    /// Restricts `lost` to name order: the RNG-bearing recovery sweeps
    /// below must walk services lexicographically (the old
    /// `BTreeMap<String, _>` order), not in ServiceId (deploy) order.
    fn lost_by_name(w: &Platform, lost: &BTreeMap<ServiceId, usize>) -> Vec<(ServiceId, usize)> {
        w.services
            .ids_by_name()
            .filter_map(|id| lost.get(&id).map(|&n| (id, n)))
            .collect()
    }

    /// The node goes down: every resident pod dies. Starting pods unwind
    /// their startup pipeline; ready pods are evicted (in-flight requests
    /// failed or re-buffered per the crash policy). Terminating pods are
    /// left to their already-scheduled teardown — they are idle by
    /// construction (only idle pods terminate), and evicting them would
    /// double-count the orderly teardown. The recovery half then
    /// reschedules one replacement per lost pod through the ordinary
    /// [`Scheduler::pick`](crate::cluster::Scheduler) path onto surviving
    /// capacity and drains requeued requests.
    pub(crate) fn node_crash(w: &mut Platform, eng: &mut Eng, node: NodeId) {
        if node.0 as usize >= w.cluster.nodes().len() || !w.cluster.node(node).up() {
            return;
        }
        w.cluster.node_mut(node).set_up(false);

        // Lost capacity per service; the sweeps below iterate it through
        // `lost_by_name` so which pods died never reorders RNG draws.
        let mut lost: BTreeMap<ServiceId, usize> = BTreeMap::new();

        // Starting pods: cancel the in-flight PodReady, unwind `starting`.
        let doomed: Vec<PodId> = w
            .starting_pods
            .iter()
            .filter(|(_, s)| s.node == node)
            .map(|(id, _)| id)
            .collect();
        for pod_id in doomed {
            let entry = w.starting_pods.remove(pod_id).unwrap();
            eng.cancel(entry.ready_event);
            if let Some(svc) = w.services.get_mut(entry.service) {
                svc.starting = svc.starting.saturating_sub(1);
            }
            w.cluster.delete_pod(pod_id);
            w.metrics.pods_evicted += 1;
            *lost.entry(entry.service).or_default() += 1;
        }

        // Ready pods, service by service (name order).
        let ids: Vec<ServiceId> = w.services.ids_by_name().collect();
        let policy = w.faults.crash_requests;
        for svc_id in ids {
            let victims: Vec<PodId> = w.services[svc_id]
                .pods
                .iter()
                .filter(|p| p.node == Some(node) && !p.terminating)
                .map(|p| p.pod)
                .collect();
            if victims.is_empty() {
                continue;
            }
            for pod_id in &victims {
                Self::evict_pod(w, eng, svc_id, *pod_id, policy);
            }
            *lost.entry(svc_id).or_default() += victims.len();
        }
        Self::committed_changed(w, eng);

        // Sharded run with no surviving local capacity: escalate the lost
        // pods to the sharded runtime instead of burning doomed local
        // scheduler attempts. The runtime delivers each entry to a sibling
        // cell one lookahead later (see `crate::shard`); nothing can drain
        // here, so the local recovery half is skipped entirely. The wire
        // format stays name-addressed — ids are per-cell, so the sibling
        // re-interns the name into its own table at delivery.
        if w.xshard_outbox.is_some() && !w.cluster.nodes().iter().any(|n| n.up()) {
            let at = eng.now();
            let order = Self::lost_by_name(w, &lost);
            let msgs: Vec<XShardMsg> = order
                .iter()
                .map(|&(id, n)| XShardMsg {
                    at,
                    service: std::sync::Arc::clone(w.services.name(id)),
                    pods: n as u32,
                })
                .collect();
            w.xshard_outbox.as_mut().unwrap().extend(msgs);
            return;
        }

        // Recovery half: reschedule replacements and drain requeued
        // requests onto whatever capacity survives (a request re-buffered
        // above is dispatched here if a surviving pod has a free slot, or
        // when its replacement pod comes up).
        for (svc_id, n) in Self::lost_by_name(w, &lost) {
            for _ in 0..n {
                if Self::start_pod(w, eng, svc_id, true) {
                    w.metrics.pods_rescheduled += 1;
                }
            }
            Self::drain_activator(w, eng, svc_id);
        }
    }

    /// Delivered by the sharded runtime one lookahead after a sibling
    /// cell's crash escalated its lost pods here: reschedule `pods`
    /// replacements for the service through the ordinary scheduler path —
    /// the cross-shard counterpart of the local recovery half above.
    pub(crate) fn xshard_reschedule(w: &mut Platform, eng: &mut Eng, service: ServiceId, pods: u32) {
        if w.services.get(service).is_none() {
            return;
        }
        for _ in 0..pods {
            if Self::start_pod(w, eng, service, true) {
                w.metrics.pods_rescheduled += 1;
            }
        }
        Self::drain_activator(w, eng, service);
    }

    /// Kills one ready pod of the service: in-flight requests are detached
    /// and failed or re-buffered, pod-scoped timers cancelled, the
    /// in-flight resize record cleared, and cluster/fleet/service state
    /// unwound. The caller re-schedules replacements.
    pub(crate) fn evict_pod(
        w: &mut Platform,
        eng: &mut Eng,
        svc_id: ServiceId,
        pod_id: PodId,
        policy: CrashRequestPolicy,
    ) {
        let orphans: Vec<RequestId> = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let Some(idx) = svc.pod_index(pod_id) else { return };
            let sp = &mut svc.pods[idx];
            if let Some(t) = sp.idle_timer.take() {
                eng.cancel(t);
            }
            sp.proxy.all_requests()
        };
        Self::clear_resize_state(w, eng, svc_id, pod_id);
        // Detach the orphans from the dead pod: their partial execution is
        // lost (serverless at-most-once inside the container — a requeue
        // restarts from scratch on another pod).
        for req in &orphans {
            if let Some(r) = w.requests.get_mut(req) {
                if let Some(ev) = r.completion.take() {
                    eng.cancel(ev);
                }
                r.pod = None;
                r.exec = None;
                r.share = MilliCpu::ZERO;
            }
        }
        {
            let svc = w.services.get_mut(svc_id).unwrap();
            svc.in_flight_pods = svc.in_flight_pods.saturating_sub(orphans.len() as u32);
            if let Some(idx) = svc.pod_index(pod_id) {
                let sp = svc.pods.remove(idx);
                if sp.ready && !sp.terminating {
                    svc.ready_count = svc.ready_count.saturating_sub(1);
                }
            }
        }
        // `pod_gone` folds residual in-flight/busy/committed counters out
        // of the per-node accounting in one step.
        w.fleet.pod_gone(pod_id);
        w.cluster.delete_pod(pod_id);
        w.metrics.pods_evicted += 1;
        let now = eng.now();
        for req in orphans {
            if let Some(obs) = &mut w.obs {
                obs.mark(req.0, Phase::Evicted, now);
            }
            match policy {
                CrashRequestPolicy::Fail => Self::fail_request(w, eng, req),
                CrashRequestPolicy::Requeue => {
                    let requeued = w
                        .services
                        .get_mut(svc_id)
                        .map(|svc| svc.activator.buffer(req, now).is_ok())
                        .unwrap_or(false);
                    if !requeued {
                        Self::fail_request(w, eng, req);
                    } else if let Some(obs) = &mut w.obs {
                        obs.mark(req.0, Phase::Requeued, now);
                    }
                }
            }
        }
    }

    /// The node comes back: serving again, but with a cold image cache —
    /// the next pod placed there pays the image pull (the paper's `kind
    /// load` side-loading happened at deploy time and a restarted node has
    /// lost it). Buffered demand gets a scale-out pass immediately rather
    /// than waiting for the next arrival tick.
    pub(crate) fn node_recover(w: &mut Platform, eng: &mut Eng, node: NodeId) {
        if node.0 as usize >= w.cluster.nodes().len() || w.cluster.node(node).up() {
            return;
        }
        {
            let n = w.cluster.node_mut(node);
            n.set_up(true);
            n.clear_image_cache();
        }
        // Name order — the RNG-bearing scale-up sweep must match the old
        // `services.keys()` (String BTreeMap) iteration exactly.
        let ids: Vec<ServiceId> = w.services.ids_by_name().collect();
        for svc_id in ids {
            Self::maybe_scale_up(w, eng, svc_id);
            Self::drain_activator(w, eng, svc_id);
        }
    }

    /// A straggler window opens: the node's pipelines slow down.
    pub(crate) fn straggler_start(
        w: &mut Platform,
        _eng: &mut Eng,
        node: NodeId,
        startup_factor: f64,
        resize_factor: f64,
    ) {
        w.faults.set_straggler(node, startup_factor, resize_factor);
    }

    /// The straggler window closes: factors return to 1.
    pub(crate) fn straggler_end(w: &mut Platform, _eng: &mut Eng, node: NodeId) {
        w.faults.set_straggler(node, 1.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::Topology;
    use crate::coordinator::platform::Simulation;
    use crate::policy::Policy;
    use crate::workload::registry::{WorkloadKind, WorkloadProfile};

    #[test]
    fn inert_detection_and_max_node() {
        let cfg = FaultsConfig::default();
        assert!(cfg.is_inert());
        assert_eq!(cfg.max_node(), None);
        let armed = FaultsConfig {
            node_crashes: vec![NodeCrash {
                node: 3,
                at: SimTime::from_secs(1),
                down: SimTime::from_secs(2),
            }],
            stragglers: vec![Straggler {
                node: 7,
                from: SimTime::ZERO,
                until: SimTime::from_secs(9),
                startup_factor: 2.0,
                resize_factor: 2.0,
            }],
            ..FaultsConfig::default()
        };
        assert!(!armed.is_inert());
        assert_eq!(armed.max_node(), Some(7));
        assert!(!FaultsConfig {
            startup_inflation: 1.5,
            ..FaultsConfig::default()
        }
        .is_inert());
        assert!(!FaultsConfig {
            resize_failure_p: 0.1,
            ..FaultsConfig::default()
        }
        .is_inert());
    }

    #[test]
    fn inflate_is_identity_at_factor_one() {
        let t = SimTime::from_nanos(123_456_789);
        assert_eq!(inflate(t, 1.0), t);
        assert_eq!(inflate(t, 2.0), SimTime::from_nanos(246_913_578));
        assert_eq!(inflate(SimTime::ZERO, 3.5), SimTime::ZERO);
    }

    /// Two warm services on a 2-node fleet (LeastAllocated spreads them);
    /// node 0 crashes and both state unwinding and rescheduling must hold.
    fn crashed_sim(kind: WorkloadKind) -> Simulation {
        let mut sim = Simulation::fleet(Topology::uniform_paper(2), 11);
        for i in 0..2 {
            sim.deploy(
                &format!("svc-{i}"),
                WorkloadProfile::paper(kind),
                Policy::Warm,
            );
        }
        sim.run(); // settle: svc-0 → node 0, svc-1 → node 1
        sim
    }

    #[test]
    fn crash_evicts_reschedules_and_recovers() {
        let mut sim = crashed_sim(WorkloadKind::HelloWorld);
        assert_eq!(
            sim.world.services["svc-0"].pods[0].node,
            Some(crate::cluster::NodeId(0))
        );
        let cfg = FaultsConfig {
            node_crashes: vec![NodeCrash {
                node: 0,
                at: SimTime::from_secs(1),
                down: SimTime::from_secs(60),
            }],
            ..FaultsConfig::default()
        };
        sim.world.install_faults(&mut sim.engine, &cfg);
        sim.run_until(sim.now() + SimTime::from_secs(30));

        // Node 0 is down; its pod was evicted and replaced on node 1.
        assert!(!sim.world.cluster.node(crate::cluster::NodeId(0)).up());
        assert_eq!(sim.world.metrics.pods_evicted, 1);
        assert_eq!(sim.world.metrics.pods_rescheduled, 1);
        assert_eq!(sim.world.services["svc-0"].ready_pods(), 1);
        assert_eq!(
            sim.world.services["svc-0"].pods[0].node,
            Some(crate::cluster::NodeId(1))
        );
        // The orderly-teardown counter is untouched by eviction.
        assert_eq!(sim.world.metrics.pods_deleted, 0);

        // Recovery: the node serves again with a cold image cache.
        sim.run();
        let node0 = sim.world.cluster.node(crate::cluster::NodeId(0));
        assert!(node0.up());
        let image = sim.world.services["svc-0"].profile.image.clone();
        assert!(!node0.image_cached(&image));
    }

    #[test]
    fn crash_requeues_in_flight_requests_to_survivors() {
        let mut sim = crashed_sim(WorkloadKind::Cpu);
        sim.submit("svc-0");
        // Mid-execution (cpu runs ~2.5 s) the pod's node crashes.
        sim.run_until(sim.now() + SimTime::from_millis(500));
        Platform::node_crash(&mut sim.world, &mut sim.engine, crate::cluster::NodeId(0));
        sim.run_to_quiescence();
        let m = sim.world.metrics.service_ref("svc-0").unwrap();
        assert_eq!(m.failed, 0, "requeue policy must not fail the request");
        assert_eq!(m.completed, 1);
        assert_eq!(sim.world.metrics.pods_evicted, 1);
    }

    #[test]
    fn crash_fails_in_flight_requests_under_fail_policy() {
        let mut sim = crashed_sim(WorkloadKind::Cpu);
        sim.world.faults.crash_requests = CrashRequestPolicy::Fail;
        sim.submit("svc-0");
        sim.run_until(sim.now() + SimTime::from_millis(500));
        Platform::node_crash(&mut sim.world, &mut sim.engine, crate::cluster::NodeId(0));
        sim.run_to_quiescence();
        let m = sim.world.metrics.service_ref("svc-0").unwrap();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 0);
    }

    /// A crash mid-startup cancels the pending PodReady, unwinds
    /// `starting`, and reschedules the pod so the buffered cold-start
    /// request still completes.
    #[test]
    fn crash_during_startup_unwinds_and_reschedules() {
        let mut sim = Simulation::fleet(Topology::uniform_paper(2), 13);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.submit("fn");
        // Let the cold start begin its pipeline (≈1.2 s) without finishing.
        sim.run_until(sim.now() + SimTime::from_millis(300));
        assert_eq!(sim.world.services["fn"].starting, 1);
        let node = sim.world.starting_pods.values().next().unwrap().node;
        let before = sim.engine.pending();
        Platform::node_crash(&mut sim.world, &mut sim.engine, node);
        assert!(sim.engine.pending() <= before, "PodReady cancelled");
        assert_eq!(sim.world.services["fn"].starting, 1, "replacement started");
        assert!(sim.world.starting_pods.len() == 1);
        sim.run_to_quiescence();
        let m = sim.world.metrics.service_ref("fn").unwrap();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn straggler_window_inflates_cold_start() {
        let cold_latency = |straggle: bool| {
            let mut sim = Simulation::fleet(Topology::uniform_paper(1), 7);
            sim.deploy(
                "fn",
                WorkloadProfile::paper(WorkloadKind::HelloWorld),
                Policy::Cold,
            );
            if straggle {
                Platform::straggler_start(
                    &mut sim.world,
                    &mut sim.engine,
                    crate::cluster::NodeId(0),
                    4.0,
                    1.0,
                );
            }
            sim.submit("fn");
            sim.run_to_quiescence();
            sim.world
                .metrics
                .service_ref("fn")
                .unwrap()
                .latency_ms
                .mean()
        };
        let normal = cold_latency(false);
        let straggled = cold_latency(true);
        assert!(
            straggled > normal * 2.0,
            "straggler 4× must dominate: {normal} vs {straggled}"
        );
    }

    #[test]
    fn resize_failures_reject_patches_and_count() {
        let mut sim = Simulation::fleet(Topology::uniform_paper(1), 7);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::InPlace,
        );
        sim.world.faults.resize_failure_p = 1.0;
        sim.run(); // the post-ready park patch is rejected
        assert!(sim.world.metrics.resize_failures >= 1);
        assert_eq!(sim.world.metrics.resizes_accepted, 0);
        // The pod keeps its current (serving) allocation.
        let pod = sim.world.services["fn"].pods[0].pod;
        assert_eq!(
            sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit,
            MilliCpu(1000)
        );
        // No desire left dangling.
        assert!(sim.world.services["fn"].pods[0].desired_limit.is_none());
    }

    #[test]
    fn crash_runs_are_deterministic() {
        let run = || {
            let mut sim = crashed_sim(WorkloadKind::Cpu);
            let cfg = FaultsConfig {
                node_crashes: vec![NodeCrash {
                    node: 0,
                    at: SimTime::from_secs(1),
                    down: SimTime::from_secs(10),
                }],
                crash_requests: CrashRequestPolicy::Requeue,
                ..FaultsConfig::default()
            };
            sim.world.install_faults(&mut sim.engine, &cfg);
            for _ in 0..3 {
                sim.submit("svc-0");
            }
            sim.run_to_quiescence();
            sim.run();
            (
                sim.world
                    .metrics
                    .service_ref("svc-0")
                    .unwrap()
                    .latency_ms
                    .mean()
                    .to_bits(),
                sim.world.metrics.pods_evicted,
                sim.world.metrics.pods_rescheduled,
            )
        };
        assert_eq!(run(), run());
    }

    /// Installing an inert config must change nothing at all: same event
    /// count, same metrics bits as never calling install_faults.
    #[test]
    fn inert_install_is_a_true_noop() {
        let run = |install: bool| {
            let mut sim = crashed_sim(WorkloadKind::HelloWorld);
            if install {
                let cfg = FaultsConfig::default();
                sim.world.install_faults(&mut sim.engine, &cfg);
            }
            sim.submit("svc-0");
            sim.run_to_quiescence();
            (
                sim.engine.processed(),
                sim.world
                    .metrics
                    .service_ref("svc-0")
                    .unwrap()
                    .latency_ms
                    .mean()
                    .to_bits(),
            )
        };
        assert_eq!(run(false), run(true));
    }
}
