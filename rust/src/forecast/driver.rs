//! The proactive driver: consumes per-service forecasts and issues
//! *driver-initiated* actions ahead of arrivals, extending the reactive
//! request-initiated resize path of [`coordinator::resize`](crate::coordinator)
//! with two mechanisms:
//!
//! * **Warm-pool maintenance** (`pooled`): every dispatch that consumes a
//!   pool pod tops the idle pool back up to `pool_size`, and pods above
//!   the target retire through the cold-style idle timer — the pool-based
//!   cold-start mitigation of arXiv:1903.12221.
//! * **Speculative pre-resize** (`predictive-inplace`): each observed
//!   arrival schedules one speculation cycle for the *next* predicted
//!   arrival — resize the parked pod up `horizon` ahead of it, and re-park
//!   2×`horizon` later if no arrival claimed the pod (a misprediction).
//!   In-place scaling becomes a speculation mechanism: a hit serves the
//!   request at the full allocation with no resize on the critical path;
//!   a miss costs one resize round-trip and restores the parked state.
//!
//! The driver is event-driven, never tick-driven: one speculation cycle
//! per observed arrival, generation-stamped so stale events no-op. With
//! no arrivals nothing is scheduled, the pod stays parked, and the event
//! queue drains — `predictive-inplace` can never do worse than the §3
//! in-place policy on a silent service.

use crate::cluster::pod::PodId;
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform};
use crate::policy::Policy;
use crate::util::intern::ServiceId;
use crate::util::quantity::MilliCpu;

impl Platform {
    /// Records an arrival with the service's predictor (driver-managed
    /// policies only; a no-op for the §3 triple) and schedules the next
    /// speculation cycle. Called from the activator's `arrive` path, so
    /// the predictor sees exactly what the activator sees.
    pub(crate) fn forecast_observe(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let now = eng.now();
        let policy = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let Some(pred) = svc.predictor.as_mut() else { return };
            pred.predictor.observe(now);
            // Every arrival supersedes in-flight speculation events: a
            // pending re-park must not fire for a forecast that just hit.
            pred.generation += 1;
            svc.policy
        };
        if policy == Policy::PredictiveInPlace {
            Self::schedule_speculation(w, eng, svc_id);
        }
    }

    /// Schedules the pre-resize for the next predicted arrival: `horizon`
    /// ahead of the predicted time (clamped to now for gaps shorter than
    /// the horizon). No prediction ⇒ nothing scheduled.
    pub(crate) fn schedule_speculation(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let (gen, lead) = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let horizon = svc.cfg.forecast.horizon;
            let Some(pred) = svc.predictor.as_mut() else { return };
            let Some(gap) = pred.predictor.predict_gap() else { return };
            (pred.generation, gap.saturating_sub(horizon))
        };
        eng.schedule_in(
            lead,
            Event::Speculate {
                service: svc_id,
                generation: gen,
            },
        );
    }

    /// The speculative pre-resize: raise every idle parked pod to the
    /// serving allocation ahead of the forecast arrival, then arm the
    /// misprediction watchdog. Skipped when a newer arrival superseded
    /// this cycle or the rate window has gone quiet (stale histogram).
    pub(crate) fn speculative_resize(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, gen: u64) {
        let now = eng.now();
        let (serving, horizon, targets) = {
            let Some(svc) = w.services.get_mut(svc_id) else { return };
            let serving = svc.cfg.serving_cpu;
            let horizon = svc.cfg.forecast.horizon;
            let Some(pred) = svc.predictor.as_mut() else { return };
            if pred.generation != gen {
                return;
            }
            if !pred.predictor.active_at(now) {
                return;
            }
            let targets: Vec<(PodId, Option<MilliCpu>)> = svc
                .idle_ready_pods()
                .map(|p| (p.pod, p.desired_limit))
                .collect();
            (serving, horizon, targets)
        };
        let mut raised = false;
        for (pod, desired) in targets {
            let applied = w.applied_limit(pod).unwrap_or(MilliCpu::ZERO);
            // Below serving, or a park still in flight that would drop it
            // below serving right before the predicted arrival.
            if applied < serving || desired.is_some_and(|d| d < serving) {
                w.metrics.row_mut(svc_id).speculative_resizes += 1;
                Self::request_resize(w, eng, svc_id, pod, serving);
                raised = true;
            }
        }
        if raised {
            // The pre-resize fired `horizon` ahead of the predicted
            // arrival; 2×horizon later the speculation window
            // [predicted − horizon, predicted + horizon] has fully
            // passed. An arrival inside it bumps the generation and this
            // watchdog no-ops — that is the hit case.
            eng.schedule_in(
                horizon + horizon,
                Event::SpeculationRepark {
                    service: svc_id,
                    generation: gen,
                },
            );
        }
    }

    /// The misprediction watchdog: no arrival claimed the speculated pods
    /// within the horizon, so restore the §3 parked state (and the
    /// resource-availability advantage it buys).
    pub(crate) fn speculation_repark(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId, gen: u64) {
        let (parked, targets) = {
            let Some(svc) = w.services.get(svc_id) else { return };
            let Some(pred) = svc.predictor.as_ref() else { return };
            if pred.generation != gen {
                return; // an arrival landed inside the window — a hit
            }
            let parked = svc.cfg.parked_cpu;
            let targets: Vec<(PodId, Option<MilliCpu>)> = svc
                .idle_ready_pods()
                .map(|p| (p.pod, p.desired_limit))
                .collect();
            (parked, targets)
        };
        let mut missed = false;
        for (pod, desired) in targets {
            let applied = w.applied_limit(pod).unwrap_or(MilliCpu::ZERO);
            if applied > parked || desired.is_some_and(|d| d > parked) {
                Self::request_resize(w, eng, svc_id, pod, parked);
                missed = true;
            }
        }
        if missed {
            w.metrics.row_mut(svc_id).mispredictions += 1;
        }
    }

    /// Pooled: tops the idle warm pool back up to `pool_size`. Starting
    /// pods count toward the refill (they arrive idle), and total live
    /// pods stay within the revision's scale ceiling — an exhausted pool
    /// under saturation degrades to buffered requests exactly like warm.
    pub(crate) fn pool_refill(w: &mut Platform, eng: &mut Eng, svc_id: ServiceId) {
        let need = {
            let Some(svc) = w.services.get(svc_id) else { return };
            if svc.policy != Policy::Pooled {
                return;
            }
            let pool = svc.cfg.forecast.pool_size.max(1);
            let incoming = svc.idle_ready_pods().count() as u32 + svc.starting;
            let live = svc.ready_count + svc.starting;
            let cap = svc.cfg.max_scale.max(pool);
            pool.saturating_sub(incoming).min(cap.saturating_sub(live))
        };
        for _ in 0..need {
            Self::start_pod(w, eng, svc_id, false);
        }
    }
}
