//! Bounded inter-arrival histogram — the keep-alive-style predictor of
//! the pool/prediction cold-start literature: bucket the gaps between
//! consecutive arrivals and read next-arrival estimates off quantiles of
//! the counts. Fixed memory (`buckets + 1` counters), integer bucket
//! math, fully deterministic.

use crate::simclock::SimTime;

/// Histogram of observed inter-arrival gaps.
#[derive(Debug, Clone)]
pub struct InterArrivalHistogram {
    bucket: SimTime,
    /// `buckets` regular counters plus a trailing overflow counter for
    /// gaps at or beyond `bucket × buckets`.
    counts: Vec<u64>,
    total: u64,
}

impl InterArrivalHistogram {
    pub fn new(bucket: SimTime, buckets: usize) -> InterArrivalHistogram {
        InterArrivalHistogram {
            bucket: bucket.max(SimTime::from_nanos(1)),
            counts: vec![0; buckets.max(1) + 1],
            total: 0,
        }
    }

    /// Records one observed gap.
    pub fn record(&mut self, gap: SimTime) {
        let idx = (gap.as_nanos() / self.bucket.as_nanos()) as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Gaps recorded so far.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Gaps that landed in the overflow bucket.
    pub fn overflowed(&self) -> u64 {
        *self.counts.last().expect("counts is never empty")
    }

    /// Upper edge of the bucket holding quantile `q` of the recorded gaps
    /// (the conservative "no later than" estimate the driver wants).
    /// `None` when the histogram is empty or the quantile falls in the
    /// overflow bucket — gaps too long or too irregular to speculate on.
    pub fn quantile(&self, q: f64) -> Option<SimTime> {
        if self.total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                if i + 1 == self.counts.len() {
                    return None; // overflow bucket
                }
                return Some(SimTime::from_nanos(
                    self.bucket.as_nanos() * (i as u64 + 1),
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist() -> InterArrivalHistogram {
        InterArrivalHistogram::new(SimTime::from_secs(1), 8)
    }

    #[test]
    fn records_into_the_right_bucket() {
        let mut h = hist();
        h.record(SimTime::from_millis(300)); // bucket 0
        h.record(SimTime::from_millis(1500)); // bucket 1
        h.record(SimTime::from_secs(1)); // exactly on the edge → bucket 1
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.overflowed(), 0);
    }

    #[test]
    fn quantile_returns_upper_bucket_edge() {
        let mut h = hist();
        for _ in 0..3 {
            h.record(SimTime::from_millis(2500)); // bucket 2
        }
        h.record(SimTime::from_millis(7500)); // bucket 7
        // Median of {2.5, 2.5, 2.5, 7.5} s → bucket 2 → upper edge 3 s.
        assert_eq!(h.quantile(0.5), Some(SimTime::from_secs(3)));
        // The tail quantile reaches the long gap's bucket edge.
        assert_eq!(h.quantile(1.0), Some(SimTime::from_secs(8)));
    }

    #[test]
    fn empty_and_overflow_yield_none() {
        let mut h = hist();
        assert_eq!(h.quantile(0.5), None);
        // All gaps beyond the last regular bucket: never speculate.
        for _ in 0..5 {
            h.record(SimTime::from_secs(100));
        }
        assert_eq!(h.overflowed(), 5);
        assert_eq!(h.quantile(0.5), None);
        // A mixed stream whose median is regular still predicts.
        let mut h = hist();
        for _ in 0..3 {
            h.record(SimTime::from_millis(500));
        }
        h.record(SimTime::from_secs(100));
        assert_eq!(h.quantile(0.5), Some(SimTime::from_secs(1)));
    }

    #[test]
    fn deterministic_under_replay() {
        let gaps: Vec<SimTime> = (0..50)
            .map(|i| SimTime::from_millis(137 * (i % 13) + 20))
            .collect();
        let mut a = hist();
        let mut b = hist();
        for &g in &gaps {
            a.record(g);
            b.record(g);
            assert_eq!(a.quantile(0.5), b.quantile(0.5));
        }
        assert_eq!(a.counts, b.counts);
    }
}
