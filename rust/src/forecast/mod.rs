//! Forecast-driven proactive scaling — the predictor layer and driver
//! behind the `pooled` and `predictive-inplace` policies.
//!
//! The paper's §3 policy space is purely *reactive*: an in-place pod is
//! parked at 1 m CPU and resized only once a request is already waiting
//! at the queue-proxy. This subsystem adds the prediction-driven side of
//! the design space the related work argues for:
//!
//! * [`histogram`] — a bounded inter-arrival histogram (the keep-alive
//!   predictor shape of the pool/prediction literature, arXiv:1903.12221
//!   and arXiv:2308.11209): bucket the gaps between arrivals, read the
//!   next-arrival estimate off a quantile.
//! * [`window`] — a sliding-window arrival-rate estimator, doubling as
//!   the staleness bound (no speculation once the window has gone quiet).
//! * [`predictor`] — [`ArrivalPredictor`] combines the two;
//!   [`ServicePredictor`] attaches one to a service together with the
//!   speculation-generation bookkeeping the driver uses.
//! * [`driver`] — `impl Platform` hooks that consume forecasts and issue
//!   *driver-initiated* actions ahead of arrivals: warm-pool refills
//!   (`pooled`) and speculative pre-resizes with misprediction re-parks
//!   (`predictive-inplace`).
//!
//! Everything is deterministic and zero-dependency: predictions are pure
//! functions of the observed arrival stream, and the driver schedules at
//! most one speculation cycle per observed arrival, so idle services
//! schedule nothing and the event queue always drains.

pub mod driver;
pub mod histogram;
pub mod predictor;
pub mod window;

pub use histogram::InterArrivalHistogram;
pub use predictor::{ArrivalPredictor, ServicePredictor};
pub use window::RateWindow;

use crate::knative::config::RevisionConfig;
use crate::policy::Policy;
use crate::simclock::SimTime;

/// Knobs of the arrival predictor and the proactive driver — carried on
/// [`RevisionConfig`] and scenario-tunable (`forecast` spec section, the
/// `forecast_bucket_ms` / `forecast_horizon_ms` / `pool_size` sweep axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ForecastConfig {
    /// Inter-arrival histogram bucket width. Predictions round up to a
    /// bucket edge, so keep `horizon >= bucket` for the speculation
    /// window to cover the rounding.
    pub bucket: SimTime,
    /// Sliding window of the rate estimator — also the staleness bound:
    /// once the window has seen no arrivals, speculation stops.
    pub window: SimTime,
    /// Speculation horizon: pre-resize this far ahead of the predicted
    /// arrival, and re-park this far after it passes unmet.
    pub horizon: SimTime,
    /// Warm-pool target for the `pooled` policy.
    pub pool_size: u32,
}

impl Default for ForecastConfig {
    fn default() -> ForecastConfig {
        ForecastConfig {
            bucket: SimTime::from_millis(1000),
            window: SimTime::from_secs(60),
            horizon: SimTime::from_millis(2000),
            pool_size: 2,
        }
    }
}

impl ForecastConfig {
    /// Histogram buckets; gaps past `bucket × BUCKETS` land in the
    /// overflow bucket and are never speculated on.
    pub const BUCKETS: usize = 128;

    /// Layers these knobs over a policy's revision config — the forecast
    /// analogue of `ScaleKnobs::apply`. For the pooled policy the pool is
    /// the replica floor; the ceiling is raised only to the structural
    /// minimum (`max_scale >= min_scale`), never beyond the configured
    /// ceiling — a pool that wants more headroom than `max_scale` allows
    /// is a spec error (`ScenarioEngine` rejects it), not a silent
    /// override that would skew cross-policy comparisons.
    pub fn apply(&self, rc: &mut RevisionConfig, policy: Policy) {
        rc.forecast = *self;
        if policy == Policy::Pooled {
            let pool = self.pool_size.max(1);
            rc.min_scale = pool;
            rc.max_scale = rc.max_scale.max(pool);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_keep_horizon_covering_the_bucket() {
        let d = ForecastConfig::default();
        assert!(d.horizon >= d.bucket, "horizon must cover bucket rounding");
        assert!(d.window > d.horizon);
        assert_eq!(d.pool_size, 2);
    }

    #[test]
    fn apply_is_identity_for_reactive_policies() {
        // The §3 triple must stay bit-identical under a default apply.
        for policy in Policy::PAPER {
            let mut rc = policy.revision_config();
            let want = rc.clone();
            ForecastConfig::default().apply(&mut rc, policy);
            assert_eq!(rc, want, "{policy:?}");
        }
    }

    #[test]
    fn apply_feeds_pool_size_into_scale_bounds() {
        let mut rc = Policy::Pooled.revision_config();
        rc.max_scale = 4; // as the fleet knobs would set it
        let cfg = ForecastConfig {
            pool_size: 5,
            ..ForecastConfig::default()
        };
        cfg.apply(&mut rc, Policy::Pooled);
        assert_eq!(rc.min_scale, 5);
        // Raised only to the structural minimum (max >= min), never to a
        // silent headroom multiple — oversize pools are a spec error.
        assert_eq!(rc.max_scale, 5);
        assert_eq!(rc.forecast.pool_size, 5);

        // A generous max_scale is kept.
        let mut rc = Policy::Pooled.revision_config();
        rc.max_scale = 100;
        cfg.apply(&mut rc, Policy::Pooled);
        assert_eq!(rc.max_scale, 100);
    }
}
