//! [`ArrivalPredictor`] — the per-function composite predictor — and
//! [`ServicePredictor`], the bundle a [`Service`](crate::coordinator::Service)
//! carries when its policy is driver-managed.

use crate::forecast::{ForecastConfig, InterArrivalHistogram, RateWindow};
use crate::simclock::SimTime;

/// Composite arrival predictor: inter-arrival histogram (shape memory)
/// plus sliding-window rate estimator (liveness/heat). Deterministic: the
/// same observation stream always yields the same forecasts.
#[derive(Debug, Clone)]
pub struct ArrivalPredictor {
    hist: InterArrivalHistogram,
    window: RateWindow,
    last_arrival: Option<SimTime>,
}

impl ArrivalPredictor {
    pub fn new(cfg: &ForecastConfig) -> ArrivalPredictor {
        ArrivalPredictor {
            hist: InterArrivalHistogram::new(cfg.bucket, ForecastConfig::BUCKETS),
            window: RateWindow::new(cfg.window),
            last_arrival: None,
        }
    }

    /// Feeds one observed arrival (times are monotone simulation time).
    pub fn observe(&mut self, now: SimTime) {
        if let Some(prev) = self.last_arrival {
            self.hist.record(now.saturating_sub(prev));
        }
        self.window.record(now);
        self.last_arrival = Some(now);
    }

    /// Median-bucket estimate of the gap from the last arrival to the
    /// next. `None` without enough signal: fewer than two arrivals ever,
    /// or a median in the histogram's overflow bucket (gaps too long or
    /// too irregular to speculate on) — the graceful-degradation path.
    pub fn predict_gap(&self) -> Option<SimTime> {
        self.hist.quantile(0.5)
    }

    /// Arrivals per second over the sliding window ending at `now`.
    pub fn rate_per_sec(&mut self, now: SimTime) -> f64 {
        self.window.rate_per_sec(now)
    }

    /// Has the window seen any arrival at `now`? The driver's staleness
    /// guard: a cold histogram full of old gaps must not keep cycling
    /// speculative resizes after traffic dies.
    pub fn active_at(&mut self, now: SimTime) -> bool {
        self.window.active_at(now)
    }

    pub fn last_arrival(&self) -> Option<SimTime> {
        self.last_arrival
    }

    /// Inter-arrival gaps recorded so far.
    pub fn observations(&self) -> u64 {
        self.hist.total()
    }
}

/// Predictor plus the driver's speculation bookkeeping for one service.
#[derive(Debug, Clone)]
pub struct ServicePredictor {
    pub predictor: ArrivalPredictor,
    /// Bumped on every observed arrival. Scheduled speculation events
    /// carry the generation they were issued under and no-op when it has
    /// moved on — an arrival superseding a speculation *is* the hit case.
    pub generation: u64,
}

impl ServicePredictor {
    pub fn new(cfg: ForecastConfig) -> ServicePredictor {
        ServicePredictor {
            predictor: ArrivalPredictor::new(&cfg),
            generation: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred() -> ArrivalPredictor {
        ArrivalPredictor::new(&ForecastConfig::default())
    }

    #[test]
    fn needs_two_arrivals_before_predicting() {
        let mut p = pred();
        assert_eq!(p.predict_gap(), None);
        p.observe(SimTime::from_secs(10));
        assert_eq!(p.predict_gap(), None);
        p.observe(SimTime::from_secs(18));
        // One 8 s gap, 1 s buckets → upper edge 9 s.
        assert_eq!(p.predict_gap(), Some(SimTime::from_secs(9)));
        assert_eq!(p.observations(), 1);
        assert_eq!(p.last_arrival(), Some(SimTime::from_secs(18)));
    }

    #[test]
    fn periodic_stream_predicts_its_period() {
        let mut p = pred();
        for i in 0..20u64 {
            p.observe(SimTime::from_millis(10_000 * i + 30));
        }
        // 10 s gaps → bucket 10 → upper edge 11 s.
        assert_eq!(p.predict_gap(), Some(SimTime::from_secs(11)));
    }

    #[test]
    fn long_gaps_degrade_to_no_prediction() {
        // Gaps beyond bucket × BUCKETS (128 s at defaults) overflow.
        let mut p = pred();
        for i in 0..5u64 {
            p.observe(SimTime::from_secs(1000 * i));
        }
        assert_eq!(p.predict_gap(), None);
        assert!(!p.active_at(SimTime::from_secs(5000)));
    }

    #[test]
    fn staleness_guard_tracks_the_window() {
        let mut p = pred();
        p.observe(SimTime::from_secs(5));
        p.observe(SimTime::from_secs(10));
        assert!(p.active_at(SimTime::from_secs(30)));
        // Default window is 60 s; at t=71 the last arrival (t=10) is out.
        assert!(!p.active_at(SimTime::from_secs(71)));
        // But the histogram still predicts — the driver must consult both.
        assert!(p.predict_gap().is_some());
    }
}
