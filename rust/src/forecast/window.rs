//! Sliding-window arrival-rate estimator: the short-memory half of the
//! predictor. Where the histogram remembers the *shape* of the arrival
//! process, the window answers "is this function currently live, and how
//! hot is it right now" — the staleness guard that keeps the driver from
//! speculating off a histogram whose traffic died minutes ago.
//!
//! Counts are kept in a fixed ring of [`SLOTS`] sub-buckets covering the
//! window, so memory is O(1) no matter how hot the function or how long
//! the window — eviction happens at slot granularity (window/64), which
//! is plenty for a rate signal. Liveness (`active_at`) is exact: it reads
//! the last-arrival time, not the slotted counts.

use crate::simclock::SimTime;

/// Sub-buckets of the ring; eviction granularity is `window / SLOTS`.
pub const SLOTS: usize = 64;

/// Slotted arrival counter over a sliding window.
#[derive(Debug, Clone)]
pub struct RateWindow {
    window: SimTime,
    /// Width of one slot in nanoseconds (`window / SLOTS`, min 1).
    slot_ns: u64,
    counts: [u64; SLOTS],
    /// Absolute slot index the ring is advanced to.
    current: u64,
    /// Arrivals currently inside the ring.
    total: u64,
    last_arrival: Option<SimTime>,
}

impl RateWindow {
    pub fn new(window: SimTime) -> RateWindow {
        let window = window.max(SimTime::from_nanos(SLOTS as u64));
        RateWindow {
            window,
            slot_ns: (window.as_nanos() / SLOTS as u64).max(1),
            counts: [0; SLOTS],
            current: 0,
            total: 0,
            last_arrival: None,
        }
    }

    /// Rotates the ring forward to `now`, evicting slots that fell out of
    /// the window. Simulation time is monotone; a probe in the past is a
    /// no-op (the ring never rewinds).
    fn advance(&mut self, now: SimTime) {
        let idx = now.as_nanos() / self.slot_ns;
        if idx <= self.current {
            return;
        }
        let steps = (idx - self.current).min(SLOTS as u64);
        for k in 1..=steps {
            let s = ((self.current + k) % SLOTS as u64) as usize;
            self.total -= self.counts[s];
            self.counts[s] = 0;
        }
        self.current = idx;
    }

    /// Records one arrival. Arrival times are monotone (simulation time).
    pub fn record(&mut self, now: SimTime) {
        self.advance(now);
        self.counts[(self.current % SLOTS as u64) as usize] += 1;
        self.total += 1;
        self.last_arrival = Some(self.last_arrival.map_or(now, |p| p.max(now)));
    }

    /// Arrivals currently inside the (slot-granular) window ending at `now`.
    pub fn count_at(&mut self, now: SimTime) -> u64 {
        self.advance(now);
        self.total
    }

    /// Average arrivals per second over the window ending at `now`.
    pub fn rate_per_sec(&mut self, now: SimTime) -> f64 {
        self.count_at(now) as f64 / self.window.as_secs_f64().max(1e-9)
    }

    /// Did any arrival land within the window ending at `now`? Exact
    /// (last-arrival based), independent of slot granularity.
    pub fn active_at(&mut self, now: SimTime) -> bool {
        self.last_arrival
            .is_some_and(|t| t >= now.saturating_sub(self.window))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_counts_only_the_window() {
        // Window 10 s ⇒ slot width 156.25 ms exactly.
        let mut w = RateWindow::new(SimTime::from_secs(10));
        for s in 0..20 {
            w.record(SimTime::from_secs(s));
        }
        // At t=19 s the 64-slot ring reaches back to t≈9.06 s, so the
        // arrivals at 10..=19 s survive and 0..=9 s are evicted.
        assert_eq!(w.count_at(SimTime::from_secs(19)), 10);
        let r = w.rate_per_sec(SimTime::from_secs(19));
        assert!((r - 1.0).abs() < 1e-9, "rate={r}");
        // Far in the future everything decays to zero.
        assert_eq!(w.count_at(SimTime::from_secs(120)), 0);
    }

    #[test]
    fn goes_quiet_after_the_window_passes() {
        let mut w = RateWindow::new(SimTime::from_secs(5));
        w.record(SimTime::from_secs(1));
        assert!(w.active_at(SimTime::from_secs(4)));
        assert!(w.active_at(SimTime::from_secs(6))); // 1 s ≥ 6-5 s edge
        assert!(!w.active_at(SimTime::from_secs(7)));
        assert_eq!(w.rate_per_sec(SimTime::from_secs(100)), 0.0);
    }

    #[test]
    fn memory_is_constant_and_counts_stay_consistent() {
        // A day-long window with a hot stream: the ring is still 64
        // counters, and total equals the sum of the slots after any
        // probe sequence.
        let mut w = RateWindow::new(SimTime::from_secs(86_400));
        for i in 0..10_000u64 {
            w.record(SimTime::from_millis(i * 37));
        }
        let total = w.count_at(SimTime::from_millis(10_000 * 37));
        assert_eq!(total, 10_000, "nothing evicted inside the window");
        assert_eq!(w.counts.iter().sum::<u64>(), w.total);
    }

    #[test]
    fn deterministic_under_replay() {
        let times: Vec<SimTime> = (0..100)
            .map(|i| SimTime::from_millis(231 * i + (i * i) % 97))
            .collect();
        let mut a = RateWindow::new(SimTime::from_secs(7));
        let mut b = RateWindow::new(SimTime::from_secs(7));
        for &t in &times {
            a.record(t);
            b.record(t);
            assert_eq!(a.count_at(t), b.count_at(t));
        }
    }
}
