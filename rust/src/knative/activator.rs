//! The activator: when a revision is scaled to zero (or all pods are at
//! their concurrency limit), requests buffer here while a pod comes up.
//! First-in first-out, with capacity + timeout guards.

use std::collections::VecDeque;

use crate::simclock::SimTime;

/// Identifies a request across the platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// A buffered request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Buffered {
    pub request: RequestId,
    pub enqueued_at: SimTime,
}

#[derive(Debug, PartialEq)]
pub enum ActivatorError {
    Overflow,
}

impl std::fmt::Display for ActivatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ActivatorError::Overflow => write!(f, "activator buffer full"),
        }
    }
}

impl std::error::Error for ActivatorError {}

/// Per-revision activator buffer.
#[derive(Debug)]
pub struct Activator {
    queue: VecDeque<Buffered>,
    capacity: usize,
    /// Requests older than this are failed on drain (k8s ingress timeout).
    pub timeout: SimTime,
    /// Counters for metrics.
    pub total_buffered: u64,
    pub total_timed_out: u64,
}

impl Default for Activator {
    fn default() -> Self {
        Activator::new(4096, SimTime::from_secs(600))
    }
}

impl Activator {
    pub fn new(capacity: usize, timeout: SimTime) -> Activator {
        Activator {
            queue: VecDeque::new(),
            capacity,
            timeout,
            total_buffered: 0,
            total_timed_out: 0,
        }
    }

    /// Buffers a request while capacity scales up.
    pub fn buffer(&mut self, request: RequestId, now: SimTime) -> Result<(), ActivatorError> {
        if self.queue.len() >= self.capacity {
            return Err(ActivatorError::Overflow);
        }
        self.queue.push_back(Buffered {
            request,
            enqueued_at: now,
        });
        self.total_buffered += 1;
        Ok(())
    }

    /// Pops up to `n` requests for dispatch, dropping timed-out entries.
    /// Returns `(dispatchable, timed_out)`.
    pub fn drain(&mut self, n: usize, now: SimTime) -> (Vec<Buffered>, Vec<Buffered>) {
        let mut out = Vec::new();
        let mut dead = Vec::new();
        while out.len() < n {
            match self.queue.front() {
                Some(b) if now.saturating_sub(b.enqueued_at) > self.timeout => {
                    dead.push(self.queue.pop_front().unwrap());
                    self.total_timed_out += 1;
                }
                Some(_) => out.push(self.queue.pop_front().unwrap()),
                None => break,
            }
        }
        (out, dead)
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest buffered request.
    pub fn oldest_wait(&self, now: SimTime) -> SimTime {
        self.queue
            .front()
            .map(|b| now.saturating_sub(b.enqueued_at))
            .unwrap_or(SimTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut a = Activator::default();
        for i in 0..5 {
            a.buffer(RequestId(i), SimTime::from_millis(i)).unwrap();
        }
        let (out, dead) = a.drain(3, SimTime::from_millis(10));
        assert!(dead.is_empty());
        let ids: Vec<u64> = out.iter().map(|b| b.request.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn overflow_rejected() {
        let mut a = Activator::new(2, SimTime::from_secs(1));
        a.buffer(RequestId(0), SimTime::ZERO).unwrap();
        a.buffer(RequestId(1), SimTime::ZERO).unwrap();
        assert_eq!(
            a.buffer(RequestId(2), SimTime::ZERO),
            Err(ActivatorError::Overflow)
        );
    }

    #[test]
    fn timeouts_dropped_on_drain() {
        let mut a = Activator::new(10, SimTime::from_secs(1));
        a.buffer(RequestId(0), SimTime::ZERO).unwrap();
        a.buffer(RequestId(1), SimTime::from_secs(2)).unwrap();
        let (out, dead) = a.drain(10, SimTime::from_secs(2));
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].request, RequestId(0));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].request, RequestId(1));
        assert_eq!(a.total_timed_out, 1);
    }

    #[test]
    fn oldest_wait_tracks_head() {
        let mut a = Activator::default();
        assert_eq!(a.oldest_wait(SimTime::from_secs(5)), SimTime::ZERO);
        a.buffer(RequestId(0), SimTime::from_secs(1)).unwrap();
        assert_eq!(a.oldest_wait(SimTime::from_secs(5)), SimTime::from_secs(4));
    }
}
