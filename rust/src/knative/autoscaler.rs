//! The KPA (Knative Pod Autoscaler), concurrency mode.
//!
//! Tracks revision concurrency as a step function of virtual time, computes
//! the time-weighted average over the stable window (and a 6× shorter panic
//! window), and recommends a replica count:
//!
//! * desired = ceil(window_avg / target_concurrency), clamped to
//!   [min_scale, max_scale];
//! * panic mode (short-window avg ≥ 2× target × pods) freezes scale-down;
//! * scale-to-zero only after the stable window has seen zero concurrency.

use std::collections::VecDeque;

use crate::knative::config::RevisionConfig;
use crate::simclock::SimTime;

/// A recommendation from the autoscaler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDecision {
    pub desired: u32,
    /// True when the panic window is hot (scale-down frozen).
    pub panicking: bool,
}

/// Concurrency sample: value in force since `at`.
#[derive(Debug, Clone, Copy)]
struct Sample {
    at: SimTime,
    concurrency: u32,
}

/// Per-revision autoscaler state.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: RevisionConfig,
    /// Step-function history, oldest first. Always non-empty.
    history: VecDeque<Sample>,
    current: u32,
    /// Time of the last moment concurrency was non-zero.
    last_active: SimTime,
}

impl Autoscaler {
    pub fn new(cfg: RevisionConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            history: VecDeque::from([Sample {
                at: SimTime::ZERO,
                concurrency: 0,
            }]),
            current: 0,
            last_active: SimTime::ZERO,
        }
    }

    pub fn config(&self) -> &RevisionConfig {
        &self.cfg
    }

    /// Records a concurrency change (request started / finished).
    pub fn record(&mut self, now: SimTime, concurrency: u32) {
        if self.current > 0 {
            self.last_active = now;
        }
        self.current = concurrency;
        if concurrency > 0 {
            self.last_active = now;
        }
        self.history.push_back(Sample { at: now, concurrency });
        self.gc(now);
    }

    fn gc(&mut self, now: SimTime) {
        let horizon = now.saturating_sub(self.cfg.stable_window + SimTime::from_secs(1));
        while self.history.len() > 1 && self.history[1].at <= horizon {
            self.history.pop_front();
        }
    }

    /// Time-weighted average concurrency over `[now - window, now]`.
    pub fn window_average(&self, now: SimTime, window: SimTime) -> f64 {
        let start = now.saturating_sub(window);
        if now == start {
            return self.current as f64;
        }
        let mut acc = 0.0f64;
        // Walk samples; each sample holds from its `at` until the next.
        for (i, s) in self.history.iter().enumerate() {
            let seg_start = s.at.max(start);
            let seg_end = self
                .history
                .get(i + 1)
                .map(|n| n.at)
                .unwrap_or(now)
                .min(now);
            if seg_end > seg_start {
                acc += s.concurrency as f64 * (seg_end - seg_start).as_millis_f64();
            }
        }
        acc / (now - start).as_millis_f64()
    }

    /// The scaling recommendation at `now`, given current ready replicas.
    /// The panic window/threshold come from the revision config (scenario
    /// specs sweep them); the defaults reproduce Knative's `/6` and `2.0×`.
    pub fn decide(&self, now: SimTime, ready: u32) -> ScaleDecision {
        let stable_avg = self.window_average(now, self.cfg.stable_window);
        let divisor = u64::from(self.cfg.panic_window_divisor.max(1));
        let panic_window = SimTime::from_nanos(self.cfg.stable_window.as_nanos() / divisor);
        let panic_avg = self.window_average(now, panic_window.max(SimTime::from_secs(1)));

        let target = self.cfg.target_concurrency.max(0.01);
        let mut desired = (stable_avg / target).ceil() as u32;

        let panicking = ready > 0 && panic_avg >= self.cfg.panic_threshold * target * ready as f64;
        if panicking {
            // Panic: react to the short window, never scale down.
            desired = desired.max((panic_avg / target).ceil() as u32).max(ready);
        }

        // Scale-to-zero gate: only when the stable window saw no activity.
        if desired == 0 {
            let quiet_for = now.saturating_sub(self.last_active);
            if self.current > 0 || quiet_for < self.cfg.stable_window {
                desired = 1.min(ready.max(1));
            }
        }

        ScaleDecision {
            desired: desired.clamp(self.cfg.min_scale, self.cfg.max_scale.max(self.cfg.min_scale)),
            panicking,
        }
    }

    /// True when the revision has been idle long enough to scale to zero.
    pub fn idle_expired(&self, now: SimTime) -> bool {
        self.current == 0
            && now.saturating_sub(self.last_active)
                >= self.cfg.stable_window + self.cfg.scale_to_zero_grace
    }

    pub fn current_concurrency(&self) -> u32 {
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(min: u32, max: u32, window_s: u64, target: f64) -> RevisionConfig {
        RevisionConfig {
            min_scale: min,
            max_scale: max,
            stable_window: SimTime::from_secs(window_s),
            target_concurrency: target,
            ..RevisionConfig::default()
        }
    }

    #[test]
    fn window_average_step_function() {
        let mut a = Autoscaler::new(cfg(0, 10, 10, 1.0));
        a.record(SimTime::from_secs(0), 2);
        a.record(SimTime::from_secs(5), 4);
        // Over [0,10]: 5s at 2 + 5s at 4 = 3.0 average.
        let avg = a.window_average(SimTime::from_secs(10), SimTime::from_secs(10));
        assert!((avg - 3.0).abs() < 1e-9, "avg={avg}");
    }

    #[test]
    fn desired_scales_with_load() {
        let mut a = Autoscaler::new(cfg(0, 10, 10, 2.0));
        a.record(SimTime::from_secs(0), 8);
        let d = a.decide(SimTime::from_secs(10), 1);
        // avg 8 / target 2 = 4 pods.
        assert_eq!(d.desired, 4);
    }

    #[test]
    fn clamped_to_max_scale() {
        let mut a = Autoscaler::new(cfg(0, 3, 10, 1.0));
        a.record(SimTime::from_secs(0), 50);
        assert_eq!(a.decide(SimTime::from_secs(10), 1).desired, 3);
    }

    #[test]
    fn min_scale_keeps_warm_pod() {
        let a = Autoscaler::new(cfg(1, 10, 10, 1.0));
        // Never any traffic — min_scale=1 still demands a pod.
        assert_eq!(a.decide(SimTime::from_secs(100), 1).desired, 1);
    }

    #[test]
    fn scale_to_zero_needs_quiet_stable_window() {
        let mut a = Autoscaler::new(cfg(0, 10, 6, 1.0));
        a.record(SimTime::from_secs(0), 1);
        a.record(SimTime::from_secs(2), 0);
        // At t=4: only 2s quiet — not yet.
        assert!(!a.idle_expired(SimTime::from_secs(4)));
        assert_eq!(a.decide(SimTime::from_secs(4), 1).desired, 1);
        // At t=9: 7s ≥ 6s window — scale to zero allowed.
        assert!(a.idle_expired(SimTime::from_secs(9)));
    }

    #[test]
    fn panic_mode_freezes_scale_down() {
        let mut a = Autoscaler::new(cfg(0, 10, 60, 1.0));
        // Long quiet history then a sudden heavy burst filling the panic
        // window (stable_window/6 = 10 s).
        a.record(SimTime::from_secs(0), 0);
        a.record(SimTime::from_secs(51), 100);
        let d = a.decide(SimTime::from_secs(60), 4);
        assert!(d.panicking);
        assert!(d.desired >= 4, "panic must not scale down, got {}", d.desired);
    }

    #[test]
    fn panic_knobs_are_configurable() {
        // Same burst as `panic_mode_freezes_scale_down`, but with the panic
        // threshold raised far above the observed short-window average the
        // autoscaler must stay calm — the knob, not a constant, decides.
        let mut calm_cfg = cfg(0, 10, 60, 1.0);
        calm_cfg.panic_threshold = 1000.0;
        let mut a = Autoscaler::new(calm_cfg);
        a.record(SimTime::from_secs(0), 0);
        a.record(SimTime::from_secs(51), 100);
        assert!(!a.decide(SimTime::from_secs(60), 4).panicking);

        // At 10 ready pods the 10 s panic window (divisor 6) still sees the
        // burst (avg 90 ≥ 2×1×10), but a divisor of 1 widens the window to
        // the whole stable window where 51 s of quiet dilutes it to 15 < 20.
        let narrow = cfg(0, 16, 60, 1.0);
        let mut a = Autoscaler::new(narrow);
        a.record(SimTime::from_secs(0), 0);
        a.record(SimTime::from_secs(51), 100);
        assert!(a.decide(SimTime::from_secs(60), 10).panicking);

        let mut wide_cfg = cfg(0, 16, 60, 1.0);
        wide_cfg.panic_window_divisor = 1;
        let mut b = Autoscaler::new(wide_cfg);
        b.record(SimTime::from_secs(0), 0);
        b.record(SimTime::from_secs(51), 100);
        assert!(!b.decide(SimTime::from_secs(60), 10).panicking);
    }

    #[test]
    fn history_gc_keeps_window_accurate() {
        let mut a = Autoscaler::new(cfg(0, 10, 5, 1.0));
        for s in 0..100 {
            a.record(SimTime::from_secs(s), (s % 3) as u32);
        }
        // History bounded (window 5s + 1s slack → ≲ 8 samples retained).
        assert!(a.history.len() < 10, "len={}", a.history.len());
        let avg = a.window_average(SimTime::from_secs(100), SimTime::from_secs(5));
        assert!(avg > 0.0 && avg < 3.0);
    }
}
