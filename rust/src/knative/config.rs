//! Revision-level autoscaling configuration — the knobs the paper tunes to
//! express its three policies (§4.2):
//!
//! * **Cold**: `stable_window = 6 s` (Knative's minimum; default is 30 s),
//!   `min_scale = 0` → the revision scales to zero between bursts and every
//!   fresh request pays a cold start.
//! * **Warm**: `min_scale = 1` → one pod always ready.
//! * **In-place**: `min_scale = 1` *but* the pod parks at 1 m CPU between
//!   requests; the queue-proxy hooks resize it around each request.

use crate::forecast::ForecastConfig;
use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;

/// Autoscaling + serving configuration for one revision.
#[derive(Debug, Clone, PartialEq)]
pub struct RevisionConfig {
    /// Minimum replicas (0 allows scale-to-zero).
    pub min_scale: u32,
    /// Maximum replicas.
    pub max_scale: u32,
    /// Window with no traffic after which a pod may be scaled to zero.
    pub stable_window: SimTime,
    /// Extra grace after the window before the pod is actually deleted.
    pub scale_to_zero_grace: SimTime,
    /// Hard cap on in-flight requests per pod (0 = unlimited).
    pub container_concurrency: u32,
    /// Soft target concurrency per pod the KPA aims for.
    pub target_concurrency: f64,
    /// Panic window = stable window / this divisor (Knative's
    /// panic-window-percentage, expressed as an exact integer divisor so the
    /// seeded reproduction never depends on float rounding; 6 ≈ 16.7%).
    pub panic_window_divisor: u32,
    /// Panic entry threshold: panic when the short-window average reaches
    /// `threshold × target × ready` (Knative's 200% default ⇒ 2.0).
    pub panic_threshold: f64,
    /// Serving CPU limit for the function container.
    pub serving_cpu: MilliCpu,
    /// Parked CPU limit between requests (in-place policy only).
    pub parked_cpu: MilliCpu,
    /// Arrival-predictor and proactive-driver knobs (the forecast-driven
    /// policies only; inert for the §3 triple).
    pub forecast: ForecastConfig,
}

impl Default for RevisionConfig {
    fn default() -> Self {
        RevisionConfig {
            min_scale: 0,
            max_scale: 1,
            // Knative default stable window.
            stable_window: SimTime::from_secs(30),
            scale_to_zero_grace: SimTime::from_secs(0),
            container_concurrency: 0,
            target_concurrency: 10.0,
            panic_window_divisor: 6,
            panic_threshold: 2.0,
            serving_cpu: MilliCpu::ONE_CPU,
            parked_cpu: MilliCpu::PARKED,
            forecast: ForecastConfig::default(),
        }
    }
}

impl RevisionConfig {
    /// The paper's cold configuration: 6 s stable window, scale-to-zero.
    pub fn paper_cold() -> RevisionConfig {
        RevisionConfig {
            min_scale: 0,
            stable_window: SimTime::from_secs(6),
            ..RevisionConfig::default()
        }
    }

    /// The paper's warm configuration: `min-scale: 1`.
    pub fn paper_warm() -> RevisionConfig {
        RevisionConfig {
            min_scale: 1,
            ..RevisionConfig::default()
        }
    }

    /// The paper's in-place configuration: one pod kept, parked at 1 m,
    /// resized to 1000 m per request.
    pub fn paper_inplace() -> RevisionConfig {
        RevisionConfig {
            min_scale: 1,
            serving_cpu: MilliCpu::ONE_CPU,
            parked_cpu: MilliCpu::PARKED,
            ..RevisionConfig::default()
        }
    }

    /// The pooled policy (arXiv:1903.12221): a warm pool of `pool_size`
    /// pods at the full serving allocation. The pool is the replica floor
    /// (pre-created at deploy), the ceiling leaves a pool's worth of
    /// serving headroom, and the proactive driver refills consumed pods /
    /// trims the excess after the stable window.
    pub fn pooled() -> RevisionConfig {
        let forecast = ForecastConfig::default();
        let pool = forecast.pool_size.max(1);
        RevisionConfig {
            min_scale: pool,
            max_scale: pool.saturating_mul(2),
            forecast,
            ..RevisionConfig::default()
        }
    }

    /// The predictive in-place policy: the paper's in-place parking (one
    /// pod, 1 m parked, queue-proxy hooks) plus speculative pre-resizes
    /// driven by the arrival predictor.
    pub fn predictive_inplace() -> RevisionConfig {
        RevisionConfig {
            min_scale: 1,
            ..RevisionConfig::default()
        }
    }

    /// Effective per-pod concurrency limit (`u32::MAX` when unlimited).
    pub fn concurrency_limit(&self) -> u32 {
        if self.container_concurrency == 0 {
            u32::MAX
        } else {
            self.container_concurrency
        }
    }
}

/// The autoscaler knobs a scenario may tune per run — the multi-tenant
/// overrides the fleet/trace harnesses used to hardwire. `apply` layers
/// them over a policy's [`RevisionConfig`]: `None` fields keep the
/// policy's own default (the cold policy's 6 s stable window must survive
/// a spec that doesn't mention windows).
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleKnobs {
    /// Horizontal headroom per tenant.
    pub max_scale: u32,
    /// KPA soft target concurrency per pod.
    pub target_concurrency: f64,
    /// Hard per-pod in-flight cap (0 = unlimited).
    pub container_concurrency: u32,
    /// Stable-window override (`None` ⇒ keep the policy default).
    pub stable_window: Option<SimTime>,
    /// Panic window divisor (stable window / divisor).
    pub panic_window_divisor: u32,
    /// Panic entry threshold (× target × ready).
    pub panic_threshold: f64,
    /// Parked CPU override for the in-place policy (`None` ⇒ 1 m).
    pub parked_cpu: Option<MilliCpu>,
}

impl ScaleKnobs {
    /// The knobs `kinetic fleet` always ran with before they were
    /// configurable — the bit-identical baseline for the fleet preset.
    pub fn fleet_default() -> ScaleKnobs {
        ScaleKnobs {
            max_scale: 4,
            target_concurrency: 2.0,
            container_concurrency: 4,
            stable_window: None,
            panic_window_divisor: 6,
            panic_threshold: 2.0,
            parked_cpu: None,
        }
    }

    /// The knobs `kinetic trace` always ran with (per-pod concurrency 2).
    pub fn trace_default() -> ScaleKnobs {
        ScaleKnobs {
            container_concurrency: 2,
            ..ScaleKnobs::fleet_default()
        }
    }

    /// Layers these knobs over a policy's revision config.
    pub fn apply(&self, rc: &mut RevisionConfig) {
        rc.max_scale = self.max_scale;
        rc.target_concurrency = self.target_concurrency;
        rc.container_concurrency = self.container_concurrency;
        rc.panic_window_divisor = self.panic_window_divisor;
        rc.panic_threshold = self.panic_threshold;
        if let Some(w) = self.stable_window {
            rc.stable_window = w;
        }
        if let Some(p) = self.parked_cpu {
            rc.parked_cpu = p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs() {
        let cold = RevisionConfig::paper_cold();
        assert_eq!(cold.min_scale, 0);
        assert_eq!(cold.stable_window, SimTime::from_secs(6));

        let warm = RevisionConfig::paper_warm();
        assert_eq!(warm.min_scale, 1);

        let inp = RevisionConfig::paper_inplace();
        assert_eq!(inp.parked_cpu, MilliCpu(1));
        assert_eq!(inp.serving_cpu, MilliCpu(1000));
    }

    #[test]
    fn concurrency_limit_zero_means_unlimited() {
        let mut c = RevisionConfig::default();
        assert_eq!(c.concurrency_limit(), u32::MAX);
        c.container_concurrency = 4;
        assert_eq!(c.concurrency_limit(), 4);
    }

    #[test]
    fn fleet_knobs_reproduce_the_old_hardwired_config() {
        // The fleet harness used to set exactly these three fields on top
        // of the policy config; everything else must stay policy-default.
        for policy_cfg in [
            RevisionConfig::paper_cold(),
            RevisionConfig::paper_warm(),
            RevisionConfig::paper_inplace(),
        ] {
            let mut got = policy_cfg.clone();
            ScaleKnobs::fleet_default().apply(&mut got);
            let mut want = policy_cfg.clone();
            want.max_scale = 4;
            want.target_concurrency = 2.0;
            want.container_concurrency = 4;
            assert_eq!(got, want);
        }
        let mut trace = RevisionConfig::paper_cold();
        ScaleKnobs::trace_default().apply(&mut trace);
        assert_eq!(trace.container_concurrency, 2);
        // The cold policy's 6 s window survives knobs that don't set one.
        assert_eq!(trace.stable_window, SimTime::from_secs(6));
    }

    #[test]
    fn knob_overrides_land() {
        let mut rc = RevisionConfig::paper_inplace();
        let knobs = ScaleKnobs {
            max_scale: 8,
            target_concurrency: 1.0,
            container_concurrency: 1,
            stable_window: Some(SimTime::from_secs(60)),
            panic_window_divisor: 10,
            panic_threshold: 3.0,
            parked_cpu: Some(MilliCpu(250)),
        };
        knobs.apply(&mut rc);
        assert_eq!(rc.max_scale, 8);
        assert_eq!(rc.stable_window, SimTime::from_secs(60));
        assert_eq!(rc.panic_window_divisor, 10);
        assert_eq!(rc.parked_cpu, MilliCpu(250));
    }
}
