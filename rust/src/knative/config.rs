//! Revision-level autoscaling configuration — the knobs the paper tunes to
//! express its three policies (§4.2):
//!
//! * **Cold**: `stable_window = 6 s` (Knative's minimum; default is 30 s),
//!   `min_scale = 0` → the revision scales to zero between bursts and every
//!   fresh request pays a cold start.
//! * **Warm**: `min_scale = 1` → one pod always ready.
//! * **In-place**: `min_scale = 1` *but* the pod parks at 1 m CPU between
//!   requests; the queue-proxy hooks resize it around each request.

use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;

/// Autoscaling + serving configuration for one revision.
#[derive(Debug, Clone, PartialEq)]
pub struct RevisionConfig {
    /// Minimum replicas (0 allows scale-to-zero).
    pub min_scale: u32,
    /// Maximum replicas.
    pub max_scale: u32,
    /// Window with no traffic after which a pod may be scaled to zero.
    pub stable_window: SimTime,
    /// Extra grace after the window before the pod is actually deleted.
    pub scale_to_zero_grace: SimTime,
    /// Hard cap on in-flight requests per pod (0 = unlimited).
    pub container_concurrency: u32,
    /// Soft target concurrency per pod the KPA aims for.
    pub target_concurrency: f64,
    /// Serving CPU limit for the function container.
    pub serving_cpu: MilliCpu,
    /// Parked CPU limit between requests (in-place policy only).
    pub parked_cpu: MilliCpu,
}

impl Default for RevisionConfig {
    fn default() -> Self {
        RevisionConfig {
            min_scale: 0,
            max_scale: 1,
            // Knative default stable window.
            stable_window: SimTime::from_secs(30),
            scale_to_zero_grace: SimTime::from_secs(0),
            container_concurrency: 0,
            target_concurrency: 10.0,
            serving_cpu: MilliCpu::ONE_CPU,
            parked_cpu: MilliCpu::PARKED,
        }
    }
}

impl RevisionConfig {
    /// The paper's cold configuration: 6 s stable window, scale-to-zero.
    pub fn paper_cold() -> RevisionConfig {
        RevisionConfig {
            min_scale: 0,
            stable_window: SimTime::from_secs(6),
            ..RevisionConfig::default()
        }
    }

    /// The paper's warm configuration: `min-scale: 1`.
    pub fn paper_warm() -> RevisionConfig {
        RevisionConfig {
            min_scale: 1,
            ..RevisionConfig::default()
        }
    }

    /// The paper's in-place configuration: one pod kept, parked at 1 m,
    /// resized to 1000 m per request.
    pub fn paper_inplace() -> RevisionConfig {
        RevisionConfig {
            min_scale: 1,
            serving_cpu: MilliCpu::ONE_CPU,
            parked_cpu: MilliCpu::PARKED,
            ..RevisionConfig::default()
        }
    }

    /// Effective per-pod concurrency limit (`u32::MAX` when unlimited).
    pub fn concurrency_limit(&self) -> u32 {
        if self.container_concurrency == 0 {
            u32::MAX
        } else {
            self.container_concurrency
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs() {
        let cold = RevisionConfig::paper_cold();
        assert_eq!(cold.min_scale, 0);
        assert_eq!(cold.stable_window, SimTime::from_secs(6));

        let warm = RevisionConfig::paper_warm();
        assert_eq!(warm.min_scale, 1);

        let inp = RevisionConfig::paper_inplace();
        assert_eq!(inp.parked_cpu, MilliCpu(1));
        assert_eq!(inp.serving_cpu, MilliCpu(1000));
    }

    #[test]
    fn concurrency_limit_zero_means_unlimited() {
        let mut c = RevisionConfig::default();
        assert_eq!(c.concurrency_limit(), u32::MAX);
        c.container_concurrency = 4;
        assert_eq!(c.concurrency_limit(), 4);
    }
}
