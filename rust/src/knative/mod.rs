//! The Knative-shaped serving layer: revision configuration, the KPA
//! (Knative Pod Autoscaler), the activator (scale-from-zero request
//! buffering) and the queue-proxy sidecar — including the paper's §4.2
//! modification: resize hooks before and after each request.

pub mod activator;
pub mod autoscaler;
pub mod config;
pub mod queue_proxy;

pub use activator::Activator;
pub use autoscaler::{Autoscaler, ScaleDecision};
pub use config::RevisionConfig;
pub use queue_proxy::{ProxyParams, QueueProxy};
