//! The queue-proxy sidecar: per-pod request breaker (concurrency limit +
//! FIFO queue) and proxy-hop overheads — plus the paper's modification, a
//! pair of resize hooks:
//!
//! > "we modified the queue-proxy in Knative ... adding a layer before the
//! >  queue-proxy redirects the request, to allocate (1000m CPU in this
//! >  study), and another layer after the request has been processed to
//! >  deallocate (1m CPU in this study)."
//!
//! The hooks themselves only *dispatch* the patch (the request is redirected
//! immediately afterwards — the paper's design); the resize's propagation
//! latency is the kubelet/cgroup path measured in §4.1.

use std::collections::VecDeque;

use crate::knative::activator::RequestId;
use crate::simclock::SimTime;
use crate::util::rng::Rng;

/// Proxy-hop latency parameters (milliseconds).
#[derive(Debug, Clone)]
pub struct ProxyParams {
    /// Ingress + queue-proxy forwarding cost per request (one way).
    pub forward_ms: f64,
    /// Response path cost.
    pub respond_ms: f64,
    /// Cost of dispatching a resize patch from the hook (API round-trip
    /// initiation; the hook does not wait for the resize to land).
    pub hook_dispatch_ms: f64,
    /// Relative jitter.
    pub jitter_cv: f64,
}

impl Default for ProxyParams {
    fn default() -> Self {
        ProxyParams {
            // Calibrated against Table 3's warm/default helloworld ratio:
            // 3.87 × 5.31 ms ≈ 20.5 ms ⇒ ~15 ms of proxy path around the
            // 5.31 ms function time.
            forward_ms: 9.0,
            respond_ms: 5.5,
            hook_dispatch_ms: 2.2,
            jitter_cv: 0.18,
        }
    }
}

impl ProxyParams {
    pub fn sample_forward(&self, rng: &mut Rng) -> SimTime {
        SimTime::from_millis_f64(rng.lognormal_mean_std(
            self.forward_ms,
            self.forward_ms * self.jitter_cv,
        ))
    }

    pub fn sample_respond(&self, rng: &mut Rng) -> SimTime {
        SimTime::from_millis_f64(rng.lognormal_mean_std(
            self.respond_ms,
            self.respond_ms * self.jitter_cv,
        ))
    }

    pub fn sample_hook(&self, rng: &mut Rng) -> SimTime {
        SimTime::from_millis_f64(rng.lognormal_mean_std(
            self.hook_dispatch_ms,
            self.hook_dispatch_ms * self.jitter_cv,
        ))
    }
}

/// Per-pod breaker state.
#[derive(Debug)]
pub struct QueueProxy {
    /// In-flight requests currently inside the function container.
    active: Vec<RequestId>,
    /// Waiting for a concurrency slot.
    queue: VecDeque<RequestId>,
    limit: u32,
    /// Whether the in-place hooks are installed (the paper's modification).
    pub inplace_hooks: bool,
}

impl QueueProxy {
    pub fn new(concurrency_limit: u32, inplace_hooks: bool) -> QueueProxy {
        QueueProxy {
            active: Vec::new(),
            queue: VecDeque::new(),
            limit: concurrency_limit.max(1),
            inplace_hooks,
        }
    }

    /// Offers a request. Returns true when it may enter the container now,
    /// false when it was queued behind the concurrency limit.
    pub fn offer(&mut self, req: RequestId) -> bool {
        if (self.active.len() as u32) < self.limit {
            self.active.push(req);
            true
        } else {
            self.queue.push_back(req);
            false
        }
    }

    /// Marks a request complete; returns the next queued request that may
    /// now enter, if any.
    pub fn complete(&mut self, req: RequestId) -> Option<RequestId> {
        if let Some(idx) = self.active.iter().position(|r| *r == req) {
            self.active.swap_remove(idx);
        }
        if (self.active.len() as u32) < self.limit {
            if let Some(next) = self.queue.pop_front() {
                self.active.push(next);
                return Some(next);
            }
        }
        None
    }

    /// Removes a request wherever it is (client disconnect / pod death).
    pub fn evict(&mut self, req: RequestId) {
        self.active.retain(|r| *r != req);
        self.queue.retain(|r| *r != req);
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn queued_count(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.active.len() + self.queue.len()
    }

    pub fn active_requests(&self) -> &[RequestId] {
        &self.active
    }

    /// Every request the proxy holds — active first, then queued, in
    /// admission order. Pod-death paths (node crash eviction) use this to
    /// fail or re-buffer the full resident set deterministically.
    pub fn all_requests(&self) -> Vec<RequestId> {
        self.active.iter().chain(self.queue.iter()).copied().collect()
    }

    /// True when the pod is idle (hook layer decides to scale down).
    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_admits_up_to_limit() {
        let mut q = QueueProxy::new(2, false);
        assert!(q.offer(RequestId(1)));
        assert!(q.offer(RequestId(2)));
        assert!(!q.offer(RequestId(3)));
        assert_eq!(q.active_count(), 2);
        assert_eq!(q.queued_count(), 1);
    }

    #[test]
    fn completion_promotes_queued() {
        let mut q = QueueProxy::new(1, false);
        q.offer(RequestId(1));
        q.offer(RequestId(2));
        let next = q.complete(RequestId(1));
        assert_eq!(next, Some(RequestId(2)));
        assert_eq!(q.active_count(), 1);
        assert!(q.queued_count() == 0);
        assert_eq!(q.complete(RequestId(2)), None);
        assert!(q.idle());
    }

    #[test]
    fn all_requests_lists_active_then_queued() {
        let mut q = QueueProxy::new(2, false);
        q.offer(RequestId(1));
        q.offer(RequestId(2));
        q.offer(RequestId(3)); // queued behind the limit
        assert_eq!(
            q.all_requests(),
            vec![RequestId(1), RequestId(2), RequestId(3)]
        );
        assert!(QueueProxy::new(1, false).all_requests().is_empty());
    }

    #[test]
    fn evict_removes_from_both_places() {
        let mut q = QueueProxy::new(1, false);
        q.offer(RequestId(1));
        q.offer(RequestId(2));
        q.evict(RequestId(2));
        assert_eq!(q.queued_count(), 0);
        q.evict(RequestId(1));
        assert!(q.idle());
    }

    #[test]
    fn proxy_params_sample_positive_and_deterministic() {
        let p = ProxyParams::default();
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        let x = p.sample_forward(&mut a);
        let y = p.sample_forward(&mut b);
        assert_eq!(x, y);
        assert!(x.as_millis_f64() > 0.0);
        // Warm-path total proxy cost lands near the Table-3 calibration.
        let mut rng = Rng::new(11);
        let n = 10_000;
        let mean: f64 = (0..n)
            .map(|_| (p.sample_forward(&mut rng) + p.sample_respond(&mut rng)).as_millis_f64())
            .sum::<f64>()
            / n as f64;
        assert!((13.0..17.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn unlimited_concurrency_variant() {
        let mut q = QueueProxy::new(u32::MAX, true);
        for i in 0..100 {
            assert!(q.offer(RequestId(i)));
        }
        assert_eq!(q.active_count(), 100);
        assert!(q.inplace_hooks);
    }
}
