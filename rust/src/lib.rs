//! # kinetic
//!
//! A three-layer (Rust + JAX + Pallas) reproduction of *"Towards Serverless
//! Optimization with In-place Scaling"* (Hsieh & Chou, 2023): a serverless
//! platform with Kubernetes-1.27-style **in-place pod vertical scaling**
//! integrated as a first-class scheduling policy, plus every substrate the
//! paper's evaluation depends on (cluster, cgroups/CFS, Knative-style
//! autoscaling, load generation) built from scratch as a deterministic
//! discrete-event simulation with a real PJRT compute path.
//!
//! Start from [`coordinator::Platform`] for the public API, or run
//! `cargo run -- exp all` to regenerate every table and figure in the paper.

pub mod simclock;
pub mod util;

pub mod analysis;
pub mod apiserver;
pub mod cgroup;
pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod forecast;
pub mod knative;
pub mod loadgen;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod scenario;
pub mod shard;
pub mod trace;
pub mod workload;
