//! Arrival processes for open-loop load generation.

use crate::simclock::SimTime;
use crate::util::rng::Rng;

/// An arrival process generating inter-arrival gaps.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Fixed rate: one request every `period`.
    Constant { period: SimTime },
    /// Poisson process with `rate_per_sec` mean arrivals per second.
    Poisson { rate_per_sec: f64 },
    /// On/off bursts: `burst_n` back-to-back requests every `period`.
    Bursty { period: SimTime, burst_n: u32 },
}

impl Arrival {
    /// Generates all arrival times in `[0, horizon)`.
    pub fn times(&self, horizon: SimTime, rng: &mut Rng) -> Vec<SimTime> {
        let mut out = Vec::new();
        match self {
            Arrival::Constant { period } => {
                assert!(period.as_nanos() > 0);
                let mut t = SimTime::ZERO;
                while t < horizon {
                    out.push(t);
                    t += *period;
                }
            }
            Arrival::Poisson { rate_per_sec } => {
                assert!(*rate_per_sec > 0.0);
                let mut t = 0.0f64;
                let horizon_s = horizon.as_secs_f64();
                loop {
                    t += rng.exponential(*rate_per_sec);
                    if t >= horizon_s {
                        break;
                    }
                    out.push(SimTime::from_secs_f64(t));
                }
            }
            Arrival::Bursty { period, burst_n } => {
                let mut t = SimTime::ZERO;
                while t < horizon {
                    for i in 0..*burst_n {
                        // Spread the burst over a millisecond so ordering
                        // stays deterministic but near-simultaneous.
                        out.push(t + SimTime::from_micros(i as u64 * 50));
                    }
                    t += *period;
                }
            }
        }
        out
    }

    /// Mean rate in requests/second (for reports).
    pub fn mean_rate(&self) -> f64 {
        match self {
            Arrival::Constant { period } => 1.0 / period.as_secs_f64(),
            Arrival::Poisson { rate_per_sec } => *rate_per_sec,
            Arrival::Bursty { period, burst_n } => *burst_n as f64 / period.as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_arrivals_evenly_spaced() {
        let mut rng = Rng::new(1);
        let ts = Arrival::Constant {
            period: SimTime::from_secs(2),
        }
        .times(SimTime::from_secs(10), &mut rng);
        assert_eq!(ts.len(), 5);
        assert_eq!(ts[1] - ts[0], SimTime::from_secs(2));
    }

    #[test]
    fn poisson_rate_approximately_respected() {
        let mut rng = Rng::new(2);
        let ts = Arrival::Poisson { rate_per_sec: 50.0 }
            .times(SimTime::from_secs(100), &mut rng);
        let n = ts.len() as f64;
        assert!((n - 5000.0).abs() < 300.0, "n={n}");
        // Sorted and within horizon.
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        assert!(ts.iter().all(|t| *t < SimTime::from_secs(100)));
    }

    #[test]
    fn bursts_cluster() {
        let mut rng = Rng::new(3);
        let ts = Arrival::Bursty {
            period: SimTime::from_secs(5),
            burst_n: 4,
        }
        .times(SimTime::from_secs(10), &mut rng);
        assert_eq!(ts.len(), 8);
        // First four within a millisecond of each other.
        assert!((ts[3] - ts[0]).as_millis_f64() < 1.0);
        // Gap to the next burst ≈ 5 s.
        assert!((ts[4] - ts[0]).as_secs_f64() > 4.9);
    }

    #[test]
    fn mean_rates() {
        assert_eq!(
            Arrival::Constant {
                period: SimTime::from_millis(100)
            }
            .mean_rate(),
            10.0
        );
        assert_eq!(Arrival::Poisson { rate_per_sec: 7.5 }.mean_rate(), 7.5);
        assert_eq!(
            Arrival::Bursty {
                period: SimTime::from_secs(2),
                burst_n: 6
            }
            .mean_rate(),
            3.0
        );
    }
}
