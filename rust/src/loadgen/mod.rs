//! k6-style load generation.
//!
//! The paper drives its §4.2 experiments with k6. This module reproduces the
//! two k6 execution models on the virtual clock:
//!
//! * **closed-loop VUs** ([`Scenario::closed`]) — N virtual users each
//!   issuing `iterations` sequential requests with optional think-time
//!   (`sleep` between iterations). The cold-policy scenario uses a
//!   think-time longer than the 6 s stable window so every request pays a
//!   cold start, mirroring §3's description of when the cold path applies.
//! * **open-loop arrivals** ([`Scenario::open`]) — Poisson or
//!   constant-rate arrivals, used by the trace replayer.

pub mod arrival;
pub mod runner;

pub use arrival::Arrival;
pub use runner::{LoadReport, Runner, Scenario};
