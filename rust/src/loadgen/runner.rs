//! Scenario runner: drives a [`Simulation`] with k6-style load and reports
//! latency statistics.

use crate::coordinator::event::Event;
use crate::coordinator::platform::{Eng, Platform, Simulation};
use crate::coordinator::request::Continuation;
use crate::loadgen::arrival::Arrival;
use crate::simclock::SimTime;
use crate::util::intern::ServiceId;

/// A load scenario against one service.
#[derive(Debug, Clone)]
pub enum Scenario {
    /// `vus` virtual users, each issuing `iterations` sequential requests
    /// with `think` sleep between them (k6 closed-loop executor).
    Closed {
        vus: u32,
        iterations: u32,
        think: SimTime,
    },
    /// Open-loop arrivals over `horizon`.
    Open { arrival: Arrival, horizon: SimTime },
}

impl Scenario {
    /// k6 defaults-ish: a handful of VUs, no think time.
    pub fn closed(vus: u32, iterations: u32) -> Scenario {
        Scenario::Closed {
            vus,
            iterations,
            think: SimTime::ZERO,
        }
    }

    pub fn closed_with_think(vus: u32, iterations: u32, think: SimTime) -> Scenario {
        Scenario::Closed {
            vus,
            iterations,
            think,
        }
    }

    pub fn total_requests(&self, rng_preview: Option<&mut crate::util::rng::Rng>) -> u64 {
        match self {
            Scenario::Closed { vus, iterations, .. } => *vus as u64 * *iterations as u64,
            Scenario::Open { arrival, horizon } => match rng_preview {
                Some(rng) => arrival.times(*horizon, rng).len() as u64,
                None => (arrival.mean_rate() * horizon.as_secs_f64()) as u64,
            },
        }
    }
}

/// Results of a scenario run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub service: String,
    pub completed: u64,
    pub failed: u64,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub wall: SimTime,
    pub throughput_rps: f64,
    pub cold_starts: u64,
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes during the run
    /// (predictive-inplace).
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival (re-parked).
    pub mispredictions: u64,
    /// Average committed CPU over the run (milliCPU) — the reservation cost.
    pub avg_committed_mcpu: f64,
}

/// Runs scenarios against a simulation.
pub struct Runner;

impl Runner {
    /// VU chain: issue one request; on completion, sleep `think` and repeat
    /// until `remaining` hits zero. The chain rides the typed
    /// [`Continuation`] on the request — no boxed hook, no allocation per
    /// iteration beyond the request itself.
    pub(crate) fn vu_iterate(
        w: &mut Platform,
        eng: &mut Eng,
        service: ServiceId,
        remaining: u32,
        think: SimTime,
    ) {
        if remaining == 0 {
            return;
        }
        let id = w.submit_id(eng, service);
        if let Some(r) = w.requests.get_mut(&id) {
            r.continuation = Some(Continuation::VuNext {
                service,
                remaining,
                think,
            });
        }
    }

    /// Executes `scenario` against `service` on `sim`, running the engine to
    /// completion, and reports. Metrics are deltas over the run.
    pub fn run(sim: &mut Simulation, service: &str, scenario: &Scenario) -> LoadReport {
        let start = sim.now();
        let (completed0, failed0, cold0, ups0, spec0, mis0) = {
            let m = sim.world.metrics.service(service);
            (
                m.completed,
                m.failed,
                m.cold_starts,
                m.inplace_scale_ups,
                m.speculative_resizes,
                m.mispredictions,
            )
        };
        let lat_mark = sim.world.metrics.service(service).latency_ms.len();

        match scenario {
            Scenario::Closed {
                vus,
                iterations,
                think,
            } => {
                let svc = sim.world.intern_service(service);
                for _ in 0..*vus {
                    // Stagger VU starts by a few ms like k6 ramp-up.
                    let jitter =
                        SimTime::from_millis_f64(sim.world.rng.range_f64(0.0, 5.0));
                    sim.engine.schedule_in(
                        jitter,
                        Event::VuIterate {
                            service: svc,
                            remaining: *iterations,
                            think: *think,
                        },
                    );
                }
            }
            Scenario::Open { arrival, horizon } => {
                let svc = sim.world.intern_service(service);
                let mut rng = sim.world.rng.fork();
                for t in arrival.times(*horizon, &mut rng) {
                    sim.engine
                        .schedule_at(start + t, Event::Submit { service: svc });
                }
            }
        }
        sim.run();

        let wall = sim.now().saturating_sub(start);
        let now = sim.now();
        let avg_committed = sim.world.metrics.committed_cpu.average_mcpu(now);
        let m = sim.world.metrics.service(service);
        let completed = m.completed - completed0;
        let failed = m.failed - failed0;
        // Percentiles over the samples recorded during this run only.
        let all = m.latency_ms.values()[lat_mark..].to_vec();
        let mut window = crate::util::stats::Samples::new();
        for v in all {
            window.record(v);
        }
        LoadReport {
            service: service.to_string(),
            completed,
            failed,
            mean_ms: window.mean(),
            std_ms: window.std_dev(),
            p50_ms: window.percentile(50.0),
            p95_ms: window.percentile(95.0),
            p99_ms: window.percentile(99.0),
            min_ms: window.min(),
            max_ms: window.max(),
            wall,
            throughput_rps: if wall.as_secs_f64() > 0.0 {
                completed as f64 / wall.as_secs_f64()
            } else {
                0.0
            },
            cold_starts: m.cold_starts - cold0,
            inplace_scale_ups: m.inplace_scale_ups - ups0,
            speculative_resizes: m.speculative_resizes - spec0,
            mispredictions: m.mispredictions - mis0,
            avg_committed_mcpu: avg_committed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Policy;
    use crate::workload::registry::{WorkloadKind, WorkloadProfile};

    fn warm_sim(kind: WorkloadKind) -> Simulation {
        let mut sim = Simulation::paper(11);
        sim.deploy("fn", WorkloadProfile::paper(kind), Policy::Warm);
        sim.run(); // bring up the min-scale pod
        sim
    }

    #[test]
    fn closed_loop_completes_all_iterations() {
        let mut sim = warm_sim(WorkloadKind::HelloWorld);
        let report = Runner::run(&mut sim, "fn", &Scenario::closed(3, 10));
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        assert!(report.mean_ms > 5.0);
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn think_time_spaces_requests() {
        let mut sim = warm_sim(WorkloadKind::HelloWorld);
        let think = SimTime::from_secs(1);
        let report = Runner::run(
            &mut sim,
            "fn",
            &Scenario::closed_with_think(1, 5, think),
        );
        assert_eq!(report.completed, 5);
        // Wall ≥ 4 think gaps.
        assert!(report.wall >= SimTime::from_secs(4), "wall={}", report.wall);
    }

    #[test]
    fn open_loop_poisson_completes() {
        let mut sim = warm_sim(WorkloadKind::HelloWorld);
        let report = Runner::run(
            &mut sim,
            "fn",
            &Scenario::Open {
                arrival: Arrival::Poisson { rate_per_sec: 20.0 },
                horizon: SimTime::from_secs(5),
            },
        );
        assert!(report.completed > 50, "completed={}", report.completed);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn cold_policy_with_long_think_pays_cold_start_each_time() {
        let mut sim = Simulation::paper(11);
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Cold,
        );
        sim.run();
        // Think 8 s > 6 s stable window ⇒ every iteration is a cold start.
        let report = Runner::run(
            &mut sim,
            "fn",
            &Scenario::closed_with_think(1, 4, SimTime::from_secs(8)),
        );
        assert_eq!(report.completed, 4);
        assert_eq!(report.cold_starts, 4, "report={report:?}");
        assert!(report.mean_ms > 1000.0);
    }

    #[test]
    fn deterministic_reports() {
        let f = || {
            let mut sim = warm_sim(WorkloadKind::Cpu);
            Runner::run(&mut sim, "fn", &Scenario::closed(2, 3)).mean_ms
        };
        assert_eq!(f().to_bits(), f().to_bits());
    }
}
