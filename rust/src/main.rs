//! `kinetic` — the platform CLI.
//!
//! Subcommands:
//! * `run`        — execute a declarative scenario (JSON spec file or preset),
//!                  optionally on `--threads N` parallel workers
//! * `analyze`    — aggregate a ScenarioReport: cross-rep stats + speedups
//!                  vs a baseline policy (the paper's ratio tables)
//! * `compare`    — diff two ScenarioReports and flag latency regressions
//! * `exp`        — regenerate paper tables/figures (t1|fig2|fig3|fig4|t2|t3|fig6|all)
//! * `fleet`      — preset: the three §3 policies over a multi-node topology
//! * `trace`      — preset: generate + replay an Azure-style trace under all policies
//! * `serve`      — run the end-to-end serving demo over the PJRT artifacts
//! * `bench`      — run the fixed perf scale ladder and write `BENCH_<n>.json`
//! * `profile`    — render the simulator self-profile from a bench report
//! * `validate-bench` — schema-check an emitted bench report JSON
//! * `validate-report` — schema-check an emitted ScenarioReport JSON
//! * `validate-obs` — schema-check an observation artifact (summary/trace/timeline/profile)
//! * `schema`     — print the scenario JSON reference (docs/SCENARIO_SCHEMA.md)
//! * `selfcheck`  — validate the AOT artifacts against the manifest oracle
//!
//! `fleet` and `trace` are thin wrappers over `run --scenario`: they build
//! the matching preset spec from their flags and render the same tables
//! they always did (the equivalence tests pin them bit-for-bit). New
//! studies should write a scenario file instead of a new subcommand.

use kinetic::analysis::{self, AnalysisReport, Format};
use kinetic::experiments::ablation;
use kinetic::experiments::bench;
use kinetic::experiments::fleet;
use kinetic::experiments::memory;
use kinetic::experiments::report::{
    fig5_table, fig6_table, overhead_series_table, overhead_table, table3_table,
    ExperimentReport,
};
use kinetic::experiments::scaling_overhead::{OverheadConfig, OverheadExperiment};
use kinetic::loadgen::runner::{Runner, Scenario};
use kinetic::policy::Policy;
use kinetic::runtime::Executor;
use kinetic::scenario::preset;
use kinetic::scenario::spec::TopologySpec;
use kinetic::scenario::{ScenarioEngine, ScenarioReport};
use kinetic::simclock::SimTime;
use kinetic::util::cli::{App, CliError, Command};
use kinetic::util::logging;
use kinetic::util::stats::Summary;
use kinetic::util::table::{fmt_ms, fmt_ratio, Table};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn app() -> App {
    App::new("kinetic", "in-place vertical scaling for serverless (paper reproduction)")
        .command(
            Command::new("run", "execute a declarative scenario (spec file or preset)")
                .opt(
                    "scenario",
                    "path to a ScenarioSpec JSON file, or a preset name \
                     (fleet|trace|paper|smoke)",
                    "smoke",
                )
                .opt("out", "directory the ScenarioReport JSON is written to", "results")
                .opt_threads("1")
                .opt_shards()
                .flag(
                    "observe",
                    "arm the observation plane (spans/timeline/profile) and \
                     write artifacts beside the report; the report itself is \
                     byte-identical either way",
                ),
        )
        .command(
            Command::new(
                "analyze",
                "aggregate a ScenarioReport: cross-rep stats + speedups vs a baseline policy",
            )
            .opt("file", "path to the ScenarioReport JSON (or first positional)", "")
            .opt_policy("baseline", "policy the speedup ratios are computed against", "cold")
            .opt("format", "markdown|ascii|csv", "markdown")
            .opt(
                "out",
                "directory the AnalysisReport JSON is written to ('' = don't write)",
                "results",
            ),
        )
        .command(
            Command::new("compare", "diff two ScenarioReports and flag latency regressions")
                .opt("base", "baseline report JSON (or first positional)", "")
                .opt("new", "candidate report JSON (or second positional)", "")
                .opt("threshold", "regression threshold in percent", "10")
                .opt("format", "markdown|ascii|csv", "markdown"),
        )
        .command(
            Command::new("exp", "regenerate paper tables and figures")
                .opt("id", "t1|fig2|fig3|fig4|t2|t3|fig6|ablation|memory|all", "all")
                .opt("reps", "repetitions per measurement", "30")
                .opt_seed("42")
                .opt("out", "results directory", "results")
                .flag("verbose", "chatty logging"),
        )
        .command(
            Command::new("fleet", "preset: the three §3 policies over a multi-node fleet")
                .opt("nodes", "node count for uniform/hetero topologies", "10")
                .opt("topology", "paper|uniform|hetero", "uniform")
                .opt(
                    "routing",
                    "activator pod selection: least-loaded|locality|hybrid, or 'all' to sweep",
                    "least-loaded",
                )
                .opt("services", "deployed tenants (0 = 2 per node)", "0")
                .opt_rate("Poisson requests/second per tenant", "0.05")
                .opt_seconds("arrival-stream horizon (virtual seconds)", "300")
                .opt_seed("42")
                .opt_shards(),
        )
        .command(
            Command::new("serve", "serve batched requests over the PJRT artifacts")
                .opt("requests", "number of requests", "64")
                .opt_policy("policy", "scheduling policy to serve under", "inplace")
                .opt_seed("42"),
        )
        .command(
            Command::new("trace", "preset: replay a synthetic Azure-style trace under all policies")
                .opt("functions", "distinct functions", "8")
                .opt_seconds("trace horizon (virtual seconds)", "600")
                .opt_rate("peak request rate per second", "4")
                .opt_seed("1"),
        )
        .command(
            Command::new("bench", "run the fixed perf scale ladder and write a bench JSON")
                .opt("json", "output path for the bench report", "BENCH_9.json")
                .opt(
                    "trace",
                    "Azure-sample CSV replayed by the last rung",
                    "examples/scenarios/azure_sample.csv",
                )
                .flag("smoke", "CI-size rungs (KINETIC_SMOKE=1 implies this)"),
        )
        .command(
            Command::new("profile", "render the simulator self-profile from a bench report")
                .opt("file", "path to the bench JSON", "BENCH_9.json"),
        )
        .command(
            Command::new("validate-bench", "schema-check a bench report JSON file")
                .opt("file", "path to the bench JSON", ""),
        )
        .command(
            Command::new(
                "validate-obs",
                "schema-check an observation artifact JSON (summary, Chrome \
                 trace, timeline, or self-profile — sniffed from the document)",
            )
            .opt("file", "path to the artifact JSON", ""),
        )
        .command(
            Command::new("validate-report", "schema-check a ScenarioReport JSON file")
                .opt("file", "path to the report JSON", ""),
        )
        .command(
            Command::new("schema", "print the scenario JSON reference")
                .flag("markdown", "emit docs/SCENARIO_SCHEMA.md content (the default)"),
        )
        .command(Command::new("selfcheck", "validate AOT artifacts against the manifest oracle"))
}

/// Unwraps a validated CLI option or exits with the parse error.
fn or_die<T>(r: Result<T, CliError>) -> T {
    match r {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn run_scenario(arg: &str, out: &str, threads: usize, shards: Option<u32>, observe: bool) {
    let spec = match ScenarioEngine::load(arg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // The effective observation config: `--observe` arms defaults when the
    // spec has no `observe` section; without the flag the spec decides.
    // The engine itself never falls back to the spec — resolution is a
    // CLI concern, like the artifacts.
    let effective = if observe {
        Some(spec.observe.clone().unwrap_or_default())
    } else {
        spec.observe.clone()
    };
    // Grid size is the product of axis lengths — no need to materialize
    // the expansion here (load() already validated it; run() performs it).
    let variants: usize = spec.sweep.iter().map(|s| s.values.len().max(1)).product();
    println!(
        "scenario '{}': {} variant(s) × {} routing × {} policies × {} rep(s)",
        spec.name,
        variants,
        spec.routing.len(),
        spec.policies.len(),
        spec.reps
    );
    // The structured-log sink counts emissions only while an observed run
    // is in flight; the counts land in the summary artifact.
    if effective.is_some() {
        logging::arm_sink();
    }
    let (report, obs) =
        match ScenarioEngine::run_observed(&spec, threads, shards, effective.as_ref()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        };
    let log_counts = if effective.is_some() {
        logging::drain_sink()
    } else {
        [0u64; 4]
    };
    println!("{}", report.table().to_ascii());
    match report.save(std::path::Path::new(out)) {
        Ok(p) => {
            println!("wrote {}", p.display());
            if let Some(oc) = &effective {
                if let Err(e) = write_obs_artifacts(&p, &report.name, &obs, oc, &log_counts) {
                    eprintln!("could not write observation artifacts: {e}");
                    std::process::exit(1);
                }
            }
        }
        Err(e) => {
            eprintln!("could not write report: {e}");
            std::process::exit(1);
        }
    }
}

/// Writes the observation artifacts beside the saved report
/// (`scenario_<slug>_obs.json`, `_trace.json`, `_spans.jsonl`,
/// `_timeline.json`, `_timeline.csv`) and prints each path. Span and
/// timeline artifacts appear only when their plane was armed; the summary
/// always does. The sharded profile is deliberately *not* written here —
/// wall-times differ run to run, so it lives in bench reports only.
fn write_obs_artifacts(
    report_path: &std::path::Path,
    name: &str,
    runs: &[kinetic::obs::export::RunObs],
    oc: &kinetic::obs::ObserveConfig,
    log_counts: &[u64; 4],
) -> std::io::Result<()> {
    use kinetic::obs::export;
    let full = report_path.to_string_lossy();
    let stem = full.strip_suffix(".json").unwrap_or(&full);
    let emit = |suffix: &str, contents: String| -> std::io::Result<()> {
        let path = format!("{stem}{suffix}");
        std::fs::write(&path, contents)?;
        println!("wrote {path}");
        Ok(())
    };
    emit(
        "_obs.json",
        export::summary_doc(name, runs, log_counts).to_string_pretty(),
    )?;
    if oc.spans {
        emit("_trace.json", export::trace_doc(runs).to_string_pretty())?;
        emit("_spans.jsonl", export::spans_jsonl(runs))?;
    }
    if oc.timeline {
        emit(
            "_timeline.json",
            export::timeline_doc(name, runs).to_string_pretty(),
        )?;
        emit("_timeline.csv", export::timeline_csv(runs))?;
    }
    Ok(())
}

/// Loads a ScenarioReport or exits with the error.
fn load_report(file: &str, what: &str) -> ScenarioReport {
    if file.is_empty() {
        eprintln!("error: missing the {what} report path");
        std::process::exit(2);
    }
    match ScenarioReport::load(std::path::Path::new(file)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("invalid {what} report: {e}");
            std::process::exit(1);
        }
    }
}

fn run_analyze(file: &str, baseline: Policy, format: &str, out: &str) {
    let format: Format = or_die_parse(format, "format");
    let report = load_report(file, "scenario");
    let analyzed = AnalysisReport::from_scenario(&report, baseline);
    println!("{}", analysis::render(&analyzed.aggregate_table(), format));
    println!("{}", analysis::render(&analyzed.speedup_table(), format));
    // Phase-breakdown table from the sibling observation summary, written
    // by `kinetic run --observe` beside the report. Absent sibling = the
    // run was unobserved; nothing extra renders.
    if let Some(t) = obs_phase_table(file) {
        println!("{}", analysis::render(&t, format));
    }
    // The paper's headline shape: the in-place policy's min–max
    // improvement over the baseline (Table 3 spans 1.16×–18.15×).
    // Meaningless when in-place *is* the baseline (always 1.00×).
    if baseline != Policy::InPlace {
        if let Some((lo, hi)) = analyzed.headline(Policy::InPlace) {
            println!(
                "headline: in-place improves on {} by {}×–{}× (mean latency)",
                baseline.name(),
                fmt_ratio(lo),
                fmt_ratio(hi)
            );
        }
    }
    if !out.is_empty() {
        match analyzed.save(std::path::Path::new(out)) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => {
                eprintln!("could not write analysis: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Loads `<report>_obs.json` beside the analyzed report, when present, and
/// builds the per-(service, policy) phase breakdown. A malformed sibling
/// is reported to stderr and skipped — the report analysis still stands.
fn obs_phase_table(report_file: &str) -> Option<Table> {
    use kinetic::util::json::Json;
    let path = format!(
        "{}_obs.json",
        report_file.strip_suffix(".json").unwrap_or(report_file)
    );
    let text = std::fs::read_to_string(&path).ok()?;
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("ignoring malformed observation summary {path}: {e}");
            return None;
        }
    };
    if let Err(e) = kinetic::obs::export::validate_summary(&doc) {
        eprintln!("ignoring invalid observation summary {path}: {e}");
        return None;
    }
    let mut t = Table::new(vec![
        "Run",
        "Service",
        "Phase",
        "Count",
        "Mean (ms)",
        "Min (ms)",
        "Max (ms)",
    ])
    .title("Request-phase breakdown (observed spans)");
    let mut rows = 0u64;
    for run in doc.get("runs")?.as_arr()? {
        let variant = run.get("variant").and_then(Json::as_str).unwrap_or("");
        let routing = run.get("routing").and_then(Json::as_str).unwrap_or("?");
        let policy = run.get("policy").and_then(Json::as_str).unwrap_or("?");
        let rep = run.get("rep").and_then(Json::as_u64).unwrap_or(0);
        let mut label = String::new();
        if !variant.is_empty() {
            label.push_str(variant);
            label.push('/');
        }
        label.push_str(routing);
        label.push('/');
        label.push_str(policy);
        if rep > 0 {
            label.push_str(&format!("#{rep}"));
        }
        for p in run.get("phases")?.as_arr()? {
            rows += 1;
            t.row(vec![
                label.clone(),
                p.get("service").and_then(Json::as_str).unwrap_or("?").to_string(),
                p.get("phase").and_then(Json::as_str).unwrap_or("?").to_string(),
                p.get("count").and_then(Json::as_u64).unwrap_or(0).to_string(),
                fmt_ms(p.get("mean_ms").and_then(Json::as_f64).unwrap_or(0.0)),
                fmt_ms(p.get("min_ms").and_then(Json::as_f64).unwrap_or(0.0)),
                fmt_ms(p.get("max_ms").and_then(Json::as_f64).unwrap_or(0.0)),
            ]);
        }
    }
    (rows > 0).then_some(t)
}

fn run_compare(base: &str, new: &str, threshold_pct: f64, format: &str) {
    let format: Format = or_die_parse(format, "format");
    let base_rep = load_report(base, "base");
    let new_rep = load_report(new, "new");
    let cmp = analysis::compare(
        &analysis::aggregate(&base_rep.rows),
        &analysis::aggregate(&new_rep.rows),
        threshold_pct,
    );
    println!("{}", analysis::render(&analysis::render::compare_table(&cmp), format));
    for k in &cmp.only_in_base {
        eprintln!("coverage: only in base report: {k}");
    }
    for k in &cmp.only_in_new {
        eprintln!("coverage: only in new report: {k}");
    }
    let mut gate_failed = false;
    if cmp.has_regressions() {
        eprintln!(
            "{} cell(s) regressed beyond {:.1}%",
            cmp.regression_count(),
            threshold_pct
        );
        gate_failed = true;
    }
    // Mismatched cell sets fail the gate too: a vanished variant means a
    // regression there would go completely unmeasured, and a comparison
    // with zero matched cells must never read as a pass.
    if cmp.keys_mismatch() {
        eprintln!(
            "cell coverage changed: {} cell(s) only in base, {} only in new",
            cmp.only_in_base.len(),
            cmp.only_in_new.len()
        );
        gate_failed = true;
    }
    if gate_failed {
        std::process::exit(1);
    }
    println!(
        "no regressions beyond {:.1}% across {} matched cell(s)",
        threshold_pct,
        cmp.deltas.len()
    );
}

/// Parses a CLI value through `FromStr` or exits with the parse error.
fn or_die_parse<T: std::str::FromStr>(raw: &str, opt: &str) -> T
where
    T::Err: std::fmt::Display,
{
    match raw.parse() {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: invalid --{opt}: {e}");
            std::process::exit(2);
        }
    }
}

fn run_bench(smoke: bool, out: &str, trace: &str) {
    let report = match bench::run_ladder(smoke, std::path::Path::new(trace)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    println!("{}", report.table().to_ascii());
    let path = std::path::Path::new(out);
    if let Err(e) = report.save(path) {
        eprintln!("could not write bench report: {e}");
        std::process::exit(1);
    }
    // Reload what we just wrote: the committed artifact must round-trip
    // through the same validator `validate-bench` applies.
    match bench::BenchReport::load(path) {
        Ok(_) => println!("wrote {} (validates)", path.display()),
        Err(e) => {
            eprintln!("wrote an invalid bench report: {e}");
            std::process::exit(1);
        }
    }
}

fn validate_bench(file: &str) {
    if file.is_empty() {
        eprintln!("error: validate-bench needs --file <bench.json>");
        std::process::exit(2);
    }
    match bench::BenchReport::load(std::path::Path::new(file)) {
        Ok(rep) => println!(
            "bench report OK: {} rung(s), measured={}, schema v{}",
            rep.rungs.len(),
            rep.measured,
            bench::SCHEMA_VERSION
        ),
        Err(e) => {
            eprintln!("invalid bench report: {e}");
            std::process::exit(1);
        }
    }
}

fn validate_report(file: &str) {
    if file.is_empty() {
        eprintln!("error: validate-report needs --file <report.json>");
        std::process::exit(2);
    }
    match ScenarioReport::load(std::path::Path::new(file)) {
        Ok(rep) => println!(
            "report OK: '{}', {} row(s), schema v{}",
            rep.name,
            rep.rows.len(),
            kinetic::scenario::report::SCHEMA_VERSION
        ),
        Err(e) => {
            eprintln!("invalid report: {e}");
            std::process::exit(1);
        }
    }
}

/// `kinetic profile` — renders the self-profile sections of a bench
/// report: per-event-kind dispatch counts/wall time plus calendar-queue
/// internals, one table per profiled rung.
fn run_profile(file: &str) {
    use kinetic::util::json::Json;
    let rep = match bench::BenchReport::load(std::path::Path::new(file)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("invalid bench report: {e}");
            std::process::exit(1);
        }
    };
    let mut any = false;
    for rung in &rep.rungs {
        let Some(p) = &rung.profile else { continue };
        any = true;
        let mut t = Table::new(vec!["Event", "Count", "Wall (ms)"])
            .title(format!("self-profile: {}", rung.name));
        if let Some(events) = p.get("events").and_then(Json::as_arr) {
            for ev in events {
                t.row(vec![
                    ev.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
                    ev.get("count").and_then(Json::as_u64).unwrap_or(0).to_string(),
                    format!(
                        "{:.3}",
                        ev.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0)
                    ),
                ]);
            }
        }
        println!("{}", t.to_ascii());
        let processed = p.get("processed").and_then(Json::as_u64).unwrap_or(0);
        if let Some(q) = p.get("queue") {
            println!(
                "queue: rebuilds={} entry_scans={} max_bucket={} (processed {processed})\n",
                q.get("rebuilds").and_then(Json::as_u64).unwrap_or(0),
                q.get("entry_scans").and_then(Json::as_u64).unwrap_or(0),
                q.get("max_bucket").and_then(Json::as_u64).unwrap_or(0),
            );
        }
    }
    if !any {
        eprintln!(
            "no self-profile sections in {file} — pre-profile bench reports \
             (BENCH_9 and earlier) do not carry them; re-run `kinetic bench`"
        );
        std::process::exit(1);
    }
}

/// `kinetic validate-obs` — strict-validates one observation artifact,
/// sniffing which schema applies from the document itself.
fn validate_obs(file: &str) {
    use kinetic::obs::export;
    use kinetic::util::json::Json;
    if file.is_empty() {
        eprintln!("error: validate-obs needs --file <artifact.json>");
        std::process::exit(2);
    }
    let text = match std::fs::read_to_string(file) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {file}: {e}");
            std::process::exit(1);
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{file}: {e}");
            std::process::exit(1);
        }
    };
    let result = match doc.get("kind").and_then(Json::as_str) {
        Some("kinetic-obs") => export::validate_summary(&doc).map(|()| "observation summary"),
        Some("kinetic-timeline") => export::validate_timeline(&doc).map(|()| "timeline"),
        _ if doc.get("traceEvents").is_some() => {
            export::validate_trace(&doc).map(|()| "Chrome trace")
        }
        _ if doc.get("events").is_some() => {
            export::validate_profile(&doc).map(|()| "self-profile")
        }
        _ => Err(
            "unrecognized artifact: expected a kinetic-obs or kinetic-timeline \
             document, a Chrome trace (traceEvents), or a self-profile \
             (events/queue/processed)"
                .to_string(),
        ),
    };
    match result {
        Ok(what) => println!("{what} OK: {file}"),
        Err(e) => {
            eprintln!("invalid observation artifact {file}: {e}");
            std::process::exit(1);
        }
    }
}

fn run_exp(id: &str, reps: u32, seed: u64, out: &str) {
    let mut report = ExperimentReport::new();
    let want = |section: &str| id == "all" || id == section;

    if want("t1") || want("fig2") || want("fig3") || want("fig4") {
        let exp = OverheadExperiment::new(OverheadConfig { reps, seed });
        if want("fig2") || want("t1") {
            for (pattern, up, points) in exp.fig2() {
                let dir = if up { "up" } else { "down" };
                let title = format!(
                    "Fig 2 ({} {}, step 100m): avg in-place scaling latency",
                    pattern.name(),
                    dir
                );
                let idlbl = format!("fig2_{}_{}", pattern.name(), dir);
                report.add_table(&idlbl, &overhead_table(&title, &points));
            }
        }
        if want("fig3") || want("t1") {
            for (up, points) in exp.fig3() {
                let dir = if up { "up" } else { "down" };
                let title = format!("Fig 3 ({dir}, step 1000m): avg in-place scaling latency");
                report.add_table(&format!("fig3_{dir}"), &overhead_table(&title, &points));
            }
        }
        if want("fig4") || want("t1") {
            let (up, down) = exp.fig4();
            // Fig 4a headline: flat mean ≈ 56.44 ms ± 8.53.
            let mut all = Summary::new();
            for p in &up {
                all.record(p.stats.mean());
            }
            println!(
                "fig4a: mean {:.2} ms (paper: 56.44), spread σ {:.2} (paper: 8.53)",
                all.mean(),
                all.std_dev()
            );
            report.add_table(
                "fig4a",
                &overhead_series_table("Fig 4a: 5m-granularity increments → 1000m (idle)", &up),
            );
            report.add_table(
                "fig4b",
                &overhead_series_table("Fig 4b: decrements from 1000m (idle)", &down),
            );
        }
    }

    if want("t2") || want("t3") || want("fig6") {
        // The policy portion of `exp` is the `paper` scenario preset: the
        // spec carries iterations/think/seed and the engine compiles it to
        // the exact PolicyExperiment these tables were always rendered from.
        let exp = ScenarioEngine::paper_policy_experiment(&preset::paper(reps, seed))
            .expect("the paper preset is a closed-loop spec");
        if want("t2") {
            let mut t = Table::new(vec!["Workload", "Runtime (ms)", "σ (ms)", "Paper (ms)"])
                .title("Table 2: runtime measurements with 1 CPU");
            for (kind, s) in exp.table2(64) {
                t.row(vec![
                    kind.name().to_string(),
                    fmt_ms(s.mean()),
                    fmt_ms(s.std_dev()),
                    fmt_ms(WorkloadProfile::paper(kind).runtime_1cpu_ms),
                ]);
            }
            report.add_table("table2", &t);
        }
        if want("t3") || want("fig6") {
            let rows = exp.table3();
            if want("t3") {
                report.add_table("table3", &table3_table(&rows));
                report.add_table("fig5", &fig5_table(&rows));
            }
            if want("fig6") {
                report.add_table("fig6", &fig6_table(&kinetic::experiments::policies::PolicyExperiment::fig6(&rows)));
            }
            if let Some(h) = rows.iter().find(|r| r.function == "helloworld") {
                println!(
                    "headline: in-place improves on cold by {}× for helloworld (paper: 18.15×)",
                    fmt_ratio(h.improvement())
                );
            }
        }
    }

    if want("ablation") {
        let mut t = Table::new(vec![
            "Parked (mCPU)",
            "Mean (ms)",
            "p99 (ms)",
            "Committed (mCPU)",
            "Conflicts",
        ])
        .title("Ablation: parked allocation (in-place, helloworld)");
        for p in ablation::parked_cpu_sweep(
            WorkloadKind::HelloWorld,
            &[1, 10, 50, 100, 250, 500],
            seed,
        ) {
            t.row(vec![
                format!("{:.0}", p.x),
                fmt_ms(p.mean_ms),
                fmt_ms(p.p99_ms),
                format!("{:.0}", p.avg_committed_mcpu),
                p.resize_conflicts.to_string(),
            ]);
        }
        report.add_table("ablation_parked", &t);

        let mut t = Table::new(vec![
            "Stable window (s)",
            "Mean (ms)",
            "Cold starts",
            "Committed (mCPU)",
        ])
        .title("Ablation: cold stable window (helloworld, 20 s gaps)");
        for p in ablation::stable_window_sweep(&[6, 15, 30, 60, 120], SimTime::from_secs(20), seed)
        {
            t.row(vec![
                format!("{:.0}", p.x),
                fmt_ms(p.mean_ms),
                p.cold_starts.to_string(),
                format!("{:.0}", p.avg_committed_mcpu),
            ]);
        }
        report.add_table("ablation_window", &t);

        let mut t = Table::new(vec![
            "Retry period (ms)",
            "Mean (ms)",
            "p99 (ms)",
            "Conflicts",
        ])
        .title("Ablation: hook retry period (in-place, back-to-back)");
        for p in ablation::retry_period_sweep(&[5, 10, 25, 50, 100, 200], seed) {
            t.row(vec![
                format!("{:.0}", p.x),
                fmt_ms(p.mean_ms),
                fmt_ms(p.p99_ms),
                p.resize_conflicts.to_string(),
            ]);
        }
        report.add_table("ablation_retry", &t);
    }

    if want("memory") {
        let mut t = Table::new(vec![
            "Parked (MiB)",
            "OOM kills / 200",
            "Mean (ms)",
            "Committed (MiB)",
        ])
        .title("Future work (§6): in-place MEMORY scaling — io workload");
        for o in memory::parked_memory_sweep(
            WorkloadKind::Io,
            &[56.0, 64.0, 96.0, 128.0, 192.0, 256.0, 512.0],
            seed,
        ) {
            t.row(vec![
                format!("{:.0}", o.parked_mb),
                o.ooms.to_string(),
                fmt_ms(o.latency.mean()),
                format!("{:.0}", o.avg_committed_mb),
            ]);
        }
        report.add_table("memory_sweep", &t);
        println!("memory ablation: unlike CPU (throttling), memory under-provision kills —");
        println!("the quantitative form of the paper's reason to defer memory scaling.");
    }

    if report.is_empty() {
        eprintln!("unknown experiment id: {id}");
        std::process::exit(2);
    }
    report.print();
    match report.write_dir(std::path::Path::new(out)) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("could not write results: {e}"),
    }
}

fn run_fleet(
    nodes: usize,
    topology_spec: &str,
    routing_spec: &str,
    services: usize,
    rate: f64,
    seconds: u64,
    seed: u64,
    shards: Option<u32>,
) {
    let topo = match TopologySpec::from_cli(topology_spec, nodes) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sweep_routing = routing_spec.eq_ignore_ascii_case("all");
    let routing = if sweep_routing {
        kinetic::coordinator::accounting::RoutingPolicy::ALL.to_vec()
    } else {
        match routing_spec.parse() {
            Ok(r) => vec![r],
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    };
    // The preset resolves `0` tenants to two per node, as the subcommand
    // always did; build it first so the header prints resolved numbers.
    let spec = preset::fleet(topo, routing, services, rate, seconds, seed);
    let topology = spec.topology.build();
    let services = match &spec.workload {
        kinetic::scenario::WorkloadSource::Synthetic { services, .. } => *services,
        _ => unreachable!("fleet preset is synthetic"),
    };
    println!(
        "fleet: {} nodes ({} mCPU total), {services} tenants, {rate} rps each over {seconds}s, routing {}",
        topology.len(),
        topology.total_capacity().cpu.0,
        if sweep_routing { "sweep" } else { spec.routing[0].name() },
    );
    let report = match ScenarioEngine::run_with_options(&spec, 1, shards) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    let rows: Vec<fleet::FleetRow> = report.rows.iter().map(|r| r.to_fleet_row()).collect();
    if sweep_routing {
        println!("{}", fleet::routing_table(&rows).to_ascii());
        return;
    }
    println!("{}", fleet::fleet_table(&rows).to_ascii());
    let warm = rows.iter().find(|r| r.policy == Policy::Warm);
    let inp = rows.iter().find(|r| r.policy == Policy::InPlace);
    if let (Some(w), Some(i)) = (warm, inp) {
        if i.avg_committed_mcpu > 0.0 {
            println!(
                "reservation: warm commits {:.1}× the CPU of in-place across the fleet",
                w.avg_committed_mcpu / i.avg_committed_mcpu
            );
        }
    }
}

fn run_serve(requests: u32, policy: Policy, seed: u64) {
    // Real-compute path: verify artifacts, then serve through the platform.
    let mut executor = match Executor::new(None) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); run `make artifacts`");
            std::process::exit(1);
        }
    };
    executor.self_check("compute").expect("compute artifact validates");
    executor.self_check("watermark").expect("watermark artifact validates");
    println!("PJRT platform: {}; artifacts OK", executor.platform());

    let mut sim = kinetic::coordinator::platform::Simulation::paper(seed);
    sim.deploy("cpu", WorkloadProfile::paper(WorkloadKind::Cpu), policy);
    sim.run();
    let report = Runner::run(&mut sim, "cpu", &Scenario::closed(4, (requests / 4).max(1)));

    // Each simulated request corresponds to real kernel executions; run a
    // batch through PJRT to demonstrate the hot path and measure it.
    let (x, w, b) = kinetic::runtime::inputs::compute_inputs();
    let t0 = std::time::Instant::now();
    let execs = 32.min(requests.max(1));
    for _ in 0..execs {
        executor.execute("compute", &[&x, &w, &b]).expect("execute");
    }
    let per = t0.elapsed().as_secs_f64() * 1000.0 / f64::from(execs);

    println!(
        "policy={} completed={} mean={} p99={} throughput={:.1} rps (virtual)",
        policy.name(),
        report.completed,
        fmt_ms(report.mean_ms),
        fmt_ms(report.p99_ms),
        report.throughput_rps
    );
    println!("real PJRT compute: {execs} executions, {per:.3} ms/exec");
}

fn run_trace(functions: usize, seconds: u64, rate: f64, seed: u64) {
    let spec = preset::trace(functions, seconds, rate, seed);
    let report = match ScenarioEngine::run(&spec) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    // The invocation count the header always printed: every submitted
    // event either completes or fails, so any row's sum is the trace
    // length — no need to generate the trace a second time here.
    let invocations = report
        .rows
        .first()
        .map(|r| r.completed + r.failed)
        .unwrap_or(0);
    println!(
        "trace: {invocations} invocations over {seconds}s across {functions} functions"
    );
    let mut t = Table::new(vec![
        "Policy",
        "Mean (ms)",
        "p99 (ms)",
        "Cold starts",
        "Avg committed (mCPU)",
        "Pods created",
    ])
    .title("Trace replay: latency vs reservation");
    for r in &report.rows {
        t.row(vec![
            r.policy.name().to_string(),
            fmt_ms(r.mean_ms),
            fmt_ms(r.p99_ms),
            r.cold_starts.to_string(),
            format!("{:.0}", r.avg_committed_mcpu),
            r.pods_created.to_string(),
        ]);
    }
    println!("{}", t.to_ascii());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let inv = match app().parse(&args) {
        Ok(inv) => inv,
        Err(CliError::Help(h)) => {
            println!("{h}");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    logging::init(if inv.flag("verbose") { 3 } else { 1 });

    match inv.command.as_str() {
        "run" => run_scenario(
            inv.get_or("scenario", "smoke"),
            inv.get_or("out", "results"),
            or_die(inv.threads()),
            or_die(inv.shards()),
            inv.flag("observe"),
        ),
        "analyze" => {
            let file = inv
                .get("file")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| inv.positionals.first().cloned())
                .unwrap_or_default();
            run_analyze(
                &file,
                or_die(inv.opt_policy("baseline")),
                inv.get_or("format", "markdown"),
                inv.get_or("out", "results"),
            );
        }
        "compare" => {
            let mut positionals = inv.positionals.iter();
            let base = inv
                .get("base")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| positionals.next().cloned())
                .unwrap_or_default();
            let new = inv
                .get("new")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| positionals.next().cloned())
                .unwrap_or_default();
            run_compare(
                &base,
                &new,
                or_die(inv.f64_in("threshold", 0.0, 10_000.0)),
                inv.get_or("format", "markdown"),
            );
        }
        "schema" => {
            // `--markdown` is the only (and default) format; accepting the
            // flag keeps `kinetic schema --markdown > docs/SCENARIO_SCHEMA.md`
            // self-documenting in CI.
            print!("{}", kinetic::scenario::schema_doc::markdown());
        }
        "exp" => run_exp(
            inv.get_or("id", "all"),
            or_die(inv.u64_in("reps", 1, 10_000)) as u32,
            or_die(inv.seed()),
            inv.get_or("out", "results"),
        ),
        "fleet" => run_fleet(
            or_die(inv.u64_in("nodes", 1, 10_000)) as usize,
            inv.get_or("topology", "uniform"),
            inv.get_or("routing", "least-loaded"),
            or_die(inv.u64_in("services", 0, 100_000)) as usize,
            or_die(inv.rate()),
            or_die(inv.seconds()),
            or_die(inv.seed()),
            or_die(inv.shards()),
        ),
        "serve" => {
            // Shared policy parsing: garbage exits with the full valid-name
            // list instead of silently falling back to in-place.
            run_serve(
                or_die(inv.u64_in("requests", 1, 1_000_000)) as u32,
                or_die(inv.opt_policy("policy")),
                or_die(inv.seed()),
            );
        }
        "trace" => run_trace(
            or_die(inv.u64_in("functions", 1, 100_000)) as usize,
            or_die(inv.seconds()),
            or_die(inv.rate()),
            or_die(inv.seed()),
        ),
        "bench" => {
            let smoke = inv.flag("smoke") || std::env::var("KINETIC_SMOKE").is_ok();
            run_bench(
                smoke,
                inv.get_or("json", "BENCH_9.json"),
                inv.get_or("trace", "examples/scenarios/azure_sample.csv"),
            );
        }
        "profile" => {
            let file = inv
                .get("file")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| inv.positionals.first().cloned())
                .unwrap_or_else(|| "BENCH_9.json".to_string());
            run_profile(&file);
        }
        "validate-bench" => {
            let file = inv
                .get("file")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| inv.positionals.first().cloned())
                .unwrap_or_default();
            validate_bench(&file);
        }
        "validate-obs" => {
            let file = inv
                .get("file")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| inv.positionals.first().cloned())
                .unwrap_or_default();
            validate_obs(&file);
        }
        "validate-report" => {
            let file = inv
                .get("file")
                .filter(|f| !f.is_empty())
                .map(str::to_string)
                .or_else(|| inv.positionals.first().cloned())
                .unwrap_or_default();
            validate_report(&file);
        }
        "selfcheck" => {
            let mut ex = match Executor::new(None) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("selfcheck unavailable ({e}); run `make artifacts`");
                    std::process::exit(1);
                }
            };
            ex.self_check("compute").expect("compute check");
            ex.self_check("watermark").expect("watermark check");
            println!("selfcheck OK: compute + watermark match the python oracle");
        }
        other => {
            eprintln!("unhandled command {other}");
            std::process::exit(2);
        }
    }
}
