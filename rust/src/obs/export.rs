//! Observation artifact builders and their strict validators.
//!
//! Four export surfaces, all derived from the per-run [`ObsBundle`]s a
//! scenario run harvests:
//!
//! - **summary** (`scenario_<name>_obs.json`) — per-run sampling stats and
//!   the per-(service, phase) breakdown tables `kinetic analyze` renders.
//! - **Chrome trace** (`scenario_<name>_trace.json`) — `traceEvents` in the
//!   trace-event format; load it in Perfetto (<https://ui.perfetto.dev>) or
//!   `chrome://tracing`. One process per run, one thread per service, one
//!   complete ("X") slice per phase interval.
//! - **spans JSONL** (`scenario_<name>_spans.jsonl`) — one span per line
//!   for ad-hoc processing.
//! - **timeline** (`scenario_<name>_timeline.{json,csv}`) — the cadence
//!   gauges; the CSV carries fleet totals for quick plotting, the JSON adds
//!   the per-node pods-by-state vectors.
//!
//! Validators are **strict**: unknown keys are rejected with their path, so
//! a hand-edited artifact can't silently pass CI.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::obs::{EventProfile, ObsBundle, Phase};
use crate::util::json::Json;
use crate::util::stats::StreamStats;

/// Schema version stamped into (and required from) the summary and
/// timeline documents.
pub const SCHEMA_VERSION: u64 = 1;

/// One observed run of a scenario grid, tagged with its grid coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct RunObs {
    /// Sweep-variant label (empty when the spec has no sweep).
    pub variant: String,
    pub routing: String,
    pub policy: String,
    pub rep: u32,
    pub bundle: ObsBundle,
}

impl RunObs {
    /// `[variant/]routing/policy[#rep]` — the run's display label.
    pub fn label(&self) -> String {
        let mut l = String::new();
        if !self.variant.is_empty() {
            l.push_str(&self.variant);
            l.push('/');
        }
        l.push_str(&self.routing);
        l.push('/');
        l.push_str(&self.policy);
        if self.rep > 0 {
            let _ = write!(l, "#{}", self.rep);
        }
        l
    }
}

/// Per-(service, phase) aggregate over a bundle's spans: the interval from
/// each mark to the next is attributed to the phase being exited, so the
/// rows of one span telescope to `marked_ms()`.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRow {
    pub service: String,
    pub phase: Phase,
    pub stats: StreamStats,
}

impl PhaseRow {
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    pub fn total_ms(&self) -> f64 {
        self.stats.sum()
    }
}

pub fn phase_rows(bundle: &ObsBundle) -> Vec<PhaseRow> {
    let mut acc: BTreeMap<(String, Phase), StreamStats> = BTreeMap::new();
    for span in &bundle.spans {
        for pair in span.marks.windows(2) {
            let (phase, at) = pair[0];
            let (_, next) = pair[1];
            let ms = (next - at).as_millis_f64();
            acc.entry((span.service.clone(), phase))
                .or_default()
                .record(ms);
        }
    }
    acc.into_iter()
        .map(|((service, phase), stats)| PhaseRow {
            service,
            phase,
            stats,
        })
        .collect()
}

/// The `scenario_<name>_obs.json` summary document.
pub fn summary_doc(name: &str, runs: &[RunObs], log_counts: &[u64; 4]) -> Json {
    let runs_json = Json::arr(runs.iter().map(|r| {
        let phases = Json::arr(phase_rows(&r.bundle).into_iter().map(|p| {
            Json::obj(vec![
                ("service", p.service.as_str().into()),
                ("phase", p.phase.name().into()),
                ("count", p.count().into()),
                ("total_ms", p.total_ms().into()),
                ("mean_ms", p.stats.mean().into()),
                ("min_ms", p.stats.min().into()),
                ("max_ms", p.stats.max().into()),
            ])
        }));
        Json::obj(vec![
            ("variant", r.variant.as_str().into()),
            ("routing", r.routing.as_str().into()),
            ("policy", r.policy.as_str().into()),
            ("rep", u64::from(r.rep).into()),
            ("sample_1_in_n", r.bundle.sample_1_in_n.into()),
            ("spans", (r.bundle.spans.len() as u64).into()),
            ("spans_dropped", r.bundle.spans_dropped.into()),
            ("spans_open", r.bundle.spans_open.into()),
            ("timeline_samples", (r.bundle.timeline.len() as u64).into()),
            ("timeline_dropped", r.bundle.timeline_dropped.into()),
            ("phases", phases),
        ])
    }));
    Json::obj(vec![
        ("kind", "kinetic-obs".into()),
        ("schema_version", SCHEMA_VERSION.into()),
        ("name", name.into()),
        (
            "log_counts",
            Json::obj(vec![
                ("error", log_counts[0].into()),
                ("warn", log_counts[1].into()),
                ("info", log_counts[2].into()),
                ("debug", log_counts[3].into()),
            ]),
        ),
        ("runs", runs_json),
    ])
}

/// The Chrome trace-event document (`scenario_<name>_trace.json`).
pub fn trace_doc(runs: &[RunObs]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (run_idx, run) in runs.iter().enumerate() {
        let pid = run_idx as u64 + 1;
        events.push(Json::obj(vec![
            ("name", "process_name".into()),
            ("ph", "M".into()),
            ("pid", pid.into()),
            ("args", Json::obj(vec![("name", run.label().as_str().into())])),
        ]));
        let mut tids: BTreeMap<&str, u64> = BTreeMap::new();
        for span in &run.bundle.spans {
            let next = tids.len() as u64 + 1;
            let tid = *tids.entry(span.service.as_str()).or_insert(next);
            for pair in span.marks.windows(2) {
                let (phase, at) = pair[0];
                let (_, end) = pair[1];
                events.push(Json::obj(vec![
                    ("name", phase.name().into()),
                    ("cat", "request".into()),
                    ("ph", "X".into()),
                    ("ts", at.as_micros_f64().into()),
                    ("dur", (end - at).as_micros_f64().into()),
                    ("pid", pid.into()),
                    ("tid", tid.into()),
                    (
                        "args",
                        Json::obj(vec![
                            ("service", span.service.as_str().into()),
                            ("index", span.index.into()),
                        ]),
                    ),
                ]));
            }
        }
        for (name, tid) in tids {
            events.push(Json::obj(vec![
                ("name", "thread_name".into()),
                ("ph", "M".into()),
                ("pid", pid.into()),
                ("tid", tid.into()),
                ("args", Json::obj(vec![("name", name.into())])),
            ]));
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", "ms".into()),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// One span per line (`scenario_<name>_spans.jsonl`).
pub fn spans_jsonl(runs: &[RunObs]) -> String {
    let mut out = String::new();
    for run in runs {
        let label = run.label();
        for span in &run.bundle.spans {
            let marks = Json::arr(span.marks.iter().map(|(p, at)| {
                Json::obj(vec![
                    ("phase", p.name().into()),
                    ("at_ms", at.as_millis_f64().into()),
                ])
            }));
            let mut pairs: Vec<(&str, Json)> = vec![
                ("run", label.as_str().into()),
                ("service", span.service.as_str().into()),
                ("index", span.index.into()),
                ("outcome", span.outcome.name().into()),
            ];
            if let Some(l) = span.latency_ms {
                pairs.push(("latency_ms", l.into()));
            }
            pairs.push(("marks", marks));
            out.push_str(&Json::obj(pairs).to_string_compact());
            out.push('\n');
        }
    }
    out
}

/// The timeline JSON document (`scenario_<name>_timeline.json`).
pub fn timeline_doc(name: &str, runs: &[RunObs]) -> Json {
    let runs_json = Json::arr(runs.iter().map(|r| {
        let samples = Json::arr(r.bundle.timeline.iter().map(|s| {
            Json::obj(vec![
                ("at_ms", s.at.as_millis_f64().into()),
                (
                    "node_ready",
                    Json::arr(s.node_ready.iter().map(|&n| Json::from(u64::from(n)))),
                ),
                (
                    "node_starting",
                    Json::arr(s.node_starting.iter().map(|&n| Json::from(u64::from(n)))),
                ),
                ("activator_depth", s.activator_depth.into()),
                ("in_flight", s.in_flight.into()),
                ("kpa_signal", s.kpa_signal.into()),
            ])
        }));
        Json::obj(vec![
            ("variant", r.variant.as_str().into()),
            ("routing", r.routing.as_str().into()),
            ("policy", r.policy.as_str().into()),
            ("rep", u64::from(r.rep).into()),
            ("dropped", r.bundle.timeline_dropped.into()),
            ("samples", samples),
        ])
    }));
    Json::obj(vec![
        ("kind", "kinetic-timeline".into()),
        ("schema_version", SCHEMA_VERSION.into()),
        ("name", name.into()),
        ("runs", runs_json),
    ])
}

/// Fleet-total gauges as CSV for quick plotting.
pub fn timeline_csv(runs: &[RunObs]) -> String {
    let mut out =
        String::from("run,at_ms,pods_ready,pods_starting,activator_depth,in_flight,kpa_signal\n");
    for run in runs {
        let label = run.label();
        for s in &run.bundle.timeline {
            let ready: u64 = s.node_ready.iter().map(|&n| u64::from(n)).sum();
            let starting: u64 = s.node_starting.iter().map(|&n| u64::from(n)).sum();
            let _ = writeln!(
                out,
                "{label},{},{ready},{starting},{},{},{}",
                s.at.as_millis_f64(),
                s.activator_depth,
                s.in_flight,
                s.kpa_signal
            );
        }
    }
    out
}

/// The self-profile section attached to bench rungs: per-event-kind counts
/// and wall time (only kinds that fired) plus calendar-queue internals.
pub fn profile_doc(profile: &EventProfile, kinds: &[&str]) -> Json {
    let events = Json::arr(profile.counts.iter().enumerate().filter_map(|(i, &c)| {
        if c == 0 {
            return None;
        }
        let wall_ns = profile.wall_ns.get(i).copied().unwrap_or(0);
        let kind = kinds.get(i).copied().unwrap_or("?");
        Some(Json::obj(vec![
            ("kind", kind.into()),
            ("count", c.into()),
            ("wall_ms", (wall_ns as f64 / 1e6).into()),
        ]))
    }));
    Json::obj(vec![
        ("events", events),
        (
            "queue",
            Json::obj(vec![
                ("rebuilds", profile.queue.rebuilds.into()),
                ("entry_scans", profile.queue.entry_scans.into()),
                ("max_bucket", profile.queue.max_bucket.into()),
            ]),
        ),
        ("processed", profile.processed.into()),
    ])
}

// ---------------------------------------------------------------------------
// Strict validators.

fn obj<'a>(j: &'a Json, path: &str) -> Result<&'a BTreeMap<String, Json>, String> {
    j.as_obj().ok_or_else(|| format!("{path}: expected an object"))
}

fn strict_keys(
    m: &BTreeMap<String, Json>,
    path: &str,
    required: &[&str],
    optional: &[&str],
) -> Result<(), String> {
    for k in required {
        if !m.contains_key(*k) {
            return Err(format!("{path}: missing required key '{k}'"));
        }
    }
    for k in m.keys() {
        if !required.contains(&k.as_str()) && !optional.contains(&k.as_str()) {
            return Err(format!("{path}: unknown key '{k}'"));
        }
    }
    Ok(())
}

fn num(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<f64, String> {
    m.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("{path}.{key}: expected a number"))
}

fn uint(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<u64, String> {
    m.get(key)
        .and_then(|v| v.as_u64())
        .ok_or_else(|| format!("{path}.{key}: expected a non-negative integer"))
}

fn string<'a>(m: &'a BTreeMap<String, Json>, path: &str, key: &str) -> Result<&'a str, String> {
    m.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("{path}.{key}: expected a string"))
}

fn arr<'a>(m: &'a BTreeMap<String, Json>, path: &str, key: &str) -> Result<&'a [Json], String> {
    m.get(key)
        .and_then(|v| v.as_arr())
        .map(Vec::as_slice)
        .ok_or_else(|| format!("{path}.{key}: expected an array"))
}

fn check_kind_version(
    m: &BTreeMap<String, Json>,
    path: &str,
    kind: &str,
) -> Result<(), String> {
    let k = string(m, path, "kind")?;
    if k != kind {
        return Err(format!("{path}.kind: expected '{kind}', got '{k}'"));
    }
    let v = uint(m, path, "schema_version")?;
    if v != SCHEMA_VERSION {
        return Err(format!(
            "{path}.schema_version: expected {SCHEMA_VERSION}, got {v}"
        ));
    }
    Ok(())
}

/// Validates a `kinetic-obs` summary document.
pub fn validate_summary(doc: &Json) -> Result<(), String> {
    let m = obj(doc, "$")?;
    strict_keys(
        m,
        "$",
        &["kind", "schema_version", "name", "log_counts", "runs"],
        &[],
    )?;
    check_kind_version(m, "$", "kinetic-obs")?;
    string(m, "$", "name")?;
    let lc = obj(m.get("log_counts").unwrap(), "$.log_counts")?;
    strict_keys(lc, "$.log_counts", &["error", "warn", "info", "debug"], &[])?;
    for k in ["error", "warn", "info", "debug"] {
        uint(lc, "$.log_counts", k)?;
    }
    for (i, run) in arr(m, "$", "runs")?.iter().enumerate() {
        let path = format!("$.runs[{i}]");
        let rm = obj(run, &path)?;
        strict_keys(
            rm,
            &path,
            &[
                "variant",
                "routing",
                "policy",
                "rep",
                "sample_1_in_n",
                "spans",
                "spans_dropped",
                "spans_open",
                "timeline_samples",
                "timeline_dropped",
                "phases",
            ],
            &[],
        )?;
        string(rm, &path, "routing")?;
        string(rm, &path, "policy")?;
        for k in [
            "rep",
            "sample_1_in_n",
            "spans",
            "spans_dropped",
            "spans_open",
            "timeline_samples",
            "timeline_dropped",
        ] {
            uint(rm, &path, k)?;
        }
        for (j, p) in arr(rm, &path, "phases")?.iter().enumerate() {
            let ppath = format!("{path}.phases[{j}]");
            let pm = obj(p, &ppath)?;
            strict_keys(
                pm,
                &ppath,
                &["service", "phase", "count", "total_ms", "mean_ms", "min_ms", "max_ms"],
                &[],
            )?;
            let phase = string(pm, &ppath, "phase")?;
            if Phase::parse(phase).is_none() {
                return Err(format!("{ppath}.phase: unknown phase '{phase}'"));
            }
            uint(pm, &ppath, "count")?;
            for k in ["total_ms", "mean_ms", "min_ms", "max_ms"] {
                num(pm, &ppath, k)?;
            }
        }
    }
    Ok(())
}

/// Validates a Chrome trace-event document.
pub fn validate_trace(doc: &Json) -> Result<(), String> {
    let m = obj(doc, "$")?;
    strict_keys(m, "$", &["displayTimeUnit", "traceEvents"], &[])?;
    for (i, ev) in arr(m, "$", "traceEvents")?.iter().enumerate() {
        let path = format!("$.traceEvents[{i}]");
        let em = obj(ev, &path)?;
        strict_keys(
            em,
            &path,
            &["name", "ph"],
            &["cat", "ts", "dur", "pid", "tid", "args"],
        )?;
        string(em, &path, "name")?;
        match string(em, &path, "ph")? {
            "M" => {}
            "X" => {
                num(em, &path, "ts")?;
                num(em, &path, "dur")?;
                uint(em, &path, "pid")?;
                uint(em, &path, "tid")?;
            }
            other => return Err(format!("{path}.ph: unsupported event type '{other}'")),
        }
    }
    Ok(())
}

/// Validates a `kinetic-timeline` document.
pub fn validate_timeline(doc: &Json) -> Result<(), String> {
    let m = obj(doc, "$")?;
    strict_keys(m, "$", &["kind", "schema_version", "name", "runs"], &[])?;
    check_kind_version(m, "$", "kinetic-timeline")?;
    string(m, "$", "name")?;
    for (i, run) in arr(m, "$", "runs")?.iter().enumerate() {
        let path = format!("$.runs[{i}]");
        let rm = obj(run, &path)?;
        strict_keys(
            rm,
            &path,
            &["variant", "routing", "policy", "rep", "dropped", "samples"],
            &[],
        )?;
        string(rm, &path, "routing")?;
        string(rm, &path, "policy")?;
        uint(rm, &path, "rep")?;
        uint(rm, &path, "dropped")?;
        for (j, s) in arr(rm, &path, "samples")?.iter().enumerate() {
            let spath = format!("{path}.samples[{j}]");
            let sm = obj(s, &spath)?;
            strict_keys(
                sm,
                &spath,
                &[
                    "at_ms",
                    "node_ready",
                    "node_starting",
                    "activator_depth",
                    "in_flight",
                    "kpa_signal",
                ],
                &[],
            )?;
            num(sm, &spath, "at_ms")?;
            num(sm, &spath, "kpa_signal")?;
            uint(sm, &spath, "activator_depth")?;
            uint(sm, &spath, "in_flight")?;
            for k in ["node_ready", "node_starting"] {
                for (n, v) in arr(sm, &spath, k)?.iter().enumerate() {
                    if v.as_u64().is_none() {
                        return Err(format!("{spath}.{k}[{n}]: expected an integer"));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Validates a self-profile section (bench rungs); requires every listed
/// event kind to have fired.
pub fn validate_profile(doc: &Json) -> Result<(), String> {
    let m = obj(doc, "$.profile")?;
    strict_keys(m, "$.profile", &["events", "queue", "processed"], &[])?;
    uint(m, "$.profile", "processed")?;
    let events = arr(m, "$.profile", "events")?;
    if events.is_empty() {
        return Err("$.profile.events: must not be empty".into());
    }
    for (i, ev) in events.iter().enumerate() {
        let path = format!("$.profile.events[{i}]");
        let em = obj(ev, &path)?;
        strict_keys(em, &path, &["kind", "count", "wall_ms"], &[])?;
        string(em, &path, "kind")?;
        if uint(em, &path, "count")? == 0 {
            return Err(format!("{path}.count: must be > 0"));
        }
        num(em, &path, "wall_ms")?;
    }
    let qm = obj(m.get("queue").unwrap(), "$.profile.queue")?;
    strict_keys(
        qm,
        "$.profile.queue",
        &["rebuilds", "entry_scans", "max_bucket"],
        &[],
    )?;
    for k in ["rebuilds", "entry_scans", "max_bucket"] {
        uint(qm, "$.profile.queue", k)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Span, SpanOutcome, TimelineSample};
    use crate::simclock::SimTime;

    fn sample_runs() -> Vec<RunObs> {
        let span = Span {
            service: "fn-0".to_string(),
            index: 3,
            marks: vec![
                (Phase::Submitted, SimTime::from_millis(10)),
                (Phase::Buffered, SimTime::from_millis(11)),
                (Phase::Dispatched, SimTime::from_millis(20)),
            ],
            latency_ms: Some(45.0),
            outcome: SpanOutcome::Completed,
        };
        let tl = TimelineSample {
            at: SimTime::from_secs(1),
            node_ready: vec![2, 0],
            node_starting: vec![0, 1],
            activator_depth: 4,
            in_flight: 3,
            kpa_signal: 3.0,
        };
        vec![RunObs {
            variant: String::new(),
            routing: "least-loaded".to_string(),
            policy: "in-place".to_string(),
            rep: 0,
            bundle: ObsBundle {
                sample_1_in_n: 1,
                spans: vec![span],
                spans_dropped: 0,
                spans_open: 0,
                timeline: vec![tl],
                timeline_dropped: 0,
                profile: EventProfile::new(4),
            },
        }]
    }

    #[test]
    fn summary_round_trips_and_validates() {
        let runs = sample_runs();
        let doc = summary_doc("t", &runs, &[0, 1, 2, 0]);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        validate_summary(&back).unwrap();
        assert_eq!(back.to_string_pretty(), text);
    }

    #[test]
    fn summary_rejects_unknown_keys() {
        let runs = sample_runs();
        let doc = summary_doc("t", &runs, &[0; 4]);
        let mut m = doc.as_obj().unwrap().clone();
        m.insert("extra".to_string(), Json::from(1u64));
        let e = validate_summary(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("extra"), "{e}");
    }

    #[test]
    fn trace_doc_validates_and_slices_phase_intervals() {
        let runs = sample_runs();
        let doc = trace_doc(&runs);
        validate_trace(&doc).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // process_name meta + 2 phase slices + thread_name meta.
        assert_eq!(events.len(), 4);
        let e = &events[1];
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), "submitted");
        assert_eq!(e.get("ts").unwrap().as_f64().unwrap(), 10_000.0);
        assert_eq!(e.get("dur").unwrap().as_f64().unwrap(), 1_000.0);
    }

    #[test]
    fn trace_rejects_unknown_event_keys() {
        let doc = Json::parse(
            r#"{"displayTimeUnit":"ms","traceEvents":[
                {"name":"x","ph":"X","ts":1,"dur":2,"pid":1,"tid":1,"zz":9}]}"#,
        )
        .unwrap();
        let e = validate_trace(&doc).unwrap_err();
        assert!(e.contains("zz"), "{e}");
    }

    #[test]
    fn timeline_json_and_csv_agree_on_totals() {
        let runs = sample_runs();
        let doc = timeline_doc("t", &runs);
        validate_timeline(&doc).unwrap();
        let csv = timeline_csv(&runs);
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "run,at_ms,pods_ready,pods_starting,activator_depth,in_flight,kpa_signal"
        );
        assert_eq!(
            lines.next().unwrap(),
            "least-loaded/in-place,1000,2,1,4,3,3"
        );
    }

    #[test]
    fn timeline_rejects_unknown_sample_keys() {
        let runs = sample_runs();
        let doc = timeline_doc("t", &runs);
        let mut m = doc.as_obj().unwrap().clone();
        let runs_arr = m.get_mut("runs").unwrap();
        if let Json::Arr(rs) = runs_arr {
            if let Json::Obj(rm) = &mut rs[0] {
                if let Some(Json::Arr(ss)) = rm.get_mut("samples") {
                    if let Json::Obj(sm) = &mut ss[0] {
                        sm.insert("bogus".to_string(), Json::from(1u64));
                    }
                }
            }
        }
        let e = validate_timeline(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("bogus"), "{e}");
    }

    #[test]
    fn spans_jsonl_is_one_parseable_object_per_line() {
        let runs = sample_runs();
        let text = spans_jsonl(&runs);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("service").unwrap().as_str().unwrap(), "fn-0");
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "completed");
        assert_eq!(j.get("marks").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn phase_rows_telescope_to_marked_interval() {
        let runs = sample_runs();
        let rows = phase_rows(&runs[0].bundle);
        let total: f64 = rows.iter().map(|r| r.total_ms()).sum();
        assert_eq!(total, runs[0].bundle.spans[0].marked_ms());
        assert_eq!(rows.len(), 2); // submitted→buffered, buffered→dispatched
    }

    #[test]
    fn profile_doc_validates_and_skips_idle_kinds() {
        let mut p = EventProfile::new(3);
        p.record(0, std::time::Duration::from_micros(5));
        p.record(0, std::time::Duration::from_micros(5));
        p.record(2, std::time::Duration::from_micros(1));
        p.processed = 3;
        let doc = profile_doc(&p, &["A", "B", "C"]);
        validate_profile(&doc).unwrap();
        let events = doc.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("kind").unwrap().as_str().unwrap(), "A");
        assert_eq!(events[0].get("count").unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn profile_rejects_zero_counts_and_unknown_keys() {
        let doc = Json::parse(
            r#"{"events":[{"kind":"A","count":0,"wall_ms":1}],
                "queue":{"rebuilds":0,"entry_scans":0,"max_bucket":0},
                "processed":1}"#,
        )
        .unwrap();
        let e = validate_profile(&doc).unwrap_err();
        assert!(e.contains("count"), "{e}");
        let doc = Json::parse(
            r#"{"events":[{"kind":"A","count":1,"wall_ms":1}],
                "queue":{"rebuilds":0,"entry_scans":0,"max_bucket":0,"depth":2},
                "processed":1}"#,
        )
        .unwrap();
        let e = validate_profile(&doc).unwrap_err();
        assert!(e.contains("depth"), "{e}");
    }
}
