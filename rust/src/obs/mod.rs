//! Observability subsystem: request-lifecycle spans, timeline gauges, and
//! simulator self-profiling.
//!
//! The subsystem is **armed only on demand** — a spec's `observe` section or
//! `kinetic run --observe` — and is built around one hard invariant: arming
//! it must never perturb the simulation. Every stamp is a read-only probe
//! behind `if let Some(obs) = &mut w.obs`; nothing here draws from the
//! platform RNG, schedules state-changing events, or touches metrics, so an
//! observe-on run emits a byte-for-byte identical scenario report to an
//! observe-off run (pinned by `tests/obs.rs`).
//!
//! Three planes:
//!
//! 1. **Request-lifecycle spans** ([`Span`]) — a per-request phase ledger
//!    (submitted → buffered → dispatched → completed, plus the fault-path
//!    phases) stamped at the existing hook points in
//!    `coordinator/{platform,routing,lifecycle,resize}.rs` and `faults/`.
//!    Sampling is deterministic per (seed, service): each service keeps an
//!    arrival counter and samples one request in `sample_1_in_n`, with the
//!    block offset drawn once from an RNG seeded
//!    `seed ^ OBS_RNG_SALT ^ fnv1a(service_name)` — per-service state makes
//!    the choice independent of shard count (a service's arrival order
//!    within its home cell is the same at any `--shards N`). Closed spans
//!    land in a bounded ring so multi-million-request replays stay O(ring).
//! 2. **Timeline gauges** ([`TimelineSample`]) — a cadence-driven sampler
//!    (its own `Event::ObsTick` variant through the calendar queue, handler
//!    strictly read-only) recording pods-by-state per node, activator queue
//!    depth, in-flight concurrency, and the KPA concurrency signal.
//! 3. **Simulator self-profiling** ([`EventProfile`]) — per-`Event`-variant
//!    dispatch counts and wall-time plus [`CalendarQueue`] internals
//!    (rebuilds, entry scans, max bucket occupancy), surfaced in
//!    `kinetic bench --json` rungs and rendered by `kinetic profile`.
//!
//! [`CalendarQueue`]: crate::simclock::CalendarQueue

pub mod export;

use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::simclock::{QueueStats, SimTime};
use crate::util::rng::Rng;

/// Salt folded into the observation sampling seed so the sampler's single
/// per-service draw can never collide with a simulation stream (same
/// discipline as `FAULT_RNG_SALT`).
pub const OBS_RNG_SALT: u64 = 0x0B5E_ACE5_A110_CA7E;

/// FNV-1a over a service name — folds the name into the per-service
/// sampling seed so the sampled subset is a function of (seed, service),
/// not of submission interleaving or shard layout.
pub fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Knobs of the `observe` spec section (strictly parsed in
/// `scenario/spec.rs`). The three plane toggles are internal — the spec
/// arms all planes; `kinetic bench` uses [`ObserveConfig::profile_only`].
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveConfig {
    /// Sample one request in `n` per service (1 = every request).
    pub sample_1_in_n: u64,
    /// Closed-span ring capacity per run (per cell when sharded).
    pub max_spans: u64,
    /// Timeline gauge sampling cadence.
    pub timeline_cadence: SimTime,
    /// Timeline ring capacity per run (per cell when sharded).
    pub max_timeline: u64,
    /// Plane toggles (not spec-exposed; default all-on).
    pub spans: bool,
    pub timeline: bool,
    pub profile: bool,
}

impl Default for ObserveConfig {
    fn default() -> ObserveConfig {
        ObserveConfig {
            sample_1_in_n: 1,
            max_spans: 65_536,
            timeline_cadence: SimTime::from_secs(1),
            max_timeline: 65_536,
            spans: true,
            timeline: true,
            profile: true,
        }
    }
}

impl ObserveConfig {
    /// Engine self-profiling only — what `kinetic bench` arms so the span
    /// and timeline planes cost nothing on the scale ladder.
    pub fn profile_only() -> ObserveConfig {
        ObserveConfig {
            spans: false,
            timeline: false,
            ..ObserveConfig::default()
        }
    }
}

/// A lifecycle phase mark. Marks are appended in event order; the exported
/// breakdown attributes the interval up to the next mark to the phase being
/// exited, so per-span phase sums telescope to `last.at - first.at` and can
/// never exceed the end-to-end latency (which additionally includes the
/// proxy forward/respond hops outside the marked window).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Request accepted by the platform (ingress, before the forward hop).
    Submitted,
    /// Arrived with a ready pod available — dispatched without buffering.
    Scheduled,
    /// Parked in the activator queue (no pod had a free slot).
    Buffered,
    /// Buffered behind an on-demand cold start this request triggered.
    StartupWait,
    /// In-flight work evicted by a node crash.
    Evicted,
    /// Re-parked at the activator after eviction (`crash_requests=requeue`).
    Requeued,
    /// Re-dispatched onto surviving capacity after a requeue.
    Rescheduled,
    /// Dispatch triggered an in-place resize; executing under the parked
    /// allocation until the patch lands.
    ResizeWait,
    /// The in-place resize patch landed on the serving pod.
    ResizeLanded,
    /// Handed to a pod's queue-proxy; execution starts.
    Dispatched,
    /// Response produced (terminal).
    Completed,
    /// Failed: buffer overflow or `crash_requests=fail` (terminal).
    Failed,
}

impl Phase {
    pub const ALL: [Phase; 12] = [
        Phase::Submitted,
        Phase::Scheduled,
        Phase::Buffered,
        Phase::StartupWait,
        Phase::Evicted,
        Phase::Requeued,
        Phase::Rescheduled,
        Phase::ResizeWait,
        Phase::ResizeLanded,
        Phase::Dispatched,
        Phase::Completed,
        Phase::Failed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Submitted => "submitted",
            Phase::Scheduled => "scheduled",
            Phase::Buffered => "buffered",
            Phase::StartupWait => "startup-wait",
            Phase::Evicted => "evicted",
            Phase::Requeued => "requeued",
            Phase::Rescheduled => "rescheduled",
            Phase::ResizeWait => "resize-wait",
            Phase::ResizeLanded => "resize-landed",
            Phase::Dispatched => "dispatched",
            Phase::Completed => "completed",
            Phase::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Terminal state of a span when the run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Still in flight when observation stopped (truncated).
    Open,
    Completed,
    Failed,
}

impl SpanOutcome {
    pub fn name(self) -> &'static str {
        match self {
            SpanOutcome::Open => "open",
            SpanOutcome::Completed => "completed",
            SpanOutcome::Failed => "failed",
        }
    }
}

/// One sampled request's phase ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub service: String,
    /// Per-service arrival index (0-based) — stable across shard counts.
    pub index: u64,
    pub marks: Vec<(Phase, SimTime)>,
    /// End-to-end latency as the report records it (includes the proxy
    /// respond hop beyond the last mark); `None` until completed.
    pub latency_ms: Option<f64>,
    pub outcome: SpanOutcome,
}

impl Span {
    /// `last mark - first mark` in ms — the telescoped sum of all phase
    /// intervals, by construction ≤ the end-to-end latency.
    pub fn marked_ms(&self) -> f64 {
        match (self.marks.first(), self.marks.last()) {
            (Some((_, a)), Some((_, b))) => (*b - *a).as_millis_f64(),
            _ => 0.0,
        }
    }
}

/// One timeline gauge sample (read-only snapshot of fleet state).
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineSample {
    pub at: SimTime,
    /// Ready (running) pods per node index.
    pub node_ready: Vec<u32>,
    /// Starting (scheduled, not yet ready) pods per node index.
    pub node_starting: Vec<u32>,
    /// Requests parked across all activators.
    pub activator_depth: u64,
    /// Requests executing on pods.
    pub in_flight: u64,
    /// The KPA input signal: observed concurrency summed over services.
    pub kpa_signal: f64,
}

/// Per-`Event`-variant dispatch counts and wall time, plus calendar-queue
/// internals. Counts are deterministic for a given run; wall times are
/// real-machine measurements and vary run to run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EventProfile {
    pub counts: Vec<u64>,
    pub wall_ns: Vec<u64>,
    pub queue: QueueStats,
    pub processed: u64,
}

impl EventProfile {
    pub fn new(kinds: usize) -> EventProfile {
        EventProfile {
            counts: vec![0; kinds],
            wall_ns: vec![0; kinds],
            queue: QueueStats::default(),
            processed: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, kind: usize, wall: std::time::Duration) {
        if kind < self.counts.len() {
            self.counts[kind] += 1;
            self.wall_ns[kind] += wall.as_nanos() as u64;
        }
    }

    /// Folds another profile in (sharded cells, bench aggregation).
    pub fn merge(&mut self, other: &EventProfile) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
            self.wall_ns.resize(other.wall_ns.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        for (i, w) in other.wall_ns.iter().enumerate() {
            self.wall_ns[i] += w;
        }
        self.queue.rebuilds += other.queue.rebuilds;
        self.queue.entry_scans += other.queue.entry_scans;
        self.queue.max_bucket = self.queue.max_bucket.max(other.queue.max_bucket);
        self.processed += other.processed;
    }
}

/// Everything one observed run produced — harvested from the platform after
/// the engine drains (per cell when sharded, then merged in canonical cell
/// order by [`ObsBundle::merge`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObsBundle {
    pub sample_1_in_n: u64,
    /// Closed spans in canonical (service, index) order.
    pub spans: Vec<Span>,
    /// Spans evicted from the ring (oldest-first) to stay bounded.
    pub spans_dropped: u64,
    /// Spans still open when observation stopped.
    pub spans_open: u64,
    pub timeline: Vec<TimelineSample>,
    pub timeline_dropped: u64,
    pub profile: EventProfile,
}

impl ObsBundle {
    /// Merges per-cell bundles in canonical cell (index) order, then
    /// re-sorts spans into the global (service, index) order so the span
    /// plane is byte-identical at any shard count.
    pub fn merge(cells: Vec<ObsBundle>) -> ObsBundle {
        let mut out = ObsBundle::default();
        for cell in cells {
            out.sample_1_in_n = out.sample_1_in_n.max(cell.sample_1_in_n);
            out.spans.extend(cell.spans);
            out.spans_dropped += cell.spans_dropped;
            out.spans_open += cell.spans_open;
            out.timeline.extend(cell.timeline);
            out.timeline_dropped += cell.timeline_dropped;
            out.profile.merge(&cell.profile);
        }
        sort_spans(&mut out.spans);
        out.timeline.sort_by(|a, b| a.at.cmp(&b.at));
        out
    }
}

fn sort_spans(spans: &mut [Span]) {
    spans.sort_by(|a, b| a.service.cmp(&b.service).then(a.index.cmp(&b.index)));
}

/// Deterministic per-service sampler state.
#[derive(Debug, Clone)]
struct Sampler {
    count: u64,
    offset: u64,
}

impl Sampler {
    fn new(seed: u64, name: &str, n: u64) -> Sampler {
        let offset = if n <= 1 {
            0
        } else {
            Rng::new(seed ^ OBS_RNG_SALT ^ fnv1a(name)).below(n)
        };
        Sampler { count: 0, offset }
    }
}

/// The armed observation state carried by a `Platform`. `None` (the
/// default) is observe-off: every probe site is a single branch.
#[derive(Debug, Clone)]
pub struct ObsState {
    cfg: ObserveConfig,
    seed: u64,
    /// Simulation time when the plane was armed (end of the settle run).
    /// Every exported timestamp is relative to it: cell-local clocks drift
    /// apart with per-cell startup jitter, so window-relative stamps are
    /// what makes sharded span output identical at any `--shards N`.
    origin: SimTime,
    /// Absolute time of the last non-`ObsTick` event dispatched — the
    /// end-of-run clock an observed run reports at. Trailing cadence ticks
    /// fire up to one period past the workload, so the engine clock alone
    /// would stretch time-averaged report gauges and break byte identity
    /// with the unobserved run.
    last_real: SimTime,
    samplers: Vec<Option<Sampler>>,
    open: BTreeMap<u64, Span>,
    closed: VecDeque<Span>,
    dropped: u64,
    timeline: Vec<TimelineSample>,
    timeline_dropped: u64,
    profile: EventProfile,
}

impl ObsState {
    pub fn new(cfg: ObserveConfig, seed: u64, event_kinds: usize, origin: SimTime) -> ObsState {
        let profile = EventProfile::new(event_kinds);
        ObsState {
            cfg,
            seed,
            origin,
            last_real: origin,
            samplers: Vec::new(),
            open: BTreeMap::new(),
            closed: VecDeque::new(),
            dropped: 0,
            timeline: Vec::new(),
            timeline_dropped: 0,
            profile,
        }
    }

    pub fn cfg(&self) -> &ObserveConfig {
        &self.cfg
    }

    pub fn spans_enabled(&self) -> bool {
        self.cfg.spans
    }

    pub fn timeline_enabled(&self) -> bool {
        self.cfg.timeline
    }

    pub fn profile_enabled(&self) -> bool {
        self.cfg.profile
    }

    /// Records that a non-`ObsTick` event was dispatched at `now`
    /// (absolute simulation time).
    pub fn note_real_event(&mut self, now: SimTime) {
        self.last_real = now;
    }

    /// Absolute time of the last real (non-`ObsTick`) event — the clock an
    /// observed run harvests metrics at, matching the unobserved run.
    pub fn last_real_event(&self) -> SimTime {
        self.last_real
    }

    /// Submission probe: advances the service's arrival counter and opens a
    /// span when the deterministic sampler selects this request.
    pub fn on_submit(&mut self, req: u64, service_idx: usize, name: &str, now: SimTime) {
        if !self.cfg.spans {
            return;
        }
        let now = now.saturating_sub(self.origin);
        if self.samplers.len() <= service_idx {
            self.samplers.resize(service_idx + 1, None);
        }
        let n = self.cfg.sample_1_in_n.max(1);
        let seed = self.seed;
        let s = self.samplers[service_idx]
            .get_or_insert_with(|| Sampler::new(seed, name, n));
        let index = s.count;
        s.count += 1;
        if index % n != s.offset {
            return;
        }
        self.open.insert(
            req,
            Span {
                service: name.to_string(),
                index,
                marks: vec![(Phase::Submitted, now)],
                latency_ms: None,
                outcome: SpanOutcome::Open,
            },
        );
    }

    /// Appends a phase mark to the request's open span, if it is sampled.
    #[inline]
    pub fn mark(&mut self, req: u64, phase: Phase, now: SimTime) {
        if let Some(span) = self.open.get_mut(&req) {
            span.marks.push((phase, now.saturating_sub(self.origin)));
        }
    }

    /// Whether the open span's most recent mark is `phase` (drives the
    /// requeue → rescheduled transition at dispatch).
    pub fn last_mark_is(&self, req: u64, phase: Phase) -> bool {
        self.open
            .get(&req)
            .and_then(|s| s.marks.last())
            .is_some_and(|(p, _)| *p == phase)
    }

    /// Request ids with open spans — for probes that only know the pod
    /// (e.g. a resize landing) and need the platform's request table to
    /// find the affected requests.
    pub fn open_ids(&self) -> Vec<u64> {
        self.open.keys().copied().collect()
    }

    /// Terminal probe: stamps the final mark and moves the span into the
    /// bounded ring.
    pub fn close(&mut self, req: u64, outcome: SpanOutcome, latency_ms: Option<f64>, now: SimTime) {
        let Some(mut span) = self.open.remove(&req) else {
            return;
        };
        let phase = match outcome {
            SpanOutcome::Completed => Phase::Completed,
            _ => Phase::Failed,
        };
        span.marks.push((phase, now.saturating_sub(self.origin)));
        span.latency_ms = latency_ms;
        span.outcome = outcome;
        self.push_closed(span);
    }

    fn push_closed(&mut self, span: Span) {
        if self.closed.len() as u64 >= self.cfg.max_spans {
            self.closed.pop_front();
            self.dropped += 1;
        }
        self.closed.push_back(span);
    }

    /// Timeline probe (called from the `ObsTick` handler). The sample's
    /// timestamp is re-based onto the measured window like span marks.
    pub fn record_timeline(&mut self, mut sample: TimelineSample) {
        if self.timeline.len() as u64 >= self.cfg.max_timeline {
            self.timeline_dropped += 1;
            return;
        }
        sample.at = sample.at.saturating_sub(self.origin);
        self.timeline.push(sample);
    }

    #[inline]
    pub fn profile_mut(&mut self) -> &mut EventProfile {
        &mut self.profile
    }

    /// Harvests the run's observation data. Spans still open are exported
    /// with outcome `open`; spans sort into canonical (service, index)
    /// order so output is independent of completion interleaving.
    pub fn finish(mut self, queue: QueueStats, processed: u64) -> ObsBundle {
        let spans_open = self.open.len() as u64;
        let open: Vec<Span> = std::mem::take(&mut self.open).into_values().collect();
        for span in open {
            self.push_closed(span);
        }
        let mut spans: Vec<Span> = self.closed.into();
        sort_spans(&mut spans);
        self.profile.queue = queue;
        self.profile.processed = processed;
        ObsBundle {
            sample_1_in_n: self.cfg.sample_1_in_n.max(1),
            spans,
            spans_dropped: self.dropped,
            spans_open,
            timeline: self.timeline,
            timeline_dropped: self.timeline_dropped,
            profile: self.profile,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(n: u64, cap: u64) -> ObsState {
        let cfg = ObserveConfig {
            sample_1_in_n: n,
            max_spans: cap,
            ..ObserveConfig::default()
        };
        ObsState::new(cfg, 42, 4, SimTime::ZERO)
    }

    #[test]
    fn sample_every_request_opens_and_closes_spans() {
        let mut o = state(1, 100);
        o.on_submit(7, 0, "fn-0", SimTime::from_millis(1));
        o.mark(7, Phase::Buffered, SimTime::from_millis(2));
        o.mark(7, Phase::Dispatched, SimTime::from_millis(5));
        o.close(7, SpanOutcome::Completed, Some(9.5), SimTime::from_millis(8));
        let b = o.finish(QueueStats::default(), 10);
        assert_eq!(b.spans.len(), 1);
        let s = &b.spans[0];
        assert_eq!(s.service, "fn-0");
        assert_eq!(s.index, 0);
        assert_eq!(s.outcome, SpanOutcome::Completed);
        assert_eq!(s.marks.len(), 4);
        assert_eq!(s.marked_ms(), 7.0);
        assert!(s.marked_ms() <= s.latency_ms.unwrap());
    }

    #[test]
    fn sampling_is_deterministic_per_seed_and_service() {
        let pick = |seed: u64| -> Vec<u64> {
            let cfg = ObserveConfig {
                sample_1_in_n: 4,
                ..ObserveConfig::default()
            };
            let mut o = ObsState::new(cfg, seed, 4, SimTime::ZERO);
            for i in 0..32u64 {
                o.on_submit(i, 0, "fn-0", SimTime::from_millis(i));
                o.close(i, SpanOutcome::Completed, Some(1.0), SimTime::from_millis(i + 1));
            }
            o.finish(QueueStats::default(), 0)
                .spans
                .iter()
                .map(|s| s.index)
                .collect()
        };
        let a = pick(42);
        assert_eq!(a, pick(42), "same seed must sample identically");
        assert_eq!(a.len(), 8, "1-in-4 of 32 arrivals");
        // Offsets within blocks of 4 are congruent.
        let off = a[0] % 4;
        assert!(a.iter().all(|i| i % 4 == off));
    }

    #[test]
    fn ring_bounds_memory_and_counts_drops() {
        let mut o = state(1, 3);
        for i in 0..10u64 {
            o.on_submit(i, 0, "fn-0", SimTime::from_millis(i));
            o.close(i, SpanOutcome::Completed, Some(1.0), SimTime::from_millis(i + 1));
        }
        let b = o.finish(QueueStats::default(), 0);
        assert_eq!(b.spans.len(), 3);
        assert_eq!(b.spans_dropped, 7);
        // The ring keeps the newest spans.
        assert_eq!(b.spans[0].index, 7);
    }

    #[test]
    fn open_spans_truncate_at_finish() {
        let mut o = state(1, 10);
        o.on_submit(1, 0, "fn-0", SimTime::ZERO);
        let b = o.finish(QueueStats::default(), 0);
        assert_eq!(b.spans_open, 1);
        assert_eq!(b.spans[0].outcome, SpanOutcome::Open);
        assert_eq!(b.spans[0].latency_ms, None);
    }

    #[test]
    fn merge_is_canonical_and_shard_invariant() {
        let span = |svc: &str, idx: u64| Span {
            service: svc.to_string(),
            index: idx,
            marks: vec![(Phase::Submitted, SimTime::ZERO)],
            latency_ms: Some(1.0),
            outcome: SpanOutcome::Completed,
        };
        let cell_a = ObsBundle {
            sample_1_in_n: 1,
            spans: vec![span("fn-1", 0), span("fn-1", 1)],
            ..ObsBundle::default()
        };
        let cell_b = ObsBundle {
            sample_1_in_n: 1,
            spans: vec![span("fn-0", 0)],
            ..ObsBundle::default()
        };
        let merged = ObsBundle::merge(vec![cell_a.clone(), cell_b.clone()]);
        let merged_rev = ObsBundle::merge(vec![cell_b, cell_a]);
        assert_eq!(merged, merged_rev);
        assert_eq!(merged.spans[0].service, "fn-0");
    }

    #[test]
    fn profile_merge_sums_counts_and_maxes_occupancy() {
        let mut a = EventProfile::new(2);
        a.record(0, std::time::Duration::from_nanos(5));
        a.queue.max_bucket = 3;
        let mut b = EventProfile::new(2);
        b.record(0, std::time::Duration::from_nanos(7));
        b.record(1, std::time::Duration::from_nanos(1));
        b.queue.max_bucket = 9;
        a.merge(&b);
        assert_eq!(a.counts, vec![2, 1]);
        assert_eq!(a.wall_ns[0], 12);
        assert_eq!(a.queue.max_bucket, 9);
    }

    #[test]
    fn fnv1a_separates_names() {
        assert_ne!(fnv1a("fn-0"), fnv1a("fn-1"));
        assert_eq!(fnv1a("fn-0"), fnv1a("fn-0"));
    }
}
