//! The platform-wide calibration bundle: every latency constant in one
//! place, each annotated with the paper measurement it was fitted to.

use crate::cgroup::latency::LatencyModel;
use crate::cluster::kubelet::StartupParams;
use crate::knative::queue_proxy::ProxyParams;
use crate::simclock::SimTime;
use crate::util::json::Json;

/// All tunables of the simulated platform.
#[derive(Debug, Clone)]
pub struct PlatformParams {
    /// Cold-start pipeline (fitted to Table 3 "Cold" ratios).
    pub startup: StartupParams,
    /// Proxy-hop costs (fitted to Table 3 "Warm" ratios).
    pub proxy: ProxyParams,
    /// In-place resize propagation (fitted to Figures 2–4).
    pub resize: LatencyModel,
    /// Queue-proxy hook retry period when a resize patch conflicts with one
    /// already in flight (kubelet applies pod resizes serially).
    pub resize_retry: SimTime,
    /// Autoscaler evaluation period (Knative ticks at 2 s).
    pub autoscaler_tick: SimTime,
    /// RNG seed for the whole platform.
    pub seed: u64,
}

impl Default for PlatformParams {
    fn default() -> Self {
        PlatformParams {
            startup: StartupParams::default(),
            proxy: ProxyParams::default(),
            resize: LatencyModel::default(),
            resize_retry: SimTime::from_millis(25),
            autoscaler_tick: SimTime::from_secs(2),
            seed: 42,
        }
    }
}

impl PlatformParams {
    pub fn with_seed(seed: u64) -> PlatformParams {
        PlatformParams {
            seed,
            ..PlatformParams::default()
        }
    }

    /// Serializes the calibration for experiment records (EXPERIMENTS.md
    /// provenance).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", self.seed.into()),
            (
                "startup_ms",
                Json::obj(vec![
                    ("schedule", self.startup.schedule_ms.into()),
                    ("sandbox", self.startup.sandbox_ms.into()),
                    ("image_cached", self.startup.image_cached_ms.into()),
                    ("container_start", self.startup.container_start_ms.into()),
                ]),
            ),
            (
                "proxy_ms",
                Json::obj(vec![
                    ("forward", self.proxy.forward_ms.into()),
                    ("respond", self.proxy.respond_ms.into()),
                    ("hook_dispatch", self.proxy.hook_dispatch_ms.into()),
                ]),
            ),
            (
                "resize_ms",
                Json::obj(vec![
                    ("api_commit", self.resize.params.api_commit_ms.into()),
                    ("sync_mean", self.resize.params.sync_mean_ms.into()),
                    ("sync_std", self.resize.params.sync_std_ms.into()),
                    ("stress_up", self.resize.params.stress_up_ms.into()),
                    ("stress_down", self.resize.params.stress_down_ms.into()),
                ]),
            ),
            ("resize_retry_ms", self.resize_retry.as_millis_f64().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_consistent() {
        let p = PlatformParams::default();
        assert!(p.resize_retry < SimTime::from_millis(100));
        assert!(p.startup.sandbox_ms > 0.0);
    }

    #[test]
    fn json_round_trips() {
        let p = PlatformParams::with_seed(7);
        let j = p.to_json();
        assert_eq!(j.req_u64("seed").unwrap(), 7);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.req_u64("seed").unwrap(), 7);
        assert!(parsed.get("resize_ms").unwrap().req_f64("sync_mean").unwrap() > 0.0);
    }
}
