//! The scheduling policies — the paper's §3 triple plus the forecast-driven
//! pair — and the platform-wide calibration bundle.

pub mod calib;

pub use calib::PlatformParams;

use crate::knative::config::RevisionConfig;

/// The scheduling policies.
///
/// The paper's §3 triple (all *reactive*):
///
/// * `Cold` — scale-to-zero; a request arriving with no live handler pays
///   the full pod startup pipeline.
/// * `Warm` — `min-scale: 1`; one pod always ready at full allocation.
/// * `InPlace` — one pod kept, parked at 1 m CPU; the queue-proxy hooks
///   resize it to the serving allocation before redirecting each request
///   and park it again when the pod goes idle.
///
/// The forecast-driven pair (driver-initiated, [`crate::forecast`]):
///
/// * `Pooled` — an n-pod warm pool at full allocation, refilled when a
///   request consumes a pod and trimmed back after the stable window (the
///   pool-based cold-start mitigation of arXiv:1903.12221).
/// * `PredictiveInPlace` — in-place parking plus speculation: the arrival
///   predictor pre-resizes the parked pod to the serving allocation ahead
///   of the forecast arrival and re-parks on mispredictions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    Cold,
    Warm,
    InPlace,
    Pooled,
    PredictiveInPlace,
}

impl Policy {
    /// Every policy the platform knows — the source for CLI/spec error
    /// text, the schema document and exhaustiveness checks. Defaults and
    /// presets compare [`Policy::PAPER`] instead, so growing this list
    /// can never silently change an existing experiment's output.
    pub const ALL: [Policy; 5] = [
        Policy::Cold,
        Policy::Warm,
        Policy::InPlace,
        Policy::Pooled,
        Policy::PredictiveInPlace,
    ];

    /// The paper's §3 triple — the default comparison set everywhere
    /// (spec `policies` default, the `fleet`/`trace`/`paper`/`smoke`
    /// presets, the golden fixture's substrate).
    pub const PAPER: [Policy; 3] = [Policy::Cold, Policy::Warm, Policy::InPlace];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Cold => "cold",
            Policy::Warm => "warm",
            Policy::InPlace => "in-place",
            Policy::Pooled => "pooled",
            Policy::PredictiveInPlace => "predictive-inplace",
        }
    }

    /// The revision configuration this policy deploys with.
    pub fn revision_config(&self) -> RevisionConfig {
        match self {
            Policy::Cold => RevisionConfig::paper_cold(),
            Policy::Warm => RevisionConfig::paper_warm(),
            Policy::InPlace => RevisionConfig::paper_inplace(),
            Policy::Pooled => RevisionConfig::pooled(),
            Policy::PredictiveInPlace => RevisionConfig::predictive_inplace(),
        }
    }

    /// Does this policy install the queue-proxy resize hooks?
    pub fn inplace_hooks(&self) -> bool {
        matches!(self, Policy::InPlace | Policy::PredictiveInPlace)
    }

    /// Does this policy scale to zero when idle?
    pub fn scales_to_zero(&self) -> bool {
        matches!(self, Policy::Cold)
    }

    /// Is this policy driver-managed (carries an arrival predictor and
    /// receives proactive actions from [`crate::forecast::driver`])?
    pub fn predictive(&self) -> bool {
        matches!(self, Policy::Pooled | Policy::PredictiveInPlace)
    }
}

/// `cold|warm|in-place|pooled|predictive-inplace` — derived from
/// [`Policy::ALL`] once, so help and error text can never omit a variant.
pub fn names_pipes() -> &'static str {
    static NAMES: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    NAMES
        .get_or_init(|| {
            Policy::ALL
                .iter()
                .map(|p| p.name())
                .collect::<Vec<_>>()
                .join("|")
        })
        .as_str()
}

impl std::str::FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "cold" => Ok(Policy::Cold),
            "warm" => Ok(Policy::Warm),
            "inplace" | "in-place" => Ok(Policy::InPlace),
            "pooled" => Ok(Policy::Pooled),
            "predictive-inplace" | "predictiveinplace" | "predictive" => {
                Ok(Policy::PredictiveInPlace)
            }
            other => Err(format!(
                "unknown policy: {other} (expected {})",
                names_pipes()
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimTime;

    #[test]
    fn policy_configs_match_paper() {
        assert_eq!(
            Policy::Cold.revision_config().stable_window,
            SimTime::from_secs(6)
        );
        assert_eq!(Policy::Warm.revision_config().min_scale, 1);
        assert!(Policy::InPlace.inplace_hooks());
        assert!(!Policy::Warm.inplace_hooks());
        assert!(Policy::Cold.scales_to_zero());
        assert!(!Policy::InPlace.scales_to_zero());
    }

    #[test]
    fn predictive_policy_configs() {
        let pooled = Policy::Pooled.revision_config();
        assert!(pooled.min_scale >= 1, "the pool is the replica floor");
        assert_eq!(pooled.min_scale, pooled.forecast.pool_size);
        assert!(pooled.max_scale >= pooled.min_scale);
        assert!(!Policy::Pooled.inplace_hooks());
        assert!(!Policy::Pooled.scales_to_zero());
        assert!(Policy::Pooled.predictive());

        let pinp = Policy::PredictiveInPlace.revision_config();
        assert_eq!(pinp.min_scale, 1);
        assert_eq!(pinp.parked_cpu, crate::util::quantity::MilliCpu(1));
        assert!(Policy::PredictiveInPlace.inplace_hooks());
        assert!(!Policy::PredictiveInPlace.scales_to_zero());
        assert!(Policy::PredictiveInPlace.predictive());

        for p in Policy::PAPER {
            assert!(!p.predictive(), "{p:?} is reactive");
        }
    }

    #[test]
    fn paper_triple_is_a_prefix_of_all() {
        assert_eq!(&Policy::ALL[..3], &Policy::PAPER[..]);
        // Names stay unique.
        let mut names: Vec<&str> = Policy::ALL.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Policy::ALL.len());
    }

    #[test]
    fn parse_policy() {
        assert_eq!("cold".parse::<Policy>().unwrap(), Policy::Cold);
        assert_eq!("in-place".parse::<Policy>().unwrap(), Policy::InPlace);
        assert_eq!("INPLACE".parse::<Policy>().unwrap(), Policy::InPlace);
        assert_eq!("pooled".parse::<Policy>().unwrap(), Policy::Pooled);
        assert_eq!(
            "predictive-inplace".parse::<Policy>().unwrap(),
            Policy::PredictiveInPlace
        );
        assert!("hot".parse::<Policy>().is_err());
    }

    /// Round trip + error text derived from `ALL`, not hand-written.
    #[test]
    fn names_round_trip_and_errors_enumerate_all() {
        for p in Policy::ALL {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p);
        }
        let e = "tepid".parse::<Policy>().unwrap_err();
        for p in Policy::ALL {
            assert!(e.contains(p.name()), "error must list {}: {e}", p.name());
        }
        assert_eq!(names_pipes().split('|').count(), Policy::ALL.len());
    }
}
