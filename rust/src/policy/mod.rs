//! The three scheduling policies of the paper's §3 and the platform-wide
//! calibration bundle.

pub mod calib;

pub use calib::PlatformParams;

use crate::knative::config::RevisionConfig;

/// The §3 policies.
///
/// * `Cold` — scale-to-zero; a request arriving with no live handler pays
///   the full pod startup pipeline.
/// * `Warm` — `min-scale: 1`; one pod always ready at full allocation.
/// * `InPlace` — one pod kept, parked at 1 m CPU; the queue-proxy hooks
///   resize it to the serving allocation before redirecting each request
///   and park it again when the pod goes idle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    Cold,
    Warm,
    InPlace,
}

impl Policy {
    pub const ALL: [Policy; 3] = [Policy::Cold, Policy::Warm, Policy::InPlace];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Cold => "cold",
            Policy::Warm => "warm",
            Policy::InPlace => "in-place",
        }
    }

    /// The revision configuration the paper uses for this policy.
    pub fn revision_config(&self) -> RevisionConfig {
        match self {
            Policy::Cold => RevisionConfig::paper_cold(),
            Policy::Warm => RevisionConfig::paper_warm(),
            Policy::InPlace => RevisionConfig::paper_inplace(),
        }
    }

    /// Does this policy install the queue-proxy resize hooks?
    pub fn inplace_hooks(&self) -> bool {
        matches!(self, Policy::InPlace)
    }

    /// Does this policy scale to zero when idle?
    pub fn scales_to_zero(&self) -> bool {
        matches!(self, Policy::Cold)
    }
}

impl std::str::FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "cold" => Ok(Policy::Cold),
            "warm" => Ok(Policy::Warm),
            "inplace" | "in-place" => Ok(Policy::InPlace),
            other => Err(format!("unknown policy: {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimTime;

    #[test]
    fn policy_configs_match_paper() {
        assert_eq!(
            Policy::Cold.revision_config().stable_window,
            SimTime::from_secs(6)
        );
        assert_eq!(Policy::Warm.revision_config().min_scale, 1);
        assert!(Policy::InPlace.inplace_hooks());
        assert!(!Policy::Warm.inplace_hooks());
        assert!(Policy::Cold.scales_to_zero());
        assert!(!Policy::InPlace.scales_to_zero());
    }

    #[test]
    fn parse_policy() {
        assert_eq!("cold".parse::<Policy>().unwrap(), Policy::Cold);
        assert_eq!("in-place".parse::<Policy>().unwrap(), Policy::InPlace);
        assert_eq!("INPLACE".parse::<Policy>().unwrap(), Policy::InPlace);
        assert!("hot".parse::<Policy>().is_err());
    }
}
