//! Artifact manifest: discovery + parsing of `artifacts/manifest.json`.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug)]
pub enum ArtifactError {
    DirNotFound(Vec<PathBuf>),
    Io(PathBuf, std::io::Error),
    Parse(String),
    NoSuchModel(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::DirNotFound(tried) => write!(
                f,
                "artifacts directory not found (tried {tried:?}); run `make artifacts`"
            ),
            ArtifactError::Io(path, e) => write!(f, "io error reading {}: {e}", path.display()),
            ArtifactError::Parse(s) => write!(f, "manifest parse error: {s}"),
            ArtifactError::NoSuchModel(s) => write!(f, "no such model in manifest: {s}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Expected-output check data emitted by `aot.py` (oracle values on the
/// deterministic example inputs).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCheck {
    pub out0_sum: f64,
    pub out0_first8: Vec<f64>,
    pub out1_first4: Vec<f64>,
    pub tolerance: f64,
}

/// One exported model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    pub file: String,
    /// Input shapes (row-major dims).
    pub input_shapes: Vec<Vec<usize>>,
    pub outputs: usize,
    pub check: ModelCheck,
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    /// Locates the artifacts dir: `$KINETIC_ARTIFACTS`, `./artifacts`, or
    /// `../artifacts` relative to the executable's cwd.
    pub fn discover() -> Result<Manifest, ArtifactError> {
        let mut candidates = Vec::new();
        if let Ok(env) = std::env::var("KINETIC_ARTIFACTS") {
            candidates.push(PathBuf::from(env));
        }
        candidates.push(PathBuf::from("artifacts"));
        candidates.push(PathBuf::from("../artifacts"));
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return Self::load(c);
            }
        }
        Err(ArtifactError::DirNotFound(candidates))
    }

    /// Loads the manifest from a specific directory.
    pub fn load(dir: &Path) -> Result<Manifest, ArtifactError> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| ArtifactError::Io(mpath.clone(), e))?;
        let json = Json::parse(&text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
        let models_json = json
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| ArtifactError::Parse("missing 'models'".into()))?;
        let mut models = Vec::new();
        for (name, m) in models_json {
            let file = m
                .req_str("file")
                .map_err(|e| ArtifactError::Parse(e.to_string()))?
                .to_string();
            let inputs = m
                .req_arr("inputs")
                .map_err(|e| ArtifactError::Parse(e.to_string()))?;
            let mut input_shapes = Vec::new();
            for i in inputs {
                let shape = i
                    .req_arr("shape")
                    .map_err(|e| ArtifactError::Parse(e.to_string()))?
                    .iter()
                    .filter_map(Json::as_u64)
                    .map(|v| v as usize)
                    .collect();
                input_shapes.push(shape);
            }
            let outputs = m
                .req_u64("outputs")
                .map_err(|e| ArtifactError::Parse(e.to_string()))? as usize;
            let chk = m
                .get("check")
                .ok_or_else(|| ArtifactError::Parse("missing 'check'".into()))?;
            let grab = |key: &str| -> Result<Vec<f64>, ArtifactError> {
                Ok(chk
                    .req_arr(key)
                    .map_err(|e| ArtifactError::Parse(e.to_string()))?
                    .iter()
                    .filter_map(Json::as_f64)
                    .collect())
            };
            let check = ModelCheck {
                out0_sum: chk
                    .req_f64("out0_sum")
                    .map_err(|e| ArtifactError::Parse(e.to_string()))?,
                out0_first8: grab("out0_first8")?,
                out1_first4: grab("out1_first4")?,
                tolerance: chk.opt_f64("tolerance", 1e-4),
            };
            models.push(ModelEntry {
                name: name.clone(),
                file,
                input_shapes,
                outputs,
                check,
            });
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry, ArtifactError> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| ArtifactError::NoSuchModel(name.to_string()))
    }

    pub fn hlo_path(&self, entry: &ModelEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "models": {
            "compute": {
              "file": "compute.hlo.txt",
              "inputs": [
                {"shape": [128, 128], "dtype": "float32"},
                {"shape": [128, 128], "dtype": "float32"},
                {"shape": [128], "dtype": "float32"}
              ],
              "outputs": 2,
              "check": {
                "out0_sum": -80.9,
                "out0_first8": [1, 2, 3, 4, 5, 6, 7, 8],
                "out1_first4": [0.1, 0.2, 0.3, 0.4],
                "tolerance": 0.0002
              }
            }
          }
        }"#
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("kinetic-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1);
        let c = m.model("compute").unwrap();
        assert_eq!(c.input_shapes[0], vec![128, 128]);
        assert_eq!(c.input_shapes[2], vec![128]);
        assert_eq!(c.outputs, 2);
        assert_eq!(c.check.out0_first8.len(), 8);
        assert_eq!(c.check.tolerance, 0.0002);
        assert!(m.model("nope").is_err());
        assert!(m.hlo_path(c).ends_with("compute.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_errors() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(matches!(err, ArtifactError::Io(_, _)));
    }

    #[test]
    fn real_artifacts_parse_when_present() {
        // Exercised in CI after `make artifacts`; skips gracefully otherwise.
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.model("compute").is_ok());
        assert!(m.model("watermark").is_ok());
    }
}
