//! The executor: a PJRT CPU client with a per-model compiled-executable
//! cache. Compilation happens once per model variant (at platform start or
//! first use); the request path only queues `execute` calls.
//!
//! The `xla` PJRT bindings come from the offline crate mirror, which not
//! every build machine carries, so the real client is gated behind the
//! `pjrt` cargo feature. Without it the same public surface compiles as an
//! uninstantiable stub whose constructor reports the feature is off —
//! callers (`kinetic serve`, `cargo bench --bench runtime_exec`, the e2e
//! example) already handle `Executor::new` failing because the artifacts
//! may equally be missing.

use std::fmt;

use crate::runtime::artifacts::ArtifactError;

#[derive(Debug)]
pub enum ExecError {
    Artifact(ArtifactError),
    Xla(String),
    InputArity(String, usize, usize),
    InputSize(usize, usize, usize),
    CheckFailed { model: String, detail: String },
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Artifact(e) => write!(f, "artifact error: {e}"),
            ExecError::Xla(s) => write!(f, "xla error: {s}"),
            ExecError::InputArity(model, want, got) => {
                write!(f, "model {model} expects {want} inputs, got {got}")
            }
            ExecError::InputSize(i, want, got) => {
                write!(f, "input {i} expects {want} elements, got {got}")
            }
            ExecError::CheckFailed { model, detail } => {
                write!(f, "numeric check failed for {model}: {detail}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

impl From<ArtifactError> for ExecError {
    fn from(e: ArtifactError) -> Self {
        ExecError::Artifact(e)
    }
}

/// Decoded outputs of one execution: each output flattened to f32.
#[derive(Debug, Clone)]
pub struct Outputs(pub Vec<Vec<f32>>);

impl Outputs {
    pub fn primary(&self) -> &[f32] {
        &self.0[0]
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Executor, Literal};

#[cfg(not(feature = "pjrt"))]
pub use stub::{Executor, Literal};

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;

    use super::{ExecError, Outputs};
    use crate::runtime::artifacts::{ArtifactError, Manifest, ModelEntry};
    use crate::runtime::inputs;

    /// Input literal handed back by [`Executor::prepare_inputs`].
    pub type Literal = xla::Literal;

    impl From<xla::Error> for ExecError {
        fn from(e: xla::Error) -> Self {
            ExecError::Xla(e.to_string())
        }
    }

    /// PJRT client + compiled executable cache.
    pub struct Executor {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Executor {
        /// Builds an executor over a manifest (discovers artifacts when `None`).
        pub fn new(manifest: Option<Manifest>) -> Result<Executor, ExecError> {
            let manifest = match manifest {
                Some(m) => m,
                None => Manifest::discover()?,
            };
            let client = xla::PjRtClient::cpu()?;
            Ok(Executor {
                client,
                manifest,
                cache: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compiles (or fetches from cache) a model's executable.
        pub fn load(&mut self, name: &str) -> Result<(), ExecError> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let entry = self.manifest.model(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        pub fn loaded(&self, name: &str) -> bool {
            self.cache.contains_key(name)
        }

        /// Executes a model with flat-f32 inputs (shapes from the manifest).
        pub fn execute(
            &mut self,
            name: &str,
            flat_inputs: &[&[f32]],
        ) -> Result<Outputs, ExecError> {
            let literals = self.prepare_inputs(name, flat_inputs)?;
            self.execute_prepared(name, &literals)
        }

        /// Builds input literals once for repeated execution (a serving tier
        /// reuses request buffers; `Literal::vec1 + reshape` copies twice per
        /// call otherwise — see EXPERIMENTS.md §Perf).
        pub fn prepare_inputs(
            &mut self,
            name: &str,
            flat_inputs: &[&[f32]],
        ) -> Result<Vec<Literal>, ExecError> {
            let entry = self.manifest.model(name)?.clone();
            if flat_inputs.len() != entry.input_shapes.len() {
                return Err(ExecError::InputArity(
                    name.to_string(),
                    entry.input_shapes.len(),
                    flat_inputs.len(),
                ));
            }
            let mut literals = Vec::with_capacity(flat_inputs.len());
            for (i, (data, shape)) in flat_inputs.iter().zip(&entry.input_shapes).enumerate() {
                let want: usize = shape.iter().product();
                if data.len() != want {
                    return Err(ExecError::InputSize(i, want, data.len()));
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                literals.push(xla::Literal::vec1(data).reshape(&dims)?);
            }
            Ok(literals)
        }

        /// Executes with pre-built literals (the repeated-execution hot path).
        pub fn execute_prepared(
            &mut self,
            name: &str,
            literals: &[Literal],
        ) -> Result<Outputs, ExecError> {
            self.load(name)?;
            let exe = self.cache.get(name).expect("loaded above");
            let result = exe.execute::<xla::Literal>(literals)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True.
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>()?);
            }
            Ok(Outputs(out))
        }

        /// Runs `model` on its deterministic example inputs and validates the
        /// outputs against the oracle values baked into the manifest — the
        /// cross-language numeric check of the whole L1→L2→AOT→PJRT stack.
        pub fn self_check(&mut self, name: &str) -> Result<(), ExecError> {
            let entry = self.manifest.model(name)?.clone();
            let outs = match name {
                "compute" => {
                    let (x, w, b) = inputs::compute_inputs();
                    self.execute(name, &[&x, &w, &b])?
                }
                "watermark" => {
                    let (f, wm, a, g) = inputs::watermark_inputs();
                    self.execute(name, &[&f, &wm, &a, &g])?
                }
                other => {
                    return Err(ExecError::Artifact(ArtifactError::NoSuchModel(
                        other.to_string(),
                    )))
                }
            };
            Self::validate(&entry, &outs)
        }

        fn validate(entry: &ModelEntry, outs: &Outputs) -> Result<(), ExecError> {
            let chk = &entry.check;
            let tol = chk.tolerance.max(1e-9);
            let fail = |detail: String| ExecError::CheckFailed {
                model: entry.name.clone(),
                detail,
            };
            if outs.0.len() != entry.outputs {
                return Err(fail(format!(
                    "expected {} outputs, got {}",
                    entry.outputs,
                    outs.0.len()
                )));
            }
            let sum: f64 = outs.0[0].iter().map(|&v| v as f64).sum();
            let sum_tol = tol * (outs.0[0].len() as f64).sqrt() * 10.0;
            if (sum - chk.out0_sum).abs() > sum_tol.max(chk.out0_sum.abs() * 1e-4) {
                return Err(fail(format!(
                    "out0 sum {} vs expected {}",
                    sum, chk.out0_sum
                )));
            }
            for (i, &want) in chk.out0_first8.iter().enumerate() {
                let got = outs.0[0][i] as f64;
                if (got - want).abs() > tol {
                    return Err(fail(format!("out0[{i}] {got} vs expected {want}")));
                }
            }
            for (i, &want) in chk.out1_first4.iter().enumerate() {
                let got = outs.0[1][i] as f64;
                if (got - want).abs() > tol {
                    return Err(fail(format!("out1[{i}] {got} vs expected {want}")));
                }
            }
            Ok(())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{ExecError, Outputs};
    use crate::runtime::artifacts::Manifest;

    /// Placeholder for `xla::Literal` when the PJRT path is compiled out.
    #[derive(Debug, Clone, Copy)]
    pub struct Literal;

    /// Uninstantiable stand-in: `new` always fails, so the other methods can
    /// never be reached — the `Infallible` field proves it to the compiler.
    pub struct Executor {
        never: std::convert::Infallible,
        manifest: Manifest,
    }

    impl Executor {
        pub fn new(_manifest: Option<Manifest>) -> Result<Executor, ExecError> {
            Err(ExecError::Xla(
                "compiled without the `pjrt` feature; rebuild with --features pjrt \
                 and the mirrored `xla` crate to run real compute"
                    .to_string(),
            ))
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn load(&mut self, _name: &str) -> Result<(), ExecError> {
            match self.never {}
        }

        pub fn loaded(&self, _name: &str) -> bool {
            match self.never {}
        }

        pub fn execute(
            &mut self,
            _name: &str,
            _flat_inputs: &[&[f32]],
        ) -> Result<Outputs, ExecError> {
            match self.never {}
        }

        pub fn prepare_inputs(
            &mut self,
            _name: &str,
            _flat_inputs: &[&[f32]],
        ) -> Result<Vec<Literal>, ExecError> {
            match self.never {}
        }

        pub fn execute_prepared(
            &mut self,
            _name: &str,
            _literals: &[Literal],
        ) -> Result<Outputs, ExecError> {
            match self.never {}
        }

        pub fn self_check(&mut self, _name: &str) -> Result<(), ExecError> {
            match self.never {}
        }
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::inputs;
    use std::path::Path;

    fn artifacts_present() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn compute_self_check_end_to_end() {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut ex = Executor::new(None).unwrap();
        assert_eq!(ex.platform(), "cpu");
        ex.self_check("compute").unwrap();
        assert!(ex.loaded("compute"));
    }

    #[test]
    fn watermark_self_check_end_to_end() {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut ex = Executor::new(None).unwrap();
        ex.self_check("watermark").unwrap();
    }

    #[test]
    fn execute_validates_arity_and_size() {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut ex = Executor::new(None).unwrap();
        let err = ex.execute("compute", &[&[1.0f32]]).unwrap_err();
        assert!(matches!(err, ExecError::InputArity(_, 3, 1)), "{err}");
        let x = vec![0.0f32; 128 * 128];
        let w = vec![0.0f32; 128 * 128];
        let b = vec![0.0f32; 7]; // wrong
        let err = ex.execute("compute", &[&x, &w, &b]).unwrap_err();
        assert!(matches!(err, ExecError::InputSize(2, 128, 7)), "{err}");
    }

    #[test]
    fn executable_cache_reused() {
        if !artifacts_present() {
            return;
        }
        let mut ex = Executor::new(None).unwrap();
        let (x, w, b) = inputs::compute_inputs();
        let t0 = std::time::Instant::now();
        ex.execute("compute", &[&x, &w, &b]).unwrap();
        let first = t0.elapsed();
        let t1 = std::time::Instant::now();
        for _ in 0..3 {
            ex.execute("compute", &[&x, &w, &b]).unwrap();
        }
        let later = t1.elapsed() / 3;
        // Cached executions skip compilation; must be much faster than the
        // first call (which compiled).
        assert!(later < first, "first={first:?} later={later:?}");
    }

    #[test]
    fn watermark_output_in_range() {
        if !artifacts_present() {
            return;
        }
        let mut ex = Executor::new(None).unwrap();
        let (f, wm, a, g) = inputs::watermark_inputs();
        let out = ex.execute("watermark", &[&f, &wm, &a, &g]).unwrap();
        let max = out.primary().iter().cloned().fold(f32::MIN, f32::max);
        assert!(max <= 1.0625 + 1e-5, "max={max}");
        assert_eq!(out.0[1].len(), 4); // per-frame luminance
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_constructor_reports_feature_off() {
        let err = Executor::new(None).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "unexpected message: {msg}");
    }
}
