//! Deterministic example inputs — bit-exact mirrors of
//! `python/compile/model.py::example_*_inputs`, used for end-to-end numeric
//! validation of the AOT bridge without Python in the loop.

/// Mirrors `example_compute_inputs`: x (128×128), w (128×128), b (128).
pub fn compute_inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..128 * 128)
        .map(|i| (i % 17) as f32 * 0.0625 - 0.5)
        .collect();
    let w: Vec<f32> = (0..128 * 128)
        .map(|i| (i % 13) as f32 * 0.03125 - 0.1875)
        .collect();
    let b: Vec<f32> = (0..128).map(|i| (i % 7) as f32 * 0.125 - 0.375).collect();
    (x, w, b)
}

/// Mirrors `example_watermark_inputs`: frames (4×64×256), wm (64×256),
/// alpha (1), gain (1).
pub fn watermark_inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = 4 * 64 * 256;
    let frames: Vec<f32> = (0..n).map(|i| (i % 251) as f32 / 250.0).collect();
    let wm: Vec<f32> = (0..64 * 256).map(|i| (i % 101) as f32 / 100.0).collect();
    (frames, wm, vec![0.25], vec![1.0625])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_inputs_shapes_and_values() {
        let (x, w, b) = compute_inputs();
        assert_eq!(x.len(), 128 * 128);
        assert_eq!(w.len(), 128 * 128);
        assert_eq!(b.len(), 128);
        assert_eq!(x[0], -0.5);
        assert_eq!(x[17], -0.5); // period 17
        assert_eq!(x[1], -0.4375);
        assert_eq!(b[0], -0.375);
        // All values exactly representable multiples of 2^-5.
        assert!(x.iter().all(|v| (v * 32.0).fract() == 0.0));
    }

    #[test]
    fn watermark_inputs_ranges() {
        let (frames, wm, a, g) = watermark_inputs();
        assert_eq!(frames.len(), 4 * 64 * 256);
        assert_eq!(wm.len(), 64 * 256);
        assert!(frames.iter().all(|v| (0.0..=1.0).contains(v)));
        assert!(wm.iter().all(|v| (0.0..=1.0).contains(v)));
        assert_eq!(a, vec![0.25]);
        assert_eq!(g, vec![1.0625]);
    }
}
