//! The PJRT runtime: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time — `make artifacts` is the only place
//! the L1/L2 layers execute; afterwards the `kinetic` binary is
//! self-contained. Interchange is HLO *text* (see `aot.py` for why).

pub mod artifacts;
pub mod executor;
pub mod inputs;

pub use artifacts::{ArtifactError, Manifest, ModelCheck, ModelEntry};
pub use executor::{ExecError, Executor, Outputs};
