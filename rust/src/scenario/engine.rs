//! [`ScenarioEngine`] — compiles a [`ScenarioSpec`] into [`Simulation`]
//! runs and aggregates a [`ScenarioReport`].
//!
//! One engine serves every experiment shape: synthetic per-tenant fleets
//! compile to `experiments::fleet::run_policy`, generated and file-loaded
//! traces to `trace::replay_with`, and the paper's closed-loop rig to
//! `experiments::policies::PolicyExperiment` — so the legacy subcommands
//! become presets over this module and can never drift from `kinetic run`.
//!
//! # Parallel execution
//!
//! A sweep grid is embarrassingly parallel: every cell is an independent
//! deterministic simulation whose seed derives from the *spec* (base seed
//! + rep), never from execution order. [`ScenarioEngine::run_with_threads`]
//! exploits that with scoped `std::thread` workers pulling cells off a
//! shared cursor. Three invariants keep the parallel report bit-identical
//! to the serial one:
//!
//! 1. **Deterministic job inputs.** Closed-loop validation happens
//!    single-threaded in [`prepare_variant`] before any worker starts;
//!    traces build lazily inside the variant's [`TraceStore`] but
//!    deterministically (files re-read byte-identically, generator
//!    traces derive from `seed + rep`), so workers only ever run pure
//!    `(PreparedVariant, routing, policy, rep) → rows` jobs.
//! 2. **Slot-addressed results.** Each job writes its rows into its own
//!    pre-allocated slot; the report concatenates slots in job order, so
//!    scheduling jitter cannot reorder rows.
//! 3. **Derived seeds.** A job's seed is `spec.seed + rep` exactly as the
//!    serial loop computed it — no thread-local or time-derived state.
//!
//! `tests/analysis.rs` pins `--threads 4` to the `--threads 1` report JSON
//! byte-for-byte.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::accounting::RoutingPolicy;
use crate::experiments::fleet::{self, FleetConfig};
use crate::experiments::policies::PolicyExperiment;
use crate::obs::export::RunObs;
use crate::obs::{ObsBundle, ObserveConfig};
use crate::policy::Policy;
use crate::scenario::report::{ScenarioReport, ScenarioRow};
use crate::scenario::spec::{ScenarioSpec, SpecError, TopologySpec, WorkloadSource};
use crate::simclock::SimTime;
use crate::trace::generator::{TraceConfig, TraceEvent, TraceGenerator};
use crate::trace::loader;
use crate::trace::replay::{replay_with_observed, ReplayConfig};
use crate::workload::registry::WorkloadKind;

pub use crate::util::cli::MAX_THREADS;

/// Compiles specs into runs.
pub struct ScenarioEngine;

impl ScenarioEngine {
    /// Resolves `--scenario <arg>`: a preset name, else a JSON file path.
    pub fn load(arg: &str) -> Result<ScenarioSpec, SpecError> {
        if let Some(spec) = crate::scenario::preset::by_name(arg) {
            return Ok(spec);
        }
        ScenarioSpec::load(std::path::Path::new(arg))
    }

    /// Runs the full grid serially: every sweep variant × routing × policy
    /// × rep. Equivalent to `run_with_threads(spec, 1)`.
    pub fn run(spec: &ScenarioSpec) -> Result<ScenarioReport, SpecError> {
        ScenarioEngine::run_with_threads(spec, 1)
    }

    /// Runs the full grid on `threads` scoped workers. The report is
    /// bit-identical to the serial run regardless of `threads` (see the
    /// module docs for why); `threads` is clamped to `[1, MAX_THREADS]`
    /// and never exceeds the number of grid cells.
    pub fn run_with_threads(
        spec: &ScenarioSpec,
        threads: usize,
    ) -> Result<ScenarioReport, SpecError> {
        ScenarioEngine::run_with_options(spec, threads, None)
    }

    /// Runs the full grid with an optional shard-count override for the
    /// sharded multi-coordinator runtime (`crate::shard`). `shards` (the
    /// CLI `--shards` flag) beats the spec's `shards` knob; `None`/no knob
    /// keeps the classic single-coordinator path byte-for-byte. Sharded
    /// reports are themselves byte-identical at any shard count.
    pub fn run_with_options(
        spec: &ScenarioSpec,
        threads: usize,
        shards: Option<u32>,
    ) -> Result<ScenarioReport, SpecError> {
        ScenarioEngine::run_observed(spec, threads, shards, None).map(|(r, _)| r)
    }

    /// [`run_with_options`] plus the observation plane. `observe` is the
    /// *effective* config — the CLI resolves `--observe` vs the spec's
    /// `observe` section before calling; the engine never falls back to
    /// the spec on its own, so library entry points stay observation-free.
    /// The report is byte-identical whether `observe` is set or not; the
    /// per-run [`RunObs`] bundles come back in job order (the same order
    /// rows land in the report).
    pub fn run_observed(
        spec: &ScenarioSpec,
        threads: usize,
        shards: Option<u32>,
        observe: Option<&ObserveConfig>,
    ) -> Result<(ScenarioReport, Vec<RunObs>), SpecError> {
        let shards = shards.or(spec.shards);
        if shards.is_some() {
            if let WorkloadSource::ClosedLoop { .. } = spec.workload {
                return Err(SpecError::invalid(
                    "shards",
                    "closed-loop scenarios run the paper's single-node rig; \
                     sharded execution does not apply — remove the shards \
                     knob or use a synthetic/trace source",
                ));
            }
        }
        let mut prepared = Vec::new();
        for (label, variant) in spec.expand()? {
            prepared.push(prepare_variant(label, variant)?);
        }
        let mut jobs = Vec::new();
        for (vi, p) in prepared.iter().enumerate() {
            for &routing in &p.spec.routing {
                for &policy in &p.spec.policies {
                    for rep in 0..p.spec.reps {
                        jobs.push(Job {
                            variant: vi,
                            routing,
                            policy,
                            rep,
                        });
                    }
                }
            }
        }
        let (rows, bundles) = execute(&prepared, &jobs, threads, shards, observe)?;
        let obs = jobs
            .iter()
            .zip(bundles)
            .filter_map(|(job, bundle)| {
                bundle.map(|bundle| RunObs {
                    variant: prepared[job.variant].label.clone(),
                    routing: job.routing.name().to_string(),
                    policy: job.policy.name().to_string(),
                    rep: job.rep,
                    bundle,
                })
            })
            .collect();
        Ok((
            ScenarioReport {
                name: spec.name.clone(),
                spec: spec.to_json(),
                rows,
            },
            obs,
        ))
    }

    /// The `kinetic exp` policy preset: a closed-loop spec as the exact
    /// [`PolicyExperiment`] the paper tables are rendered from.
    pub fn paper_policy_experiment(spec: &ScenarioSpec) -> Result<PolicyExperiment, SpecError> {
        match spec.workload {
            WorkloadSource::ClosedLoop { iterations, think_s } => Ok(PolicyExperiment {
                iterations,
                think: SimTime::from_secs_f64(think_s),
                seed: spec.seed,
                routing: *spec.routing.first().unwrap_or(&RoutingPolicy::LeastLoaded),
            }),
            _ => Err(SpecError::invalid(
                "workload.type",
                "the paper policy tables need a 'closed-loop' workload source",
            )),
        }
    }
}

/// One grid cell. Executing a job is a pure function of its
/// [`PreparedVariant`] — seeds derive from the spec, never from execution
/// order — so jobs may run on any thread in any order.
#[derive(Debug, Clone, Copy)]
struct Job {
    /// Index into the prepared-variant list.
    variant: usize,
    routing: RoutingPolicy,
    policy: Policy,
    rep: u32,
}

/// A sweep variant with its jobs' shared state: closed-loop restrictions
/// already validated, and a [`TraceStore`] for trace sources.
struct PreparedVariant {
    label: String,
    spec: ScenarioSpec,
    trace: Option<TraceStore>,
}

/// The trace (events, function count) every job of a variant replays —
/// shared read-only across routing × policy so each policy sees the
/// identical arrival stream, the comparison the paper's §3 tables rest on.
type TraceData = (Vec<TraceEvent>, usize);

/// Reference-counted, lazily built trace storage for one variant.
///
/// Slots fill on first checkout — the build is deterministic (files
/// re-read byte-identically; generator traces derive from `seed + rep`),
/// so build order cannot change results — and every checkout decrements
/// a job countdown that drops the slots once the variant's last job has
/// taken its reference. A large sweep therefore holds only the
/// in-flight variants' traces, the serial engine's old memory shape,
/// instead of the whole grid's. I/O errors surface from the variant's
/// first job, exactly where the serial engine raised them.
struct TraceStore {
    inner: Mutex<TraceSlots>,
}

struct TraceSlots {
    /// Jobs that have not yet taken their reference.
    remaining: usize,
    /// One slot per rep (file traces: a single rep-independent slot).
    slots: Vec<Option<Arc<TraceData>>>,
}

impl TraceStore {
    /// `slots` empty slots (1 for rep-independent file traces, one per
    /// rep for the generator) to be taken by `jobs` checkouts.
    fn new(slots: usize, jobs: usize) -> TraceStore {
        TraceStore {
            inner: Mutex::new(TraceSlots {
                remaining: jobs,
                slots: vec![None; slots],
            }),
        }
    }

    /// Hands one job its trace reference, building the slot if it is
    /// still empty and dropping all slots after the last checkout.
    fn checkout(&self, spec: &ScenarioSpec, rep: u32) -> Result<Arc<TraceData>, SpecError> {
        let idx = |s: &TraceSlots| if s.slots.len() == 1 { 0 } else { rep as usize };
        {
            let mut s = self.inner.lock().unwrap();
            let i = idx(&s);
            if let Some(data) = &s.slots[i] {
                let data = Arc::clone(data);
                s.remaining -= 1;
                if s.remaining == 0 {
                    s.slots.clear();
                }
                return Ok(data);
            }
        }
        // Build outside the lock so concurrent jobs of the same variant
        // construct different reps' traces in parallel. Two jobs racing
        // on the *same* empty slot both build (identical, deterministic
        // data); the first to re-lock wins the slot. The slots cannot
        // have been cleared meanwhile: this job has not decremented
        // `remaining` yet, so it is still positive.
        let built = Arc::new(build_trace(spec, rep)?);
        let mut s = self.inner.lock().unwrap();
        let i = idx(&s);
        if s.slots[i].is_none() {
            s.slots[i] = Some(built);
        }
        let data = Arc::clone(s.slots[i].as_ref().expect("slot was just filled"));
        s.remaining -= 1;
        if s.remaining == 0 {
            s.slots.clear();
        }
        Ok(data)
    }
}

fn prepare_variant(label: String, spec: ScenarioSpec) -> Result<PreparedVariant, SpecError> {
    // A warm pool larger than the scale ceiling cannot exist without
    // silently raising the ceiling for the pooled cells only — which
    // would skew every cross-policy comparison in the grid. Reject it
    // (sweeps over pool_size/max_scale are checked per expanded variant).
    if spec.policies.contains(&Policy::Pooled)
        && spec.forecast.pool_size > spec.autoscaler.max_scale
    {
        return Err(SpecError::invalid(
            "forecast.pool_size",
            format!(
                "pool_size {} exceeds autoscaler.max_scale {} — the warm \
                 pool is the replica floor; raise max_scale or shrink the \
                 pool",
                spec.forecast.pool_size, spec.autoscaler.max_scale
            ),
        ));
    }
    // Fault schedules name nodes by index; a crash or straggler aimed past
    // the variant's topology would silently never fire. Reject it
    // (sweeps over topology are checked per expanded variant).
    if let Some(max) = spec.faults.max_node() {
        let nodes = spec.topology.nodes();
        if max as usize >= nodes {
            return Err(SpecError::invalid(
                "faults",
                format!(
                    "fault targets node {max} but the topology has {nodes} \
                     node(s) (indices 0..={})",
                    nodes.saturating_sub(1)
                ),
            ));
        }
    }
    if let WorkloadSource::ClosedLoop { .. } = &spec.workload {
        if spec.topology != TopologySpec::Paper {
            return Err(SpecError::invalid(
                "topology.kind",
                "the closed-loop rig reproduces the paper's single-node \
                 testbed; use topology kind 'paper'",
            ));
        }
        // The rig runs the paper's revision configs verbatim; rather
        // than silently ignore autoscaler/hybrid settings (a swept
        // knob would then run identical variants), reject them.
        if spec.autoscaler != crate::knative::config::ScaleKnobs::fleet_default() {
            return Err(SpecError::invalid(
                "autoscaler",
                "closed-loop scenarios run the paper's per-policy revision \
                 configs; autoscaler knobs (and sweeps over them) do not \
                 apply — remove them or use a synthetic/trace source",
            ));
        }
        if spec.hybrid != crate::coordinator::accounting::HybridWeights::default() {
            return Err(SpecError::invalid(
                "hybrid_weights",
                "closed-loop scenarios are single-pod; hybrid weights do \
                 not apply — remove them or use a synthetic/trace source",
            ));
        }
        // Predictive policies *are* allowed on the rig (they run their
        // revision-config defaults, like the §3 triple), but tuned
        // forecast knobs would be silently ignored — reject instead.
        if spec.forecast != crate::forecast::ForecastConfig::default() {
            return Err(SpecError::invalid(
                "forecast",
                "closed-loop scenarios run the paper's per-policy revision \
                 configs; forecast knobs (and sweeps over them) do not \
                 apply — remove them or use a synthetic/trace source",
            ));
        }
        // The rig drives the coordinator directly (no fleet settle phase
        // to install a fault schedule into); rather than silently ignore
        // a faults section, reject it.
        if spec.faults != crate::faults::FaultsConfig::default() {
            return Err(SpecError::invalid(
                "faults",
                "closed-loop scenarios run the paper's fault-free rig; \
                 fault injection (and sweeps over it) does not apply — \
                 remove it or use a synthetic/trace source",
            ));
        }
        // Routing is provably a no-op on the single-pod paper rig (the
        // golden routing-invariance test pins it), so comparing routing
        // policies here would emit identical rows per policy.
        if spec.routing.len() > 1 {
            return Err(SpecError::invalid(
                "routing",
                "closed-loop scenarios are routing-invariant (single \
                 pod); listing several routing policies would duplicate \
                 every row — keep one",
            ));
        }
    }
    let jobs = spec.routing.len() * spec.policies.len() * spec.reps as usize;
    let trace = match &spec.workload {
        WorkloadSource::TraceFile { .. } => Some(TraceStore::new(1, jobs)),
        WorkloadSource::AzureGenerator { .. } => Some(TraceStore::new(spec.reps as usize, jobs)),
        _ => None,
    };
    Ok(PreparedVariant { label, spec, trace })
}

/// Runs every job and returns the rows (concatenated) plus one optional
/// observation bundle per job, both in job order. `threads <= 1` runs
/// inline (stopping at the first error, like the old serial loop);
/// otherwise scoped workers pull jobs off a shared cursor and write into
/// per-job slots, which serializes the output identically.
fn execute(
    prepared: &[PreparedVariant],
    jobs: &[Job],
    threads: usize,
    shards: Option<u32>,
    observe: Option<&ObserveConfig>,
) -> Result<(Vec<ScenarioRow>, Vec<Option<ObsBundle>>), SpecError> {
    let workers = threads.clamp(1, MAX_THREADS).min(jobs.len().max(1));
    if workers <= 1 {
        let mut rows = Vec::new();
        let mut bundles = Vec::new();
        for job in jobs {
            let (r, b) = run_job(&prepared[job.variant], job, shards, observe)?;
            rows.extend(r);
            bundles.push(b);
        }
        return Ok((rows, bundles));
    }
    let cursor = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let results = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                // A failed job stops the grid; later-queued jobs are
                // skipped (their slots stay None, which is fine — an
                // erroring run returns no rows at all).
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let job = &jobs[i];
                let out = run_job(&prepared[job.variant], job, shards, observe);
                if out.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    let mut rows = Vec::new();
    let mut bundles = Vec::new();
    for slot in results.into_inner().unwrap() {
        match slot {
            Some(Ok((r, b))) => {
                rows.extend(r);
                bundles.push(b);
            }
            Some(Err(e)) => return Err(e),
            // Skipped after a failure elsewhere; the error slot that
            // caused it is found by this same scan.
            None => {}
        }
    }
    Ok((rows, bundles))
}

/// Executes one grid cell: a full deterministic simulation. Closed-loop
/// cells expand to one row per Table-2 workload; everything else is one
/// row per cell. The only fallible part is trace checkout (a missing or
/// malformed trace file). With `observe` set the cell's platform is armed
/// over its measured window and the bundle rides back alongside the rows
/// (closed-loop cells run the paper rig, which has no observation hooks —
/// they return `None`).
fn run_job(
    p: &PreparedVariant,
    job: &Job,
    shards: Option<u32>,
    observe: Option<&ObserveConfig>,
) -> Result<(Vec<ScenarioRow>, Option<ObsBundle>), SpecError> {
    let v = &p.spec;
    let seed = v.seed.wrapping_add(u64::from(job.rep));
    Ok(match &v.workload {
        WorkloadSource::Synthetic {
            services,
            rate_per_service,
            horizon_s,
            mix,
        } => {
            let cfg = FleetConfig {
                topology: v.topology.build(),
                services: *services,
                rate_per_service: *rate_per_service,
                horizon: SimTime::from_secs_f64(*horizon_s),
                seed,
                routing: job.routing,
                mix: mix.clone(),
                knobs: v.autoscaler.clone(),
                hybrid: v.hybrid,
                forecast: v.forecast,
                faults: v.faults.clone(),
            };
            let (f, bundle) = match shards {
                Some(n) => {
                    let (f, _, b) =
                        crate::shard::run_policy_sharded_observed(&cfg, job.policy, n, observe);
                    (f, b)
                }
                None => fleet::run_policy_observed(&cfg, job.policy, observe),
            };
            let rows = vec![ScenarioRow {
                scenario: v.name.clone(),
                variant: p.label.clone(),
                workload: "mix".to_string(),
                rep: job.rep,
                policy: job.policy,
                routing: job.routing,
                nodes: f.nodes,
                services: f.services,
                completed: f.completed,
                failed: f.failed,
                mean_ms: f.mean_ms,
                p50_ms: f.p50_ms,
                p99_ms: f.p99_ms,
                cold_starts: f.cold_starts,
                inplace_scale_ups: f.inplace_scale_ups,
                speculative_resizes: f.speculative_resizes,
                mispredictions: f.mispredictions,
                avg_committed_mcpu: f.avg_committed_mcpu,
                pods_created: f.pods_created,
                pods_unschedulable: f.pods_unschedulable,
                pods_evicted: f.pods_evicted,
                pods_rescheduled: f.pods_rescheduled,
                resize_failures: f.resize_failures,
            }];
            (rows, bundle)
        }
        WorkloadSource::AzureGenerator { .. } | WorkloadSource::TraceFile { .. } => {
            let data = p
                .trace
                .as_ref()
                .expect("trace sources are prepared before execution")
                .checkout(v, job.rep)?;
            let (trace, functions) = (&data.0, data.1);
            let cfg = ReplayConfig {
                functions,
                policy: job.policy,
                routing: job.routing,
                topology: v.topology.build(),
                knobs: v.autoscaler.clone(),
                hybrid: v.hybrid,
                forecast: v.forecast,
                faults: v.faults.clone(),
                seed,
            };
            let (r, bundle) = match shards {
                Some(n) => crate::shard::replay_sharded_observed(trace, &cfg, n, observe),
                None => replay_with_observed(trace, &cfg, observe),
            };
            let rows = vec![ScenarioRow {
                scenario: v.name.clone(),
                variant: p.label.clone(),
                workload: "trace".to_string(),
                rep: job.rep,
                policy: job.policy,
                routing: job.routing,
                nodes: v.topology.nodes(),
                services: functions,
                completed: r.completed,
                failed: r.failed,
                mean_ms: r.mean_ms,
                p50_ms: r.p50_ms,
                p99_ms: r.p99_ms,
                cold_starts: r.cold_starts,
                inplace_scale_ups: r.inplace_scale_ups,
                speculative_resizes: r.speculative_resizes,
                mispredictions: r.mispredictions,
                avg_committed_mcpu: r.avg_committed_mcpu,
                pods_created: r.pods_created,
                pods_unschedulable: r.pods_unschedulable,
                pods_evicted: r.pods_evicted,
                pods_rescheduled: r.pods_rescheduled,
                resize_failures: r.resize_failures,
            }];
            (rows, bundle)
        }
        WorkloadSource::ClosedLoop { iterations, think_s } => {
            let exp = PolicyExperiment {
                iterations: *iterations,
                think: SimTime::from_secs_f64(*think_s),
                seed,
                routing: job.routing,
            };
            let rows = WorkloadKind::ALL
                .iter()
                .map(|&kind| {
                    let r = exp.measure_cell_report(kind, job.policy);
                    ScenarioRow {
                        scenario: v.name.clone(),
                        variant: p.label.clone(),
                        workload: kind.name().to_string(),
                        rep: job.rep,
                        policy: job.policy,
                        routing: job.routing,
                        nodes: 1,
                        services: 1,
                        completed: r.completed,
                        failed: r.failed,
                        mean_ms: r.mean_ms,
                        p50_ms: r.p50_ms,
                        p99_ms: r.p99_ms,
                        cold_starts: r.cold_starts,
                        inplace_scale_ups: r.inplace_scale_ups,
                        speculative_resizes: r.speculative_resizes,
                        mispredictions: r.mispredictions,
                        avg_committed_mcpu: r.avg_committed_mcpu,
                        // The rig keeps one min-scale pod; churn is
                        // not a closed-loop metric, and faults are
                        // rejected on this source at prepare time.
                        pods_created: 0,
                        pods_unschedulable: 0,
                        pods_evicted: 0,
                        pods_rescheduled: 0,
                        resize_failures: 0,
                    }
                })
                .collect();
            (rows, None)
        }
    })
}

/// Materializes the trace for one rep: the generator reseeded per rep, or
/// the file (rep-independent, loaded once per call).
fn build_trace(v: &ScenarioSpec, rep: u32) -> Result<(Vec<TraceEvent>, usize), SpecError> {
    match &v.workload {
        WorkloadSource::AzureGenerator {
            functions,
            peak_rate,
            horizon_s,
            popularity_s,
            trough_ratio,
            period_s,
            burst_p,
            pattern,
        } => {
            let cfg = TraceConfig {
                functions: *functions,
                popularity_s: *popularity_s,
                peak_rate: *peak_rate,
                trough_ratio: *trough_ratio,
                period: SimTime::from_secs_f64(*period_s),
                horizon: SimTime::from_secs_f64(*horizon_s),
                burst_p: *burst_p,
                pattern: *pattern,
                seed: v.seed.wrapping_add(u64::from(rep)),
            };
            Ok((TraceGenerator::new(cfg).generate(), *functions))
        }
        WorkloadSource::TraceFile { path, time_scale } => {
            let loaded = loader::load_azure_csv(std::path::Path::new(path), *time_scale)
                .map_err(|e| SpecError::Io {
                    path: path.clone(),
                    msg: e,
                })?;
            Ok((loaded.events, loaded.functions))
        }
        _ => unreachable!("build_trace is only called for trace sources"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Policy;
    use crate::scenario::preset;

    #[test]
    fn smoke_preset_runs_end_to_end() {
        let spec = preset::by_name("smoke").expect("smoke preset exists");
        let report = ScenarioEngine::run(&spec).unwrap();
        // 1 variant × 1 routing × 3 policies × 1 rep.
        assert_eq!(report.rows.len(), 3);
        for r in &report.rows {
            assert_eq!(r.failed, 0, "{:?}", r.policy);
            assert!(r.completed > 0);
        }
        // The emitted JSON validates against the schema.
        ScenarioReport::validate(&report.to_json()).unwrap();
    }

    #[test]
    fn engine_is_deterministic() {
        let spec = preset::by_name("smoke").unwrap();
        let a = ScenarioEngine::run(&spec).unwrap();
        let b = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_run_matches_serial_exactly() {
        // A grid with several variants, reps and routing policies so jobs
        // genuinely interleave: 2 variants × 2 routing × 2 policies × 2
        // reps = 16 jobs on 3 workers.
        let spec = ScenarioSpec::parse(
            r#"{"name":"par",
                "workload":{"type":"synthetic","services":4,
                            "rate_per_service":0.2,"horizon_s":20},
                "topology":{"kind":"uniform","nodes":2},
                "policies":["cold","in-place"],
                "routing":["least-loaded","hybrid"],
                "reps":2,
                "sweep":[{"param":"target_concurrency","values":[1,4]}]}"#,
        )
        .unwrap();
        let serial = ScenarioEngine::run_with_threads(&spec, 1).unwrap();
        assert_eq!(serial.rows.len(), 16);
        let parallel = ScenarioEngine::run_with_threads(&spec, 3).unwrap();
        assert_eq!(serial, parallel);
        // More workers than jobs also degrades cleanly.
        let oversubscribed = ScenarioEngine::run_with_threads(&spec, 64).unwrap();
        assert_eq!(serial, oversubscribed);
    }

    #[test]
    fn sweep_produces_one_row_per_grid_cell() {
        let mut spec = preset::by_name("smoke").unwrap();
        spec.policies = vec![Policy::InPlace];
        spec.sweep = vec![crate::scenario::spec::Sweep {
            param: "target_concurrency".into(),
            values: vec![1.0, 4.0],
        }];
        let report = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.rows[0].variant, "target_concurrency=1");
        assert_eq!(report.rows[1].variant, "target_concurrency=4");
        // The knob reached the platform: a tighter target scales out more.
        assert!(report.rows[0].pods_created >= report.rows[1].pods_created);
    }

    #[test]
    fn trace_file_scenario_replays() {
        let dir = std::env::temp_dir().join(format!("kinetic-eng-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini.csv");
        std::fs::write(&path, "HashFunction,1,2\nhot,6,4\ncool,1,0\n").unwrap();
        let spec = ScenarioSpec::parse(&format!(
            r#"{{"name":"file-replay",
                "workload":{{"type":"trace-file","path":"{}"}},
                "policies":["warm"]}}"#,
            path.display()
        ))
        .unwrap();
        let report = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(report.rows.len(), 1);
        assert_eq!(report.rows[0].completed, 11);
        assert_eq!(report.rows[0].failed, 0);
        assert_eq!(report.rows[0].services, 2);
        std::fs::remove_dir_all(&dir).ok();

        // A missing file surfaces as an Io error, not a panic.
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"trace-file","path":"/nope.csv"}}"#,
        )
        .unwrap();
        assert!(matches!(
            ScenarioEngine::run(&spec),
            Err(SpecError::Io { .. })
        ));
    }

    #[test]
    fn closed_loop_requires_paper_topology() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "topology":{"kind":"uniform","nodes":2}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("paper"), "{e}");
    }

    /// Autoscaler knobs (and sweeps over them) must not silently no-op on
    /// the closed-loop rig — they are rejected, not ignored.
    #[test]
    fn closed_loop_rejects_inapplicable_knobs() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "autoscaler":{"target_concurrency":1}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("do not apply"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "sweep":[{"param":"target_concurrency","values":[1,2]}]}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("do not apply"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "hybrid_weights":{"pressure_div":1}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("hybrid"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "routing":["least-loaded","locality"]}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("routing-invariant"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "forecast":{"pool_size":4}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("forecast") && e.contains("do not apply"), "{e}");
    }

    /// A fault aimed past the variant's topology is rejected instead of
    /// silently never firing.
    #[test]
    fn fault_node_out_of_range_is_rejected() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.1,"horizon_s":10},
                "topology":{"kind":"uniform","nodes":2},
                "faults":{"node_crashes":[{"node":5,"at_s":1,"down_s":5}]}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("node 5") && e.contains("0..=1"), "{e}");
        // Stragglers are checked through the same path.
        let spec = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.1,"horizon_s":10},
                "faults":{"stragglers":[{"node":1,"until_s":30}]}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("node 1") && e.contains("1 node"), "{e}");
    }

    /// The closed-loop rig has no fault installation point; a faults
    /// section is rejected rather than silently ignored.
    #[test]
    fn closed_loop_rejects_faults() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "faults":{"resize_failure_p":0.5}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("fault") && e.contains("does not apply"), "{e}");
    }

    /// A crash scenario runs end to end: the fault fires mid-run, the
    /// recovery counters land in the rows, and the document emits (and
    /// validates) under the fault schema version.
    #[test]
    fn crash_scenario_runs_end_to_end_with_counters() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"crash",
                "workload":{"type":"synthetic","services":4,
                            "rate_per_service":0.3,"horizon_s":60},
                "topology":{"kind":"uniform","nodes":2},
                "policies":["warm"],
                "faults":{"node_crashes":[{"node":1,"at_s":10,"down_s":30}]}}"#,
        )
        .unwrap();
        let report = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(report.rows.len(), 1);
        let r = &report.rows[0];
        assert!(r.pods_evicted > 0, "crash must evict the node's pods");
        assert_eq!(
            r.pods_rescheduled, r.pods_evicted,
            "warm pods reschedule onto the survivor"
        );
        assert!(r.completed > 0);
        let j = report.to_json();
        ScenarioReport::validate(&j).unwrap();
        assert!(j
            .to_string_pretty()
            .contains("\"schema_version\": 3"));
    }

    /// A pool that outgrows the scale ceiling is rejected instead of
    /// silently raising the ceiling for the pooled cells only.
    #[test]
    fn pool_larger_than_max_scale_is_rejected() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":0.1,"horizon_s":10},
                "policies":["pooled"],
                "autoscaler":{"max_scale":2},
                "forecast":{"pool_size":8}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("pool_size 8") && e.contains("max_scale 2"), "{e}");
        // Without the pooled policy the same knobs are fine (the pool
        // config is inert).
        let spec = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":0.1,"horizon_s":10},
                "policies":["warm"],
                "autoscaler":{"max_scale":2},
                "forecast":{"pool_size":8}}"#,
        )
        .unwrap();
        assert!(ScenarioEngine::run(&spec).is_ok());
    }

    /// The forecast-driven policies run end-to-end through the engine and
    /// their knobs reach the platform (a bigger pool commits more CPU).
    #[test]
    fn predictive_policies_run_through_the_engine() {
        let doc = |pool: u32| {
            format!(
                r#"{{"name":"pred",
                    "workload":{{"type":"synthetic","services":3,
                                "rate_per_service":0.3,"horizon_s":30}},
                    "topology":{{"kind":"uniform","nodes":2}},
                    "policies":["pooled","predictive-inplace"],
                    "forecast":{{"pool_size":{pool}}}}}"#
            )
        };
        let report = ScenarioEngine::run(&ScenarioSpec::parse(&doc(1)).unwrap()).unwrap();
        assert_eq!(report.rows.len(), 2);
        for r in &report.rows {
            assert_eq!(r.failed, 0, "{:?}", r.policy);
            assert!(r.completed > 0, "{:?}", r.policy);
        }
        ScenarioReport::validate(&report.to_json()).unwrap();

        let small = &report.rows[0];
        assert_eq!(small.policy, Policy::Pooled);
        let big_report =
            ScenarioEngine::run(&ScenarioSpec::parse(&doc(3)).unwrap()).unwrap();
        let big = &big_report.rows[0];
        assert_eq!(big.policy, Policy::Pooled);
        assert!(
            big.avg_committed_mcpu > small.avg_committed_mcpu,
            "pool 3 must reserve more than pool 1: {} vs {}",
            big.avg_committed_mcpu,
            small.avg_committed_mcpu
        );
    }
}
