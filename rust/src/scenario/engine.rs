//! [`ScenarioEngine`] — compiles a [`ScenarioSpec`] into [`Simulation`]
//! runs and aggregates a [`ScenarioReport`].
//!
//! One engine serves every experiment shape: synthetic per-tenant fleets
//! compile to `experiments::fleet::run_policy`, generated and file-loaded
//! traces to `trace::replay_with`, and the paper's closed-loop rig to
//! `experiments::policies::PolicyExperiment` — so the legacy subcommands
//! become presets over this module and can never drift from `kinetic run`.

use std::collections::BTreeMap;

use crate::coordinator::accounting::RoutingPolicy;
use crate::experiments::fleet::{self, FleetConfig};
use crate::experiments::policies::PolicyExperiment;
use crate::scenario::report::{ScenarioReport, ScenarioRow};
use crate::scenario::spec::{ScenarioSpec, SpecError, TopologySpec, WorkloadSource};
use crate::simclock::SimTime;
use crate::trace::generator::{TraceConfig, TraceEvent, TraceGenerator};
use crate::trace::loader;
use crate::trace::replay::{replay_with, ReplayConfig};
use crate::workload::registry::WorkloadKind;

/// Compiles specs into runs.
pub struct ScenarioEngine;

impl ScenarioEngine {
    /// Resolves `--scenario <arg>`: a preset name, else a JSON file path.
    pub fn load(arg: &str) -> Result<ScenarioSpec, SpecError> {
        if let Some(spec) = crate::scenario::preset::by_name(arg) {
            return Ok(spec);
        }
        ScenarioSpec::load(std::path::Path::new(arg))
    }

    /// Runs the full grid: every sweep variant × routing × policy × rep.
    pub fn run(spec: &ScenarioSpec) -> Result<ScenarioReport, SpecError> {
        let mut rows = Vec::new();
        for (label, variant) in spec.expand()? {
            run_variant(&label, &variant, &mut rows)?;
        }
        Ok(ScenarioReport {
            name: spec.name.clone(),
            spec: spec.to_json(),
            rows,
        })
    }

    /// The `kinetic exp` policy preset: a closed-loop spec as the exact
    /// [`PolicyExperiment`] the paper tables are rendered from.
    pub fn paper_policy_experiment(spec: &ScenarioSpec) -> Result<PolicyExperiment, SpecError> {
        match spec.workload {
            WorkloadSource::ClosedLoop { iterations, think_s } => Ok(PolicyExperiment {
                iterations,
                think: SimTime::from_secs_f64(think_s),
                seed: spec.seed,
                routing: *spec.routing.first().unwrap_or(&RoutingPolicy::LeastLoaded),
            }),
            _ => Err(SpecError::invalid(
                "workload.type",
                "the paper policy tables need a 'closed-loop' workload source",
            )),
        }
    }
}

fn run_variant(
    label: &str,
    v: &ScenarioSpec,
    rows: &mut Vec<ScenarioRow>,
) -> Result<(), SpecError> {
    match &v.workload {
        WorkloadSource::Synthetic {
            services,
            rate_per_service,
            horizon_s,
            mix,
        } => {
            for &routing in &v.routing {
                for &policy in &v.policies {
                    for rep in 0..v.reps {
                        let cfg = FleetConfig {
                            topology: v.topology.build(),
                            services: *services,
                            rate_per_service: *rate_per_service,
                            horizon: SimTime::from_secs_f64(*horizon_s),
                            seed: v.seed.wrapping_add(u64::from(rep)),
                            routing,
                            mix: mix.clone(),
                            knobs: v.autoscaler.clone(),
                            hybrid: v.hybrid,
                        };
                        let f = fleet::run_policy(&cfg, policy);
                        rows.push(ScenarioRow {
                            scenario: v.name.clone(),
                            variant: label.to_string(),
                            workload: "mix".to_string(),
                            rep,
                            policy,
                            routing,
                            nodes: f.nodes,
                            services: f.services,
                            completed: f.completed,
                            failed: f.failed,
                            mean_ms: f.mean_ms,
                            p50_ms: f.p50_ms,
                            p99_ms: f.p99_ms,
                            cold_starts: f.cold_starts,
                            inplace_scale_ups: f.inplace_scale_ups,
                            avg_committed_mcpu: f.avg_committed_mcpu,
                            pods_created: f.pods_created,
                        });
                    }
                }
            }
        }
        WorkloadSource::AzureGenerator { .. } | WorkloadSource::TraceFile { .. } => {
            // One trace per rep for the generator (it reseeds per rep); a
            // file never changes, so it is read and parsed exactly once.
            // Either way the trace is shared by every routing × policy so
            // each policy replays the identical arrival stream — the
            // comparison the paper's §3 tables rest on.
            let mut cache: BTreeMap<u32, (Vec<TraceEvent>, usize)> = BTreeMap::new();
            let file_trace = if matches!(v.workload, WorkloadSource::TraceFile { .. }) {
                Some(build_trace(v, 0)?)
            } else {
                for rep in 0..v.reps {
                    cache.insert(rep, build_trace(v, rep)?);
                }
                None
            };
            for &routing in &v.routing {
                for &policy in &v.policies {
                    for rep in 0..v.reps {
                        let (trace, functions) = match &file_trace {
                            Some(t) => t,
                            None => &cache[&rep],
                        };
                        let cfg = ReplayConfig {
                            functions: *functions,
                            policy,
                            routing,
                            topology: v.topology.build(),
                            knobs: v.autoscaler.clone(),
                            hybrid: v.hybrid,
                            seed: v.seed.wrapping_add(u64::from(rep)),
                        };
                        let r = replay_with(trace, &cfg);
                        rows.push(ScenarioRow {
                            scenario: v.name.clone(),
                            variant: label.to_string(),
                            workload: "trace".to_string(),
                            rep,
                            policy,
                            routing,
                            nodes: v.topology.nodes(),
                            services: *functions,
                            completed: r.completed,
                            failed: r.failed,
                            mean_ms: r.mean_ms,
                            p50_ms: r.p50_ms,
                            p99_ms: r.p99_ms,
                            cold_starts: r.cold_starts,
                            inplace_scale_ups: r.inplace_scale_ups,
                            avg_committed_mcpu: r.avg_committed_mcpu,
                            pods_created: r.pods_created,
                        });
                    }
                }
            }
        }
        WorkloadSource::ClosedLoop { iterations, think_s } => {
            if v.topology != TopologySpec::Paper {
                return Err(SpecError::invalid(
                    "topology.kind",
                    "the closed-loop rig reproduces the paper's single-node \
                     testbed; use topology kind 'paper'",
                ));
            }
            // The rig runs the paper's revision configs verbatim; rather
            // than silently ignore autoscaler/hybrid settings (a swept
            // knob would then run identical variants), reject them.
            if v.autoscaler != crate::knative::config::ScaleKnobs::fleet_default() {
                return Err(SpecError::invalid(
                    "autoscaler",
                    "closed-loop scenarios run the paper's per-policy revision \
                     configs; autoscaler knobs (and sweeps over them) do not \
                     apply — remove them or use a synthetic/trace source",
                ));
            }
            if v.hybrid != crate::coordinator::accounting::HybridWeights::default() {
                return Err(SpecError::invalid(
                    "hybrid_weights",
                    "closed-loop scenarios are single-pod; hybrid weights do \
                     not apply — remove them or use a synthetic/trace source",
                ));
            }
            // Routing is provably a no-op on the single-pod paper rig (the
            // golden routing-invariance test pins it), so comparing routing
            // policies here would emit identical rows per policy.
            if v.routing.len() > 1 {
                return Err(SpecError::invalid(
                    "routing",
                    "closed-loop scenarios are routing-invariant (single \
                     pod); listing several routing policies would duplicate \
                     every row — keep one",
                ));
            }
            for &routing in &v.routing {
                for &policy in &v.policies {
                    for rep in 0..v.reps {
                        let exp = PolicyExperiment {
                            iterations: *iterations,
                            think: SimTime::from_secs_f64(*think_s),
                            seed: v.seed.wrapping_add(u64::from(rep)),
                            routing,
                        };
                        for kind in WorkloadKind::ALL {
                            let r = exp.measure_cell_report(kind, policy);
                            rows.push(ScenarioRow {
                                scenario: v.name.clone(),
                                variant: label.to_string(),
                                workload: kind.name().to_string(),
                                rep,
                                policy,
                                routing,
                                nodes: 1,
                                services: 1,
                                completed: r.completed,
                                failed: r.failed,
                                mean_ms: r.mean_ms,
                                p50_ms: r.p50_ms,
                                p99_ms: r.p99_ms,
                                cold_starts: r.cold_starts,
                                inplace_scale_ups: r.inplace_scale_ups,
                                avg_committed_mcpu: r.avg_committed_mcpu,
                                // The rig keeps one min-scale pod; churn is
                                // not a closed-loop metric.
                                pods_created: 0,
                            });
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Materializes the trace for one rep: the generator reseeded per rep, or
/// the file (rep-independent, loaded once per call).
fn build_trace(v: &ScenarioSpec, rep: u32) -> Result<(Vec<TraceEvent>, usize), SpecError> {
    match &v.workload {
        WorkloadSource::AzureGenerator {
            functions,
            peak_rate,
            horizon_s,
            popularity_s,
            trough_ratio,
            period_s,
            burst_p,
        } => {
            let cfg = TraceConfig {
                functions: *functions,
                popularity_s: *popularity_s,
                peak_rate: *peak_rate,
                trough_ratio: *trough_ratio,
                period: SimTime::from_secs_f64(*period_s),
                horizon: SimTime::from_secs_f64(*horizon_s),
                burst_p: *burst_p,
                seed: v.seed.wrapping_add(u64::from(rep)),
            };
            Ok((TraceGenerator::new(cfg).generate(), *functions))
        }
        WorkloadSource::TraceFile { path, time_scale } => {
            let loaded = loader::load_azure_csv(std::path::Path::new(path), *time_scale)
                .map_err(|e| SpecError::Io {
                    path: path.clone(),
                    msg: e,
                })?;
            Ok((loaded.events, loaded.functions))
        }
        _ => unreachable!("build_trace is only called for trace sources"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Policy;
    use crate::scenario::preset;

    #[test]
    fn smoke_preset_runs_end_to_end() {
        let spec = preset::by_name("smoke").expect("smoke preset exists");
        let report = ScenarioEngine::run(&spec).unwrap();
        // 1 variant × 1 routing × 3 policies × 1 rep.
        assert_eq!(report.rows.len(), 3);
        for r in &report.rows {
            assert_eq!(r.failed, 0, "{:?}", r.policy);
            assert!(r.completed > 0);
        }
        // The emitted JSON validates against the schema.
        ScenarioReport::validate(&report.to_json()).unwrap();
    }

    #[test]
    fn engine_is_deterministic() {
        let spec = preset::by_name("smoke").unwrap();
        let a = ScenarioEngine::run(&spec).unwrap();
        let b = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn sweep_produces_one_row_per_grid_cell() {
        let mut spec = preset::by_name("smoke").unwrap();
        spec.policies = vec![Policy::InPlace];
        spec.sweep = vec![crate::scenario::spec::Sweep {
            param: "target_concurrency".into(),
            values: vec![1.0, 4.0],
        }];
        let report = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.rows[0].variant, "target_concurrency=1");
        assert_eq!(report.rows[1].variant, "target_concurrency=4");
        // The knob reached the platform: a tighter target scales out more.
        assert!(report.rows[0].pods_created >= report.rows[1].pods_created);
    }

    #[test]
    fn trace_file_scenario_replays() {
        let dir = std::env::temp_dir().join(format!("kinetic-eng-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini.csv");
        std::fs::write(&path, "HashFunction,1,2\nhot,6,4\ncool,1,0\n").unwrap();
        let spec = ScenarioSpec::parse(&format!(
            r#"{{"name":"file-replay",
                "workload":{{"type":"trace-file","path":"{}"}},
                "policies":["warm"]}}"#,
            path.display()
        ))
        .unwrap();
        let report = ScenarioEngine::run(&spec).unwrap();
        assert_eq!(report.rows.len(), 1);
        assert_eq!(report.rows[0].completed, 11);
        assert_eq!(report.rows[0].failed, 0);
        assert_eq!(report.rows[0].services, 2);
        std::fs::remove_dir_all(&dir).ok();

        // A missing file surfaces as an Io error, not a panic.
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"trace-file","path":"/nope.csv"}}"#,
        )
        .unwrap();
        assert!(matches!(
            ScenarioEngine::run(&spec),
            Err(SpecError::Io { .. })
        ));
    }

    #[test]
    fn closed_loop_requires_paper_topology() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "topology":{"kind":"uniform","nodes":2}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("paper"), "{e}");
    }

    /// Autoscaler knobs (and sweeps over them) must not silently no-op on
    /// the closed-loop rig — they are rejected, not ignored.
    #[test]
    fn closed_loop_rejects_inapplicable_knobs() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "autoscaler":{"target_concurrency":1}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("do not apply"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "sweep":[{"param":"target_concurrency","values":[1,2]}]}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("do not apply"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "hybrid_weights":{"pressure_div":1}}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("hybrid"), "{e}");

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2},
                "routing":["least-loaded","locality"]}"#,
        )
        .unwrap();
        let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
        assert!(e.contains("routing-invariant"), "{e}");
    }
}
