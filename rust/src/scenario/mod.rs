//! The declarative scenario API: one engine for every experiment, sweep
//! and trace replay.
//!
//! * [`spec`] — [`ScenarioSpec`]: workload source × topology × policies ×
//!   routing × autoscaler knobs (+ [`spec::Sweep`] axes that expand a
//!   single spec into a grid). Strict JSON parsing with path-qualified
//!   errors.
//! * [`engine`] — [`ScenarioEngine`]: compiles specs into `Simulation`
//!   runs via the fleet harness, the trace replayer or the paper's
//!   closed-loop rig.
//! * [`report`] — [`ScenarioReport`]: the unified, schema-validated JSON
//!   result document (`kinetic validate-report` gates it in CI).
//! * [`preset`] — the legacy subcommands (`fleet`, `trace`, the policy
//!   tables of `exp`) and the CI `smoke` gate as named specs.
//! * [`schema_doc`] — the generated scenario JSON reference
//!   (`kinetic schema --markdown` → `docs/SCENARIO_SCHEMA.md`, pinned by
//!   `tests/docs_drift.rs`).

pub mod engine;
pub mod preset;
pub mod report;
pub mod schema_doc;
pub mod spec;

pub use engine::ScenarioEngine;
pub use report::{ScenarioReport, ScenarioRow};
pub use spec::{ScenarioSpec, SpecError, TopologySpec, WorkloadSource};
