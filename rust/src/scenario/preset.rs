//! Named scenario presets — the legacy subcommands expressed as specs.
//!
//! `kinetic fleet`, `kinetic trace` and the policy portion of `kinetic exp`
//! are thin wrappers that build these presets from their flags; `kinetic
//! run --scenario fleet|trace|paper|smoke` runs the same specs with their
//! default flag values. The equivalence tests pin the presets to the
//! pre-redesign subcommand outputs bit-for-bit.

use crate::coordinator::accounting::{HybridWeights, RoutingPolicy};
use crate::experiments::fleet::FLEET_MIX;
use crate::forecast::ForecastConfig;
use crate::knative::config::ScaleKnobs;
use crate::policy::Policy;
use crate::scenario::spec::{ScenarioSpec, TopologySpec, WorkloadSource};

/// Looks up a preset by name (`fleet`, `trace`, `paper`, `smoke`).
pub fn by_name(name: &str) -> Option<ScenarioSpec> {
    match name.to_ascii_lowercase().as_str() {
        "fleet" => Some(fleet(
            TopologySpec::Uniform { nodes: 10 },
            vec![RoutingPolicy::LeastLoaded],
            0,
            0.05,
            300,
            42,
        )),
        "trace" => Some(trace(8, 600, 4.0, 1)),
        "paper" => Some(paper(30, 42)),
        "smoke" => Some(smoke()),
        _ => None,
    }
}

/// Every preset name, for help/error text.
pub const NAMES: [&str; 4] = ["fleet", "trace", "paper", "smoke"];

/// The `kinetic fleet` subcommand as a spec. `services == 0` resolves to
/// two tenants per node, exactly as the subcommand always did.
pub fn fleet(
    topology: TopologySpec,
    routing: Vec<RoutingPolicy>,
    services: usize,
    rate: f64,
    seconds: u64,
    seed: u64,
) -> ScenarioSpec {
    let services = if services == 0 {
        (2 * topology.nodes()).max(1)
    } else {
        services
    };
    ScenarioSpec {
        name: "fleet".to_string(),
        workload: WorkloadSource::Synthetic {
            services,
            rate_per_service: rate,
            horizon_s: seconds as f64,
            mix: FLEET_MIX.to_vec(),
        },
        topology,
        policies: Policy::PAPER.to_vec(),
        routing,
        autoscaler: ScaleKnobs::fleet_default(),
        hybrid: HybridWeights::default(),
        forecast: ForecastConfig::default(),
        faults: crate::faults::FaultsConfig::default(),
        observe: None,
        shards: None,
        seed,
        reps: 1,
        sweep: Vec::new(),
    }
}

/// The `kinetic trace` subcommand as a spec: the Azure-style generator
/// replayed on the paper testbed under every §3 policy.
pub fn trace(functions: usize, seconds: u64, rate: f64, seed: u64) -> ScenarioSpec {
    ScenarioSpec {
        name: "trace".to_string(),
        workload: WorkloadSource::AzureGenerator {
            functions,
            peak_rate: rate,
            horizon_s: seconds as f64,
            // TraceConfig::default's shape parameters, spelled out.
            popularity_s: 1.2,
            trough_ratio: 0.15,
            period_s: 600.0,
            burst_p: 0.25,
            pattern: crate::trace::generator::RatePattern::Diurnal,
        },
        topology: TopologySpec::Paper,
        policies: Policy::PAPER.to_vec(),
        routing: vec![RoutingPolicy::LeastLoaded],
        autoscaler: ScaleKnobs::trace_default(),
        hybrid: HybridWeights::default(),
        forecast: ForecastConfig::default(),
        faults: crate::faults::FaultsConfig::default(),
        observe: None,
        shards: None,
        seed,
        reps: 1,
        sweep: Vec::new(),
    }
}

/// The policy portion of `kinetic exp` (Tables 2/3, Figs 5/6) as a spec:
/// the paper's closed-loop rig. `reps` is clamped exactly as the
/// subcommand clamps it.
pub fn paper(reps: u32, seed: u64) -> ScenarioSpec {
    ScenarioSpec {
        name: "paper".to_string(),
        workload: WorkloadSource::ClosedLoop {
            iterations: reps.clamp(3, 16),
            think_s: 8.0,
        },
        topology: TopologySpec::Paper,
        policies: Policy::PAPER.to_vec(),
        routing: vec![RoutingPolicy::LeastLoaded],
        autoscaler: ScaleKnobs::fleet_default(),
        hybrid: HybridWeights::default(),
        forecast: ForecastConfig::default(),
        faults: crate::faults::FaultsConfig::default(),
        observe: None,
        shards: None,
        seed,
        reps: 1,
        sweep: Vec::new(),
    }
}

/// A seconds-fast synthetic fleet — the CI smoke gate. Kept in lockstep
/// with `examples/scenarios/smoke.json` (a test asserts they are equal).
pub fn smoke() -> ScenarioSpec {
    ScenarioSpec {
        name: "smoke".to_string(),
        workload: WorkloadSource::Synthetic {
            services: 6,
            rate_per_service: 0.2,
            horizon_s: 30.0,
            mix: FLEET_MIX.to_vec(),
        },
        topology: TopologySpec::Uniform { nodes: 3 },
        policies: Policy::PAPER.to_vec(),
        routing: vec![RoutingPolicy::LeastLoaded],
        autoscaler: ScaleKnobs::fleet_default(),
        hybrid: HybridWeights::default(),
        forecast: ForecastConfig::default(),
        faults: crate::faults::FaultsConfig::default(),
        observe: None,
        shards: None,
        seed: 42,
        reps: 1,
        sweep: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_round_trip() {
        for name in NAMES {
            let spec = by_name(name).unwrap_or_else(|| panic!("preset {name}"));
            assert_eq!(spec.name, name);
            let again = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, again, "{name} canonical form drifted");
        }
        assert!(by_name("warp-speed").is_none());
    }

    #[test]
    fn fleet_preset_resolves_default_services() {
        let spec = by_name("fleet").unwrap();
        match spec.workload {
            WorkloadSource::Synthetic { services, .. } => assert_eq!(services, 20),
            other => panic!("{other:?}"),
        }
        let explicit = fleet(
            TopologySpec::Hetero { nodes: 4 },
            vec![RoutingPolicy::Hybrid],
            7,
            0.5,
            60,
            1,
        );
        match explicit.workload {
            WorkloadSource::Synthetic { services, .. } => assert_eq!(services, 7),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn paper_preset_clamps_iterations_like_exp() {
        match paper(30, 42).workload {
            WorkloadSource::ClosedLoop { iterations, .. } => assert_eq!(iterations, 16),
            other => panic!("{other:?}"),
        }
        match paper(1, 42).workload {
            WorkloadSource::ClosedLoop { iterations, .. } => assert_eq!(iterations, 3),
            other => panic!("{other:?}"),
        }
    }
}
