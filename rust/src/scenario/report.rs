//! [`ScenarioReport`] — the unified result document every scenario run
//! emits: one row per (variant, routing, policy, rep), JSON-serializable
//! through `util::json` (f64 metrics survive the round trip bit-for-bit —
//! the writer prints shortest-round-trip floats) and schema-validated so
//! the CI smoke gate can reject a malformed emission.

use std::path::{Path, PathBuf};

use crate::coordinator::accounting::RoutingPolicy;
use crate::experiments::fleet::FleetRow;
use crate::policy::Policy;
use crate::util::json::Json;
use crate::util::table::{fmt_ms, Table};

/// Bumped when a field changes meaning; `validate` pins it.
/// v2: rows carry the predictive-policy speculation counters
/// (`speculative_resizes`, `mispredictions`).
pub const SCHEMA_VERSION: u64 = 2;

/// Schema version emitted by fault-injection runs: rows additionally carry
/// the fault counters (`pods_unschedulable`, `pods_evicted`,
/// `pods_rescheduled`, `resize_failures`). A spec without a `faults`
/// section (and without fault sweep axes) still emits
/// [`SCHEMA_VERSION`]-versioned documents byte-identical to pre-fault
/// builds; `validate` accepts both versions.
pub const SCHEMA_VERSION_FAULTS: u64 = 3;

/// Sweep axes that inject faults without a `faults` section in the spec
/// echo (`resize_failure_p` can be swept over an otherwise fault-free
/// base spec).
const FAULT_SWEEP_AXES: [&str; 3] = ["resize_failure_p", "crash_down_s", "straggler_factor"];

/// True when the spec echo configures fault injection — the condition
/// under which the report upgrades to [`SCHEMA_VERSION_FAULTS`] and the
/// table grows the fault columns.
fn spec_has_faults(spec: &Json) -> bool {
    if spec.get("faults").is_some() {
        return true;
    }
    spec.get("sweep")
        .and_then(Json::as_arr)
        .is_some_and(|sweeps| {
            sweeps.iter().any(|s| {
                s.get("param")
                    .and_then(Json::as_str)
                    .is_some_and(|p| FAULT_SWEEP_AXES.contains(&p))
            })
        })
}

/// One run's aggregate metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioRow {
    /// Spec name.
    pub scenario: String,
    /// Sweep label (`"param=value ..."`; empty when nothing swept).
    pub variant: String,
    /// What generated the load (`mix`, a workload name, `trace`, ...).
    pub workload: String,
    pub rep: u32,
    pub policy: Policy,
    pub routing: RoutingPolicy,
    pub nodes: usize,
    pub services: usize,
    pub completed: u64,
    pub failed: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_starts: u64,
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes (predictive-inplace) —
    /// together with `mispredictions` the hit-rate signal the
    /// forecast-horizon sweeps measure.
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival (re-parked).
    pub mispredictions: u64,
    pub avg_committed_mcpu: f64,
    pub pods_created: u64,
    /// Scheduling attempts that found no feasible node. Serialized only in
    /// [`SCHEMA_VERSION_FAULTS`] documents (fault specs); zero otherwise.
    pub pods_unschedulable: u64,
    /// Pods killed by injected node crashes.
    pub pods_evicted: u64,
    /// Replacement pods started by crash recovery.
    pub pods_rescheduled: u64,
    /// Resize patches rejected by injected API failures.
    pub resize_failures: u64,
}

impl ScenarioRow {
    /// View as a fleet row (the fleet preset renders through the original
    /// `fleet_table`/`routing_table`, proving the presets share schema).
    pub fn to_fleet_row(&self) -> FleetRow {
        FleetRow {
            policy: self.policy,
            routing: self.routing,
            nodes: self.nodes,
            services: self.services,
            completed: self.completed,
            failed: self.failed,
            mean_ms: self.mean_ms,
            p50_ms: self.p50_ms,
            p99_ms: self.p99_ms,
            cold_starts: self.cold_starts,
            inplace_scale_ups: self.inplace_scale_ups,
            speculative_resizes: self.speculative_resizes,
            mispredictions: self.mispredictions,
            avg_committed_mcpu: self.avg_committed_mcpu,
            pods_created: self.pods_created,
            pods_unschedulable: self.pods_unschedulable,
            pods_evicted: self.pods_evicted,
            pods_rescheduled: self.pods_rescheduled,
            resize_failures: self.resize_failures,
        }
    }

    /// `with_faults` selects the schema: v3 rows append the fault
    /// counters, v2 rows stay byte-identical to pre-fault emissions.
    fn to_json(&self, with_faults: bool) -> Json {
        let mut fields = vec![
            ("scenario", self.scenario.as_str().into()),
            ("variant", self.variant.as_str().into()),
            ("workload", self.workload.as_str().into()),
            ("rep", u64::from(self.rep).into()),
            ("policy", self.policy.name().into()),
            ("routing", self.routing.name().into()),
            ("nodes", (self.nodes as u64).into()),
            ("services", (self.services as u64).into()),
            ("completed", self.completed.into()),
            ("failed", self.failed.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("cold_starts", self.cold_starts.into()),
            ("inplace_scale_ups", self.inplace_scale_ups.into()),
            ("speculative_resizes", self.speculative_resizes.into()),
            ("mispredictions", self.mispredictions.into()),
            ("avg_committed_mcpu", self.avg_committed_mcpu.into()),
            ("pods_created", self.pods_created.into()),
        ];
        if with_faults {
            fields.extend([
                ("pods_unschedulable", self.pods_unschedulable.into()),
                ("pods_evicted", self.pods_evicted.into()),
                ("pods_rescheduled", self.pods_rescheduled.into()),
                ("resize_failures", self.resize_failures.into()),
            ]);
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json, path: &str) -> Result<ScenarioRow, String> {
        let req_u64 = |k: &str| {
            j.req_u64(k)
                .map_err(|e| format!("{path}.{k}: {e}"))
        };
        let req_f64 = |k: &str| {
            j.req_f64(k)
                .map_err(|e| format!("{path}.{k}: {e}"))
        };
        let req_str = |k: &str| {
            j.req_str(k)
                .map(str::to_string)
                .map_err(|e| format!("{path}.{k}: {e}"))
        };
        // Fault counters only exist in v3 rows; absent (v2) means zero.
        let opt_u64 = |k: &str| match j.get(k) {
            None => Ok(0u64),
            Some(_) => req_u64(k),
        };
        Ok(ScenarioRow {
            scenario: req_str("scenario")?,
            variant: req_str("variant")?,
            workload: req_str("workload")?,
            rep: req_u64("rep")? as u32,
            policy: req_str("policy")?
                .parse::<Policy>()
                .map_err(|e| format!("{path}.policy: {e}"))?,
            routing: req_str("routing")?
                .parse::<RoutingPolicy>()
                .map_err(|e| format!("{path}.routing: {e}"))?,
            nodes: req_u64("nodes")? as usize,
            services: req_u64("services")? as usize,
            completed: req_u64("completed")?,
            failed: req_u64("failed")?,
            mean_ms: req_f64("mean_ms")?,
            p50_ms: req_f64("p50_ms")?,
            p99_ms: req_f64("p99_ms")?,
            cold_starts: req_u64("cold_starts")?,
            inplace_scale_ups: req_u64("inplace_scale_ups")?,
            speculative_resizes: req_u64("speculative_resizes")?,
            mispredictions: req_u64("mispredictions")?,
            avg_committed_mcpu: req_f64("avg_committed_mcpu")?,
            pods_created: req_u64("pods_created")?,
            pods_unschedulable: opt_u64("pods_unschedulable")?,
            pods_evicted: opt_u64("pods_evicted")?,
            pods_rescheduled: opt_u64("pods_rescheduled")?,
            resize_failures: opt_u64("resize_failures")?,
        })
    }
}

/// The unified result document.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub name: String,
    /// Canonical echo of the spec that produced the rows (provenance).
    pub spec: Json,
    pub rows: Vec<ScenarioRow>,
}

impl ScenarioReport {
    pub fn to_json(&self) -> Json {
        // A fault spec upgrades the whole document to the fault schema;
        // anything else emits exactly the pre-fault v2 bytes.
        let with_faults = spec_has_faults(&self.spec);
        let version = if with_faults {
            SCHEMA_VERSION_FAULTS
        } else {
            SCHEMA_VERSION
        };
        Json::obj(vec![
            ("schema_version", version.into()),
            ("name", self.name.as_str().into()),
            ("spec", self.spec.clone()),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| r.to_json(with_faults))),
            ),
        ])
    }

    /// Validates a JSON document against the report schema; returns the
    /// first problem found, with its path. (Thin wrapper over the single
    /// parsing pass in [`ScenarioReport::from_json`].)
    pub fn validate(j: &Json) -> Result<(), String> {
        ScenarioReport::from_json(j).map(|_| ())
    }

    /// Parses and validates a document in one pass.
    pub fn from_json(j: &Json) -> Result<ScenarioReport, String> {
        let m = j.as_obj().ok_or("report must be a JSON object")?;
        for key in ["schema_version", "name", "spec", "rows"] {
            if !m.contains_key(key) {
                return Err(format!("missing top-level field '{key}'"));
            }
        }
        for key in m.keys() {
            if !["schema_version", "name", "spec", "rows"].contains(&key.as_str()) {
                return Err(format!("unknown top-level field '{key}'"));
            }
        }
        let version = j
            .req_u64("schema_version")
            .map_err(|e| e.to_string())?;
        if version != SCHEMA_VERSION && version != SCHEMA_VERSION_FAULTS {
            return Err(format!(
                "schema_version {version} unsupported (expected {SCHEMA_VERSION} \
                 or {SCHEMA_VERSION_FAULTS})"
            ));
        }
        let spec = j
            .get("spec")
            .filter(|s| s.as_obj().is_some())
            .cloned()
            .ok_or("'spec' must be an object")?;
        let rows = j
            .req_arr("rows")
            .map_err(|e| e.to_string())?
            .iter()
            .enumerate()
            .map(|(i, r)| ScenarioRow::from_json(r, &format!("rows[{i}]")))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ScenarioReport {
            name: j.req_str("name").map_err(|e| e.to_string())?.to_string(),
            spec,
            rows,
        })
    }

    /// Writes `<dir>/scenario_<name>.json` (pretty) and returns the path.
    pub fn save(&self, dir: &Path) -> std::io::Result<PathBuf> {
        crate::util::json::save_named(dir, "scenario", &self.name, &self.to_json())
    }

    /// Loads and validates a saved report.
    pub fn load(path: &Path) -> Result<ScenarioReport, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        ScenarioReport::from_json(&j)
    }

    /// Renders the rows as one table (the generic `kinetic run` view).
    /// The speculation columns appear exactly when a predictive policy is
    /// in the comparison — keyed on the policy, not on observed counts,
    /// so a spec always renders the same columns (a zero-speculation
    /// predictive run is visible as such) and §3-only runs render exactly
    /// as before.
    pub fn table(&self) -> Table {
        let swept = self.rows.iter().any(|r| !r.variant.is_empty());
        let multi_rep = self.rows.iter().any(|r| r.rep > 0);
        let speculative = self.rows.iter().any(|r| r.policy.predictive());
        // Like the speculation columns: keyed on the spec, not on observed
        // counts, so a fault run that happened to hurt nothing still shows
        // its zeros and a fault-free spec renders exactly as before.
        let faulty = spec_has_faults(&self.spec);
        let mut headers = Vec::new();
        if swept {
            headers.push("Variant");
        }
        if multi_rep {
            headers.push("Rep");
        }
        headers.extend([
            "Workload",
            "Routing",
            "Policy",
            "Completed",
            "Failed",
            "Mean (ms)",
            "p50 (ms)",
            "p99 (ms)",
            "Cold",
        ]);
        if speculative {
            headers.extend(["Spec", "Miss"]);
        }
        if faulty {
            headers.extend(["Unsched", "Evict", "Resched", "RszFail"]);
        }
        headers.extend(["Committed (mCPU)", "Pods"]);
        let mut t = Table::new(headers).title(format!("Scenario: {}", self.name));
        for r in &self.rows {
            let mut cells = Vec::new();
            if swept {
                cells.push(r.variant.clone());
            }
            if multi_rep {
                cells.push(r.rep.to_string());
            }
            cells.extend([
                r.workload.clone(),
                r.routing.name().to_string(),
                r.policy.name().to_string(),
                r.completed.to_string(),
                r.failed.to_string(),
                fmt_ms(r.mean_ms),
                fmt_ms(r.p50_ms),
                fmt_ms(r.p99_ms),
                r.cold_starts.to_string(),
            ]);
            if speculative {
                cells.push(r.speculative_resizes.to_string());
                cells.push(r.mispredictions.to_string());
            }
            if faulty {
                cells.push(r.pods_unschedulable.to_string());
                cells.push(r.pods_evicted.to_string());
                cells.push(r.pods_rescheduled.to_string());
                cells.push(r.resize_failures.to_string());
            }
            cells.extend([
                format!("{:.0}", r.avg_committed_mcpu),
                r.pods_created.to_string(),
            ]);
            t.row(cells);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(variant: &str, mean: f64) -> ScenarioRow {
        ScenarioRow {
            scenario: "t".into(),
            variant: variant.into(),
            workload: "mix".into(),
            rep: 0,
            policy: Policy::InPlace,
            routing: RoutingPolicy::LeastLoaded,
            nodes: 4,
            services: 8,
            completed: 100,
            failed: 0,
            mean_ms: mean,
            p50_ms: mean * 0.9,
            p99_ms: mean * 3.0,
            cold_starts: 0,
            inplace_scale_ups: 100,
            speculative_resizes: 7,
            mispredictions: 2,
            avg_committed_mcpu: 123.4,
            pods_created: 8,
            pods_unschedulable: 0,
            pods_evicted: 0,
            pods_rescheduled: 0,
            resize_failures: 0,
        }
    }

    fn report() -> ScenarioReport {
        ScenarioReport {
            name: "t".into(),
            spec: Json::obj(vec![("name", "t".into())]),
            rows: vec![row("", 81.25), row("rate=0.5", 0.1 + 0.2)],
        }
    }

    #[test]
    fn json_round_trip_preserves_f64_bits() {
        let rep = report();
        let text = rep.to_json().to_string_pretty();
        let back = ScenarioReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
        // The awkward 0.30000000000000004 survives exactly.
        assert_eq!(
            back.rows[1].mean_ms.to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        let good = report().to_json();
        assert!(ScenarioReport::validate(&good).is_ok());

        let e = ScenarioReport::validate(&Json::parse("[1]").unwrap()).unwrap_err();
        assert!(e.contains("object"), "{e}");

        let mut m = good.as_obj().unwrap().clone();
        m.remove("rows");
        let e = ScenarioReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("rows"), "{e}");

        let mut m = good.as_obj().unwrap().clone();
        m.insert("extra".into(), Json::Null);
        let e = ScenarioReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("extra"), "{e}");

        let mut m = good.as_obj().unwrap().clone();
        m.insert("schema_version".into(), 99u64.into());
        let e = ScenarioReport::validate(&Json::Obj(m)).unwrap_err();
        assert!(e.contains("schema_version 99"), "{e}");

        // A row missing a metric names its path.
        let text = good.to_string_compact().replace("\"p99_ms\":", "\"p99_xx\":");
        let e = ScenarioReport::validate(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(e.contains("rows[0].p99_ms") || e.contains("rows[1].p99_ms"), "{e}");

        // A bogus policy name is caught.
        let text = good.to_string_compact().replace("\"in-place\"", "\"tepid\"");
        let e = ScenarioReport::validate(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(e.contains("policy"), "{e}");
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("kinetic-scen-{}", std::process::id()));
        let rep = report();
        let path = rep.save(&dir).unwrap();
        assert!(path.ends_with("scenario_t.json"));
        let back = ScenarioReport::load(&path).unwrap();
        assert_eq!(back, rep);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_shows_variant_column_only_when_swept() {
        let rep = report();
        let ascii = rep.table().to_ascii();
        assert!(ascii.contains("Variant"));
        assert!(ascii.contains("rate=0.5"));
        let plain = ScenarioReport {
            rows: vec![row("", 10.0)],
            ..report()
        };
        assert!(!plain.table().to_ascii().contains("Variant"));
    }

    #[test]
    fn fleet_row_view_carries_everything() {
        let r = row("", 50.0);
        let f = r.to_fleet_row();
        assert_eq!(f.policy, Policy::InPlace);
        assert_eq!(f.nodes, 4);
        assert_eq!(f.mean_ms.to_bits(), 50.0f64.to_bits());
        assert_eq!(f.pods_created, 8);
        assert_eq!(f.speculative_resizes, 7);
        assert_eq!(f.mispredictions, 2);
    }

    /// A spec with a `faults` section (or a fault sweep axis) upgrades the
    /// document to v3 with the fault counters; a fault-free spec emits v2
    /// bytes with no trace of them. Both versions load back.
    #[test]
    fn fault_specs_emit_v3_and_plain_specs_stay_v2() {
        let plain = report();
        let text = plain.to_json().to_string_pretty();
        assert!(text.contains("\"schema_version\": 2"), "{text}");
        assert!(!text.contains("pods_evicted"), "{text}");

        let mut faulty = report();
        faulty.spec = Json::obj(vec![
            ("name", "t".into()),
            ("faults", Json::obj(vec![])),
        ]);
        faulty.rows[0].pods_evicted = 3;
        faulty.rows[0].pods_rescheduled = 3;
        faulty.rows[0].resize_failures = 1;
        let text = faulty.to_json().to_string_pretty();
        assert!(text.contains("\"schema_version\": 3"), "{text}");
        assert!(text.contains("\"pods_evicted\": 3"), "{text}");
        let back = ScenarioReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, faulty);

        // A fault sweep axis over a fault-free base spec also upgrades
        // (its variants inject even though the base section is absent).
        let mut swept = report();
        swept.spec = Json::obj(vec![
            ("name", "t".into()),
            (
                "sweep",
                Json::arr([Json::obj(vec![
                    ("param", "resize_failure_p".into()),
                    ("values", Json::arr([0.0.into(), 0.5.into()])),
                ])]),
            ),
        ]);
        let text = swept.to_json().to_string_pretty();
        assert!(text.contains("\"schema_version\": 3"), "{text}");
    }

    /// v2 documents (no fault counters) still validate and load with the
    /// counters zeroed — old saved reports keep working.
    #[test]
    fn v2_documents_without_fault_counters_still_load() {
        let rep = report();
        let back = ScenarioReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back.rows[0].pods_evicted, 0);
        assert_eq!(back.rows[0].resize_failures, 0);
        assert_eq!(back, rep);
    }

    #[test]
    fn fault_columns_keyed_on_spec_not_counts() {
        // A fault spec renders the columns even when nothing broke...
        let mut rep = report();
        rep.spec = Json::obj(vec![
            ("name", "t".into()),
            ("faults", Json::obj(vec![])),
        ]);
        let ascii = rep.table().to_ascii();
        assert!(ascii.contains("Evict") && ascii.contains("RszFail"), "{ascii}");
        // ...and a fault-free report never grows them.
        let quiet = report();
        let ascii = quiet.table().to_ascii();
        assert!(!ascii.contains("Evict"), "fault-free tables must not grow columns: {ascii}");
    }

    #[test]
    fn speculation_columns_keyed_on_predictive_policy_presence() {
        // A predictive policy in the comparison renders the columns even
        // when its counters happen to be zero (stable schema per spec)...
        let mut rep = report();
        rep.rows[0].policy = Policy::PredictiveInPlace;
        rep.rows[0].speculative_resizes = 0;
        rep.rows[0].mispredictions = 0;
        let ascii = rep.table().to_ascii();
        assert!(ascii.contains("Spec") && ascii.contains("Miss"), "{ascii}");
        // ...and a §3-only report never grows them.
        let quiet = report();
        let ascii = quiet.table().to_ascii();
        assert!(!ascii.contains("Spec"), "§3-only tables must not grow columns: {ascii}");
    }
}
