//! [`ScenarioSpec`] — the one declarative description every experiment,
//! sweep and trace replay compiles from.
//!
//! A spec names a workload source (per-tenant synthetic streams, the
//! Azure-style generator, an Azure Functions trace file, or the paper's
//! closed-loop rig), a [`Topology`], the §3 policies and routing policies
//! to compare, the autoscaler knobs, and optional [`Sweep`] axes that
//! expand the spec into a grid of runs. Parsing is *strict*: unknown
//! fields and out-of-range values are rejected with the JSON path in the
//! error, so a typo'd knob can never silently run the default experiment.

use std::collections::BTreeMap;
use std::fmt;

use crate::cluster::topology::{NodeShape, Topology};
use crate::coordinator::accounting::{HybridWeights, RoutingPolicy};
use crate::experiments::fleet::FLEET_MIX;
use crate::faults::{CrashRequestPolicy, FaultsConfig, NodeCrash, Straggler};
use crate::forecast::ForecastConfig;
use crate::knative::config::ScaleKnobs;
use crate::obs::ObserveConfig;
use crate::policy::Policy;
use crate::simclock::SimTime;
use crate::trace::generator::RatePattern;
use crate::util::json::Json;
use crate::util::quantity::{Memory, MilliCpu, Resources};
use crate::workload::registry::WorkloadKind;

/// Hard cap on `variants × routing × policies × reps` — a sweep that
/// expands past this is almost certainly a typo'd axis.
pub const MAX_RUNS: usize = 4096;

/// Largest integer the f64-backed JSON layer represents exactly (2⁵³);
/// seeds above this would silently round, so parsing rejects them.
pub const MAX_EXACT_SEED: u64 = 1 << 53;

/// Every sweepable parameter, in the order [`ScenarioSpec::apply_param`]
/// handles them — the single source for the unknown-parameter error text
/// and the generated schema document (`kinetic schema --markdown`).
pub const SWEEP_PARAMS: [&str; 27] = [
    "services",
    "rate_per_service",
    "horizon_s",
    "functions",
    "peak_rate",
    "burst_p",
    "time_scale",
    "iterations",
    "think_s",
    "nodes",
    "max_scale",
    "target_concurrency",
    "container_concurrency",
    "stable_window_s",
    "panic_window_divisor",
    "panic_threshold",
    "parked_cpu_m",
    "forecast_bucket_ms",
    "forecast_horizon_ms",
    "pool_size",
    "hybrid_in_flight",
    "hybrid_pressure_div",
    "hybrid_resize",
    "resize_failure_p",
    "crash_down_s",
    "straggler_factor",
    "seed",
];

/// Parse/validation error, carrying the JSON path it occurred at.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The document is not valid JSON at all.
    Json(String),
    /// A field the schema does not know (strict parsing).
    UnknownField {
        path: String,
        field: String,
        known: String,
    },
    /// A required field is absent.
    Missing(String),
    /// A field is present but its value is out of range / the wrong type.
    Invalid { path: String, msg: String },
    /// Could not read a referenced file.
    Io { path: String, msg: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Json(e) => write!(f, "scenario is not valid JSON: {e}"),
            SpecError::UnknownField { path, field, known } => write!(
                f,
                "unknown field '{field}' in {path} (known fields: {known})"
            ),
            SpecError::Missing(path) => write!(f, "missing required field {path}"),
            SpecError::Invalid { path, msg } => write!(f, "invalid value at {path}: {msg}"),
            SpecError::Io { path, msg } => write!(f, "cannot read {path}: {msg}"),
        }
    }
}

impl std::error::Error for SpecError {}

impl SpecError {
    pub fn invalid(path: &str, msg: impl Into<String>) -> SpecError {
        SpecError::Invalid {
            path: path.to_string(),
            msg: msg.into(),
        }
    }
}

/// Where the requests come from.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSource {
    /// `services` tenants, each an open-loop Poisson stream — the
    /// `kinetic fleet` shape. Workloads cycle through `mix`.
    Synthetic {
        services: usize,
        rate_per_service: f64,
        horizon_s: f64,
        mix: Vec<WorkloadKind>,
    },
    /// The synthetic Azure-style generator — the `kinetic trace` shape.
    AzureGenerator {
        functions: usize,
        peak_rate: f64,
        horizon_s: f64,
        popularity_s: f64,
        trough_ratio: f64,
        period_s: f64,
        burst_p: f64,
        /// Aggregate-rate shape (diurnal default; flash-crowd / on-off are
        /// the adversarial patterns for fault scenarios).
        pattern: RatePattern,
    },
    /// Replay of a real Azure Functions minute-count CSV.
    TraceFile { path: String, time_scale: f64 },
    /// The paper's §4.2 closed-loop rig (single VU, think time) over every
    /// Table-2 workload — the policy portion of `kinetic exp`.
    ClosedLoop { iterations: u32, think_s: f64 },
}

impl WorkloadSource {
    pub fn type_name(&self) -> &'static str {
        match self {
            WorkloadSource::Synthetic { .. } => "synthetic",
            WorkloadSource::AzureGenerator { .. } => "azure-generator",
            WorkloadSource::TraceFile { .. } => "trace-file",
            WorkloadSource::ClosedLoop { .. } => "closed-loop",
        }
    }
}

/// The fleet shape a scenario runs on.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologySpec {
    /// The paper's single 8-core / 10 GB node.
    Paper,
    /// `nodes` paper-shaped workers.
    Uniform { nodes: usize },
    /// The calibrated large/paper/small preset.
    Hetero { nodes: usize },
    /// An explicit list of node shapes.
    Explicit { shapes: Vec<ShapeSpec> },
}

/// One explicit node shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSpec {
    pub name: String,
    pub cpu_m: u64,
    pub mem_mib: u64,
    /// Startup/resize pipelines scaled by this factor (>1 ⇒ slower node).
    pub calibration: Option<f64>,
}

impl TopologySpec {
    pub fn build(&self) -> Topology {
        match self {
            TopologySpec::Paper => Topology::paper(),
            TopologySpec::Uniform { nodes } => Topology::uniform_paper(*nodes),
            TopologySpec::Hetero { nodes } => Topology::hetero_preset(*nodes),
            TopologySpec::Explicit { shapes } => Topology::heterogeneous(
                shapes
                    .iter()
                    .map(|s| {
                        let shape = NodeShape::new(
                            &s.name,
                            Resources::new(MilliCpu(s.cpu_m), Memory::from_mib(s.mem_mib)),
                        );
                        match s.calibration {
                            Some(f) => shape.calibrated(f),
                            None => shape,
                        }
                    })
                    .collect(),
            ),
        }
    }

    pub fn nodes(&self) -> usize {
        match self {
            TopologySpec::Paper => 1,
            TopologySpec::Uniform { nodes } | TopologySpec::Hetero { nodes } => *nodes,
            TopologySpec::Explicit { shapes } => shapes.len(),
        }
    }

    /// Parses the `--topology` CLI value (the one parser for it — the old
    /// `Topology::from_cli` twin was removed so the spellings and error
    /// text cannot drift).
    pub fn from_cli(spec: &str, nodes: usize) -> Result<TopologySpec, String> {
        match spec.to_ascii_lowercase().as_str() {
            "paper" => Ok(TopologySpec::Paper),
            "uniform" => Ok(TopologySpec::Uniform { nodes: nodes.max(1) }),
            "hetero" | "heterogeneous" => Ok(TopologySpec::Hetero { nodes: nodes.max(1) }),
            other => Err(format!(
                "unknown topology: {other} (expected paper|uniform|hetero)"
            )),
        }
    }
}

/// One sweep axis: a named parameter and the values it takes. All axes
/// combine as a cartesian grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Sweep {
    pub param: String,
    pub values: Vec<f64>,
}

/// The declarative scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub workload: WorkloadSource,
    pub topology: TopologySpec,
    pub policies: Vec<Policy>,
    pub routing: Vec<RoutingPolicy>,
    pub autoscaler: ScaleKnobs,
    pub hybrid: HybridWeights,
    /// Predictor/driver knobs for the forecast-driven policies (`pooled`,
    /// `predictive-inplace`); inert for the §3 triple.
    pub forecast: ForecastConfig,
    /// Fault-injection schedule: node crashes, stragglers, startup
    /// inflation and probabilistic resize failures. Default (no `faults`
    /// section) is inert — specs without one keep byte-identical output.
    pub faults: FaultsConfig,
    /// Observation plane (spans, timeline gauges, self-profiling). `None`
    /// (no `observe` section) leaves the plane disarmed; arming it never
    /// changes the report — observation is strictly read-only. The CLI
    /// `--observe` flag arms the defaults when the section is absent.
    pub observe: Option<ObserveConfig>,
    /// Worker shards for the sharded multi-coordinator runtime (`None` =
    /// the classic single-coordinator path). Reports are byte-identical at
    /// any shard count; the CLI `--shards` flag overrides this knob.
    pub shards: Option<u32>,
    pub seed: u64,
    pub reps: u32,
    pub sweep: Vec<Sweep>,
}

// ---------------------------------------------------------------- helpers

fn check_keys(
    m: &BTreeMap<String, Json>,
    path: &str,
    allowed: &[&str],
) -> Result<(), SpecError> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(SpecError::UnknownField {
                path: path.to_string(),
                field: k.clone(),
                known: allowed.join(", "),
            });
        }
    }
    Ok(())
}

fn as_obj<'a>(j: &'a Json, path: &str) -> Result<&'a BTreeMap<String, Json>, SpecError> {
    j.as_obj()
        .ok_or_else(|| SpecError::invalid(path, "expected an object"))
}

fn field_path(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

fn get_f64(
    m: &BTreeMap<String, Json>,
    path: &str,
    key: &str,
    default: f64,
) -> Result<f64, SpecError> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a number")),
    }
}

fn get_u64(
    m: &BTreeMap<String, Json>,
    path: &str,
    key: &str,
    default: u64,
) -> Result<u64, SpecError> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v.as_u64().ok_or_else(|| {
            SpecError::invalid(&field_path(path, key), "expected a non-negative integer")
        }),
    }
}

fn req_f64(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<f64, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a number")),
    }
}

fn req_u64(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<u64, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v.as_u64().ok_or_else(|| {
            SpecError::invalid(&field_path(path, key), "expected a non-negative integer")
        }),
    }
}

fn req_str<'a>(
    m: &'a BTreeMap<String, Json>,
    path: &str,
    key: &str,
) -> Result<&'a str, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v
            .as_str()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a string")),
    }
}

fn check_range_f64(path: &str, v: f64, lo: f64, hi: f64) -> Result<f64, SpecError> {
    if !v.is_finite() || v < lo || v > hi {
        return Err(SpecError::invalid(
            path,
            format!("{v} is outside [{lo}, {hi}]"),
        ));
    }
    Ok(v)
}

fn check_range_u64(path: &str, v: u64, lo: u64, hi: u64) -> Result<u64, SpecError> {
    if v < lo || v > hi {
        return Err(SpecError::invalid(
            path,
            format!("{v} is outside [{lo}, {hi}]"),
        ));
    }
    Ok(v)
}

/// Formats a swept value the way the JSON writer would (integers without a
/// decimal point) so variant labels stay readable.
pub fn fmt_value(v: f64) -> String {
    Json::Num(v).to_string_compact()
}

// ---------------------------------------------------------------- parsing

impl ScenarioSpec {
    /// Parses a spec from JSON text (strict).
    pub fn parse(text: &str) -> Result<ScenarioSpec, SpecError> {
        let j = Json::parse(text).map_err(|e| SpecError::Json(e.to_string()))?;
        ScenarioSpec::from_json(&j)
    }

    /// Reads and parses a spec file.
    pub fn load(path: &std::path::Path) -> Result<ScenarioSpec, SpecError> {
        let text = std::fs::read_to_string(path).map_err(|e| SpecError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        ScenarioSpec::parse(&text)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec, SpecError> {
        let m = as_obj(j, "scenario")?;
        check_keys(
            m,
            "scenario",
            &[
                "name",
                "workload",
                "topology",
                "policies",
                "routing",
                "autoscaler",
                "hybrid_weights",
                "forecast",
                "faults",
                "observe",
                "shards",
                "seed",
                "reps",
                "sweep",
            ],
        )?;
        let name = req_str(m, "", "name")?.to_string();
        if name.is_empty() {
            return Err(SpecError::invalid("name", "must not be empty"));
        }
        let workload = parse_workload(
            m.get("workload").ok_or(SpecError::Missing("workload".into()))?,
        )?;
        let topology = match m.get("topology") {
            None => TopologySpec::Paper,
            Some(t) => parse_topology(t)?,
        };
        // The default stays the §3 triple — the predictive policies join a
        // comparison only when listed, so specs that predate them keep
        // their exact output. Error text still enumerates `Policy::ALL`
        // (through the shared `FromStr`).
        let policies = parse_name_list(m.get("policies"), "policies", Policy::PAPER.to_vec(), |s| {
            s.parse::<Policy>()
        })?;
        let routing = parse_name_list(
            m.get("routing"),
            "routing",
            vec![RoutingPolicy::LeastLoaded],
            |s| s.parse::<RoutingPolicy>(),
        )?;
        let autoscaler = match m.get("autoscaler") {
            None => ScaleKnobs::fleet_default(),
            Some(a) => parse_autoscaler(a)?,
        };
        let hybrid = match m.get("hybrid_weights") {
            None => HybridWeights::default(),
            Some(h) => parse_hybrid(h)?,
        };
        let forecast = match m.get("forecast") {
            None => ForecastConfig::default(),
            Some(f) => parse_forecast(f)?,
        };
        let faults = match m.get("faults") {
            None => FaultsConfig::default(),
            Some(f) => parse_faults(f)?,
        };
        let observe = match m.get("observe") {
            None => None,
            Some(o) => Some(parse_observe(o)?),
        };
        let shards = match m.get("shards") {
            None => None,
            Some(_) => Some(check_range_u64(
                "shards",
                get_u64(m, "", "shards", 1)?,
                1,
                crate::util::cli::MAX_SHARDS,
            )? as u32),
        };
        let seed = check_range_u64("seed", get_u64(m, "", "seed", 42)?, 0, MAX_EXACT_SEED)?;
        let reps = check_range_u64("reps", get_u64(m, "", "reps", 1)?, 1, 1000)? as u32;
        let sweep = match m.get("sweep") {
            None => Vec::new(),
            Some(s) => parse_sweep(s)?,
        };
        let spec = ScenarioSpec {
            name,
            workload,
            topology,
            policies,
            routing,
            autoscaler,
            hybrid,
            forecast,
            faults,
            observe,
            shards,
            seed,
            reps,
            sweep,
        };
        // Every swept (param, value) must apply cleanly, and the grid must
        // stay within MAX_RUNS — validated here so errors surface at parse
        // time, not mid-run.
        spec.validate_sweep()?;
        Ok(spec)
    }

    /// Parse-time sweep validation: probes each (param, value) against a
    /// clone and checks the run-count product — O(Σ axis lengths), without
    /// materializing the cartesian grid `expand` builds at run time.
    fn validate_sweep(&self) -> Result<(), SpecError> {
        let mut runs = self
            .routing
            .len()
            .max(1)
            .saturating_mul(self.policies.len().max(1))
            .saturating_mul(self.reps as usize);
        for axis in &self.sweep {
            if axis.values.is_empty() {
                return Err(SpecError::invalid(
                    &format!("sweep.{}", axis.param),
                    "values must not be empty",
                ));
            }
            for &v in &axis.values {
                let mut probe = self.clone();
                probe.apply_param(&axis.param, v)?;
            }
            runs = runs.saturating_mul(axis.values.len());
        }
        if runs > MAX_RUNS {
            return Err(SpecError::invalid(
                "sweep",
                format!("grid expands to {runs} runs (cap {MAX_RUNS})"),
            ));
        }
        Ok(())
    }

    // ------------------------------------------------------------ writing

    /// Canonical JSON form (full, explicit; `None` knobs omitted).
    pub fn to_json(&self) -> Json {
        let workload = match &self.workload {
            WorkloadSource::Synthetic {
                services,
                rate_per_service,
                horizon_s,
                mix,
            } => Json::obj(vec![
                ("type", "synthetic".into()),
                ("services", (*services as u64).into()),
                ("rate_per_service", (*rate_per_service).into()),
                ("horizon_s", (*horizon_s).into()),
                (
                    "mix",
                    Json::arr(mix.iter().map(|k| Json::from(k.name()))),
                ),
            ]),
            WorkloadSource::AzureGenerator {
                functions,
                peak_rate,
                horizon_s,
                popularity_s,
                trough_ratio,
                period_s,
                burst_p,
                pattern,
            } => {
                let mut fields = vec![
                    ("type", Json::from("azure-generator")),
                    ("functions", (*functions as u64).into()),
                    ("peak_rate", (*peak_rate).into()),
                    ("horizon_s", (*horizon_s).into()),
                    ("popularity_s", (*popularity_s).into()),
                    ("trough_ratio", (*trough_ratio).into()),
                    ("period_s", (*period_s).into()),
                    ("burst_p", (*burst_p).into()),
                ];
                // The diurnal default is omitted so pre-pattern specs
                // echo byte-identically.
                if *pattern != RatePattern::Diurnal {
                    fields.push(("pattern", pattern_to_json(pattern)));
                }
                Json::obj(fields)
            }
            WorkloadSource::TraceFile { path, time_scale } => Json::obj(vec![
                ("type", "trace-file".into()),
                ("path", path.as_str().into()),
                ("time_scale", (*time_scale).into()),
            ]),
            WorkloadSource::ClosedLoop { iterations, think_s } => Json::obj(vec![
                ("type", "closed-loop".into()),
                ("iterations", u64::from(*iterations).into()),
                ("think_s", (*think_s).into()),
            ]),
        };
        let topology = match &self.topology {
            TopologySpec::Paper => Json::obj(vec![("kind", "paper".into())]),
            TopologySpec::Uniform { nodes } => Json::obj(vec![
                ("kind", "uniform".into()),
                ("nodes", (*nodes as u64).into()),
            ]),
            TopologySpec::Hetero { nodes } => Json::obj(vec![
                ("kind", "hetero".into()),
                ("nodes", (*nodes as u64).into()),
            ]),
            TopologySpec::Explicit { shapes } => Json::obj(vec![
                ("kind", "explicit".into()),
                (
                    "shapes",
                    Json::arr(shapes.iter().map(|s| {
                        let mut pairs = vec![
                            ("name", Json::from(s.name.as_str())),
                            ("cpu_m", s.cpu_m.into()),
                            ("mem_mib", s.mem_mib.into()),
                        ];
                        if let Some(c) = s.calibration {
                            pairs.push(("calibration", c.into()));
                        }
                        Json::obj(pairs)
                    })),
                ),
            ]),
        };
        let mut autoscaler = vec![
            ("max_scale", u64::from(self.autoscaler.max_scale).into()),
            (
                "target_concurrency",
                self.autoscaler.target_concurrency.into(),
            ),
            (
                "container_concurrency",
                u64::from(self.autoscaler.container_concurrency).into(),
            ),
            (
                "panic_window_divisor",
                u64::from(self.autoscaler.panic_window_divisor).into(),
            ),
            ("panic_threshold", self.autoscaler.panic_threshold.into()),
        ];
        if let Some(w) = self.autoscaler.stable_window {
            autoscaler.push(("stable_window_s", w.as_secs_f64().into()));
        }
        if let Some(p) = self.autoscaler.parked_cpu {
            autoscaler.push(("parked_cpu_m", p.0.into()));
        }
        let mut top = vec![
            ("name", self.name.as_str().into()),
            ("workload", workload),
            ("topology", topology),
            (
                "policies",
                Json::arr(self.policies.iter().map(|p| Json::from(p.name()))),
            ),
            (
                "routing",
                Json::arr(self.routing.iter().map(|r| Json::from(r.name()))),
            ),
            ("autoscaler", Json::obj(autoscaler)),
            (
                "hybrid_weights",
                Json::obj(vec![
                    ("in_flight", self.hybrid.in_flight.into()),
                    ("pressure_div", self.hybrid.pressure_div.into()),
                    ("resize", self.hybrid.resize.into()),
                ]),
            ),
            (
                "forecast",
                Json::obj(vec![
                    (
                        "bucket_ms",
                        (self.forecast.bucket.as_nanos() / 1_000_000).into(),
                    ),
                    ("window_s", self.forecast.window.as_secs_f64().into()),
                    (
                        "horizon_ms",
                        (self.forecast.horizon.as_nanos() / 1_000_000).into(),
                    ),
                    ("pool_size", u64::from(self.forecast.pool_size).into()),
                ]),
            ),
        ];
        // Fault-free specs omit the section entirely, keeping the canonical
        // form (and therefore the spec echo inside every report) exactly as
        // it was before fault injection existed.
        if self.faults != FaultsConfig::default() {
            top.push(("faults", faults_to_json(&self.faults)));
        }
        // The `observe` section is deliberately NEVER echoed: the canonical
        // form feeds the spec echo inside every report, and the hard
        // observability invariant is that an observe-on run's report is
        // byte-for-byte identical to the observe-off run (artifacts land in
        // sibling files instead). Round-tripping a spec therefore drops the
        // section by design.
        // Unsharded specs omit the knob, keeping the canonical form (and
        // the spec echo inside every report) exactly as before sharding.
        if let Some(s) = self.shards {
            top.push(("shards", u64::from(s).into()));
        }
        top.push(("seed", self.seed.into()));
        top.push(("reps", u64::from(self.reps).into()));
        top.push((
            "sweep",
            Json::arr(self.sweep.iter().map(|s| {
                Json::obj(vec![
                    ("param", s.param.as_str().into()),
                    ("values", Json::arr(s.values.iter().map(|&v| Json::from(v)))),
                ])
            })),
        ));
        Json::obj(top)
    }

    // ----------------------------------------------------------- sweeping

    /// Expands the sweep grid into concrete (label, spec) variants. With no
    /// sweep axes this is the spec itself under an empty label.
    pub fn expand(&self) -> Result<Vec<(String, ScenarioSpec)>, SpecError> {
        let mut variants: Vec<(String, ScenarioSpec)> = vec![(String::new(), self.clone())];
        for axis in &self.sweep {
            if axis.values.is_empty() {
                return Err(SpecError::invalid(
                    &format!("sweep.{}", axis.param),
                    "values must not be empty",
                ));
            }
            let mut next = Vec::with_capacity(variants.len() * axis.values.len());
            for (label, spec) in &variants {
                for &v in &axis.values {
                    let mut s = spec.clone();
                    s.apply_param(&axis.param, v)?;
                    let piece = format!("{}={}", axis.param, fmt_value(v));
                    let label = if label.is_empty() {
                        piece
                    } else {
                        format!("{label} {piece}")
                    };
                    next.push((label, s));
                }
            }
            variants = next;
        }
        let runs = variants.len()
            * self.routing.len().max(1)
            * self.policies.len().max(1)
            * self.reps as usize;
        if runs > MAX_RUNS {
            return Err(SpecError::invalid(
                "sweep",
                format!("grid expands to {runs} runs (cap {MAX_RUNS})"),
            ));
        }
        // Swept specs must not themselves sweep when run.
        for (_, s) in &mut variants {
            s.sweep.clear();
        }
        Ok(variants)
    }

    /// Applies one swept value by parameter name.
    fn apply_param(&mut self, param: &str, v: f64) -> Result<(), SpecError> {
        let path = format!("sweep.{param}");
        let as_u64 = |p: &str| -> Result<u64, SpecError> {
            if v < 0.0 || v.fract() != 0.0 || !v.is_finite() {
                return Err(SpecError::invalid(p, format!("{v} is not a non-negative integer")));
            }
            Ok(v as u64)
        };
        match param {
            // Workload axes.
            "services" => match &mut self.workload {
                WorkloadSource::Synthetic { services, .. } => {
                    *services = check_range_u64(&path, as_u64(&path)?, 1, 100_000)? as usize;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "rate_per_service" => match &mut self.workload {
                WorkloadSource::Synthetic { rate_per_service, .. } => {
                    *rate_per_service = check_range_f64(&path, v, 1e-6, 1e6)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "horizon_s" => match &mut self.workload {
                WorkloadSource::Synthetic { horizon_s, .. }
                | WorkloadSource::AzureGenerator { horizon_s, .. } => {
                    *horizon_s = check_range_f64(&path, v, 1e-3, 1e7)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "functions" => match &mut self.workload {
                WorkloadSource::AzureGenerator { functions, .. } => {
                    *functions = check_range_u64(&path, as_u64(&path)?, 1, 100_000)? as usize;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "peak_rate" => match &mut self.workload {
                WorkloadSource::AzureGenerator { peak_rate, .. } => {
                    *peak_rate = check_range_f64(&path, v, 1e-6, 1e6)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "burst_p" => match &mut self.workload {
                WorkloadSource::AzureGenerator { burst_p, .. } => {
                    *burst_p = check_range_f64(&path, v, 0.0, 1.0)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "time_scale" => match &mut self.workload {
                WorkloadSource::TraceFile { time_scale, .. } => {
                    *time_scale = check_range_f64(&path, v, 1e-6, 1e3)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "iterations" => match &mut self.workload {
                WorkloadSource::ClosedLoop { iterations, .. } => {
                    *iterations = check_range_u64(&path, as_u64(&path)?, 1, 10_000)? as u32;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "think_s" => match &mut self.workload {
                WorkloadSource::ClosedLoop { think_s, .. } => {
                    *think_s = check_range_f64(&path, v, 0.0, 1e5)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            // Topology axis.
            "nodes" => match &mut self.topology {
                TopologySpec::Uniform { nodes } | TopologySpec::Hetero { nodes } => {
                    *nodes = check_range_u64(&path, as_u64(&path)?, 1, 10_000)? as usize;
                }
                _ => {
                    return Err(SpecError::invalid(
                        &path,
                        "nodes is only sweepable on uniform/hetero topologies",
                    ))
                }
            },
            // Autoscaler axes.
            "max_scale" => {
                self.autoscaler.max_scale =
                    check_range_u64(&path, as_u64(&path)?, 1, 1000)? as u32;
            }
            "target_concurrency" => {
                self.autoscaler.target_concurrency = check_range_f64(&path, v, 0.01, 1e4)?;
            }
            "container_concurrency" => {
                self.autoscaler.container_concurrency =
                    check_range_u64(&path, as_u64(&path)?, 0, 10_000)? as u32;
            }
            "stable_window_s" => {
                self.autoscaler.stable_window =
                    Some(SimTime::from_secs_f64(check_range_f64(&path, v, 1.0, 3600.0)?));
            }
            "panic_window_divisor" => {
                self.autoscaler.panic_window_divisor =
                    check_range_u64(&path, as_u64(&path)?, 1, 100)? as u32;
            }
            "panic_threshold" => {
                self.autoscaler.panic_threshold = check_range_f64(&path, v, 1.0, 1e3)?;
            }
            "parked_cpu_m" => {
                self.autoscaler.parked_cpu =
                    Some(MilliCpu(check_range_u64(&path, as_u64(&path)?, 1, 8000)?));
            }
            // Forecast axes (the predictive-policy knob space).
            "forecast_bucket_ms" => {
                self.forecast.bucket = SimTime::from_millis(check_range_u64(
                    &path,
                    as_u64(&path)?,
                    1,
                    3_600_000,
                )?);
            }
            "forecast_horizon_ms" => {
                self.forecast.horizon = SimTime::from_millis(check_range_u64(
                    &path,
                    as_u64(&path)?,
                    1,
                    3_600_000,
                )?);
            }
            "pool_size" => {
                self.forecast.pool_size =
                    check_range_u64(&path, as_u64(&path)?, 1, 1000)? as u32;
            }
            // Hybrid-routing axes.
            "hybrid_in_flight" => {
                self.hybrid.in_flight = check_range_u64(&path, as_u64(&path)?, 0, 1_000_000)?;
            }
            "hybrid_pressure_div" => {
                self.hybrid.pressure_div = check_range_u64(&path, as_u64(&path)?, 1, 1_000_000)?;
            }
            "hybrid_resize" => {
                self.hybrid.resize = check_range_u64(&path, as_u64(&path)?, 0, 1_000_000)?;
            }
            // Fault axes. `resize_failure_p` stands alone; the crash and
            // straggler axes reshape entries the `faults` section must
            // already declare — sweeping a fault that isn't configured is a
            // spec bug, not an implicit default.
            "resize_failure_p" => {
                self.faults.resize_failure_p = check_range_f64(&path, v, 0.0, 1.0)?;
            }
            "crash_down_s" => {
                if self.faults.node_crashes.is_empty() {
                    return Err(SpecError::invalid(
                        &path,
                        "no faults.node_crashes configured to apply the down time to",
                    ));
                }
                let down = SimTime::from_secs_f64(check_range_f64(&path, v, 1e-3, 1e7)?);
                for c in &mut self.faults.node_crashes {
                    c.down = down;
                }
            }
            "straggler_factor" => {
                if self.faults.stragglers.is_empty() {
                    return Err(SpecError::invalid(
                        &path,
                        "no faults.stragglers configured to apply the factor to",
                    ));
                }
                let f = check_range_f64(&path, v, 1.0, 1000.0)?;
                for s in &mut self.faults.stragglers {
                    s.startup_factor = f;
                    s.resize_factor = f;
                }
            }
            "seed" => {
                self.seed = check_range_u64(&path, as_u64(&path)?, 0, MAX_EXACT_SEED)?;
            }
            other => {
                return Err(SpecError::invalid(
                    &path,
                    format!(
                        "unknown sweep parameter '{other}' (known: {})",
                        SWEEP_PARAMS.join(", ")
                    ),
                ))
            }
        }
        Ok(())
    }
}

fn bad_axis(path: &str, source: &str) -> SpecError {
    SpecError::invalid(
        path,
        format!("parameter does not apply to a '{source}' workload source"),
    )
}

fn parse_name_list<T>(
    j: Option<&Json>,
    path: &str,
    default: Vec<T>,
    parse: impl Fn(&str) -> Result<T, String>,
) -> Result<Vec<T>, SpecError> {
    let Some(j) = j else { return Ok(default) };
    let arr = j
        .as_arr()
        .ok_or_else(|| SpecError::invalid(path, "expected an array of names"))?;
    if arr.is_empty() {
        return Err(SpecError::invalid(path, "must not be empty"));
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            let s = v
                .as_str()
                .ok_or_else(|| SpecError::invalid(&format!("{path}[{i}]"), "expected a string"))?;
            parse(s).map_err(|e| SpecError::invalid(&format!("{path}[{i}]"), e))
        })
        .collect()
}

fn parse_workload(j: &Json) -> Result<WorkloadSource, SpecError> {
    let m = as_obj(j, "workload")?;
    let ty = req_str(m, "workload", "type")?;
    match ty {
        "synthetic" => {
            check_keys(
                m,
                "workload",
                &["type", "services", "rate_per_service", "horizon_s", "mix"],
            )?;
            let services = check_range_u64(
                "workload.services",
                req_u64(m, "workload", "services")?,
                1,
                100_000,
            )? as usize;
            let rate_per_service = check_range_f64(
                "workload.rate_per_service",
                req_f64(m, "workload", "rate_per_service")?,
                1e-6,
                1e6,
            )?;
            let horizon_s = check_range_f64(
                "workload.horizon_s",
                req_f64(m, "workload", "horizon_s")?,
                1e-3,
                1e7,
            )?;
            let mix = match m.get("mix") {
                None => FLEET_MIX.to_vec(),
                Some(mx) => parse_name_list(Some(mx), "workload.mix", Vec::new(), |s| {
                    s.parse::<WorkloadKind>()
                })?,
            };
            Ok(WorkloadSource::Synthetic {
                services,
                rate_per_service,
                horizon_s,
                mix,
            })
        }
        "azure-generator" => {
            check_keys(
                m,
                "workload",
                &[
                    "type",
                    "functions",
                    "peak_rate",
                    "horizon_s",
                    "popularity_s",
                    "trough_ratio",
                    "period_s",
                    "burst_p",
                    "pattern",
                ],
            )?;
            Ok(WorkloadSource::AzureGenerator {
                functions: check_range_u64(
                    "workload.functions",
                    req_u64(m, "workload", "functions")?,
                    1,
                    100_000,
                )? as usize,
                peak_rate: check_range_f64(
                    "workload.peak_rate",
                    req_f64(m, "workload", "peak_rate")?,
                    1e-6,
                    1e6,
                )?,
                horizon_s: check_range_f64(
                    "workload.horizon_s",
                    req_f64(m, "workload", "horizon_s")?,
                    1e-3,
                    1e7,
                )?,
                popularity_s: check_range_f64(
                    "workload.popularity_s",
                    get_f64(m, "workload", "popularity_s", 1.2)?,
                    0.0,
                    10.0,
                )?,
                trough_ratio: check_range_f64(
                    "workload.trough_ratio",
                    get_f64(m, "workload", "trough_ratio", 0.15)?,
                    1e-3,
                    1.0,
                )?,
                period_s: check_range_f64(
                    "workload.period_s",
                    get_f64(m, "workload", "period_s", 600.0)?,
                    1.0,
                    1e7,
                )?,
                burst_p: check_range_f64(
                    "workload.burst_p",
                    get_f64(m, "workload", "burst_p", 0.25)?,
                    0.0,
                    1.0,
                )?,
                pattern: match m.get("pattern") {
                    None => RatePattern::Diurnal,
                    Some(p) => parse_pattern(p)?,
                },
            })
        }
        "trace-file" => {
            check_keys(m, "workload", &["type", "path", "time_scale"])?;
            Ok(WorkloadSource::TraceFile {
                path: req_str(m, "workload", "path")?.to_string(),
                time_scale: check_range_f64(
                    "workload.time_scale",
                    get_f64(m, "workload", "time_scale", 1.0)?,
                    1e-6,
                    1e3,
                )?,
            })
        }
        "closed-loop" => {
            check_keys(m, "workload", &["type", "iterations", "think_s"])?;
            Ok(WorkloadSource::ClosedLoop {
                iterations: check_range_u64(
                    "workload.iterations",
                    req_u64(m, "workload", "iterations")?,
                    1,
                    10_000,
                )? as u32,
                think_s: check_range_f64(
                    "workload.think_s",
                    get_f64(m, "workload", "think_s", 8.0)?,
                    0.0,
                    1e5,
                )?,
            })
        }
        other => Err(SpecError::invalid(
            "workload.type",
            format!(
                "unknown workload type '{other}' \
                 (expected synthetic|azure-generator|trace-file|closed-loop)"
            ),
        )),
    }
}

fn parse_topology(j: &Json) -> Result<TopologySpec, SpecError> {
    let m = as_obj(j, "topology")?;
    let kind = req_str(m, "topology", "kind")?;
    match kind {
        "paper" => {
            check_keys(m, "topology", &["kind"])?;
            Ok(TopologySpec::Paper)
        }
        "uniform" | "hetero" => {
            check_keys(m, "topology", &["kind", "nodes"])?;
            let nodes = check_range_u64(
                "topology.nodes",
                req_u64(m, "topology", "nodes")?,
                1,
                10_000,
            )? as usize;
            Ok(if kind == "uniform" {
                TopologySpec::Uniform { nodes }
            } else {
                TopologySpec::Hetero { nodes }
            })
        }
        "explicit" => {
            check_keys(m, "topology", &["kind", "shapes"])?;
            let arr = m
                .get("shapes")
                .ok_or(SpecError::Missing("topology.shapes".into()))?
                .as_arr()
                .ok_or_else(|| SpecError::invalid("topology.shapes", "expected an array"))?;
            if arr.is_empty() {
                return Err(SpecError::invalid("topology.shapes", "must not be empty"));
            }
            let shapes = arr
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let path = format!("topology.shapes[{i}]");
                    let sm = as_obj(s, &path)?;
                    check_keys(sm, &path, &["name", "cpu_m", "mem_mib", "calibration"])?;
                    Ok(ShapeSpec {
                        name: req_str(sm, &path, "name")?.to_string(),
                        cpu_m: check_range_u64(
                            &format!("{path}.cpu_m"),
                            req_u64(sm, &path, "cpu_m")?,
                            1,
                            1_000_000,
                        )?,
                        mem_mib: check_range_u64(
                            &format!("{path}.mem_mib"),
                            req_u64(sm, &path, "mem_mib")?,
                            1,
                            10_000_000,
                        )?,
                        calibration: match sm.get("calibration") {
                            None => None,
                            Some(c) => Some(check_range_f64(
                                &format!("{path}.calibration"),
                                c.as_f64().ok_or_else(|| {
                                    SpecError::invalid(
                                        &format!("{path}.calibration"),
                                        "expected a number",
                                    )
                                })?,
                                0.01,
                                100.0,
                            )?),
                        },
                    })
                })
                .collect::<Result<Vec<_>, SpecError>>()?;
            Ok(TopologySpec::Explicit { shapes })
        }
        other => Err(SpecError::invalid(
            "topology.kind",
            format!("unknown topology kind '{other}' (expected paper|uniform|hetero|explicit)"),
        )),
    }
}

fn parse_autoscaler(j: &Json) -> Result<ScaleKnobs, SpecError> {
    let m = as_obj(j, "autoscaler")?;
    check_keys(
        m,
        "autoscaler",
        &[
            "max_scale",
            "target_concurrency",
            "container_concurrency",
            "stable_window_s",
            "panic_window_divisor",
            "panic_threshold",
            "parked_cpu_m",
        ],
    )?;
    let d = ScaleKnobs::fleet_default();
    Ok(ScaleKnobs {
        max_scale: check_range_u64(
            "autoscaler.max_scale",
            get_u64(m, "autoscaler", "max_scale", u64::from(d.max_scale))?,
            1,
            1000,
        )? as u32,
        target_concurrency: check_range_f64(
            "autoscaler.target_concurrency",
            get_f64(m, "autoscaler", "target_concurrency", d.target_concurrency)?,
            0.01,
            1e4,
        )?,
        container_concurrency: check_range_u64(
            "autoscaler.container_concurrency",
            get_u64(
                m,
                "autoscaler",
                "container_concurrency",
                u64::from(d.container_concurrency),
            )?,
            0,
            10_000,
        )? as u32,
        stable_window: match m.get("stable_window_s") {
            None => None,
            Some(w) => Some(SimTime::from_secs_f64(check_range_f64(
                "autoscaler.stable_window_s",
                w.as_f64().ok_or_else(|| {
                    SpecError::invalid("autoscaler.stable_window_s", "expected a number")
                })?,
                1.0,
                3600.0,
            )?)),
        },
        panic_window_divisor: check_range_u64(
            "autoscaler.panic_window_divisor",
            get_u64(
                m,
                "autoscaler",
                "panic_window_divisor",
                u64::from(d.panic_window_divisor),
            )?,
            1,
            100,
        )? as u32,
        panic_threshold: check_range_f64(
            "autoscaler.panic_threshold",
            get_f64(m, "autoscaler", "panic_threshold", d.panic_threshold)?,
            1.0,
            1e3,
        )?,
        parked_cpu: match m.get("parked_cpu_m") {
            None => None,
            Some(p) => Some(MilliCpu(check_range_u64(
                "autoscaler.parked_cpu_m",
                p.as_u64().ok_or_else(|| {
                    SpecError::invalid("autoscaler.parked_cpu_m", "expected an integer")
                })?,
                1,
                8000,
            )?)),
        },
    })
}

fn parse_hybrid(j: &Json) -> Result<HybridWeights, SpecError> {
    let m = as_obj(j, "hybrid_weights")?;
    check_keys(m, "hybrid_weights", &["in_flight", "pressure_div", "resize"])?;
    let d = HybridWeights::default();
    Ok(HybridWeights {
        in_flight: check_range_u64(
            "hybrid_weights.in_flight",
            get_u64(m, "hybrid_weights", "in_flight", d.in_flight)?,
            0,
            1_000_000,
        )?,
        pressure_div: check_range_u64(
            "hybrid_weights.pressure_div",
            get_u64(m, "hybrid_weights", "pressure_div", d.pressure_div)?,
            1,
            1_000_000,
        )?,
        resize: check_range_u64(
            "hybrid_weights.resize",
            get_u64(m, "hybrid_weights", "resize", d.resize)?,
            0,
            1_000_000,
        )?,
    })
}

fn parse_forecast(j: &Json) -> Result<ForecastConfig, SpecError> {
    let m = as_obj(j, "forecast")?;
    check_keys(m, "forecast", &["bucket_ms", "window_s", "horizon_ms", "pool_size"])?;
    let d = ForecastConfig::default();
    Ok(ForecastConfig {
        bucket: SimTime::from_millis(check_range_u64(
            "forecast.bucket_ms",
            get_u64(m, "forecast", "bucket_ms", d.bucket.as_nanos() / 1_000_000)?,
            1,
            3_600_000,
        )?),
        window: SimTime::from_secs_f64(check_range_f64(
            "forecast.window_s",
            get_f64(m, "forecast", "window_s", d.window.as_secs_f64())?,
            1.0,
            86_400.0,
        )?),
        horizon: SimTime::from_millis(check_range_u64(
            "forecast.horizon_ms",
            get_u64(m, "forecast", "horizon_ms", d.horizon.as_nanos() / 1_000_000)?,
            1,
            3_600_000,
        )?),
        pool_size: check_range_u64(
            "forecast.pool_size",
            get_u64(m, "forecast", "pool_size", u64::from(d.pool_size))?,
            1,
            1000,
        )? as u32,
    })
}

/// Strictly parses `workload.pattern` — the aggregate-rate shape of the
/// azure-generator source.
fn parse_pattern(j: &Json) -> Result<RatePattern, SpecError> {
    let m = as_obj(j, "workload.pattern")?;
    let path = "workload.pattern";
    match req_str(m, path, "type")? {
        "diurnal" => {
            check_keys(m, path, &["type"])?;
            Ok(RatePattern::Diurnal)
        }
        "flash-crowd" => {
            check_keys(m, path, &["type", "at_s", "magnitude", "width_s"])?;
            Ok(RatePattern::FlashCrowd {
                at: SimTime::from_secs_f64(check_range_f64(
                    "workload.pattern.at_s",
                    req_f64(m, path, "at_s")?,
                    0.0,
                    1e7,
                )?),
                magnitude: check_range_f64(
                    "workload.pattern.magnitude",
                    req_f64(m, path, "magnitude")?,
                    1.0,
                    1e4,
                )?,
                width: SimTime::from_secs_f64(check_range_f64(
                    "workload.pattern.width_s",
                    req_f64(m, path, "width_s")?,
                    1e-3,
                    1e7,
                )?),
            })
        }
        "on-off" => {
            check_keys(m, path, &["type", "on_s", "off_s"])?;
            Ok(RatePattern::OnOff {
                on: SimTime::from_secs_f64(check_range_f64(
                    "workload.pattern.on_s",
                    req_f64(m, path, "on_s")?,
                    1e-3,
                    1e7,
                )?),
                off: SimTime::from_secs_f64(check_range_f64(
                    "workload.pattern.off_s",
                    req_f64(m, path, "off_s")?,
                    1e-3,
                    1e7,
                )?),
            })
        }
        other => Err(SpecError::invalid(
            "workload.pattern.type",
            format!("unknown pattern type '{other}' (expected diurnal|flash-crowd|on-off)"),
        )),
    }
}

fn pattern_to_json(p: &RatePattern) -> Json {
    match p {
        RatePattern::Diurnal => Json::obj(vec![("type", "diurnal".into())]),
        RatePattern::FlashCrowd { at, magnitude, width } => Json::obj(vec![
            ("type", "flash-crowd".into()),
            ("at_s", at.as_secs_f64().into()),
            ("magnitude", (*magnitude).into()),
            ("width_s", width.as_secs_f64().into()),
        ]),
        RatePattern::OnOff { on, off } => Json::obj(vec![
            ("type", "on-off".into()),
            ("on_s", on.as_secs_f64().into()),
            ("off_s", off.as_secs_f64().into()),
        ]),
    }
}

fn parse_faults(j: &Json) -> Result<FaultsConfig, SpecError> {
    let m = as_obj(j, "faults")?;
    check_keys(
        m,
        "faults",
        &[
            "node_crashes",
            "crash_requests",
            "stragglers",
            "startup_inflation",
            "resize_failure_p",
        ],
    )?;
    let node_crashes = match m.get("node_crashes") {
        None => Vec::new(),
        Some(a) => a
            .as_arr()
            .ok_or_else(|| SpecError::invalid("faults.node_crashes", "expected an array"))?
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let path = format!("faults.node_crashes[{i}]");
                let cm = as_obj(c, &path)?;
                check_keys(cm, &path, &["node", "at_s", "down_s"])?;
                Ok(NodeCrash {
                    node: check_range_u64(
                        &format!("{path}.node"),
                        req_u64(cm, &path, "node")?,
                        0,
                        9_999,
                    )? as u32,
                    at: SimTime::from_secs_f64(check_range_f64(
                        &format!("{path}.at_s"),
                        req_f64(cm, &path, "at_s")?,
                        0.0,
                        1e7,
                    )?),
                    down: SimTime::from_secs_f64(check_range_f64(
                        &format!("{path}.down_s"),
                        req_f64(cm, &path, "down_s")?,
                        1e-3,
                        1e7,
                    )?),
                })
            })
            .collect::<Result<Vec<_>, SpecError>>()?,
    };
    let crash_requests = match m.get("crash_requests") {
        None => CrashRequestPolicy::default(),
        Some(v) => v
            .as_str()
            .ok_or_else(|| {
                SpecError::invalid("faults.crash_requests", "expected a string")
            })?
            .parse::<CrashRequestPolicy>()
            .map_err(|e| SpecError::invalid("faults.crash_requests", e))?,
    };
    let stragglers = match m.get("stragglers") {
        None => Vec::new(),
        Some(a) => a
            .as_arr()
            .ok_or_else(|| SpecError::invalid("faults.stragglers", "expected an array"))?
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let path = format!("faults.stragglers[{i}]");
                let sm = as_obj(s, &path)?;
                check_keys(
                    sm,
                    &path,
                    &["node", "from_s", "until_s", "startup_factor", "resize_factor"],
                )?;
                let from_s = check_range_f64(
                    &format!("{path}.from_s"),
                    get_f64(sm, &path, "from_s", 0.0)?,
                    0.0,
                    1e7,
                )?;
                let until_s = check_range_f64(
                    &format!("{path}.until_s"),
                    req_f64(sm, &path, "until_s")?,
                    1e-3,
                    1e7,
                )?;
                if until_s <= from_s {
                    return Err(SpecError::invalid(
                        &format!("{path}.until_s"),
                        format!("window is empty ({until_s} <= from_s {from_s})"),
                    ));
                }
                Ok(Straggler {
                    node: check_range_u64(
                        &format!("{path}.node"),
                        req_u64(sm, &path, "node")?,
                        0,
                        9_999,
                    )? as u32,
                    from: SimTime::from_secs_f64(from_s),
                    until: SimTime::from_secs_f64(until_s),
                    startup_factor: check_range_f64(
                        &format!("{path}.startup_factor"),
                        get_f64(sm, &path, "startup_factor", 1.0)?,
                        1.0,
                        1000.0,
                    )?,
                    resize_factor: check_range_f64(
                        &format!("{path}.resize_factor"),
                        get_f64(sm, &path, "resize_factor", 1.0)?,
                        1.0,
                        1000.0,
                    )?,
                })
            })
            .collect::<Result<Vec<_>, SpecError>>()?,
    };
    Ok(FaultsConfig {
        node_crashes,
        crash_requests,
        stragglers,
        startup_inflation: check_range_f64(
            "faults.startup_inflation",
            get_f64(m, "faults", "startup_inflation", 1.0)?,
            1.0,
            1000.0,
        )?,
        resize_failure_p: check_range_f64(
            "faults.resize_failure_p",
            get_f64(m, "faults", "resize_failure_p", 0.0)?,
            0.0,
            1.0,
        )?,
    })
}

/// Canonical JSON form of a non-default `faults` section — inert knobs are
/// omitted, matching the style of the other optional sections.
fn faults_to_json(f: &FaultsConfig) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if !f.node_crashes.is_empty() {
        pairs.push((
            "node_crashes",
            Json::arr(f.node_crashes.iter().map(|c| {
                Json::obj(vec![
                    ("node", u64::from(c.node).into()),
                    ("at_s", c.at.as_secs_f64().into()),
                    ("down_s", c.down.as_secs_f64().into()),
                ])
            })),
        ));
    }
    if f.crash_requests != CrashRequestPolicy::default() {
        pairs.push(("crash_requests", f.crash_requests.name().into()));
    }
    if !f.stragglers.is_empty() {
        pairs.push((
            "stragglers",
            Json::arr(f.stragglers.iter().map(|s| {
                Json::obj(vec![
                    ("node", u64::from(s.node).into()),
                    ("from_s", s.from.as_secs_f64().into()),
                    ("until_s", s.until.as_secs_f64().into()),
                    ("startup_factor", s.startup_factor.into()),
                    ("resize_factor", s.resize_factor.into()),
                ])
            })),
        ));
    }
    if f.startup_inflation != 1.0 {
        pairs.push(("startup_inflation", f.startup_inflation.into()));
    }
    if f.resize_failure_p != 0.0 {
        pairs.push(("resize_failure_p", f.resize_failure_p.into()));
    }
    Json::obj(pairs)
}

/// Strictly parses the `observe` section. All knobs default (an empty
/// `"observe": {}` arms the plane with defaults); the plane toggles are
/// not spec-exposed — a spec arms all three.
fn parse_observe(j: &Json) -> Result<ObserveConfig, SpecError> {
    let m = as_obj(j, "observe")?;
    check_keys(
        m,
        "observe",
        &["sample_1_in_n", "max_spans", "timeline_cadence_s", "max_timeline"],
    )?;
    let d = ObserveConfig::default();
    Ok(ObserveConfig {
        sample_1_in_n: check_range_u64(
            "observe.sample_1_in_n",
            get_u64(m, "observe", "sample_1_in_n", d.sample_1_in_n)?,
            1,
            1_000_000,
        )?,
        max_spans: check_range_u64(
            "observe.max_spans",
            get_u64(m, "observe", "max_spans", d.max_spans)?,
            1,
            10_000_000,
        )?,
        timeline_cadence: SimTime::from_secs_f64(check_range_f64(
            "observe.timeline_cadence_s",
            get_f64(
                m,
                "observe",
                "timeline_cadence_s",
                d.timeline_cadence.as_secs_f64(),
            )?,
            1e-3,
            1e5,
        )?),
        max_timeline: check_range_u64(
            "observe.max_timeline",
            get_u64(m, "observe", "max_timeline", d.max_timeline)?,
            1,
            10_000_000,
        )?,
        ..d
    })
}

fn parse_sweep(j: &Json) -> Result<Vec<Sweep>, SpecError> {
    let arr = j
        .as_arr()
        .ok_or_else(|| SpecError::invalid("sweep", "expected an array of axes"))?;
    arr.iter()
        .enumerate()
        .map(|(i, a)| {
            let path = format!("sweep[{i}]");
            let m = as_obj(a, &path)?;
            check_keys(m, &path, &["param", "values"])?;
            let param = req_str(m, &path, "param")?.to_string();
            let values = m
                .get("values")
                .ok_or_else(|| SpecError::Missing(format!("{path}.values")))?
                .as_arr()
                .ok_or_else(|| {
                    SpecError::invalid(&format!("{path}.values"), "expected an array of numbers")
                })?
                .iter()
                .enumerate()
                .map(|(vi, v)| {
                    v.as_f64().ok_or_else(|| {
                        SpecError::invalid(
                            &format!("{path}.values[{vi}]"),
                            "expected a number",
                        )
                    })
                })
                .collect::<Result<Vec<_>, SpecError>>()?;
            if values.is_empty() {
                return Err(SpecError::invalid(&format!("{path}.values"), "must not be empty"));
            }
            Ok(Sweep { param, values })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> &'static str {
        r#"{
            "name": "t",
            "workload": {"type": "synthetic", "services": 4,
                         "rate_per_service": 0.1, "horizon_s": 30}
        }"#
    }

    #[test]
    fn minimal_spec_fills_defaults() {
        let s = ScenarioSpec::parse(minimal()).unwrap();
        // The default comparison stays the §3 triple; the predictive
        // policies must be requested explicitly.
        assert_eq!(s.policies, Policy::PAPER.to_vec());
        assert_eq!(s.routing, vec![RoutingPolicy::LeastLoaded]);
        assert_eq!(s.topology, TopologySpec::Paper);
        assert_eq!(s.autoscaler, ScaleKnobs::fleet_default());
        assert_eq!(s.forecast, ForecastConfig::default());
        assert_eq!(s.seed, 42);
        assert_eq!(s.reps, 1);
        match &s.workload {
            WorkloadSource::Synthetic { mix, .. } => assert_eq!(mix, &FLEET_MIX.to_vec()),
            other => panic!("wrong source {other:?}"),
        }
    }

    #[test]
    fn round_trip_is_identity() {
        let s = ScenarioSpec::parse(minimal()).unwrap();
        let again = ScenarioSpec::from_json(&Json::parse(&s.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn unknown_fields_rejected_with_path() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"sedd":1}"#,
        )
        .unwrap_err();
        match &e {
            SpecError::UnknownField { field, known, .. } => {
                assert_eq!(field, "sedd");
                assert!(known.contains("seed"));
            }
            other => panic!("wrong error {other:?}"),
        }
        let msg = e.to_string();
        assert!(msg.contains("sedd") && msg.contains("seed"), "{msg}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1,"rate":2}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("workload") && e.contains("rate"), "{e}");
    }

    #[test]
    fn invalid_values_explain_the_range() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":0,
                "rate_per_service":1,"horizon_s":1}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("workload.services") && e.contains("outside"), "{e}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":-2,"horizon_s":1}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("rate_per_service"), "{e}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"policies":["tepid"]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("policies[0]") && e.contains("tepid"), "{e}");
    }

    #[test]
    fn sweep_expands_cartesian_grid() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.1,"horizon_s":10},
                "topology":{"kind":"uniform","nodes":2},
                "sweep":[{"param":"rate_per_service","values":[0.1,0.5]},
                         {"param":"target_concurrency","values":[1,2,4]}]}"#,
        )
        .unwrap();
        let vs = s.expand().unwrap();
        assert_eq!(vs.len(), 6);
        assert_eq!(vs[0].0, "rate_per_service=0.1 target_concurrency=1");
        let mut labels: Vec<&str> = vs.iter().map(|(l, _)| l.as_str()).collect();
        labels.dedup();
        assert_eq!(labels.len(), 6, "labels must be unique");
        match &vs[5].1.workload {
            WorkloadSource::Synthetic { rate_per_service, .. } => {
                assert_eq!(*rate_per_service, 0.5)
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(vs[5].1.autoscaler.target_concurrency, 4.0);
        assert!(vs[5].1.sweep.is_empty());
    }

    #[test]
    fn sweep_rejects_unknown_param_and_oversize_grid() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "sweep":[{"param":"warp","values":[1]}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("warp") && e.contains("known:"), "{e}");

        // 100 × 100 values × 3 policies > 4096.
        let vals: Vec<String> = (1..=100).map(|i| i.to_string()).collect();
        let doc = format!(
            r#"{{"name":"t","workload":{{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1}},
                "sweep":[{{"param":"seed","values":[{v}]}},
                         {{"param":"max_scale","values":[{w}]}}]}}"#,
            v = vals.join(","),
            w = vals.join(",")
        );
        let e = ScenarioSpec::parse(&doc).unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");
    }

    /// `SWEEP_PARAMS` (the error text + generated schema doc) must track
    /// `apply_param` exactly: every listed name is recognized by at least
    /// one workload source, and unlisted names stay unknown.
    #[test]
    fn sweep_params_const_matches_apply_param() {
        let docs = [
            r#"{"name":"t","topology":{"kind":"uniform","nodes":2},
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":1,"horizon_s":1}}"#,
            r#"{"name":"t","workload":{"type":"azure-generator","functions":2,
                "peak_rate":1,"horizon_s":1}}"#,
            r#"{"name":"t","workload":{"type":"trace-file","path":"a.csv"}}"#,
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2}}"#,
        ];
        for &param in SWEEP_PARAMS.iter() {
            let recognized = docs.iter().any(|doc| {
                let mut s = ScenarioSpec::parse(doc).unwrap();
                match s.apply_param(param, 2.0) {
                    Ok(()) => true,
                    // A range/applicability error still proves the name is
                    // known; only "unknown sweep parameter" fails.
                    Err(e) => !e.to_string().contains("unknown sweep parameter"),
                }
            });
            assert!(recognized, "'{param}' is listed but apply_param rejects it");
        }
        let mut s = ScenarioSpec::parse(docs[0]).unwrap();
        let e = s.apply_param("warp", 1.0).unwrap_err().to_string();
        assert!(e.contains("unknown sweep parameter"), "{e}");
    }

    #[test]
    fn forecast_section_parses_round_trips_and_sweeps() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.5,"horizon_s":30},
                "policies":["cold","pooled","predictive-inplace"],
                "forecast":{"bucket_ms":500,"window_s":30,
                            "horizon_ms":1500,"pool_size":4},
                "sweep":[{"param":"forecast_horizon_ms","values":[1000,2000]},
                         {"param":"pool_size","values":[2,4,8]}]}"#,
        )
        .unwrap();
        assert_eq!(s.forecast.bucket, SimTime::from_millis(500));
        assert_eq!(s.forecast.window, SimTime::from_secs(30));
        assert_eq!(s.forecast.horizon, SimTime::from_millis(1500));
        assert_eq!(s.forecast.pool_size, 4);
        assert!(s.policies.contains(&Policy::Pooled));
        assert!(s.policies.contains(&Policy::PredictiveInPlace));

        let again = ScenarioSpec::parse(&s.to_json().to_string_pretty()).unwrap();
        assert_eq!(s, again);

        let vs = s.expand().unwrap();
        assert_eq!(vs.len(), 6);
        assert_eq!(vs[0].0, "forecast_horizon_ms=1000 pool_size=2");
        assert_eq!(vs[5].1.forecast.horizon, SimTime::from_millis(2000));
        assert_eq!(vs[5].1.forecast.pool_size, 8);

        // Strictness: unknown forecast keys and out-of-range values fail
        // with the path.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "forecast":{"buckets_ms":500}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("forecast") && e.contains("buckets_ms"), "{e}");
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "forecast":{"pool_size":0}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("forecast.pool_size") && e.contains("outside"), "{e}");
    }

    #[test]
    fn faults_section_parses_round_trips_and_sweeps() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.5,"horizon_s":120},
                "topology":{"kind":"uniform","nodes":4},
                "faults":{
                    "node_crashes":[{"node":1,"at_s":30,"down_s":60}],
                    "crash_requests":"fail",
                    "stragglers":[{"node":2,"from_s":0,"until_s":90,
                                   "startup_factor":4,"resize_factor":2}],
                    "startup_inflation":1.5,
                    "resize_failure_p":0.05},
                "sweep":[{"param":"crash_down_s","values":[30,60]},
                         {"param":"straggler_factor","values":[2,8]},
                         {"param":"resize_failure_p","values":[0,0.5]}]}"#,
        )
        .unwrap();
        assert_eq!(s.faults.node_crashes.len(), 1);
        assert_eq!(s.faults.node_crashes[0].node, 1);
        assert_eq!(s.faults.node_crashes[0].at, SimTime::from_secs(30));
        assert_eq!(s.faults.node_crashes[0].down, SimTime::from_secs(60));
        assert_eq!(s.faults.crash_requests, CrashRequestPolicy::Fail);
        assert_eq!(s.faults.stragglers.len(), 1);
        assert_eq!(s.faults.stragglers[0].startup_factor, 4.0);
        assert_eq!(s.faults.startup_inflation, 1.5);
        assert_eq!(s.faults.resize_failure_p, 0.05);

        let again = ScenarioSpec::parse(&s.to_json().to_string_pretty()).unwrap();
        assert_eq!(s, again);

        // 2 × 2 × 2 fault values × 3 policies; axes apply to the clones.
        let vs = s.expand().unwrap();
        assert_eq!(vs.len(), 8);
        assert_eq!(vs[7].1.faults.node_crashes[0].down, SimTime::from_secs(60));
        assert_eq!(vs[7].1.faults.stragglers[0].startup_factor, 8.0);
        assert_eq!(vs[7].1.faults.stragglers[0].resize_factor, 8.0);
        assert_eq!(vs[7].1.faults.resize_failure_p, 0.5);
    }

    #[test]
    fn faults_defaults_stay_inert_and_omitted() {
        // No `faults` key ⇒ the default (inert) config, and the canonical
        // form does not grow a `faults` key — pre-fault specs keep their
        // exact spec echo.
        let s = ScenarioSpec::parse(minimal()).unwrap();
        assert_eq!(s.faults, FaultsConfig::default());
        assert!(s.faults.is_inert());
        let text = s.to_json().to_string_pretty();
        assert!(!text.contains("faults"), "{text}");

        // An explicit empty section is equally inert (and stays omitted on
        // the way back out).
        let s2 = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":4,
                "rate_per_service":0.1,"horizon_s":30},"faults":{}}"#,
        )
        .unwrap();
        assert_eq!(s2.faults, FaultsConfig::default());
        assert_eq!(s2.to_json().to_string_pretty(), text);
    }

    #[test]
    fn generator_patterns_parse_round_trip_and_stay_omitted_by_default() {
        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":4,
                "peak_rate":2.0,"horizon_s":120,
                "pattern":{"type":"flash-crowd","at_s":60,"magnitude":8,"width_s":10}}}"#,
        )
        .unwrap();
        match &spec.workload {
            WorkloadSource::AzureGenerator { pattern, .. } => assert_eq!(
                *pattern,
                RatePattern::FlashCrowd {
                    at: SimTime::from_secs(60),
                    magnitude: 8.0,
                    width: SimTime::from_secs(10),
                }
            ),
            other => panic!("wrong source: {other:?}"),
        }
        let text = spec.to_json().to_string_pretty();
        assert!(text.contains("flash-crowd"), "{text}");
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);

        let spec = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":4,
                "peak_rate":2.0,"horizon_s":120,
                "pattern":{"type":"on-off","on_s":30,"off_s":60}}}"#,
        )
        .unwrap();
        match &spec.workload {
            WorkloadSource::AzureGenerator { pattern, .. } => assert_eq!(
                *pattern,
                RatePattern::OnOff {
                    on: SimTime::from_secs(30),
                    off: SimTime::from_secs(60),
                }
            ),
            other => panic!("wrong source: {other:?}"),
        }
        let text = spec.to_json().to_string_pretty();
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);

        // No pattern key (or an explicit diurnal) echoes no pattern key.
        let plain = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":4,
                "peak_rate":2.0,"horizon_s":120}}"#,
        )
        .unwrap();
        assert!(!plain.to_json().to_string_pretty().contains("pattern"));
        let diurnal = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":4,
                "peak_rate":2.0,"horizon_s":120,"pattern":{"type":"diurnal"}}}"#,
        )
        .unwrap();
        assert_eq!(
            diurnal.to_json().to_string_pretty(),
            plain.to_json().to_string_pretty()
        );
    }

    #[test]
    fn generator_pattern_strictness_rejects_bad_values_with_paths() {
        let azure = |pattern: &str| {
            format!(
                r#"{{"name":"t","workload":{{"type":"azure-generator","functions":4,
                    "peak_rate":2.0,"horizon_s":120,"pattern":{pattern}}}}}"#
            )
        };
        let e = ScenarioSpec::parse(&azure(r#"{"type":"square"}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("square") && e.contains("pattern"), "{e}");

        let e = ScenarioSpec::parse(&azure(r#"{"type":"flash-crowd","at_s":60,"magnitude":8}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("width_s"), "{e}");

        let e = ScenarioSpec::parse(&azure(
            r#"{"type":"flash-crowd","at_s":60,"magnitude":0.5,"width_s":10}"#,
        ))
        .unwrap_err()
        .to_string();
        assert!(e.contains("magnitude"), "{e}");

        let e = ScenarioSpec::parse(&azure(r#"{"type":"on-off","on_s":30,"of_s":60}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("of_s"), "{e}");

        // A pattern on a non-generator source is an unknown workload key.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":2,
                "rate_per_service":0.1,"horizon_s":10,
                "pattern":{"type":"diurnal"}}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("pattern"), "{e}");
    }

    #[test]
    fn faults_strictness_rejects_bad_values_with_paths() {
        let base = |faults: &str| {
            format!(
                r#"{{"name":"t","workload":{{"type":"synthetic","services":1,
                    "rate_per_service":1,"horizon_s":1}},"faults":{faults}}}"#
            )
        };
        let e = ScenarioSpec::parse(&base(r#"{"node_crashs":[]}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("node_crashs") && e.contains("node_crashes"), "{e}");

        let e = ScenarioSpec::parse(&base(
            r#"{"node_crashes":[{"node":0,"at_s":10}]}"#,
        ))
        .unwrap_err()
        .to_string();
        assert!(e.contains("node_crashes[0].down_s"), "{e}");

        let e = ScenarioSpec::parse(&base(r#"{"crash_requests":"retry"}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("crash_requests") && e.contains("retry"), "{e}");

        let e = ScenarioSpec::parse(&base(
            r#"{"stragglers":[{"node":0,"from_s":50,"until_s":50}]}"#,
        ))
        .unwrap_err()
        .to_string();
        assert!(e.contains("until_s") && e.contains("empty"), "{e}");

        let e = ScenarioSpec::parse(&base(r#"{"resize_failure_p":1.5}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("resize_failure_p") && e.contains("outside"), "{e}");

        let e = ScenarioSpec::parse(&base(r#"{"startup_inflation":0.5}"#))
            .unwrap_err()
            .to_string();
        assert!(e.contains("startup_inflation") && e.contains("outside"), "{e}");

        // Sweeping a crash/straggler axis without the matching entries is a
        // parse-time error, not a silent no-op mid-run.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "sweep":[{"param":"crash_down_s","values":[30]}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("crash_down_s") && e.contains("node_crashes"), "{e}");
    }

    #[test]
    fn observe_section_parses_strictly_and_never_echoes() {
        // Empty section ⇒ defaults, armed.
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":4,
                "rate_per_service":0.1,"horizon_s":30},"observe":{}}"#,
        )
        .unwrap();
        assert_eq!(s.observe, Some(ObserveConfig::default()));

        // Explicit knobs land.
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":4,
                "rate_per_service":0.1,"horizon_s":30},
                "observe":{"sample_1_in_n":8,"max_spans":1024,
                           "timeline_cadence_s":0.5,"max_timeline":2048}}"#,
        )
        .unwrap();
        let oc = s.observe.clone().unwrap();
        assert_eq!(oc.sample_1_in_n, 8);
        assert_eq!(oc.max_spans, 1024);
        assert_eq!(oc.timeline_cadence, SimTime::from_millis(500));
        assert_eq!(oc.max_timeline, 2048);
        assert!(oc.spans && oc.timeline && oc.profile);

        // The canonical form never grows an `observe` key — that is the
        // mechanism behind observe-on/off report byte-identity. The echo of
        // an observed spec is byte-identical to the same spec without the
        // section.
        let text = s.to_json().to_string_pretty();
        assert!(!text.contains("observe"), "{text}");
        let mut plain = s.clone();
        plain.observe = None;
        assert_eq!(text, plain.to_json().to_string_pretty());
        assert_eq!(ScenarioSpec::parse(&text).unwrap().observe, None);

        // Strictness: unknown keys and out-of-range values fail with paths.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "observe":{"sample_one_in_n":8}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("observe") && e.contains("sample_1_in_n"), "{e}");
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "observe":{"sample_1_in_n":0}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("observe.sample_1_in_n") && e.contains("outside"), "{e}");
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "observe":{"timeline_cadence_s":0}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("observe.timeline_cadence_s"), "{e}");
    }

    #[test]
    fn predictive_policy_names_parse_in_specs() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "policies":["pooled","predictive-inplace","in-place"]}"#,
        )
        .unwrap();
        assert_eq!(
            s.policies,
            vec![Policy::Pooled, Policy::PredictiveInPlace, Policy::InPlace]
        );
        // A bad name's error enumerates every known policy (derived from
        // Policy::ALL, not a hand-written list).
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"policies":["tepid"]}"#,
        )
        .unwrap_err()
        .to_string();
        for p in Policy::ALL {
            assert!(e.contains(p.name()), "error must list {}: {e}", p.name());
        }
    }

    #[test]
    fn explicit_topology_builds() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":1,"horizon_s":1},
                "topology":{"kind":"explicit","shapes":[
                    {"name":"big","cpu_m":16000,"mem_mib":32768,"calibration":0.85},
                    {"name":"small","cpu_m":4000,"mem_mib":8192}]}}"#,
        )
        .unwrap();
        let t = s.topology.build();
        assert_eq!(t.len(), 2);
        assert_eq!(t.shapes()[0].capacity.cpu, MilliCpu(16_000));
        assert_eq!(t.shapes()[0].calibration_scale, Some(0.85));
        assert_eq!(t.shapes()[1].calibration_scale, None);
        // Round-trips too.
        let again =
            ScenarioSpec::from_json(&Json::parse(&s.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn topology_cli_parsing() {
        assert_eq!(
            TopologySpec::from_cli("paper", 99).unwrap().build(),
            Topology::paper()
        );
        assert_eq!(TopologySpec::from_cli("uniform", 10).unwrap().nodes(), 10);
        assert_eq!(TopologySpec::from_cli("hetero", 5).unwrap().build().len(), 5);
        assert_eq!(TopologySpec::from_cli("uniform", 0).unwrap().nodes(), 1);
        assert!(TopologySpec::from_cli("ring", 3).is_err());
    }

    #[test]
    fn seed_above_f64_precision_rejected() {
        // 2^53 + 2 is representable in f64 (even), but past the exact-
        // integer range — the spec must refuse rather than silently run a
        // rounded seed.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"seed":9007199254740994}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("seed") && e.contains("outside"), "{e}");
    }

    #[test]
    fn trace_sources_parse() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":8,
                "peak_rate":4,"horizon_s":600}}"#,
        )
        .unwrap();
        match s.workload {
            WorkloadSource::AzureGenerator { popularity_s, burst_p, .. } => {
                assert_eq!(popularity_s, 1.2);
                assert_eq!(burst_p, 0.25);
            }
            other => panic!("{other:?}"),
        }
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"trace-file","path":"a.csv"}}"#,
        )
        .unwrap();
        match s.workload {
            WorkloadSource::TraceFile { time_scale, .. } => assert_eq!(time_scale, 1.0),
            other => panic!("{other:?}"),
        }
        let e =
            ScenarioSpec::parse(r#"{"name":"t","workload":{"type":"quantum"}}"#).unwrap_err();
        assert!(e.to_string().contains("quantum"), "{e}");
    }
}
