//! [`ScenarioSpec`] — the one declarative description every experiment,
//! sweep and trace replay compiles from.
//!
//! A spec names a workload source (per-tenant synthetic streams, the
//! Azure-style generator, an Azure Functions trace file, or the paper's
//! closed-loop rig), a [`Topology`], the §3 policies and routing policies
//! to compare, the autoscaler knobs, and optional [`Sweep`] axes that
//! expand the spec into a grid of runs. Parsing is *strict*: unknown
//! fields and out-of-range values are rejected with the JSON path in the
//! error, so a typo'd knob can never silently run the default experiment.

use std::collections::BTreeMap;
use std::fmt;

use crate::cluster::topology::{NodeShape, Topology};
use crate::coordinator::accounting::{HybridWeights, RoutingPolicy};
use crate::experiments::fleet::FLEET_MIX;
use crate::forecast::ForecastConfig;
use crate::knative::config::ScaleKnobs;
use crate::policy::Policy;
use crate::simclock::SimTime;
use crate::util::json::Json;
use crate::util::quantity::{Memory, MilliCpu, Resources};
use crate::workload::registry::WorkloadKind;

/// Hard cap on `variants × routing × policies × reps` — a sweep that
/// expands past this is almost certainly a typo'd axis.
pub const MAX_RUNS: usize = 4096;

/// Largest integer the f64-backed JSON layer represents exactly (2⁵³);
/// seeds above this would silently round, so parsing rejects them.
pub const MAX_EXACT_SEED: u64 = 1 << 53;

/// Every sweepable parameter, in the order [`ScenarioSpec::apply_param`]
/// handles them — the single source for the unknown-parameter error text
/// and the generated schema document (`kinetic schema --markdown`).
pub const SWEEP_PARAMS: [&str; 24] = [
    "services",
    "rate_per_service",
    "horizon_s",
    "functions",
    "peak_rate",
    "burst_p",
    "time_scale",
    "iterations",
    "think_s",
    "nodes",
    "max_scale",
    "target_concurrency",
    "container_concurrency",
    "stable_window_s",
    "panic_window_divisor",
    "panic_threshold",
    "parked_cpu_m",
    "forecast_bucket_ms",
    "forecast_horizon_ms",
    "pool_size",
    "hybrid_in_flight",
    "hybrid_pressure_div",
    "hybrid_resize",
    "seed",
];

/// Parse/validation error, carrying the JSON path it occurred at.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The document is not valid JSON at all.
    Json(String),
    /// A field the schema does not know (strict parsing).
    UnknownField {
        path: String,
        field: String,
        known: String,
    },
    /// A required field is absent.
    Missing(String),
    /// A field is present but its value is out of range / the wrong type.
    Invalid { path: String, msg: String },
    /// Could not read a referenced file.
    Io { path: String, msg: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Json(e) => write!(f, "scenario is not valid JSON: {e}"),
            SpecError::UnknownField { path, field, known } => write!(
                f,
                "unknown field '{field}' in {path} (known fields: {known})"
            ),
            SpecError::Missing(path) => write!(f, "missing required field {path}"),
            SpecError::Invalid { path, msg } => write!(f, "invalid value at {path}: {msg}"),
            SpecError::Io { path, msg } => write!(f, "cannot read {path}: {msg}"),
        }
    }
}

impl std::error::Error for SpecError {}

impl SpecError {
    pub fn invalid(path: &str, msg: impl Into<String>) -> SpecError {
        SpecError::Invalid {
            path: path.to_string(),
            msg: msg.into(),
        }
    }
}

/// Where the requests come from.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSource {
    /// `services` tenants, each an open-loop Poisson stream — the
    /// `kinetic fleet` shape. Workloads cycle through `mix`.
    Synthetic {
        services: usize,
        rate_per_service: f64,
        horizon_s: f64,
        mix: Vec<WorkloadKind>,
    },
    /// The synthetic Azure-style generator — the `kinetic trace` shape.
    AzureGenerator {
        functions: usize,
        peak_rate: f64,
        horizon_s: f64,
        popularity_s: f64,
        trough_ratio: f64,
        period_s: f64,
        burst_p: f64,
    },
    /// Replay of a real Azure Functions minute-count CSV.
    TraceFile { path: String, time_scale: f64 },
    /// The paper's §4.2 closed-loop rig (single VU, think time) over every
    /// Table-2 workload — the policy portion of `kinetic exp`.
    ClosedLoop { iterations: u32, think_s: f64 },
}

impl WorkloadSource {
    pub fn type_name(&self) -> &'static str {
        match self {
            WorkloadSource::Synthetic { .. } => "synthetic",
            WorkloadSource::AzureGenerator { .. } => "azure-generator",
            WorkloadSource::TraceFile { .. } => "trace-file",
            WorkloadSource::ClosedLoop { .. } => "closed-loop",
        }
    }
}

/// The fleet shape a scenario runs on.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologySpec {
    /// The paper's single 8-core / 10 GB node.
    Paper,
    /// `nodes` paper-shaped workers.
    Uniform { nodes: usize },
    /// The calibrated large/paper/small preset.
    Hetero { nodes: usize },
    /// An explicit list of node shapes.
    Explicit { shapes: Vec<ShapeSpec> },
}

/// One explicit node shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSpec {
    pub name: String,
    pub cpu_m: u64,
    pub mem_mib: u64,
    /// Startup/resize pipelines scaled by this factor (>1 ⇒ slower node).
    pub calibration: Option<f64>,
}

impl TopologySpec {
    pub fn build(&self) -> Topology {
        match self {
            TopologySpec::Paper => Topology::paper(),
            TopologySpec::Uniform { nodes } => Topology::uniform_paper(*nodes),
            TopologySpec::Hetero { nodes } => Topology::hetero_preset(*nodes),
            TopologySpec::Explicit { shapes } => Topology::heterogeneous(
                shapes
                    .iter()
                    .map(|s| {
                        let shape = NodeShape::new(
                            &s.name,
                            Resources::new(MilliCpu(s.cpu_m), Memory::from_mib(s.mem_mib)),
                        );
                        match s.calibration {
                            Some(f) => shape.calibrated(f),
                            None => shape,
                        }
                    })
                    .collect(),
            ),
        }
    }

    pub fn nodes(&self) -> usize {
        match self {
            TopologySpec::Paper => 1,
            TopologySpec::Uniform { nodes } | TopologySpec::Hetero { nodes } => *nodes,
            TopologySpec::Explicit { shapes } => shapes.len(),
        }
    }

    /// Parses the `--topology` CLI value (the one parser for it — the old
    /// `Topology::from_cli` twin was removed so the spellings and error
    /// text cannot drift).
    pub fn from_cli(spec: &str, nodes: usize) -> Result<TopologySpec, String> {
        match spec.to_ascii_lowercase().as_str() {
            "paper" => Ok(TopologySpec::Paper),
            "uniform" => Ok(TopologySpec::Uniform { nodes: nodes.max(1) }),
            "hetero" | "heterogeneous" => Ok(TopologySpec::Hetero { nodes: nodes.max(1) }),
            other => Err(format!(
                "unknown topology: {other} (expected paper|uniform|hetero)"
            )),
        }
    }
}

/// One sweep axis: a named parameter and the values it takes. All axes
/// combine as a cartesian grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Sweep {
    pub param: String,
    pub values: Vec<f64>,
}

/// The declarative scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub workload: WorkloadSource,
    pub topology: TopologySpec,
    pub policies: Vec<Policy>,
    pub routing: Vec<RoutingPolicy>,
    pub autoscaler: ScaleKnobs,
    pub hybrid: HybridWeights,
    /// Predictor/driver knobs for the forecast-driven policies (`pooled`,
    /// `predictive-inplace`); inert for the §3 triple.
    pub forecast: ForecastConfig,
    pub seed: u64,
    pub reps: u32,
    pub sweep: Vec<Sweep>,
}

// ---------------------------------------------------------------- helpers

fn check_keys(
    m: &BTreeMap<String, Json>,
    path: &str,
    allowed: &[&str],
) -> Result<(), SpecError> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(SpecError::UnknownField {
                path: path.to_string(),
                field: k.clone(),
                known: allowed.join(", "),
            });
        }
    }
    Ok(())
}

fn as_obj<'a>(j: &'a Json, path: &str) -> Result<&'a BTreeMap<String, Json>, SpecError> {
    j.as_obj()
        .ok_or_else(|| SpecError::invalid(path, "expected an object"))
}

fn field_path(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

fn get_f64(
    m: &BTreeMap<String, Json>,
    path: &str,
    key: &str,
    default: f64,
) -> Result<f64, SpecError> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a number")),
    }
}

fn get_u64(
    m: &BTreeMap<String, Json>,
    path: &str,
    key: &str,
    default: u64,
) -> Result<u64, SpecError> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v.as_u64().ok_or_else(|| {
            SpecError::invalid(&field_path(path, key), "expected a non-negative integer")
        }),
    }
}

fn req_f64(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<f64, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a number")),
    }
}

fn req_u64(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<u64, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v.as_u64().ok_or_else(|| {
            SpecError::invalid(&field_path(path, key), "expected a non-negative integer")
        }),
    }
}

fn req_str<'a>(
    m: &'a BTreeMap<String, Json>,
    path: &str,
    key: &str,
) -> Result<&'a str, SpecError> {
    match m.get(key) {
        None => Err(SpecError::Missing(field_path(path, key))),
        Some(v) => v
            .as_str()
            .ok_or_else(|| SpecError::invalid(&field_path(path, key), "expected a string")),
    }
}

fn check_range_f64(path: &str, v: f64, lo: f64, hi: f64) -> Result<f64, SpecError> {
    if !v.is_finite() || v < lo || v > hi {
        return Err(SpecError::invalid(
            path,
            format!("{v} is outside [{lo}, {hi}]"),
        ));
    }
    Ok(v)
}

fn check_range_u64(path: &str, v: u64, lo: u64, hi: u64) -> Result<u64, SpecError> {
    if v < lo || v > hi {
        return Err(SpecError::invalid(
            path,
            format!("{v} is outside [{lo}, {hi}]"),
        ));
    }
    Ok(v)
}

/// Formats a swept value the way the JSON writer would (integers without a
/// decimal point) so variant labels stay readable.
pub fn fmt_value(v: f64) -> String {
    Json::Num(v).to_string_compact()
}

// ---------------------------------------------------------------- parsing

impl ScenarioSpec {
    /// Parses a spec from JSON text (strict).
    pub fn parse(text: &str) -> Result<ScenarioSpec, SpecError> {
        let j = Json::parse(text).map_err(|e| SpecError::Json(e.to_string()))?;
        ScenarioSpec::from_json(&j)
    }

    /// Reads and parses a spec file.
    pub fn load(path: &std::path::Path) -> Result<ScenarioSpec, SpecError> {
        let text = std::fs::read_to_string(path).map_err(|e| SpecError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        ScenarioSpec::parse(&text)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec, SpecError> {
        let m = as_obj(j, "scenario")?;
        check_keys(
            m,
            "scenario",
            &[
                "name",
                "workload",
                "topology",
                "policies",
                "routing",
                "autoscaler",
                "hybrid_weights",
                "forecast",
                "seed",
                "reps",
                "sweep",
            ],
        )?;
        let name = req_str(m, "", "name")?.to_string();
        if name.is_empty() {
            return Err(SpecError::invalid("name", "must not be empty"));
        }
        let workload = parse_workload(
            m.get("workload").ok_or(SpecError::Missing("workload".into()))?,
        )?;
        let topology = match m.get("topology") {
            None => TopologySpec::Paper,
            Some(t) => parse_topology(t)?,
        };
        // The default stays the §3 triple — the predictive policies join a
        // comparison only when listed, so specs that predate them keep
        // their exact output. Error text still enumerates `Policy::ALL`
        // (through the shared `FromStr`).
        let policies = parse_name_list(m.get("policies"), "policies", Policy::PAPER.to_vec(), |s| {
            s.parse::<Policy>()
        })?;
        let routing = parse_name_list(
            m.get("routing"),
            "routing",
            vec![RoutingPolicy::LeastLoaded],
            |s| s.parse::<RoutingPolicy>(),
        )?;
        let autoscaler = match m.get("autoscaler") {
            None => ScaleKnobs::fleet_default(),
            Some(a) => parse_autoscaler(a)?,
        };
        let hybrid = match m.get("hybrid_weights") {
            None => HybridWeights::default(),
            Some(h) => parse_hybrid(h)?,
        };
        let forecast = match m.get("forecast") {
            None => ForecastConfig::default(),
            Some(f) => parse_forecast(f)?,
        };
        let seed = check_range_u64("seed", get_u64(m, "", "seed", 42)?, 0, MAX_EXACT_SEED)?;
        let reps = check_range_u64("reps", get_u64(m, "", "reps", 1)?, 1, 1000)? as u32;
        let sweep = match m.get("sweep") {
            None => Vec::new(),
            Some(s) => parse_sweep(s)?,
        };
        let spec = ScenarioSpec {
            name,
            workload,
            topology,
            policies,
            routing,
            autoscaler,
            hybrid,
            forecast,
            seed,
            reps,
            sweep,
        };
        // Every swept (param, value) must apply cleanly, and the grid must
        // stay within MAX_RUNS — validated here so errors surface at parse
        // time, not mid-run.
        spec.validate_sweep()?;
        Ok(spec)
    }

    /// Parse-time sweep validation: probes each (param, value) against a
    /// clone and checks the run-count product — O(Σ axis lengths), without
    /// materializing the cartesian grid `expand` builds at run time.
    fn validate_sweep(&self) -> Result<(), SpecError> {
        let mut runs = self
            .routing
            .len()
            .max(1)
            .saturating_mul(self.policies.len().max(1))
            .saturating_mul(self.reps as usize);
        for axis in &self.sweep {
            if axis.values.is_empty() {
                return Err(SpecError::invalid(
                    &format!("sweep.{}", axis.param),
                    "values must not be empty",
                ));
            }
            for &v in &axis.values {
                let mut probe = self.clone();
                probe.apply_param(&axis.param, v)?;
            }
            runs = runs.saturating_mul(axis.values.len());
        }
        if runs > MAX_RUNS {
            return Err(SpecError::invalid(
                "sweep",
                format!("grid expands to {runs} runs (cap {MAX_RUNS})"),
            ));
        }
        Ok(())
    }

    // ------------------------------------------------------------ writing

    /// Canonical JSON form (full, explicit; `None` knobs omitted).
    pub fn to_json(&self) -> Json {
        let workload = match &self.workload {
            WorkloadSource::Synthetic {
                services,
                rate_per_service,
                horizon_s,
                mix,
            } => Json::obj(vec![
                ("type", "synthetic".into()),
                ("services", (*services as u64).into()),
                ("rate_per_service", (*rate_per_service).into()),
                ("horizon_s", (*horizon_s).into()),
                (
                    "mix",
                    Json::arr(mix.iter().map(|k| Json::from(k.name()))),
                ),
            ]),
            WorkloadSource::AzureGenerator {
                functions,
                peak_rate,
                horizon_s,
                popularity_s,
                trough_ratio,
                period_s,
                burst_p,
            } => Json::obj(vec![
                ("type", "azure-generator".into()),
                ("functions", (*functions as u64).into()),
                ("peak_rate", (*peak_rate).into()),
                ("horizon_s", (*horizon_s).into()),
                ("popularity_s", (*popularity_s).into()),
                ("trough_ratio", (*trough_ratio).into()),
                ("period_s", (*period_s).into()),
                ("burst_p", (*burst_p).into()),
            ]),
            WorkloadSource::TraceFile { path, time_scale } => Json::obj(vec![
                ("type", "trace-file".into()),
                ("path", path.as_str().into()),
                ("time_scale", (*time_scale).into()),
            ]),
            WorkloadSource::ClosedLoop { iterations, think_s } => Json::obj(vec![
                ("type", "closed-loop".into()),
                ("iterations", u64::from(*iterations).into()),
                ("think_s", (*think_s).into()),
            ]),
        };
        let topology = match &self.topology {
            TopologySpec::Paper => Json::obj(vec![("kind", "paper".into())]),
            TopologySpec::Uniform { nodes } => Json::obj(vec![
                ("kind", "uniform".into()),
                ("nodes", (*nodes as u64).into()),
            ]),
            TopologySpec::Hetero { nodes } => Json::obj(vec![
                ("kind", "hetero".into()),
                ("nodes", (*nodes as u64).into()),
            ]),
            TopologySpec::Explicit { shapes } => Json::obj(vec![
                ("kind", "explicit".into()),
                (
                    "shapes",
                    Json::arr(shapes.iter().map(|s| {
                        let mut pairs = vec![
                            ("name", Json::from(s.name.as_str())),
                            ("cpu_m", s.cpu_m.into()),
                            ("mem_mib", s.mem_mib.into()),
                        ];
                        if let Some(c) = s.calibration {
                            pairs.push(("calibration", c.into()));
                        }
                        Json::obj(pairs)
                    })),
                ),
            ]),
        };
        let mut autoscaler = vec![
            ("max_scale", u64::from(self.autoscaler.max_scale).into()),
            (
                "target_concurrency",
                self.autoscaler.target_concurrency.into(),
            ),
            (
                "container_concurrency",
                u64::from(self.autoscaler.container_concurrency).into(),
            ),
            (
                "panic_window_divisor",
                u64::from(self.autoscaler.panic_window_divisor).into(),
            ),
            ("panic_threshold", self.autoscaler.panic_threshold.into()),
        ];
        if let Some(w) = self.autoscaler.stable_window {
            autoscaler.push(("stable_window_s", w.as_secs_f64().into()));
        }
        if let Some(p) = self.autoscaler.parked_cpu {
            autoscaler.push(("parked_cpu_m", p.0.into()));
        }
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("workload", workload),
            ("topology", topology),
            (
                "policies",
                Json::arr(self.policies.iter().map(|p| Json::from(p.name()))),
            ),
            (
                "routing",
                Json::arr(self.routing.iter().map(|r| Json::from(r.name()))),
            ),
            ("autoscaler", Json::obj(autoscaler)),
            (
                "hybrid_weights",
                Json::obj(vec![
                    ("in_flight", self.hybrid.in_flight.into()),
                    ("pressure_div", self.hybrid.pressure_div.into()),
                    ("resize", self.hybrid.resize.into()),
                ]),
            ),
            (
                "forecast",
                Json::obj(vec![
                    (
                        "bucket_ms",
                        (self.forecast.bucket.as_nanos() / 1_000_000).into(),
                    ),
                    ("window_s", self.forecast.window.as_secs_f64().into()),
                    (
                        "horizon_ms",
                        (self.forecast.horizon.as_nanos() / 1_000_000).into(),
                    ),
                    ("pool_size", u64::from(self.forecast.pool_size).into()),
                ]),
            ),
            ("seed", self.seed.into()),
            ("reps", u64::from(self.reps).into()),
            (
                "sweep",
                Json::arr(self.sweep.iter().map(|s| {
                    Json::obj(vec![
                        ("param", s.param.as_str().into()),
                        ("values", Json::arr(s.values.iter().map(|&v| Json::from(v)))),
                    ])
                })),
            ),
        ])
    }

    // ----------------------------------------------------------- sweeping

    /// Expands the sweep grid into concrete (label, spec) variants. With no
    /// sweep axes this is the spec itself under an empty label.
    pub fn expand(&self) -> Result<Vec<(String, ScenarioSpec)>, SpecError> {
        let mut variants: Vec<(String, ScenarioSpec)> = vec![(String::new(), self.clone())];
        for axis in &self.sweep {
            if axis.values.is_empty() {
                return Err(SpecError::invalid(
                    &format!("sweep.{}", axis.param),
                    "values must not be empty",
                ));
            }
            let mut next = Vec::with_capacity(variants.len() * axis.values.len());
            for (label, spec) in &variants {
                for &v in &axis.values {
                    let mut s = spec.clone();
                    s.apply_param(&axis.param, v)?;
                    let piece = format!("{}={}", axis.param, fmt_value(v));
                    let label = if label.is_empty() {
                        piece
                    } else {
                        format!("{label} {piece}")
                    };
                    next.push((label, s));
                }
            }
            variants = next;
        }
        let runs = variants.len()
            * self.routing.len().max(1)
            * self.policies.len().max(1)
            * self.reps as usize;
        if runs > MAX_RUNS {
            return Err(SpecError::invalid(
                "sweep",
                format!("grid expands to {runs} runs (cap {MAX_RUNS})"),
            ));
        }
        // Swept specs must not themselves sweep when run.
        for (_, s) in &mut variants {
            s.sweep.clear();
        }
        Ok(variants)
    }

    /// Applies one swept value by parameter name.
    fn apply_param(&mut self, param: &str, v: f64) -> Result<(), SpecError> {
        let path = format!("sweep.{param}");
        let as_u64 = |p: &str| -> Result<u64, SpecError> {
            if v < 0.0 || v.fract() != 0.0 || !v.is_finite() {
                return Err(SpecError::invalid(p, format!("{v} is not a non-negative integer")));
            }
            Ok(v as u64)
        };
        match param {
            // Workload axes.
            "services" => match &mut self.workload {
                WorkloadSource::Synthetic { services, .. } => {
                    *services = check_range_u64(&path, as_u64(&path)?, 1, 100_000)? as usize;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "rate_per_service" => match &mut self.workload {
                WorkloadSource::Synthetic { rate_per_service, .. } => {
                    *rate_per_service = check_range_f64(&path, v, 1e-6, 1e6)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "horizon_s" => match &mut self.workload {
                WorkloadSource::Synthetic { horizon_s, .. }
                | WorkloadSource::AzureGenerator { horizon_s, .. } => {
                    *horizon_s = check_range_f64(&path, v, 1e-3, 1e7)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "functions" => match &mut self.workload {
                WorkloadSource::AzureGenerator { functions, .. } => {
                    *functions = check_range_u64(&path, as_u64(&path)?, 1, 100_000)? as usize;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "peak_rate" => match &mut self.workload {
                WorkloadSource::AzureGenerator { peak_rate, .. } => {
                    *peak_rate = check_range_f64(&path, v, 1e-6, 1e6)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "burst_p" => match &mut self.workload {
                WorkloadSource::AzureGenerator { burst_p, .. } => {
                    *burst_p = check_range_f64(&path, v, 0.0, 1.0)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "time_scale" => match &mut self.workload {
                WorkloadSource::TraceFile { time_scale, .. } => {
                    *time_scale = check_range_f64(&path, v, 1e-6, 1e3)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "iterations" => match &mut self.workload {
                WorkloadSource::ClosedLoop { iterations, .. } => {
                    *iterations = check_range_u64(&path, as_u64(&path)?, 1, 10_000)? as u32;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            "think_s" => match &mut self.workload {
                WorkloadSource::ClosedLoop { think_s, .. } => {
                    *think_s = check_range_f64(&path, v, 0.0, 1e5)?;
                }
                _ => return Err(bad_axis(&path, self.workload.type_name())),
            },
            // Topology axis.
            "nodes" => match &mut self.topology {
                TopologySpec::Uniform { nodes } | TopologySpec::Hetero { nodes } => {
                    *nodes = check_range_u64(&path, as_u64(&path)?, 1, 10_000)? as usize;
                }
                _ => {
                    return Err(SpecError::invalid(
                        &path,
                        "nodes is only sweepable on uniform/hetero topologies",
                    ))
                }
            },
            // Autoscaler axes.
            "max_scale" => {
                self.autoscaler.max_scale =
                    check_range_u64(&path, as_u64(&path)?, 1, 1000)? as u32;
            }
            "target_concurrency" => {
                self.autoscaler.target_concurrency = check_range_f64(&path, v, 0.01, 1e4)?;
            }
            "container_concurrency" => {
                self.autoscaler.container_concurrency =
                    check_range_u64(&path, as_u64(&path)?, 0, 10_000)? as u32;
            }
            "stable_window_s" => {
                self.autoscaler.stable_window =
                    Some(SimTime::from_secs_f64(check_range_f64(&path, v, 1.0, 3600.0)?));
            }
            "panic_window_divisor" => {
                self.autoscaler.panic_window_divisor =
                    check_range_u64(&path, as_u64(&path)?, 1, 100)? as u32;
            }
            "panic_threshold" => {
                self.autoscaler.panic_threshold = check_range_f64(&path, v, 1.0, 1e3)?;
            }
            "parked_cpu_m" => {
                self.autoscaler.parked_cpu =
                    Some(MilliCpu(check_range_u64(&path, as_u64(&path)?, 1, 8000)?));
            }
            // Forecast axes (the predictive-policy knob space).
            "forecast_bucket_ms" => {
                self.forecast.bucket = SimTime::from_millis(check_range_u64(
                    &path,
                    as_u64(&path)?,
                    1,
                    3_600_000,
                )?);
            }
            "forecast_horizon_ms" => {
                self.forecast.horizon = SimTime::from_millis(check_range_u64(
                    &path,
                    as_u64(&path)?,
                    1,
                    3_600_000,
                )?);
            }
            "pool_size" => {
                self.forecast.pool_size =
                    check_range_u64(&path, as_u64(&path)?, 1, 1000)? as u32;
            }
            // Hybrid-routing axes.
            "hybrid_in_flight" => {
                self.hybrid.in_flight = check_range_u64(&path, as_u64(&path)?, 0, 1_000_000)?;
            }
            "hybrid_pressure_div" => {
                self.hybrid.pressure_div = check_range_u64(&path, as_u64(&path)?, 1, 1_000_000)?;
            }
            "hybrid_resize" => {
                self.hybrid.resize = check_range_u64(&path, as_u64(&path)?, 0, 1_000_000)?;
            }
            "seed" => {
                self.seed = check_range_u64(&path, as_u64(&path)?, 0, MAX_EXACT_SEED)?;
            }
            other => {
                return Err(SpecError::invalid(
                    &path,
                    format!(
                        "unknown sweep parameter '{other}' (known: {})",
                        SWEEP_PARAMS.join(", ")
                    ),
                ))
            }
        }
        Ok(())
    }
}

fn bad_axis(path: &str, source: &str) -> SpecError {
    SpecError::invalid(
        path,
        format!("parameter does not apply to a '{source}' workload source"),
    )
}

fn parse_name_list<T>(
    j: Option<&Json>,
    path: &str,
    default: Vec<T>,
    parse: impl Fn(&str) -> Result<T, String>,
) -> Result<Vec<T>, SpecError> {
    let Some(j) = j else { return Ok(default) };
    let arr = j
        .as_arr()
        .ok_or_else(|| SpecError::invalid(path, "expected an array of names"))?;
    if arr.is_empty() {
        return Err(SpecError::invalid(path, "must not be empty"));
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            let s = v
                .as_str()
                .ok_or_else(|| SpecError::invalid(&format!("{path}[{i}]"), "expected a string"))?;
            parse(s).map_err(|e| SpecError::invalid(&format!("{path}[{i}]"), e))
        })
        .collect()
}

fn parse_workload(j: &Json) -> Result<WorkloadSource, SpecError> {
    let m = as_obj(j, "workload")?;
    let ty = req_str(m, "workload", "type")?;
    match ty {
        "synthetic" => {
            check_keys(
                m,
                "workload",
                &["type", "services", "rate_per_service", "horizon_s", "mix"],
            )?;
            let services = check_range_u64(
                "workload.services",
                req_u64(m, "workload", "services")?,
                1,
                100_000,
            )? as usize;
            let rate_per_service = check_range_f64(
                "workload.rate_per_service",
                req_f64(m, "workload", "rate_per_service")?,
                1e-6,
                1e6,
            )?;
            let horizon_s = check_range_f64(
                "workload.horizon_s",
                req_f64(m, "workload", "horizon_s")?,
                1e-3,
                1e7,
            )?;
            let mix = match m.get("mix") {
                None => FLEET_MIX.to_vec(),
                Some(mx) => parse_name_list(Some(mx), "workload.mix", Vec::new(), |s| {
                    s.parse::<WorkloadKind>()
                })?,
            };
            Ok(WorkloadSource::Synthetic {
                services,
                rate_per_service,
                horizon_s,
                mix,
            })
        }
        "azure-generator" => {
            check_keys(
                m,
                "workload",
                &[
                    "type",
                    "functions",
                    "peak_rate",
                    "horizon_s",
                    "popularity_s",
                    "trough_ratio",
                    "period_s",
                    "burst_p",
                ],
            )?;
            Ok(WorkloadSource::AzureGenerator {
                functions: check_range_u64(
                    "workload.functions",
                    req_u64(m, "workload", "functions")?,
                    1,
                    100_000,
                )? as usize,
                peak_rate: check_range_f64(
                    "workload.peak_rate",
                    req_f64(m, "workload", "peak_rate")?,
                    1e-6,
                    1e6,
                )?,
                horizon_s: check_range_f64(
                    "workload.horizon_s",
                    req_f64(m, "workload", "horizon_s")?,
                    1e-3,
                    1e7,
                )?,
                popularity_s: check_range_f64(
                    "workload.popularity_s",
                    get_f64(m, "workload", "popularity_s", 1.2)?,
                    0.0,
                    10.0,
                )?,
                trough_ratio: check_range_f64(
                    "workload.trough_ratio",
                    get_f64(m, "workload", "trough_ratio", 0.15)?,
                    1e-3,
                    1.0,
                )?,
                period_s: check_range_f64(
                    "workload.period_s",
                    get_f64(m, "workload", "period_s", 600.0)?,
                    1.0,
                    1e7,
                )?,
                burst_p: check_range_f64(
                    "workload.burst_p",
                    get_f64(m, "workload", "burst_p", 0.25)?,
                    0.0,
                    1.0,
                )?,
            })
        }
        "trace-file" => {
            check_keys(m, "workload", &["type", "path", "time_scale"])?;
            Ok(WorkloadSource::TraceFile {
                path: req_str(m, "workload", "path")?.to_string(),
                time_scale: check_range_f64(
                    "workload.time_scale",
                    get_f64(m, "workload", "time_scale", 1.0)?,
                    1e-6,
                    1e3,
                )?,
            })
        }
        "closed-loop" => {
            check_keys(m, "workload", &["type", "iterations", "think_s"])?;
            Ok(WorkloadSource::ClosedLoop {
                iterations: check_range_u64(
                    "workload.iterations",
                    req_u64(m, "workload", "iterations")?,
                    1,
                    10_000,
                )? as u32,
                think_s: check_range_f64(
                    "workload.think_s",
                    get_f64(m, "workload", "think_s", 8.0)?,
                    0.0,
                    1e5,
                )?,
            })
        }
        other => Err(SpecError::invalid(
            "workload.type",
            format!(
                "unknown workload type '{other}' \
                 (expected synthetic|azure-generator|trace-file|closed-loop)"
            ),
        )),
    }
}

fn parse_topology(j: &Json) -> Result<TopologySpec, SpecError> {
    let m = as_obj(j, "topology")?;
    let kind = req_str(m, "topology", "kind")?;
    match kind {
        "paper" => {
            check_keys(m, "topology", &["kind"])?;
            Ok(TopologySpec::Paper)
        }
        "uniform" | "hetero" => {
            check_keys(m, "topology", &["kind", "nodes"])?;
            let nodes = check_range_u64(
                "topology.nodes",
                req_u64(m, "topology", "nodes")?,
                1,
                10_000,
            )? as usize;
            Ok(if kind == "uniform" {
                TopologySpec::Uniform { nodes }
            } else {
                TopologySpec::Hetero { nodes }
            })
        }
        "explicit" => {
            check_keys(m, "topology", &["kind", "shapes"])?;
            let arr = m
                .get("shapes")
                .ok_or(SpecError::Missing("topology.shapes".into()))?
                .as_arr()
                .ok_or_else(|| SpecError::invalid("topology.shapes", "expected an array"))?;
            if arr.is_empty() {
                return Err(SpecError::invalid("topology.shapes", "must not be empty"));
            }
            let shapes = arr
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let path = format!("topology.shapes[{i}]");
                    let sm = as_obj(s, &path)?;
                    check_keys(sm, &path, &["name", "cpu_m", "mem_mib", "calibration"])?;
                    Ok(ShapeSpec {
                        name: req_str(sm, &path, "name")?.to_string(),
                        cpu_m: check_range_u64(
                            &format!("{path}.cpu_m"),
                            req_u64(sm, &path, "cpu_m")?,
                            1,
                            1_000_000,
                        )?,
                        mem_mib: check_range_u64(
                            &format!("{path}.mem_mib"),
                            req_u64(sm, &path, "mem_mib")?,
                            1,
                            10_000_000,
                        )?,
                        calibration: match sm.get("calibration") {
                            None => None,
                            Some(c) => Some(check_range_f64(
                                &format!("{path}.calibration"),
                                c.as_f64().ok_or_else(|| {
                                    SpecError::invalid(
                                        &format!("{path}.calibration"),
                                        "expected a number",
                                    )
                                })?,
                                0.01,
                                100.0,
                            )?),
                        },
                    })
                })
                .collect::<Result<Vec<_>, SpecError>>()?;
            Ok(TopologySpec::Explicit { shapes })
        }
        other => Err(SpecError::invalid(
            "topology.kind",
            format!("unknown topology kind '{other}' (expected paper|uniform|hetero|explicit)"),
        )),
    }
}

fn parse_autoscaler(j: &Json) -> Result<ScaleKnobs, SpecError> {
    let m = as_obj(j, "autoscaler")?;
    check_keys(
        m,
        "autoscaler",
        &[
            "max_scale",
            "target_concurrency",
            "container_concurrency",
            "stable_window_s",
            "panic_window_divisor",
            "panic_threshold",
            "parked_cpu_m",
        ],
    )?;
    let d = ScaleKnobs::fleet_default();
    Ok(ScaleKnobs {
        max_scale: check_range_u64(
            "autoscaler.max_scale",
            get_u64(m, "autoscaler", "max_scale", u64::from(d.max_scale))?,
            1,
            1000,
        )? as u32,
        target_concurrency: check_range_f64(
            "autoscaler.target_concurrency",
            get_f64(m, "autoscaler", "target_concurrency", d.target_concurrency)?,
            0.01,
            1e4,
        )?,
        container_concurrency: check_range_u64(
            "autoscaler.container_concurrency",
            get_u64(
                m,
                "autoscaler",
                "container_concurrency",
                u64::from(d.container_concurrency),
            )?,
            0,
            10_000,
        )? as u32,
        stable_window: match m.get("stable_window_s") {
            None => None,
            Some(w) => Some(SimTime::from_secs_f64(check_range_f64(
                "autoscaler.stable_window_s",
                w.as_f64().ok_or_else(|| {
                    SpecError::invalid("autoscaler.stable_window_s", "expected a number")
                })?,
                1.0,
                3600.0,
            )?)),
        },
        panic_window_divisor: check_range_u64(
            "autoscaler.panic_window_divisor",
            get_u64(
                m,
                "autoscaler",
                "panic_window_divisor",
                u64::from(d.panic_window_divisor),
            )?,
            1,
            100,
        )? as u32,
        panic_threshold: check_range_f64(
            "autoscaler.panic_threshold",
            get_f64(m, "autoscaler", "panic_threshold", d.panic_threshold)?,
            1.0,
            1e3,
        )?,
        parked_cpu: match m.get("parked_cpu_m") {
            None => None,
            Some(p) => Some(MilliCpu(check_range_u64(
                "autoscaler.parked_cpu_m",
                p.as_u64().ok_or_else(|| {
                    SpecError::invalid("autoscaler.parked_cpu_m", "expected an integer")
                })?,
                1,
                8000,
            )?)),
        },
    })
}

fn parse_hybrid(j: &Json) -> Result<HybridWeights, SpecError> {
    let m = as_obj(j, "hybrid_weights")?;
    check_keys(m, "hybrid_weights", &["in_flight", "pressure_div", "resize"])?;
    let d = HybridWeights::default();
    Ok(HybridWeights {
        in_flight: check_range_u64(
            "hybrid_weights.in_flight",
            get_u64(m, "hybrid_weights", "in_flight", d.in_flight)?,
            0,
            1_000_000,
        )?,
        pressure_div: check_range_u64(
            "hybrid_weights.pressure_div",
            get_u64(m, "hybrid_weights", "pressure_div", d.pressure_div)?,
            1,
            1_000_000,
        )?,
        resize: check_range_u64(
            "hybrid_weights.resize",
            get_u64(m, "hybrid_weights", "resize", d.resize)?,
            0,
            1_000_000,
        )?,
    })
}

fn parse_forecast(j: &Json) -> Result<ForecastConfig, SpecError> {
    let m = as_obj(j, "forecast")?;
    check_keys(m, "forecast", &["bucket_ms", "window_s", "horizon_ms", "pool_size"])?;
    let d = ForecastConfig::default();
    Ok(ForecastConfig {
        bucket: SimTime::from_millis(check_range_u64(
            "forecast.bucket_ms",
            get_u64(m, "forecast", "bucket_ms", d.bucket.as_nanos() / 1_000_000)?,
            1,
            3_600_000,
        )?),
        window: SimTime::from_secs_f64(check_range_f64(
            "forecast.window_s",
            get_f64(m, "forecast", "window_s", d.window.as_secs_f64())?,
            1.0,
            86_400.0,
        )?),
        horizon: SimTime::from_millis(check_range_u64(
            "forecast.horizon_ms",
            get_u64(m, "forecast", "horizon_ms", d.horizon.as_nanos() / 1_000_000)?,
            1,
            3_600_000,
        )?),
        pool_size: check_range_u64(
            "forecast.pool_size",
            get_u64(m, "forecast", "pool_size", u64::from(d.pool_size))?,
            1,
            1000,
        )? as u32,
    })
}

fn parse_sweep(j: &Json) -> Result<Vec<Sweep>, SpecError> {
    let arr = j
        .as_arr()
        .ok_or_else(|| SpecError::invalid("sweep", "expected an array of axes"))?;
    arr.iter()
        .enumerate()
        .map(|(i, a)| {
            let path = format!("sweep[{i}]");
            let m = as_obj(a, &path)?;
            check_keys(m, &path, &["param", "values"])?;
            let param = req_str(m, &path, "param")?.to_string();
            let values = m
                .get("values")
                .ok_or_else(|| SpecError::Missing(format!("{path}.values")))?
                .as_arr()
                .ok_or_else(|| {
                    SpecError::invalid(&format!("{path}.values"), "expected an array of numbers")
                })?
                .iter()
                .enumerate()
                .map(|(vi, v)| {
                    v.as_f64().ok_or_else(|| {
                        SpecError::invalid(
                            &format!("{path}.values[{vi}]"),
                            "expected a number",
                        )
                    })
                })
                .collect::<Result<Vec<_>, SpecError>>()?;
            if values.is_empty() {
                return Err(SpecError::invalid(&format!("{path}.values"), "must not be empty"));
            }
            Ok(Sweep { param, values })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> &'static str {
        r#"{
            "name": "t",
            "workload": {"type": "synthetic", "services": 4,
                         "rate_per_service": 0.1, "horizon_s": 30}
        }"#
    }

    #[test]
    fn minimal_spec_fills_defaults() {
        let s = ScenarioSpec::parse(minimal()).unwrap();
        // The default comparison stays the §3 triple; the predictive
        // policies must be requested explicitly.
        assert_eq!(s.policies, Policy::PAPER.to_vec());
        assert_eq!(s.routing, vec![RoutingPolicy::LeastLoaded]);
        assert_eq!(s.topology, TopologySpec::Paper);
        assert_eq!(s.autoscaler, ScaleKnobs::fleet_default());
        assert_eq!(s.forecast, ForecastConfig::default());
        assert_eq!(s.seed, 42);
        assert_eq!(s.reps, 1);
        match &s.workload {
            WorkloadSource::Synthetic { mix, .. } => assert_eq!(mix, &FLEET_MIX.to_vec()),
            other => panic!("wrong source {other:?}"),
        }
    }

    #[test]
    fn round_trip_is_identity() {
        let s = ScenarioSpec::parse(minimal()).unwrap();
        let again = ScenarioSpec::from_json(&Json::parse(&s.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn unknown_fields_rejected_with_path() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"sedd":1}"#,
        )
        .unwrap_err();
        match &e {
            SpecError::UnknownField { field, known, .. } => {
                assert_eq!(field, "sedd");
                assert!(known.contains("seed"));
            }
            other => panic!("wrong error {other:?}"),
        }
        let msg = e.to_string();
        assert!(msg.contains("sedd") && msg.contains("seed"), "{msg}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1,"rate":2}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("workload") && e.contains("rate"), "{e}");
    }

    #[test]
    fn invalid_values_explain_the_range() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":0,
                "rate_per_service":1,"horizon_s":1}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("workload.services") && e.contains("outside"), "{e}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":-2,"horizon_s":1}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("rate_per_service"), "{e}");

        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"policies":["tepid"]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("policies[0]") && e.contains("tepid"), "{e}");
    }

    #[test]
    fn sweep_expands_cartesian_grid() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.1,"horizon_s":10},
                "topology":{"kind":"uniform","nodes":2},
                "sweep":[{"param":"rate_per_service","values":[0.1,0.5]},
                         {"param":"target_concurrency","values":[1,2,4]}]}"#,
        )
        .unwrap();
        let vs = s.expand().unwrap();
        assert_eq!(vs.len(), 6);
        assert_eq!(vs[0].0, "rate_per_service=0.1 target_concurrency=1");
        let mut labels: Vec<&str> = vs.iter().map(|(l, _)| l.as_str()).collect();
        labels.dedup();
        assert_eq!(labels.len(), 6, "labels must be unique");
        match &vs[5].1.workload {
            WorkloadSource::Synthetic { rate_per_service, .. } => {
                assert_eq!(*rate_per_service, 0.5)
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(vs[5].1.autoscaler.target_concurrency, 4.0);
        assert!(vs[5].1.sweep.is_empty());
    }

    #[test]
    fn sweep_rejects_unknown_param_and_oversize_grid() {
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "sweep":[{"param":"warp","values":[1]}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("warp") && e.contains("known:"), "{e}");

        // 100 × 100 values × 3 policies > 4096.
        let vals: Vec<String> = (1..=100).map(|i| i.to_string()).collect();
        let doc = format!(
            r#"{{"name":"t","workload":{{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1}},
                "sweep":[{{"param":"seed","values":[{v}]}},
                         {{"param":"max_scale","values":[{w}]}}]}}"#,
            v = vals.join(","),
            w = vals.join(",")
        );
        let e = ScenarioSpec::parse(&doc).unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");
    }

    /// `SWEEP_PARAMS` (the error text + generated schema doc) must track
    /// `apply_param` exactly: every listed name is recognized by at least
    /// one workload source, and unlisted names stay unknown.
    #[test]
    fn sweep_params_const_matches_apply_param() {
        let docs = [
            r#"{"name":"t","topology":{"kind":"uniform","nodes":2},
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":1,"horizon_s":1}}"#,
            r#"{"name":"t","workload":{"type":"azure-generator","functions":2,
                "peak_rate":1,"horizon_s":1}}"#,
            r#"{"name":"t","workload":{"type":"trace-file","path":"a.csv"}}"#,
            r#"{"name":"t","workload":{"type":"closed-loop","iterations":2}}"#,
        ];
        for &param in SWEEP_PARAMS.iter() {
            let recognized = docs.iter().any(|doc| {
                let mut s = ScenarioSpec::parse(doc).unwrap();
                match s.apply_param(param, 2.0) {
                    Ok(()) => true,
                    // A range/applicability error still proves the name is
                    // known; only "unknown sweep parameter" fails.
                    Err(e) => !e.to_string().contains("unknown sweep parameter"),
                }
            });
            assert!(recognized, "'{param}' is listed but apply_param rejects it");
        }
        let mut s = ScenarioSpec::parse(docs[0]).unwrap();
        let e = s.apply_param("warp", 1.0).unwrap_err().to_string();
        assert!(e.contains("unknown sweep parameter"), "{e}");
    }

    #[test]
    fn forecast_section_parses_round_trips_and_sweeps() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":2,
                            "rate_per_service":0.5,"horizon_s":30},
                "policies":["cold","pooled","predictive-inplace"],
                "forecast":{"bucket_ms":500,"window_s":30,
                            "horizon_ms":1500,"pool_size":4},
                "sweep":[{"param":"forecast_horizon_ms","values":[1000,2000]},
                         {"param":"pool_size","values":[2,4,8]}]}"#,
        )
        .unwrap();
        assert_eq!(s.forecast.bucket, SimTime::from_millis(500));
        assert_eq!(s.forecast.window, SimTime::from_secs(30));
        assert_eq!(s.forecast.horizon, SimTime::from_millis(1500));
        assert_eq!(s.forecast.pool_size, 4);
        assert!(s.policies.contains(&Policy::Pooled));
        assert!(s.policies.contains(&Policy::PredictiveInPlace));

        let again = ScenarioSpec::parse(&s.to_json().to_string_pretty()).unwrap();
        assert_eq!(s, again);

        let vs = s.expand().unwrap();
        assert_eq!(vs.len(), 6);
        assert_eq!(vs[0].0, "forecast_horizon_ms=1000 pool_size=2");
        assert_eq!(vs[5].1.forecast.horizon, SimTime::from_millis(2000));
        assert_eq!(vs[5].1.forecast.pool_size, 8);

        // Strictness: unknown forecast keys and out-of-range values fail
        // with the path.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "forecast":{"buckets_ms":500}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("forecast") && e.contains("buckets_ms"), "{e}");
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "forecast":{"pool_size":0}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("forecast.pool_size") && e.contains("outside"), "{e}");
    }

    #[test]
    fn predictive_policy_names_parse_in_specs() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},
                "policies":["pooled","predictive-inplace","in-place"]}"#,
        )
        .unwrap();
        assert_eq!(
            s.policies,
            vec![Policy::Pooled, Policy::PredictiveInPlace, Policy::InPlace]
        );
        // A bad name's error enumerates every known policy (derived from
        // Policy::ALL, not a hand-written list).
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"policies":["tepid"]}"#,
        )
        .unwrap_err()
        .to_string();
        for p in Policy::ALL {
            assert!(e.contains(p.name()), "error must list {}: {e}", p.name());
        }
    }

    #[test]
    fn explicit_topology_builds() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t",
                "workload":{"type":"synthetic","services":1,
                            "rate_per_service":1,"horizon_s":1},
                "topology":{"kind":"explicit","shapes":[
                    {"name":"big","cpu_m":16000,"mem_mib":32768,"calibration":0.85},
                    {"name":"small","cpu_m":4000,"mem_mib":8192}]}}"#,
        )
        .unwrap();
        let t = s.topology.build();
        assert_eq!(t.len(), 2);
        assert_eq!(t.shapes()[0].capacity.cpu, MilliCpu(16_000));
        assert_eq!(t.shapes()[0].calibration_scale, Some(0.85));
        assert_eq!(t.shapes()[1].calibration_scale, None);
        // Round-trips too.
        let again =
            ScenarioSpec::from_json(&Json::parse(&s.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn topology_cli_parsing() {
        assert_eq!(
            TopologySpec::from_cli("paper", 99).unwrap().build(),
            Topology::paper()
        );
        assert_eq!(TopologySpec::from_cli("uniform", 10).unwrap().nodes(), 10);
        assert_eq!(TopologySpec::from_cli("hetero", 5).unwrap().build().len(), 5);
        assert_eq!(TopologySpec::from_cli("uniform", 0).unwrap().nodes(), 1);
        assert!(TopologySpec::from_cli("ring", 3).is_err());
    }

    #[test]
    fn seed_above_f64_precision_rejected() {
        // 2^53 + 2 is representable in f64 (even), but past the exact-
        // integer range — the spec must refuse rather than silently run a
        // rounded seed.
        let e = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"synthetic","services":1,
                "rate_per_service":1,"horizon_s":1},"seed":9007199254740994}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("seed") && e.contains("outside"), "{e}");
    }

    #[test]
    fn trace_sources_parse() {
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"azure-generator","functions":8,
                "peak_rate":4,"horizon_s":600}}"#,
        )
        .unwrap();
        match s.workload {
            WorkloadSource::AzureGenerator { popularity_s, burst_p, .. } => {
                assert_eq!(popularity_s, 1.2);
                assert_eq!(burst_p, 0.25);
            }
            other => panic!("{other:?}"),
        }
        let s = ScenarioSpec::parse(
            r#"{"name":"t","workload":{"type":"trace-file","path":"a.csv"}}"#,
        )
        .unwrap();
        match s.workload {
            WorkloadSource::TraceFile { time_scale, .. } => assert_eq!(time_scale, 1.0),
            other => panic!("{other:?}"),
        }
        let e =
            ScenarioSpec::parse(r#"{"name":"t","workload":{"type":"quantum"}}"#).unwrap_err();
        assert!(e.to_string().contains("quantum"), "{e}");
    }
}
