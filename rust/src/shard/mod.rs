//! Sharded multi-coordinator execution: parallelism *inside* one run.
//!
//! The serial engine simulates one global platform; the sweep grid (PR 4)
//! parallelized *across* runs. This subsystem parallelizes a single run:
//! the fleet is partitioned into cells (one node + coordinator + engine
//! each) grouped onto shards of scoped worker threads, synchronized by a
//! conservative time-window protocol with lookahead equal to the
//! kube-scheduler decision stage — the minimum cross-cell latency.
//!
//! * [`plan`] — the deterministic shard planner and its schema-versioned
//!   manifest (`kinetic-shard-manifest`).
//! * [`runtime`] — the lockstep window driver, cross-shard message
//!   delivery, and the sharded counterparts of the fleet/replay runners.
//!
//! The contract, pinned by `tests/shard.rs` and the CI diff gate: reports
//! are **byte-identical at any shard count**. See `docs/REPRODUCING.md`
//! ("Sharded execution") for the protocol sketch and the determinism
//! argument.

pub mod plan;
pub mod runtime;

pub use plan::{stable_hash, ShardPlan, MANIFEST_KIND, MANIFEST_SCHEMA_VERSION};
pub use runtime::{
    replay_sharded, replay_sharded_observed, run_policy_sharded, run_policy_sharded_counting,
    run_policy_sharded_observed,
};
